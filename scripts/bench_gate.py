#!/usr/bin/env python3
"""CI gate: fail when engine bench throughput regresses more than 30 %.

Usage:
    python3 scripts/bench_gate.py <baseline_dir> <fresh_dir>

Compares the committed `BENCH_eventsim.json` / `BENCH_cogsim.json` /
`BENCH_fluid.json` baselines (copied to <baseline_dir> before the
bench run overwrites them) against the files a fresh `cargo bench
--bench eventsim_bench -- --smoke` just wrote to <fresh_dir>.  For
every benchmark key the fresh throughput (`events_per_s`, or
`cells_per_s` for the fluid tier) must be at least 70 % of the
baseline's.

Baselines carrying `"baseline_floor": true` are conservative floors
recorded without a local toolchain (deliberate underestimates so the
gate arms without false alarms).  Floor entries never gain measured
values on their own, so when the gate sees a floor baseline next to a
real run it emits a re-baseline artifact `REBASELINE_<name>` into
<fresh_dir> — the fresh document with the floor flag dropped and
measured `iters`/`mean_run_us` filled in — and prints the
floor-vs-measured diff.  Commit that artifact over the repo's
BENCH_*.json to converge the committed floors toward CI-measured
numbers.

Configurations are only comparable like-for-like: if the baseline and
the fresh run disagree on the workload shape (`smoke`, `ranks`), the
gate warns and passes rather than comparing apples to oranges.

Stdlib only — no third-party imports.
"""

import json
import os
import sys

FILES = ("BENCH_eventsim.json", "BENCH_cogsim.json", "BENCH_fluid.json")
SHAPE_KEYS = ("smoke", "ranks", "horizon_us", "timesteps", "swap_us", "cells")
RATE_KEYS = ("events_per_s", "cells_per_s")
MAX_REGRESSION = 0.30


def rate_of(entry, where):
    for key in RATE_KEYS:
        if key in entry:
            return float(entry[key])
    raise SystemExit(f"{where}: no throughput key ({'/'.join(RATE_KEYS)})")


def load(path):
    with open(path) as fh:
        return json.load(fh)


def is_floor(base):
    """A floor baseline: flagged as such, or any entry still carrying
    the `iters: 0` placeholder a no-toolchain floor is born with."""
    if base.get("baseline_floor"):
        return True
    return any(
        int(entry.get("iters", 1)) == 0
        for entry in base.get("results", {}).values()
    )


def emit_rebaseline(name, base, fresh, fresh_dir):
    """Write the measured fresh doc as a re-baseline artifact and
    print the floor -> measured diff, so a CI bench run converges the
    committed floors toward real numbers."""
    artifact = dict(fresh)
    artifact.pop("baseline_floor", None)
    out_path = os.path.join(fresh_dir, f"REBASELINE_{name}")
    with open(out_path, "w") as fh:
        json.dump(artifact, fh, indent=2, sort_keys=True)
        fh.write("\n")
    print(f"{name}: floor baseline measured — re-baseline artifact at "
          f"{out_path}; diff vs committed floor:")
    base_results = base.get("results", {})
    for key, got in sorted(artifact.get("results", {}).items()):
        want = base_results.get(key, {})
        old_rate = rate_of(want, f"{name}:{key} (floor)") if want else 0.0
        new_rate = rate_of(got, f"{name}:{key} (measured)")
        print(f"  {key}: iters {want.get('iters', 0)} -> {got.get('iters')}, "
              f"mean_run_us {want.get('mean_run_us', 0)} -> "
              f"{got.get('mean_run_us')}, "
              f"rate {old_rate:.0f} -> {new_rate:.0f}")
    print(f"  commit {out_path} over {name} to drop the floor")


def main():
    if len(sys.argv) != 3:
        sys.exit(__doc__.strip().splitlines()[2].strip())
    baseline_dir, fresh_dir = sys.argv[1], sys.argv[2]
    failures = []
    for name in FILES:
        base_path = os.path.join(baseline_dir, name)
        fresh_path = os.path.join(fresh_dir, name)
        if not os.path.exists(base_path):
            print(f"{name}: no committed baseline, skipping")
            continue
        base = load(base_path)
        fresh = load(fresh_path)
        shape_diff = [
            k for k in SHAPE_KEYS
            if k in base and k in fresh and base[k] != fresh[k]
        ]
        if shape_diff:
            print(f"{name}: workload shape changed ({', '.join(shape_diff)}); "
                  "not comparable — re-baseline")
            continue
        floor = " (floor baseline)" if base.get("baseline_floor") else ""
        for key, want in sorted(base.get("results", {}).items()):
            got = fresh.get("results", {}).get(key)
            if got is None:
                failures.append(f"{name}:{key}: benchmark disappeared")
                continue
            base_eps = rate_of(want, f"{name}:{key} (baseline)")
            fresh_eps = rate_of(got, f"{name}:{key} (fresh)")
            unit = "cells/s" if "cells_per_s" in want else "events/s"
            limit = (1.0 - MAX_REGRESSION) * base_eps
            verdict = "ok" if fresh_eps >= limit else "REGRESSED"
            print(f"{name}:{key}: {fresh_eps:.0f} {unit} vs baseline "
                  f"{base_eps:.0f}{floor} (limit {limit:.0f}) {verdict}")
            if fresh_eps < limit:
                failures.append(
                    f"{name}:{key}: {fresh_eps:.0f} {unit} is more than "
                    f"{MAX_REGRESSION:.0%} below the baseline {base_eps:.0f}")
        if is_floor(base) and not is_floor(fresh):
            emit_rebaseline(name, base, fresh, fresh_dir)
    if failures:
        print()
        for f in failures:
            print(f"FAIL {f}")
        sys.exit(1)
    print("bench gate: no >30% throughput regression")


if __name__ == "__main__":
    main()
