#!/usr/bin/env python3
"""Structural validator for flight-recorder Chrome trace files.

Usage:
    python3 scripts/validate_trace.py <trace.json> [more.json ...]

Checks the invariants Perfetto / chrome://tracing rely on, so CI
catches a malformed export before a human ever loads one:

  * the document is a JSON object with a ``traceEvents`` array (a
    bare array is also accepted);
  * every event is an object carrying a string ``ph``;
  * every timed event (anything but metadata ``M``) carries numeric
    ``pid``/``tid``/``ts`` with ``ts >= 0``;
  * within each (pid, tid) track, ``ts`` is non-decreasing in file
    order (the exporter sorts; an unsorted file breaks counters);
  * duration events pair up: each ``E`` closes the innermost open
    ``B`` of the same name on its track, and no track ends with an
    open ``B``;
  * complete events (``X``) carry a numeric ``dur >= 0``;
  * every pid referenced by a timed event has a ``process_name``
    metadata record, and every (pid, tid) a ``thread_name`` record.

Stdlib only — no third-party imports.  Exits non-zero on the first
malformed file, after listing every violation found in it.
"""

import json
import sys


def load_events(path):
    with open(path, "r", encoding="utf-8") as f:
        doc = json.load(f)
    if isinstance(doc, dict):
        events = doc.get("traceEvents")
        if not isinstance(events, list):
            raise ValueError("object document has no 'traceEvents' array")
        return events
    if isinstance(doc, list):
        return doc
    raise ValueError("document is neither an object nor an array")


def is_num(x):
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def validate(events):
    """Return a list of violation strings (empty = valid)."""
    errors = []
    last_ts = {}  # (pid, tid) -> last seen ts
    stacks = {}  # (pid, tid) -> open B-event name stack
    named_pids = set()  # pids with a process_name metadata record
    named_tids = set()  # (pid, tid) with a thread_name record
    used_pids = {}  # pid -> first event index referencing it
    used_tids = {}  # (pid, tid) -> first event index referencing it

    for i, e in enumerate(events):
        where = f"event {i}"
        if not isinstance(e, dict):
            errors.append(f"{where}: not an object")
            continue
        ph = e.get("ph")
        if not isinstance(ph, str) or not ph:
            errors.append(f"{where}: missing or non-string 'ph'")
            continue
        pid, tid = e.get("pid"), e.get("tid")
        if not is_num(pid) or not is_num(tid):
            errors.append(f"{where} (ph={ph}): missing numeric 'pid'/'tid'")
            continue

        if ph == "M":
            which = e.get("name")
            name = (e.get("args") or {}).get("name")
            if which == "process_name" and isinstance(name, str):
                named_pids.add(pid)
            elif which == "thread_name" and isinstance(name, str):
                named_tids.add((pid, tid))
            continue

        track = (pid, tid)
        used_pids.setdefault(pid, i)
        used_tids.setdefault(track, i)

        ts = e.get("ts")
        if not is_num(ts):
            errors.append(f"{where} (ph={ph}): missing numeric 'ts'")
            continue
        if ts < 0:
            errors.append(f"{where} (ph={ph}): negative ts {ts}")
        prev = last_ts.get(track)
        if prev is not None and ts < prev:
            errors.append(
                f"{where} (ph={ph}): ts {ts} goes backwards on track "
                f"pid={pid} tid={tid} (previous {prev})"
            )
        last_ts[track] = ts

        name = e.get("name")
        if ph == "B":
            stacks.setdefault(track, []).append(name)
        elif ph == "E":
            stack = stacks.get(track) or []
            if not stack:
                errors.append(
                    f"{where}: 'E' with no open 'B' on track pid={pid} tid={tid}"
                )
            elif stack[-1] != name:
                errors.append(
                    f"{where}: 'E' named {name!r} closes open 'B' named "
                    f"{stack[-1]!r} on track pid={pid} tid={tid}"
                )
            else:
                stack.pop()
        elif ph == "X":
            dur = e.get("dur")
            if not is_num(dur):
                errors.append(f"{where}: 'X' without numeric 'dur'")
            elif dur < 0:
                errors.append(f"{where}: 'X' with negative dur {dur}")

    for track, stack in sorted(stacks.items()):
        if stack:
            errors.append(
                f"track pid={track[0]} tid={track[1]} ends with "
                f"{len(stack)} unclosed 'B' event(s): {stack}"
            )
    for pid, i in sorted(used_pids.items()):
        if pid not in named_pids:
            errors.append(
                f"pid {pid} (first used by event {i}) has no "
                "process_name metadata"
            )
    for (pid, tid), i in sorted(used_tids.items()):
        if (pid, tid) not in named_tids:
            errors.append(
                f"track pid={pid} tid={tid} (first used by event {i}) "
                "has no thread_name metadata"
            )
    return errors


def main(argv):
    if len(argv) < 2:
        print(__doc__.strip().splitlines()[0])
        print(f"usage: {argv[0]} <trace.json> [more.json ...]")
        return 2
    for path in argv[1:]:
        try:
            events = load_events(path)
        except (OSError, ValueError, json.JSONDecodeError) as e:
            print(f"{path}: unreadable trace: {e}")
            return 1
        errors = validate(events)
        if errors:
            for err in errors:
                print(f"{path}: {err}")
            print(f"{path}: INVALID ({len(errors)} violation(s), "
                  f"{len(events)} events)")
            return 1
        timed = sum(1 for e in events
                    if isinstance(e, dict) and e.get("ph") != "M")
        print(f"{path}: ok ({len(events)} events, {timed} timed)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
