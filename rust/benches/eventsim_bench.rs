//! Event-engine throughput micro-benchmarks: events/sec with and
//! without the contention-aware fabric layer, for **both** engines
//! that drive the shared [`cogsim_disagg::simcore`] pipeline.
//!
//! The fabric turns every remote dispatch into 3–4 events plus a
//! max-min fair-share recomputation per flow start/finish; these
//! benches pin what that costs the simulator itself (not the
//! simulated system), and guard the SimCore extraction against
//! throughput regressions.  Results go to `BENCH_eventsim.json`
//! (open-loop EventSim) and `BENCH_cogsim.json` (coupled CogSim) at
//! the repo root so runs can be diffed across commits.
//!
//! The fluid tier rides along in `BENCH_fluid.json` (cells/sec over
//! the full 40-cell `repro scale` campaign — its reason to exist is
//! being ~6 orders of magnitude cheaper per cell than the event
//! engines, so a throughput regression there is a product bug, not a
//! nicety).
//!
//! ```bash
//! cargo bench --bench eventsim_bench            # full budget
//! cargo bench --bench eventsim_bench -- --smoke # CI-sized
//! ```

use std::collections::BTreeMap;

use cogsim_disagg::cluster::{Backend, Policy, RduBackend};
use cogsim_disagg::eventsim::{CogSim, CogSimConfig, EventSim, EventSimConfig};
use cogsim_disagg::fabric::{FabricSpec, Topology};
use cogsim_disagg::fluid::{run_scale_campaign, ScaleCampaignConfig};
use cogsim_disagg::rdu::RduApi;
use cogsim_disagg::util::bench::Bencher;
use cogsim_disagg::util::json::{write as json_write, Value};

fn pool() -> Vec<Box<dyn Backend>> {
    vec![
        Box::new(RduBackend::disaggregated("rdu/pool0", 4, RduApi::CppOptimized)),
        Box::new(RduBackend::disaggregated("rdu/pool1", 2, RduApi::Python)),
    ]
}

fn spec(ranks: usize) -> FabricSpec {
    FabricSpec {
        topology: Topology::pooled(ranks, 2, 4.0),
        accel_of_backend: vec![0, 1],
    }
}

/// One measured event-sim configuration: run to completion, report
/// events processed so the bench can normalise to events/sec.
/// `rec_off` attaches a disarmed flight recorder first — the
/// `Option` checks on every hook are the recorder's entire cost when
/// tracing is off, and this variant pins that cost at ~zero.
fn run_event_once(
    ranks: usize,
    horizon_s: f64,
    fabric: bool,
    rec_off: bool,
    heapq: bool,
) -> u64 {
    let cfg = EventSimConfig { ranks, horizon_s, ..Default::default() };
    let mut sim = if fabric {
        EventSim::with_fabric(
            pool(),
            Policy::LeastOutstanding,
            cfg,
            vec![0, 1],
            vec![0, 1],
            spec(ranks),
        )
    } else {
        EventSim::new(pool(), Policy::LeastOutstanding, cfg)
    };
    if heapq {
        sim.use_binary_heap_queue();
    }
    if rec_off {
        sim.attach_disarmed_recorder();
    }
    sim.run_to_completion();
    sim.events_processed()
}

/// One measured coupled configuration: the CogSim path adds the
/// timestep barrier, residency swaps, and (with the fabric) the
/// weights-ready gate to every dispatch.
fn run_cog_once(
    ranks: usize,
    timesteps: usize,
    fabric: bool,
    rec_off: bool,
    heapq: bool,
) -> u64 {
    let cfg = CogSimConfig {
        ranks,
        timesteps,
        swap_s: 200e-6,
        ..Default::default()
    };
    let mut sim = if fabric {
        CogSim::with_fabric(
            pool(),
            Policy::LeastOutstanding,
            cfg,
            vec![0, 1],
            vec![0, 1],
            spec(ranks),
        )
    } else {
        CogSim::new(pool(), Policy::LeastOutstanding, cfg)
    };
    if heapq {
        sim.use_binary_heap_queue();
    }
    if rec_off {
        sim.attach_disarmed_recorder();
    }
    sim.run_to_completion();
    sim.events_processed()
}

/// Benchmark one `(key, runner)` pair and record its events/sec.
fn bench_into(
    bencher: &Bencher,
    results: &mut BTreeMap<String, Value>,
    group: &str,
    key: &str,
    run: impl Fn() -> u64,
) {
    let events = run();
    let r = bencher.run(&format!("{group}/{key}"), || {
        std::hint::black_box(run());
    });
    let events_per_s = events as f64 / r.mean_secs();
    println!("{r}");
    println!("  -> {events} events/run, {events_per_s:.0} events/s");
    let mut m = BTreeMap::new();
    m.insert("events_per_run".to_string(), Value::Number(events as f64));
    m.insert("events_per_s".to_string(), Value::Number(events_per_s.round()));
    m.insert("mean_run_us".to_string(), Value::Number((r.mean_secs() * 1e6).round()));
    m.insert("iters".to_string(), Value::Number(r.iters as f64));
    results.insert(key.to_string(), Value::Object(m));
}

fn write_doc(out: &str, meta: BTreeMap<String, Value>, results: BTreeMap<String, Value>) {
    let mut doc = meta;
    doc.insert("results".to_string(), Value::Object(results));
    std::fs::write(out, json_write(&Value::Object(doc))).expect("write bench json");
    println!("wrote {out}");
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");

    // CI "Scale smoke": one 1024-rank coupled cell under the step's
    // wall-clock budget (the shell `timeout` is the budget; the run
    // just has to finish).  No BENCH files are written in this mode.
    if std::env::args().any(|a| a == "--scale-smoke") {
        let t0 = std::time::Instant::now();
        let events = run_cog_once(1024, 2, true, false, false);
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "scale-smoke: 1024-rank cog cell, {events} events in {dt:.2}s \
             ({:.0} events/s)",
            events as f64 / dt
        );
        return;
    }

    let bencher = if smoke { Bencher::quick() } else { Bencher::default() };

    // ------------------------------------------------ EventSim path
    let (ranks, horizon_s) = if smoke { (16, 0.045) } else { (64, 0.205) };
    let mut meta = BTreeMap::new();
    meta.insert("ranks".to_string(), Value::Number(ranks as f64));
    meta.insert("horizon_us".to_string(), Value::Number(horizon_s * 1e6));
    meta.insert("smoke".to_string(), Value::Bool(smoke));
    let mut results = BTreeMap::new();
    for (key, fabric, rec_off) in [
        ("legacy_link", false, false),
        ("fabric_4to1", true, false),
        ("fabric_4to1_rec_off", true, true),
    ] {
        bench_into(&bencher, &mut results, "eventsim", key, || {
            run_event_once(ranks, horizon_s, fabric, rec_off, false)
        });
    }
    // 256-rank scale-out cell, ladder vs reference-heap A/B.  Fixed
    // shape in smoke and full runs so the committed floors stay
    // comparable; the `_heapq` twin pins the ladder's speedup.
    for (key, heapq) in [("fabric_4to1_r256", false), ("fabric_4to1_r256_heapq", true)] {
        bench_into(&bencher, &mut results, "eventsim", key, || {
            run_event_once(256, 0.02, true, false, heapq)
        });
    }
    write_doc("BENCH_eventsim.json", meta, results);

    // -------------------------------------------------- CogSim path
    let (cog_ranks, timesteps) = if smoke { (16, 4) } else { (64, 16) };
    let mut meta = BTreeMap::new();
    meta.insert("ranks".to_string(), Value::Number(cog_ranks as f64));
    meta.insert("timesteps".to_string(), Value::Number(timesteps as f64));
    meta.insert("swap_us".to_string(), Value::Number(200.0));
    meta.insert("smoke".to_string(), Value::Bool(smoke));
    let mut results = BTreeMap::new();
    for (key, fabric, rec_off) in [
        ("legacy_link", false, false),
        ("fabric_4to1", true, false),
        ("fabric_4to1_rec_off", true, true),
    ] {
        bench_into(&bencher, &mut results, "cogsim", key, || {
            run_cog_once(cog_ranks, timesteps, fabric, rec_off, false)
        });
    }
    write_doc("BENCH_cogsim.json", meta, results);

    // --------------------------------------------------- fluid tier
    // Always the full default campaign (40 cells, milliseconds):
    // --smoke must not change the shape or the committed baseline
    // stops being comparable.
    let fluid_cfg = ScaleCampaignConfig::default();
    let cells: u64 = fluid_cfg.rank_counts.len() as u64
        * (1 + fluid_cfg.pool_sizes.len() as u64);
    let r = bencher.run("fluid/scale_default", || {
        std::hint::black_box(run_scale_campaign(&fluid_cfg));
    });
    let cells_per_s = cells as f64 / r.mean_secs();
    println!("{r}");
    println!("  -> {cells} cells/run, {cells_per_s:.0} cells/s");
    let mut meta = BTreeMap::new();
    meta.insert("cells".to_string(), Value::Number(cells as f64));
    let mut m = BTreeMap::new();
    m.insert("cells_per_run".to_string(), Value::Number(cells as f64));
    m.insert("cells_per_s".to_string(), Value::Number(cells_per_s.round()));
    m.insert("mean_run_us".to_string(), Value::Number((r.mean_secs() * 1e6).round()));
    m.insert("iters".to_string(), Value::Number(r.iters as f64));
    let mut results = BTreeMap::new();
    results.insert("scale_default".to_string(), Value::Object(m));
    write_doc("BENCH_fluid.json", meta, results);
}
