//! Event-engine throughput micro-benchmark: events/sec with and
//! without the contention-aware fabric layer.
//!
//! The fabric turns every remote dispatch into 3–4 events plus a
//! max-min fair-share recomputation per flow start/finish; this
//! bench pins what that costs the simulator itself (not the
//! simulated system).  Results go to `BENCH_eventsim.json` at the
//! repo root so runs can be diffed across commits.
//!
//! ```bash
//! cargo bench --bench eventsim_bench            # full budget
//! cargo bench --bench eventsim_bench -- --smoke # CI-sized
//! ```

use std::collections::BTreeMap;

use cogsim_disagg::cluster::{Backend, Policy, RduBackend};
use cogsim_disagg::eventsim::{EventSim, EventSimConfig};
use cogsim_disagg::fabric::{FabricSpec, Topology};
use cogsim_disagg::rdu::RduApi;
use cogsim_disagg::util::bench::Bencher;
use cogsim_disagg::util::json::{write as json_write, Value};

fn pool() -> Vec<Box<dyn Backend>> {
    vec![
        Box::new(RduBackend::disaggregated("rdu/pool0", 4, RduApi::CppOptimized)),
        Box::new(RduBackend::disaggregated("rdu/pool1", 2, RduApi::Python)),
    ]
}

fn sim_cfg(ranks: usize, horizon_s: f64) -> EventSimConfig {
    EventSimConfig { ranks, horizon_s, ..Default::default() }
}

/// One measured configuration: run the sim to completion, report
/// events processed so the bench can normalise to events/sec.
fn run_once(ranks: usize, horizon_s: f64, fabric: bool) -> u64 {
    let cfg = sim_cfg(ranks, horizon_s);
    let mut sim = if fabric {
        let spec = FabricSpec {
            topology: Topology::pooled(ranks, 2, 4.0),
            accel_of_backend: vec![0, 1],
        };
        EventSim::with_fabric(pool(), Policy::LeastOutstanding, cfg, vec![0, 1], vec![0, 1], spec)
    } else {
        EventSim::new(pool(), Policy::LeastOutstanding, cfg)
    };
    sim.run_to_completion();
    sim.events_processed()
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let bencher = if smoke { Bencher::quick() } else { Bencher::default() };
    let (ranks, horizon_s) = if smoke { (16, 0.045) } else { (64, 0.205) };

    let mut doc = BTreeMap::new();
    doc.insert("ranks".to_string(), Value::Number(ranks as f64));
    doc.insert("horizon_us".to_string(), Value::Number(horizon_s * 1e6));
    doc.insert("smoke".to_string(), Value::Bool(smoke));

    let mut results = BTreeMap::new();
    for (key, fabric) in [("legacy_link", false), ("fabric_4to1", true)] {
        let events = run_once(ranks, horizon_s, fabric);
        let r = bencher.run(&format!("eventsim/{key}"), || {
            std::hint::black_box(run_once(ranks, horizon_s, fabric));
        });
        let events_per_s = events as f64 / r.mean_secs();
        println!("{r}");
        println!("  -> {events} events/run, {events_per_s:.0} events/s");
        let mut m = BTreeMap::new();
        m.insert("events_per_run".to_string(), Value::Number(events as f64));
        m.insert(
            "events_per_s".to_string(),
            Value::Number((events_per_s).round()),
        );
        m.insert(
            "mean_run_us".to_string(),
            Value::Number((r.mean_secs() * 1e6).round()),
        );
        m.insert("iters".to_string(), Value::Number(r.iters as f64));
        results.insert(key.to_string(), Value::Object(m));
    }
    doc.insert("results".to_string(), Value::Object(results));

    let out = "BENCH_eventsim.json";
    std::fs::write(out, json_write(&Value::Object(doc))).expect("write bench json");
    println!("wrote {out}");
}
