//! Coordinator hot-path micro-benchmarks (the §Perf L3 targets):
//!
//! * wire-protocol encode/decode bandwidth,
//! * dynamic-batcher enqueue/drain cost,
//! * end-to-end TCP loopback request latency vs in-process submit
//!   (the coordinator + transport overhead on top of PJRT execute).

use std::sync::Arc;
use std::time::{Duration, Instant};

use cogsim_disagg::coordinator::batcher::{BatcherConfig, DynamicBatcher, PendingRequest, Priority};
use cogsim_disagg::coordinator::{Coordinator, CoordinatorConfig, Registry};
use cogsim_disagg::net::protocol::{self, Request};
use cogsim_disagg::net::{Client, Server};
use cogsim_disagg::runtime::Engine;
use cogsim_disagg::util::bench::Bencher;
use cogsim_disagg::util::rng::Rng;

fn main() {
    let bencher = Bencher::default();
    let mut rng = Rng::new(0);

    // ---------------- protocol codec ----------------
    println!("== wire protocol ==");
    for &n in &[4usize, 256, 16384] {
        let payload = rng.normal_vec(n * 42);
        let req = Request {
            id: 7,
            model: "hermit/mat0".into(),
            priority: 0,
            n_samples: n as u32,
            payload: payload.clone(),
        };
        let bytes = protocol::encode_request(&req);
        let mb = bytes.len() as f64 / 1e6;
        let enc = bencher.run(&format!("encode_request b={n}"), || {
            let _ = std::hint::black_box(protocol::encode_request(&req));
        });
        println!("{enc}   -> {:>8.0} MB/s", mb / enc.mean_secs());
        let dec = bencher.run(&format!("decode_request b={n}"), || {
            let _ = std::hint::black_box(
                protocol::read_request(&mut &bytes[..]).unwrap().unwrap(),
            );
        });
        println!("{dec}   -> {:>8.0} MB/s", mb / dec.mean_secs());
    }

    // ---------------- batcher ----------------
    println!("\n== dynamic batcher ==");
    let r = bencher.run("enqueue+drain 64 reqs x 4 samples", || {
        let t0 = Instant::now();
        let mut b = DynamicBatcher::new(BatcherConfig {
            target_batch: 256,
            max_wait: Duration::ZERO,
            deferred_max_wait: std::time::Duration::from_millis(50),
            max_batch: 1024,
        });
        for id in 0..64u64 {
            b.enqueue(
                "m",
                PendingRequest { id, input: vec![0.0; 4 * 42], samples: 4, arrived: t0, priority: Priority::Critical },
            );
        }
        while !b.drain_ready(t0).is_empty() {}
    });
    println!("{r}");

    // ---------------- end-to-end ----------------
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("no artifacts/ — skipping end-to-end benches");
        return;
    }
    println!("\n== end-to-end (hermit, warm) ==");
    let engine = Engine::load(&dir, Some(&["hermit"])).expect("engine");
    let mut registry = Registry::new();
    registry.register_materials("hermit", 8);
    let coordinator = Arc::new(
        Coordinator::start(engine, registry, CoordinatorConfig::default()).unwrap(),
    );
    let server = Server::serve(Arc::clone(&coordinator), "127.0.0.1:0").unwrap();
    let client = Client::connect(server.addr()).unwrap();

    for &batch in &[1usize, 4, 64, 256] {
        let x = rng.normal_vec(batch * 42);
        // warm-up: 10 mini-batches (paper protocol)
        for _ in 0..10 {
            let _ = client.infer("hermit/mat0", batch, &x).unwrap();
        }
        let local = bencher.run(&format!("in-process submit b={batch}"), || {
            let _ = coordinator.infer("hermit/mat0", x.clone()).unwrap();
        });
        println!("{local}");
        let remote = bencher.run(&format!("TCP loopback infer  b={batch}"), || {
            let _ = client.infer("hermit/mat0", batch, &x).unwrap();
        });
        println!(
            "{remote}   (+{:.1}% vs in-process)",
            100.0 * (remote.mean_secs() - local.mean_secs()) / local.mean_secs()
        );
    }
    server.shutdown();
}
