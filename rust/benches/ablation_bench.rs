//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. **Batcher policy** (target batch × deadline) on the measured
//!    CPU stack: in-the-loop request latency vs engine batches — the
//!    latency/efficiency trade the paper's small-batch regime forces.
//! 2. **Padding ladder**: request-size distribution vs padding waste
//!    for different compiled-batch ladders.
//! 3. **RDU micro-batch policy**: swept-optimal micro vs fixed-micro
//!    heuristics on the calibrated model (what Fig. 11/12's sweep
//!    buys over naive policies).

use std::sync::Arc;
use std::time::{Duration, Instant};

use cogsim_disagg::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig, Registry};
use cogsim_disagg::devices::profiles;
use cogsim_disagg::metrics::LatencyRecorder;
use cogsim_disagg::rdu::{RduApi, RduModel};
use cogsim_disagg::runtime::Engine;
use cogsim_disagg::util::rng::Rng;
use cogsim_disagg::workload::HydraWorkload;

fn main() {
    ablation_rdu_micro_policy();
    ablation_padding_ladder();
    ablation_batcher_policy();
}

/// 3. micro-batch policy on the calibrated RDU model (no hardware
/// needed — pure model evaluation).
fn ablation_rdu_micro_policy() {
    println!("== ablation: RDU micro-batch policy (Hermit, 1 RDU, C++ opt) ==");
    println!(
        "{:>10} {:>14} {:>14} {:>14} {:>14}",
        "mini", "swept (ms)", "micro=1 (ms)", "micro=mini", "micro=64"
    );
    let m = RduModel::new(profiles::hermit(), 4, RduApi::CppOptimized);
    for mini in [64usize, 1024, 8192, 32768] {
        let swept = m.latency_best_s(mini) * 1e3;
        let one = m.latency_s(mini, 1) * 1e3;
        let full = m.latency_s(mini, mini) * 1e3;
        let fixed = m.latency_s(mini, 64.min(mini)) * 1e3;
        println!("{mini:>10} {swept:>14.3} {one:>14.3} {full:>14.3} {fixed:>14.3}");
    }
    println!();
}

/// 2. padding waste vs ladder shape for the Hydra request-size mix.
fn ablation_padding_ladder() {
    println!("== ablation: compiled-batch ladder vs padding waste ==");
    let ladders: [(&str, Vec<usize>); 3] = [
        ("powers of 4 (1,4,16,64,256,1024)", vec![1, 4, 16, 64, 256, 1024]),
        ("powers of 2 (1..1024)", vec![1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024]),
        ("single 1024", vec![1024]),
    ];
    // Hydra request sizes: per-(rank, material) samples
    let w = HydraWorkload::default();
    let sizes: Vec<usize> = (0..5).flat_map(|t| w.timestep(t)).map(|r| r.samples).collect();

    for (name, ladder) in &ladders {
        let mut executed = 0usize;
        let mut real = 0usize;
        for &n in &sizes {
            let mut left = n;
            let max = *ladder.last().unwrap();
            while left > 0 {
                let chunk = left.min(max);
                let slot = ladder.iter().copied().find(|&b| b >= chunk).unwrap_or(max);
                executed += slot;
                real += chunk;
                left -= chunk;
            }
        }
        println!(
            "  {name:<38} waste {:>5.1}%  ({} compiled variants)",
            100.0 * (1.0 - real as f64 / executed as f64),
            ladder.len()
        );
    }
    println!();
}

/// 1. batcher policy on the real engine (needs artifacts).
fn ablation_batcher_policy() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("no artifacts/ — skipping batcher-policy ablation");
        return;
    }
    println!("== ablation: batcher policy (measured, hermit, 64 concurrent 2-sample reqs) ==");
    println!(
        "{:>28} {:>12} {:>12} {:>10}",
        "policy", "mean (ms)", "p95 (ms)", "batches"
    );
    for (label, target, wait_us) in [
        ("target 16, wait 50us", 16usize, 50u64),
        ("target 64, wait 200us", 64, 200),
        ("target 256, wait 300us", 256, 300),
        ("target 256, wait 2ms", 256, 2000),
        ("no batching (target 1)", 1, 0),
    ] {
        let engine = Engine::load(&dir, Some(&["hermit"])).unwrap();
        let mut registry = Registry::new();
        registry.register_materials("hermit", 1);
        let c = Arc::new(
            Coordinator::start(
                engine,
                registry,
                CoordinatorConfig {
                    batcher: BatcherConfig {
                        target_batch: target,
                        max_wait: Duration::from_micros(wait_us),
                        deferred_max_wait: Duration::from_millis(20),
                        max_batch: 1024,
                    },
                    workers: 1,
                },
            )
            .unwrap(),
        );
        let mut rng = Rng::new(0);
        // warm
        for _ in 0..5 {
            let _ = c.infer("hermit/mat0", rng.normal_vec(2 * 42)).unwrap();
        }
        let mut lat = LatencyRecorder::new();
        for _round in 0..6 {
            let pending: Vec<_> = (0..64)
                .map(|_| {
                    let x = rng.normal_vec(2 * 42);
                    (Instant::now(), c.submit("hermit/mat0", x).unwrap())
                })
                .collect();
            for (t0, rx) in pending {
                rx.recv().unwrap().unwrap();
                lat.record(t0.elapsed());
            }
        }
        let batches = c.stats.batches.load(std::sync::atomic::Ordering::Relaxed);
        println!(
            "{label:>28} {:>12.3} {:>12.3} {batches:>10}",
            lat.mean_s() * 1e3,
            lat.p95_s() * 1e3
        );
    }
}
