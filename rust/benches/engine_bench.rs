//! Measured engine performance on THIS testbed (CPU PJRT): latency +
//! throughput per (model, mini-batch) following the paper's protocol
//! (§V-A: 10-mini-batch warm-up, mean across mini-batches).
//!
//! These are the "this-testbed" numbers recorded in EXPERIMENTS.md —
//! the absolute values live on a CPU, so they are compared against
//! the pure-jnp reference and the coordinator overhead, not against
//! the paper's A100/RDU numbers (those come from the calibrated
//! models in `cargo bench --bench figures_bench`).

use cogsim_disagg::runtime::Engine;
use cogsim_disagg::util::bench::Bencher;
use cogsim_disagg::util::rng::Rng;

fn main() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("no artifacts/ — run `make artifacts` first; skipping");
        return;
    }
    let engine = Engine::load(&dir, None).expect("engine");
    let bencher = Bencher::default();
    let mut rng = Rng::new(0);

    println!("== engine execute() latency/throughput (CPU PJRT testbed) ==");
    for model in engine.model_names() {
        let spec = engine.spec(&model).unwrap().clone();
        for batch in spec.batch_ladder() {
            let x = rng.normal_vec(batch * spec.input_elems());
            let r = bencher.run(&format!("{model} b={batch}"), || {
                let _ = engine.execute(&model, batch, &x).unwrap();
            });
            println!(
                "{r}   -> {:>12.0} samples/s",
                r.throughput(batch)
            );
        }
    }

    println!("\n== execute() phase breakdown (hermit, warm) ==");
    for batch in engine.spec("hermit").unwrap().batch_ladder() {
        let x = rng.normal_vec(batch * 42);
        // warm
        for _ in 0..5 {
            let _ = engine.execute("hermit", batch, &x).unwrap();
        }
        let mut up = std::time::Duration::ZERO;
        let mut ex = std::time::Duration::ZERO;
        let mut fe = std::time::Duration::ZERO;
        let n = 20;
        for _ in 0..n {
            let (_, t) = engine.execute("hermit", batch, &x).unwrap();
            up += t.upload;
            ex += t.execute;
            fe += t.fetch;
        }
        println!(
            "b={batch:<6} upload {:>10.3?}  execute {:>10.3?}  fetch {:>10.3?}",
            up / n,
            ex / n,
            fe / n
        );
    }
}
