//! The paper-figure regeneration harness as a bench target: rebuilds
//! every evaluation figure (4–20) from the calibrated device models
//! and prints the series — `cargo bench --bench figures_bench` is the
//! one-command reproduction of the paper's evaluation section.
//!
//! CSVs additionally land in `results/` (same as `repro repro all`).

use cogsim_disagg::harness::{run_figure, FIGURES};

fn main() {
    std::fs::create_dir_all("results").ok();
    let t0 = std::time::Instant::now();
    for id in FIGURES {
        let fig = run_figure(id).expect(id);
        println!("================ {} — {}", fig.id, fig.caption);
        for (i, table) in fig.tables.iter().enumerate() {
            println!("{}", table.render());
            let suffix = if fig.tables.len() > 1 {
                format!("{}_{}", fig.id, (b'a' + i as u8) as char)
            } else {
                fig.id.to_string()
            };
            std::fs::write(format!("results/{suffix}.csv"), table.to_csv()).ok();
        }
    }
    println!(
        "regenerated {} figures in {:?} (CSVs in results/)",
        FIGURES.len(),
        t0.elapsed()
    );
}
