//! Differential test: in the contention-free limit the discrete-event
//! simulator must agree with the analytic virtual-time `Cluster` —
//! request for request, backend for backend, to 1e-9 seconds.
//!
//! The limit: **one rank, closed loop** (a new request only after the
//! previous one completed plus think time), **batching off** (every
//! request dispatches alone), fixed request size equal to one ladder
//! step.  Then every request finds empty queues in both models, the
//! routing policy sees identical state, and both compute latency as
//! `wait + link_overhead + execute` through the *same* Backend
//! methods — so the two models must coincide exactly.  Any divergence
//! means the event engine's queue accounting, clock advancement, or
//! policy wiring drifted from the analytic semantics.

use cogsim_disagg::cluster::{Backend, Cluster, GpuBackend, Policy, RduBackend};
use cogsim_disagg::devices::{profiles, Api, Gpu};
use cogsim_disagg::eventsim::{ArrivalProcess, Batching, EventSim, EventSimConfig};
use cogsim_disagg::rdu::RduApi;

/// Two identical backends so every policy has a real choice to make.
fn gpu_fleet() -> Vec<Box<dyn Backend>> {
    (0..2)
        .map(|i| {
            Box::new(GpuBackend::node_local(
                format!("gpu/rank{i}"),
                Gpu::a100(),
                Api::TrtCudaGraphs,
            )) as Box<dyn Backend>
        })
        .collect()
}

fn rdu_fleet() -> Vec<Box<dyn Backend>> {
    (0..2)
        .map(|i| {
            Box::new(RduBackend::disaggregated(format!("rdu/pool{i}"), 4, RduApi::CppOptimized))
                as Box<dyn Backend>
        })
        .collect()
}

/// Run the event sim in the contention-free limit and replay the same
/// request sequence through the analytic cluster.
fn assert_event_matches_analytic(
    fleet_name: &str,
    event_fleet: Vec<Box<dyn Backend>>,
    analytic_fleet: Vec<Box<dyn Backend>>,
    policy: Policy,
    batch: usize,
) {
    let cfg = EventSimConfig {
        ranks: 1,
        materials: 4,
        // batch = one ladder step, every request
        samples_per_request: (batch, batch),
        arrival: ArrivalProcess::ClosedLoop { think_s: 5e-3 },
        batching: Batching::Off,
        horizon_s: 0.3,
        seed: 7,
        ..Default::default()
    };
    let mut sim = EventSim::new(event_fleet, policy, cfg);
    sim.run_to_completion();
    let records = sim.records();
    assert!(
        records.len() >= 40,
        "{fleet_name}/{policy:?}: want a meaningful sequence, got {}",
        records.len()
    );

    let mut cluster = Cluster::new(analytic_fleet, policy);
    let profile = profiles::hermit();
    for (i, rec) in records.iter().enumerate() {
        assert_eq!(rec.samples, batch);
        assert_eq!(rec.batch_samples, batch, "batching off must dispatch alone");
        // contention-free: the request never waits in the router
        assert_eq!(
            rec.dispatch_s, rec.arrival_s,
            "{fleet_name}/{policy:?} req {i}: batching off must dispatch on arrival"
        );
        cluster.advance_to(rec.arrival_s);
        let routed = cluster.submit(&rec.model, &profile, rec.samples);
        assert_eq!(
            routed.backend, rec.backend,
            "{fleet_name}/{policy:?} req {i} ({}): routed to different backends",
            rec.model
        );
        let event_latency = rec.complete_s - rec.arrival_s;
        assert!(
            (routed.latency_s - event_latency).abs() < 1e-9,
            "{fleet_name}/{policy:?} req {i}: analytic {} vs event {}",
            routed.latency_s,
            event_latency
        );
        assert!(
            (routed.link_overhead_s - rec.link_overhead_s).abs() < 1e-12,
            "{fleet_name}/{policy:?} req {i}: link overhead diverged"
        );
        assert!(
            routed.wait_s.abs() < 1e-12,
            "{fleet_name}/{policy:?} req {i}: limit must be contention-free, wait {}",
            routed.wait_s
        );
    }
}

#[test]
fn gpu_fleet_matches_analytic_for_every_policy() {
    for policy in Policy::ALL {
        assert_event_matches_analytic("gpu", gpu_fleet(), gpu_fleet(), policy, 4);
    }
}

#[test]
fn rdu_fleet_matches_analytic_for_every_policy() {
    for policy in Policy::ALL {
        assert_event_matches_analytic("rdu", rdu_fleet(), rdu_fleet(), policy, 4);
    }
}

#[test]
fn agreement_holds_across_ladder_steps() {
    // a second ladder step on both architectures: the agreement is a
    // property of the engine, not of one operating point
    for batch in [1usize, 256] {
        assert_event_matches_analytic("gpu", gpu_fleet(), gpu_fleet(), Policy::LatencyAware, batch);
        assert_event_matches_analytic("rdu", rdu_fleet(), rdu_fleet(), Policy::LeastOutstanding, batch);
    }
}

#[test]
fn contention_breaks_the_equivalence_as_expected() {
    // Sanity check on the test itself: once many ranks burst at the
    // same instant, the event sim *must* report queueing the analytic
    // single-shot route would miss — i.e. the differential limit above
    // is genuinely the contention-free special case.
    let cfg = EventSimConfig {
        ranks: 32,
        samples_per_request: (4, 4),
        arrival: ArrivalProcess::Synchronized { period_s: 0.02, jitter_s: 0.0 },
        batching: Batching::Off,
        horizon_s: 0.05,
        seed: 7,
        ..Default::default()
    };
    let mut sim = EventSim::new(rdu_fleet(), Policy::LeastOutstanding, cfg);
    sim.run_to_completion();
    let idle = {
        let fleet = rdu_fleet();
        let p = profiles::hermit();
        fleet[0].latency_s(&p, 4)
    };
    let max_latency = sim
        .records()
        .iter()
        .map(|r| r.complete_s - r.arrival_s)
        .fold(0.0f64, f64::max);
    assert!(
        max_latency > 2.0 * idle,
        "bursts must queue: max {max_latency} vs idle {idle}"
    );
}
