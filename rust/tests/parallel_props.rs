//! Determinism properties of the parallel sweep + incremental fabric.
//!
//! The tentpole contract: the thread count is a *performance* knob,
//! never a *results* knob.  `run_grid_threads` at 1, 2, and 8 workers
//! must render byte-identical campaign JSON for every workload kind,
//! and the fabric engine's incremental fair-share bookkeeping must
//! agree with a from-scratch solve after every arrival and departure.

use cogsim_disagg::cluster::Policy;
use cogsim_disagg::fabric::{max_min_rates, FabricEngine, Topology as FabricTopology};
use cogsim_disagg::harness::{run_grid_threads, Axes, Fleet, Grid, Kind, Knobs, Topology};
use cogsim_disagg::util::json;
use cogsim_disagg::util::rng::Rng;

/// One grid covering all three engines (analytic, event, cogsim) on
/// a mixed fleet behind a pooled fabric — the same shape the default
/// campaign sweeps.
fn every_kind_grid() -> Grid {
    Grid {
        axes: Axes {
            kinds: Kind::ALL.to_vec(),
            topologies: vec![Topology::Pooled],
            fleets: vec![Fleet::Mixed { gpus: 2, rdus: 1 }],
            policies: vec![Policy::LatencyAware],
            rank_counts: vec![4],
            fabric_oversubs: vec![1.0],
            ..Axes::default()
        },
        knobs: Knobs { timesteps: 3, horizon_s: 0.05, ..Knobs::default() },
    }
}

#[test]
fn grid_json_is_byte_identical_across_thread_counts() {
    let grid = every_kind_grid();
    let sequential = json::write(&run_grid_threads(&grid, 1).to_json());
    for threads in [2, 8] {
        let parallel = json::write(&run_grid_threads(&grid, threads).to_json());
        assert_eq!(
            sequential, parallel,
            "--threads {threads} changed the campaign JSON"
        );
    }
}

#[test]
fn default_thread_count_matches_sequential() {
    let grid = every_kind_grid();
    let sequential = json::write(&run_grid_threads(&grid, 1).to_json());
    let all_cores = json::write(&run_grid_threads(&grid, 0).to_json());
    assert_eq!(sequential, all_cores, "--threads 0 (all cores) diverged");
}

/// Relative agreement to 1e-12 (infinities must match exactly).
fn close(a: f64, b: f64) -> bool {
    if a.is_infinite() || b.is_infinite() {
        return a == b;
    }
    (a - b).abs() <= 1e-12 * a.abs().max(b.abs()).max(1.0)
}

#[test]
fn incremental_fabric_matches_from_scratch_solves() {
    // Drive the engine through randomized flow arrivals (pooled
    // request/response/swap paths, free node-local paths, zero-byte
    // transfers) and departures (draining completions), checking
    // after every mutation that each live flow's incremental rate
    // agrees with a fresh max_min_rates over the live flow set.
    let topo = FabricTopology::pooled(4, 2, 2.0);
    let caps: Vec<f64> = topo.capacities().to_vec();
    let mut eng = FabricEngine::new(topo.clone());
    let mut rng = Rng::new(0xfab51c);
    let mut live: Vec<(u64, Vec<usize>)> = Vec::new();
    let mut now = 0.0_f64;

    let check = |eng: &mut FabricEngine, live: &[(u64, Vec<usize>)]| {
        let paths: Vec<&[usize]> = live.iter().map(|(_, p)| p.as_slice()).collect();
        let scratch = max_min_rates(&caps, &paths);
        for ((id, path), want) in live.iter().zip(&scratch) {
            let got = eng.rate_of(*id).expect("live flow has a rate");
            assert!(
                close(got, *want),
                "flow {id} over {path:?}: incremental {got} vs scratch {want}"
            );
        }
    };

    for step in 0..400 {
        let arrive = live.len() < 2 || (rng.below(3) > 0 && live.len() < 24);
        if arrive {
            let path = match rng.below(5) {
                0 => Vec::new(), // node-local: free path
                1 => topo.response_path(rng.below(4), rng.below(2)),
                2 => topo.swap_path(rng.below(2)),
                _ => topo.request_path(rng.below(4), rng.below(2)),
            };
            let bytes = if rng.below(8) == 0 { 0.0 } else { rng.uniform(1e4, 2e6) };
            now += rng.uniform(0.0, 1e-4);
            let id = eng.start(now, path.clone(), bytes);
            live.push((id, path));
        } else {
            let t = eng
                .next_completion_s()
                .expect("constrained flows are live")
                .max(now);
            now = t;
            for id in eng.take_completed(t) {
                let pos = live.iter().position(|(l, _)| *l == id).expect("tracked");
                live.remove(pos);
            }
        }
        check(&mut eng, &live);
        // the armed wake-up time must be reproducible too
        if let Some(t) = eng.next_completion_s() {
            assert!(t.is_finite() && t >= now, "step {step}: bad wake {t}");
        }
    }
}
