//! End-to-end serving tests: coordinator + TCP server/client over the
//! real PJRT engine and AOT artifacts.
//!
//! Requires `make artifacts` (skipped silently otherwise).

use std::sync::Arc;
use std::time::Duration;

use cogsim_disagg::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, Registry,
};
use cogsim_disagg::net::{Client, Server};
use cogsim_disagg::runtime::Engine;
use cogsim_disagg::util::rng::Rng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn start_coordinator(materials: usize) -> Option<Arc<Coordinator>> {
    let dir = artifacts_dir()?;
    let engine = Engine::load(&dir, Some(&["hermit", "mir"])).unwrap();
    let mut registry = Registry::new();
    registry.register_materials("hermit", materials);
    registry.register("mir", "mir");
    let config = CoordinatorConfig {
        batcher: BatcherConfig {
            target_batch: 64,
            max_wait: Duration::from_micros(200),
            deferred_max_wait: std::time::Duration::from_millis(50),
            max_batch: 1024,
        },
        workers: 1,
    };
    Some(Arc::new(Coordinator::start(engine, registry, config).unwrap()))
}

#[test]
fn coordinator_single_request() {
    let Some(c) = start_coordinator(2) else { return };
    let mut rng = Rng::new(1);
    let out = c.infer("hermit/mat0", rng.normal_vec(42)).unwrap();
    assert_eq!(out.len(), 30);
    assert!(out.iter().all(|v| v.is_finite()));
}

#[test]
fn coordinator_routes_by_instance() {
    let Some(c) = start_coordinator(4) else { return };
    let mut rng = Rng::new(2);
    let x = rng.normal_vec(42);
    // same engine model behind every material: outputs must agree,
    // but every instance must be addressable.
    let base = c.infer("hermit/mat0", x.clone()).unwrap();
    for m in 1..4 {
        let out = c.infer(&format!("hermit/mat{m}"), x.clone()).unwrap();
        assert_eq!(out, base, "mat{m}");
    }
    assert!(c.infer("hermit/mat9", x).is_err(), "unregistered material");
}

#[test]
fn coordinator_batches_concurrent_requests() {
    let Some(c) = start_coordinator(1) else { return };
    let mut rng = Rng::new(3);

    // fire 32 single-sample requests without waiting: the batcher
    // should coalesce them into far fewer engine executions.
    let receivers: Vec<_> = (0..32)
        .map(|_| {
            let x = rng.normal_vec(42);
            (x.clone(), c.submit("hermit/mat0", x).unwrap())
        })
        .collect();
    for (x, rx) in receivers {
        let out = rx.recv().unwrap().unwrap();
        assert_eq!(out.len(), 30);
        // response must match a solo execution of the same sample
        let solo = c.infer("hermit/mat0", x).unwrap();
        for i in 0..30 {
            assert!((out[i] - solo[i]).abs() < 1e-4);
        }
    }
    let stats = &c.stats;
    let batches = stats.batches.load(std::sync::atomic::Ordering::Relaxed);
    let requests = stats.requests.load(std::sync::atomic::Ordering::Relaxed);
    assert!(requests >= 64, "{requests}");
    assert!(
        batches < requests,
        "batching never coalesced: {batches} batches for {requests} requests"
    );
}

#[test]
fn coordinator_rejects_bad_input() {
    let Some(c) = start_coordinator(1) else { return };
    assert!(c.infer("hermit/mat0", vec![0.0; 41]).is_err()); // not a multiple
    assert!(c.infer("hermit/mat0", vec![]).is_err()); // empty
    assert!(c.infer("unknown", vec![0.0; 42]).is_err());
}

#[test]
fn coordinator_multi_model_concurrent() {
    let Some(c) = start_coordinator(2) else { return };
    let mut rng = Rng::new(5);
    let hermit_x = rng.normal_vec(2 * 42);
    let mir_x: Vec<f32> = (0..48 * 48).map(|i| (i % 7) as f32 / 7.0).collect();

    let rx1 = c.submit("hermit/mat0", hermit_x).unwrap();
    let rx2 = c.submit("mir", mir_x).unwrap();
    let out1 = rx1.recv().unwrap().unwrap();
    let out2 = rx2.recv().unwrap().unwrap();
    assert_eq!(out1.len(), 2 * 30);
    assert_eq!(out2.len(), 48 * 48);
    assert!(out2.iter().all(|&v| (0.0..=1.0).contains(&v)), "mir sigmoid range");
}

// ------------------------------------------------------------ TCP path

#[test]
fn tcp_end_to_end_roundtrip() {
    let Some(c) = start_coordinator(2) else { return };
    let server = Server::serve(Arc::clone(&c), "127.0.0.1:0").unwrap();
    let client = Client::connect(server.addr()).unwrap();

    let mut rng = Rng::new(7);
    let x = rng.normal_vec(4 * 42);
    let remote = client.infer("hermit/mat1", 4, &x).unwrap();
    assert_eq!(remote.len(), 4 * 30);

    // remote result == local coordinator result
    let local = c.infer("hermit/mat1", x).unwrap();
    assert_eq!(remote, local);
    server.shutdown();
}

#[test]
fn tcp_multiple_clients_parallel() {
    let Some(c) = start_coordinator(4) else { return };
    let server = Server::serve(Arc::clone(&c), "127.0.0.1:0").unwrap();
    let addr = server.addr();

    let handles: Vec<_> = (0..4)
        .map(|rank| {
            std::thread::spawn(move || {
                let client = Client::connect(addr).unwrap();
                let mut rng = Rng::new(100 + rank as u64);
                for i in 0..10 {
                    let n = 1 + (i % 3);
                    let x = rng.normal_vec(n * 42);
                    let out = client
                        .infer(&format!("hermit/mat{rank}"), n, &x)
                        .unwrap();
                    assert_eq!(out.len(), n * 30);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(server.connections_accepted(), 4);
}

#[test]
fn tcp_pipelined_submission() {
    // The paper's throughput mode: mini-batch n+1 in flight before n
    // returns.
    let Some(c) = start_coordinator(1) else { return };
    let server = Server::serve(Arc::clone(&c), "127.0.0.1:0").unwrap();
    let client = Client::connect(server.addr()).unwrap();

    let mut rng = Rng::new(9);
    let x = rng.normal_vec(8 * 42);
    let rxs: Vec<_> = (0..8)
        .map(|_| client.submit("hermit/mat0", 8, &x).unwrap())
        .collect();
    assert!(client.in_flight() > 0);
    for rx in rxs {
        let rows = client.recv(rx).unwrap();
        assert_eq!(rows.len(), 8 * 30);
    }
    assert_eq!(client.in_flight(), 0);
}

#[test]
fn tcp_error_propagates_to_client() {
    let Some(c) = start_coordinator(1) else { return };
    let server = Server::serve(Arc::clone(&c), "127.0.0.1:0").unwrap();
    let client = Client::connect(server.addr()).unwrap();

    let err = client.infer("no/such/model", 1, &[0.0; 42]).unwrap_err();
    assert!(format!("{err:#}").contains("no/such/model"), "{err:#}");

    // mismatched payload size
    let err = client.infer("hermit/mat0", 2, &[0.0; 42]).unwrap_err();
    assert!(format!("{err:#}").contains("samples"), "{err:#}");

    // the connection must still work after errors
    let ok = client.infer("hermit/mat0", 1, &[0.1; 42]).unwrap();
    assert_eq!(ok.len(), 30);
}

#[test]
fn tcp_out_of_order_completion_demuxes_correctly() {
    // A big MIR request then a tiny Hermit request: the Hermit result
    // usually lands first; ids must demux correctly either way.
    let Some(c) = start_coordinator(1) else { return };
    let server = Server::serve(Arc::clone(&c), "127.0.0.1:0").unwrap();
    let client = Client::connect(server.addr()).unwrap();

    let mir_x = vec![0.25f32; 16 * 48 * 48];
    let hermit_x = vec![0.5f32; 42];
    let rx_big = client.submit("mir", 16, &mir_x).unwrap();
    let rx_small = client.submit("hermit/mat0", 1, &hermit_x).unwrap();

    let small = client.recv(rx_small).unwrap();
    let big = client.recv(rx_big).unwrap();
    assert_eq!(small.len(), 30);
    assert_eq!(big.len(), 16 * 48 * 48);
}

#[test]
fn deferred_priority_over_tcp() {
    // On-the-loop traffic (paper §II-B): deferred requests complete
    // correctly and never block critical ones.
    let Some(c) = start_coordinator(2) else { return };
    let server = Server::serve(Arc::clone(&c), "127.0.0.1:0").unwrap();
    let client = Client::connect(server.addr()).unwrap();

    let mut rng = Rng::new(21);
    let x = rng.normal_vec(2 * 42);
    let rx_deferred = client.submit_deferred("hermit/mat1", 2, &x).unwrap();
    // critical request on the other instance goes through promptly
    let critical = client.infer("hermit/mat0", 2, &x).unwrap();
    assert_eq!(critical.len(), 2 * 30);
    // the deferred one completes too (within its longer deadline)
    let deferred = client.recv(rx_deferred).unwrap();
    assert_eq!(deferred.len(), 2 * 30);
    // identical inputs, same weights -> same rows
    assert_eq!(deferred, critical);
}
