//! Golden-file regression test for the campaign harness, plus the
//! Fig. 15/16 remote-overhead anchors on the shared-pool topology.
//!
//! The campaign runs entirely in virtual time on the calibrated
//! analytic models, so a fixed seed must produce a **byte-stable**
//! JSON summary.  The golden file lives at
//! `rust/tests/golden/campaign_summary.json`; on first run (fresh
//! checkout without the file) the test writes it, afterwards every
//! run must reproduce it byte for byte.

use std::path::PathBuf;

use cogsim_disagg::cluster::Policy;
use cogsim_disagg::harness::campaign::{
    run_campaign, run_scenario_with_link, CampaignConfig, Topology,
};
use cogsim_disagg::netsim::Link;
use cogsim_disagg::util::json;

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust")
        .join("tests")
        .join("golden")
        .join("campaign_summary.json")
}

fn campaign_json() -> String {
    json::write(&run_campaign(&CampaignConfig::default()).to_json())
}

#[test]
fn fixed_seed_summary_is_byte_stable() {
    let a = campaign_json();
    let b = campaign_json();
    assert_eq!(a, b, "two identical runs must serialise identically");

    let path = golden_path();
    if path.exists() {
        let golden = std::fs::read_to_string(&path).unwrap();
        assert_eq!(
            a, golden,
            "campaign summary drifted from {path:?}; if the change is \
             intentional, delete the golden file and rerun to regenerate"
        );
    } else {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, &a).unwrap();
        // bootstrap run: regenerate and confirm stability against the
        // file we just wrote
        assert_eq!(campaign_json(), std::fs::read_to_string(&path).unwrap());
    }
}

#[test]
fn summary_parses_and_covers_the_full_sweep() {
    let doc = json::parse(&campaign_json()).unwrap();
    let scenarios = doc.get("scenarios").unwrap().as_array().unwrap();
    assert_eq!(scenarios.len(), Topology::ALL.len() * Policy::ALL.len());
    for s in scenarios {
        for field in ["topology", "policy", "hydra", "mir", "backends"] {
            assert!(s.get(field).is_some(), "missing {field}");
        }
        assert!(s.get("hydra").unwrap().get("p99_us").unwrap().as_f64().unwrap() > 0.0);
    }
}

#[test]
fn latency_aware_beats_round_robin_on_hybrid_hydra_p99() {
    // The acceptance headline: with a heterogeneous pool, the only
    // policy that sees (queue + link + execute) must win the tail.
    let result = run_campaign(&CampaignConfig::default());
    let la = result.scenario(Topology::Hybrid, Policy::LatencyAware);
    let rr = result.scenario(Topology::Hybrid, Policy::RoundRobin);
    assert!(
        la.hydra.p99_s < rr.hydra.p99_s,
        "latency-aware p99 {:.1}us must beat round-robin {:.1}us",
        la.hydra.p99_s * 1e6,
        rr.hydra.p99_s * 1e6
    );
    // ... and in the fully pooled topology too
    let la_p = result.scenario(Topology::Pooled, Policy::LatencyAware);
    let rr_p = result.scenario(Topology::Pooled, Policy::RoundRobin);
    assert!(la_p.hydra.p99_s < rr_p.hydra.p99_s);
}

#[test]
fn pooled_topology_reproduces_fig15_16_remote_overhead_shape() {
    let cfg = CampaignConfig::default();
    let result = run_campaign(&cfg);

    // Fig. 15 shape, campaign level: the local topology pays no link
    // overhead; the pool pays the paper's ~10 µs-plus-payload
    // software path on every Hermit request.
    let local = result.scenario(Topology::Local, Policy::LatencyAware);
    let pooled = result.scenario(Topology::Pooled, Policy::LatencyAware);
    assert_eq!(local.hydra.mean_link_overhead_s, 0.0);
    assert_eq!(local.mir.mean_link_overhead_s, 0.0);
    let hermit_overhead = pooled.hydra.mean_link_overhead_s;
    assert!(
        (8e-6..=60e-6).contains(&hermit_overhead),
        "Hermit remote overhead {:.1}us outside the Fig. 15 band",
        hermit_overhead * 1e6
    );
    // overhead grows with payload (Fig. 15's slope): MIR's 2×2304-el
    // samples dwarf Hermit's 42+30
    assert!(pooled.mir.mean_link_overhead_s > 10.0 * hermit_overhead);

    // Link ablation (same pool hardware, link on/off) — the direct
    // Fig. 15/16 analogue: remote latency above local, remote
    // throughput below local.
    let remote = run_scenario_with_link(
        Topology::Pooled,
        Policy::LatencyAware,
        &cfg,
        &Link::infiniband_cx6(),
    );
    let local_link = run_scenario_with_link(
        Topology::Pooled,
        Policy::LatencyAware,
        &cfg,
        &Link::local(),
    );
    let gap = remote.hydra.p50_s - local_link.hydra.p50_s;
    assert!(gap > 0.0, "remote must add latency (Fig. 15)");
    assert!((5e-6..=0.2).contains(&gap), "remote-overhead gap {gap}s implausible");
    assert!(remote.mir.p99_s > local_link.mir.p99_s);
    assert!(
        remote.hydra.samples_per_s <= local_link.hydra.samples_per_s,
        "remote throughput must not exceed local (Fig. 16): {} vs {}",
        remote.hydra.samples_per_s,
        local_link.hydra.samples_per_s
    );

    // Hybrid pays the link only on the long tail: the hot MIR model
    // stays local and beats the fully pooled placement outright.
    let hybrid = result.scenario(Topology::Hybrid, Policy::LatencyAware);
    assert_eq!(hybrid.mir.mean_link_overhead_s, 0.0);
    assert!(hybrid.hydra.mean_link_overhead_s > 0.0);
    assert!(hybrid.mir.p50_s < pooled.mir.p50_s);
}
