//! Golden-file regression test for the campaign harness, plus the
//! Fig. 15/16 remote-overhead anchors on the shared-pool topology.
//!
//! The campaign runs entirely in virtual time on the calibrated
//! analytic models, so a fixed seed must produce a **byte-stable**
//! JSON summary.  The golden files live at
//! `rust/tests/golden/campaign_summary.json` (analytic sweep),
//! `rust/tests/golden/event_summary.json` (event-sim sweep),
//! `rust/tests/golden/cogsim_summary.json` (coupled cogsim sweep), and
//! `rust/tests/golden/scale_summary.json` (fluid-tier scale-out
//! study).
//! The files are **committed**; a run that does not reproduce them
//! byte for byte fails loudly.  Regeneration is gated behind an
//! explicit `GOLDEN_BOOTSTRAP=1` environment variable so CI can
//! never silently rewrite a drifted golden:
//!
//! ```bash
//! rm rust/tests/golden/*.json
//! GOLDEN_BOOTSTRAP=1 cargo test --test campaign_golden
//! ```
//!
//! The event mode also pins the queueing headline the analytic sweep
//! cannot express — dynamic batching shrinks p99 under bursty
//! 64-rank arrivals on the pooled topology — the cogsim mode pins
//! the coupled headline (model-affinity routing beats round-robin on
//! time-to-solution once the swap cost exceeds the service time),
//! and the fabric axis pins the contention crossover: pooled TTS
//! degrades monotonically with oversubscription and falls behind
//! node-local GPUs at high rank count.

use std::path::PathBuf;

use cogsim_disagg::cluster::Policy;
use cogsim_disagg::eventsim::ArrivalProcess;
use cogsim_disagg::fluid::{run_scale_campaign_with_anchors, ScaleCampaignConfig};
use cogsim_disagg::harness::{
    run_campaign, run_cog_campaign, run_cog_scenario, run_event_campaign, run_event_scenario,
    run_scenario_with_link, CampaignConfig, CogCampaignConfig, EventCampaignConfig, Topology,
};
use cogsim_disagg::netsim::Link;
use cogsim_disagg::util::json;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust")
        .join("tests")
        .join("golden")
}

fn golden_path() -> PathBuf {
    golden_dir().join("campaign_summary.json")
}

fn event_golden_path() -> PathBuf {
    golden_dir().join("event_summary.json")
}

fn cogsim_golden_path() -> PathBuf {
    golden_dir().join("cogsim_summary.json")
}

fn scale_golden_path() -> PathBuf {
    golden_dir().join("scale_summary.json")
}

fn campaign_json() -> String {
    json::write(&run_campaign(&CampaignConfig::default()).to_json())
}

fn event_campaign_json() -> String {
    json::write(&run_event_campaign(&EventCampaignConfig::default()).to_json())
}

fn cogsim_campaign_json() -> String {
    json::write(&run_cog_campaign(&CogCampaignConfig::default()).to_json())
}

fn scale_campaign_json() -> String {
    json::write(&run_scale_campaign_with_anchors(&ScaleCampaignConfig::default()).to_json())
}

/// Shared golden-file protocol: byte-compare against the committed
/// file.  Regeneration never happens implicitly — a missing golden
/// fails unless `GOLDEN_BOOTSTRAP=1` is set, so CI drift is always a
/// loud failure, never a silent rewrite.
fn assert_golden(actual: &str, path: &PathBuf, regen: impl Fn() -> String) {
    if path.exists() {
        let golden = std::fs::read_to_string(path).unwrap();
        assert_eq!(
            actual, &golden,
            "summary drifted from {path:?}; if the change is intentional, \
             delete the golden file and rerun with GOLDEN_BOOTSTRAP=1 to regenerate"
        );
    } else {
        assert!(
            std::env::var("GOLDEN_BOOTSTRAP").as_deref() == Ok("1"),
            "golden file {path:?} is missing; goldens are committed artifacts — \
             rerun with GOLDEN_BOOTSTRAP=1 to bootstrap it deliberately"
        );
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(path, actual).unwrap();
        // bootstrap run: regenerate and confirm stability against the
        // file we just wrote
        assert_eq!(regen(), std::fs::read_to_string(path).unwrap());
    }
}

#[test]
fn fixed_seed_summary_is_byte_stable() {
    let a = campaign_json();
    let b = campaign_json();
    assert_eq!(a, b, "two identical runs must serialise identically");
    assert_golden(&a, &golden_path(), campaign_json);
}

#[test]
fn fixed_seed_event_summary_is_byte_stable() {
    let a = event_campaign_json();
    let b = event_campaign_json();
    assert_eq!(a, b, "two identical event runs must serialise identically");
    assert_golden(&a, &event_golden_path(), event_campaign_json);
}

#[test]
fn fixed_seed_cogsim_summary_is_byte_stable() {
    let a = cogsim_campaign_json();
    let b = cogsim_campaign_json();
    assert_eq!(a, b, "two identical cogsim runs must serialise identically");
    assert_golden(&a, &cogsim_golden_path(), cogsim_campaign_json);
}

#[test]
fn fixed_scale_summary_is_byte_stable() {
    // The fluid-tier scale-out golden: 40 closed-form cells to 16384
    // ranks plus the event-engine anchor cells at 64/256 ranks,
    // regenerated byte-exactly by python/sim/run_goldens.py.
    let a = scale_campaign_json();
    let b = scale_campaign_json();
    assert_eq!(a, b, "two identical scale runs must serialise identically");
    assert_golden(&a, &scale_golden_path(), scale_campaign_json);
}

#[test]
fn model_affinity_beats_round_robin_on_tts_once_swaps_cost_more_than_service() {
    // The cogsim headline: on the shared heterogeneous pool, sticky
    // model-affinity routing pins each per-material model to one
    // backend, so after first sighting its weights stay resident and
    // swaps stop.  Blind round-robin bounces every model across the
    // pool and re-pays the swap continuously.  With swaps free the
    // two policies are within noise of each other; once the swap cost
    // exceeds the small-batch service time (tens of µs here, 2 ms
    // swap), affinity must win time-to-solution outright.
    let cfg = CogCampaignConfig::default();
    let cell = |policy, swap_s| {
        run_cog_scenario(Topology::Pooled, policy, 4, 8, swap_s, 0.0, 1.0, &cfg)
    };
    let swap = 2e-3;
    let aff = cell(Policy::ModelAffinity, swap);
    let rr = cell(Policy::RoundRobin, swap);
    assert!(
        aff.summary.time_to_solution_s < rr.summary.time_to_solution_s,
        "affinity TTS {:.2}ms must beat round-robin {:.2}ms at swap {:.0}us",
        aff.summary.time_to_solution_s * 1e3,
        rr.summary.time_to_solution_s * 1e3,
        swap * 1e6
    );
    // the mechanism: affinity stops swapping after warmup — far fewer
    // misses than round-robin's continuous thrash
    assert!(
        aff.summary.swaps * 2 < rr.summary.swaps,
        "affinity {} swaps vs round-robin {}",
        aff.summary.swaps,
        rr.summary.swaps
    );
    // and the swap share of the critical path collapses
    assert!(aff.summary.total_swap_s < rr.summary.total_swap_s);
    // with free swaps the gap is the point: affinity's win above
    // comes from residency, not from generally better routing
    let aff0 = cell(Policy::ModelAffinity, 0.0);
    let rr0 = cell(Policy::RoundRobin, 0.0);
    let ratio_free = aff0.summary.time_to_solution_s / rr0.summary.time_to_solution_s;
    let ratio_swap = aff.summary.time_to_solution_s / rr.summary.time_to_solution_s;
    assert!(
        ratio_swap < ratio_free,
        "swap pressure must move the comparison toward affinity: {ratio_swap} vs {ratio_free}"
    );
}

#[test]
fn batching_window_shrinks_p99_under_bursty_64_rank_arrivals_on_the_pool() {
    // The event-mode headline: 64 ranks hit the shared RDU pool with
    // perfectly synchronised per-timestep bursts of tiny per-material
    // requests.  Without batching, every request pays its own
    // per-message software path and host overhead and the queue
    // explodes; a 200 us coalescing window collapses each burst into a
    // handful of per-material batches and wins the tail outright.
    // Run just the four cells the headline needs — not the full
    // default sweep the byte-stability test already runs twice.
    let cfg = EventCampaignConfig::default();
    let bursty = ArrivalProcess::Synchronized { period_s: 0.02, jitter_s: 0.0 };
    let cell = |policy, window_us| {
        run_event_scenario(Topology::Pooled, policy, bursty, 64, window_us, 1.0, &cfg)
    };
    for policy in [Policy::RoundRobin, Policy::LatencyAware] {
        let off = cell(policy, 0.0);
        let on = cell(policy, 200.0);
        assert!(
            on.summary.latency.p99_s < off.summary.latency.p99_s,
            "{policy:?}: batched p99 {:.1}us must beat unbatched {:.1}us",
            on.summary.latency.p99_s * 1e6,
            off.summary.latency.p99_s * 1e6
        );
        // the mechanism: far fewer, much larger batches
        assert!(on.summary.batches < off.summary.batches / 4);
        assert!(on.summary.mean_batch_samples > 4.0 * off.summary.mean_batch_samples);
    }
    // and the distribution is genuinely a tail: p99.9 >= p99 >= p50
    let on = cell(Policy::LatencyAware, 200.0);
    assert!(on.summary.latency.p999_s >= on.summary.latency.p99_s);
    assert!(on.summary.latency.p99_s >= on.summary.latency.p50_s);
}

#[test]
fn pooled_tts_degrades_with_oversubscription_and_loses_to_local_at_scale() {
    // The fabric acceptance headline, pinned on the default cogsim
    // campaign grid (all numbers verified out-of-band against the
    // python/sim transliteration of the whole pipeline): starving
    // the pooled fabric's bisection monotonically inflates
    // time-to-solution, and at 32 ranks the shared pool falls behind
    // per-rank node-local GPUs — the contention crossover the
    // constant-overhead Link model could never show.
    let cfg = CogCampaignConfig::default();
    let pooled = |ranks: usize, oversub: f64| {
        run_cog_scenario(Topology::Pooled, Policy::LatencyAware, ranks, 8, 0.0, 0.0, oversub, &cfg)
            .summary
    };
    let local = |ranks: usize| {
        run_cog_scenario(Topology::Local, Policy::LatencyAware, ranks, 8, 0.0, 0.0, 1.0, &cfg)
            .summary
    };

    // (1) monotone degradation along the whole swept axis
    for ranks in [4usize, 32] {
        let mut last = 0.0;
        for oversub in [1.0, 2.0, 4.0, 8.0] {
            let tts = pooled(ranks, oversub).time_to_solution_s;
            assert!(
                tts >= last - 1e-12,
                "ranks {ranks}: TTS {tts} at {oversub}:1 beats {last} at the previous factor"
            );
            last = tts;
        }
    }

    // (2) contention is the mechanism: the network share of the
    // critical path grows with oversubscription at 32 ranks
    let relaxed = pooled(32, 1.0);
    let starved = pooled(32, 8.0);
    assert!(starved.total_contention_s > relaxed.total_contention_s);
    assert!(starved.total_network_s > relaxed.total_network_s);

    // (3) the crossover: the pool's fast shared RDUs win the
    // low-rank regime outright, but at 32 ranks the shared fabric +
    // shared accelerators lose to per-rank local GPUs — and starving
    // the bisection to 8:1 only widens the gap
    assert!(
        pooled(4, 1.0).time_to_solution_s < local(4).time_to_solution_s,
        "4 ranks, non-blocking: pooled {} must beat local {}",
        pooled(4, 1.0).time_to_solution_s,
        local(4).time_to_solution_s
    );
    let local32 = local(32).time_to_solution_s;
    assert!(
        starved.time_to_solution_s > local32,
        "32 ranks at 8:1: pooled {} must fall behind local {local32}",
        starved.time_to_solution_s
    );

    // (4) the numbers, pinned (python/sim transliteration, ±2%):
    // pooled 4-rank 1:1 ≈ 20.70 ms beats local ≈ 21.64 ms; at 32
    // ranks the pool queues to ≈ 53.43 ms against the same ≈ 21.64 ms
    // local (per-rank GPUs don't care about rank count), and 8:1
    // multiplies the critical-path contention share ~10× over 1:1.
    let within = |x: f64, target: f64| (x / target - 1.0).abs() < 0.02;
    assert!(within(pooled(4, 1.0).time_to_solution_s, 20.70e-3));
    assert!(within(local(4).time_to_solution_s, 21.64e-3));
    assert!(within(local32, 21.64e-3));
    assert!(within(starved.time_to_solution_s, 53.43e-3));
    assert!(
        starved.total_contention_s > 8.0 * relaxed.total_contention_s,
        "8:1 contention {} vs 1:1 {}",
        starved.total_contention_s,
        relaxed.total_contention_s
    );
}

#[test]
fn summary_parses_and_covers_the_full_sweep() {
    let doc = json::parse(&campaign_json()).unwrap();
    let scenarios = doc.get("scenarios").unwrap().as_array().unwrap();
    assert_eq!(scenarios.len(), Topology::ALL.len() * Policy::ALL.len());
    for s in scenarios {
        for field in ["topology", "policy", "hydra", "mir", "backends"] {
            assert!(s.get(field).is_some(), "missing {field}");
        }
        assert!(s.get("hydra").unwrap().get("p99_us").unwrap().as_f64().unwrap() > 0.0);
    }
}

#[test]
fn latency_aware_beats_round_robin_on_hybrid_hydra_p99() {
    // The acceptance headline: with a heterogeneous pool, the only
    // policy that sees (queue + link + execute) must win the tail.
    let result = run_campaign(&CampaignConfig::default());
    let la = result.scenario(Topology::Hybrid, Policy::LatencyAware);
    let rr = result.scenario(Topology::Hybrid, Policy::RoundRobin);
    assert!(
        la.hydra.p99_s < rr.hydra.p99_s,
        "latency-aware p99 {:.1}us must beat round-robin {:.1}us",
        la.hydra.p99_s * 1e6,
        rr.hydra.p99_s * 1e6
    );
    // ... and in the fully pooled topology too
    let la_p = result.scenario(Topology::Pooled, Policy::LatencyAware);
    let rr_p = result.scenario(Topology::Pooled, Policy::RoundRobin);
    assert!(la_p.hydra.p99_s < rr_p.hydra.p99_s);
}

#[test]
fn pooled_topology_reproduces_fig15_16_remote_overhead_shape() {
    let cfg = CampaignConfig::default();
    let result = run_campaign(&cfg);

    // Fig. 15 shape, campaign level: the local topology pays no link
    // overhead; the pool pays the paper's ~10 µs-plus-payload
    // software path on every Hermit request.
    let local = result.scenario(Topology::Local, Policy::LatencyAware);
    let pooled = result.scenario(Topology::Pooled, Policy::LatencyAware);
    assert_eq!(local.hydra.mean_link_overhead_s, 0.0);
    assert_eq!(local.mir.mean_link_overhead_s, 0.0);
    let hermit_overhead = pooled.hydra.mean_link_overhead_s;
    assert!(
        (8e-6..=60e-6).contains(&hermit_overhead),
        "Hermit remote overhead {:.1}us outside the Fig. 15 band",
        hermit_overhead * 1e6
    );
    // overhead grows with payload (Fig. 15's slope): MIR's 2×2304-el
    // samples dwarf Hermit's 42+30
    assert!(pooled.mir.mean_link_overhead_s > 10.0 * hermit_overhead);

    // Link ablation (same pool hardware, link on/off) — the direct
    // Fig. 15/16 analogue: remote latency above local, remote
    // throughput below local.
    let remote = run_scenario_with_link(
        Topology::Pooled,
        Policy::LatencyAware,
        &cfg,
        &Link::infiniband_cx6(),
    );
    let local_link = run_scenario_with_link(
        Topology::Pooled,
        Policy::LatencyAware,
        &cfg,
        &Link::local(),
    );
    let gap = remote.hydra.p50_s - local_link.hydra.p50_s;
    assert!(gap > 0.0, "remote must add latency (Fig. 15)");
    assert!((5e-6..=0.2).contains(&gap), "remote-overhead gap {gap}s implausible");
    assert!(remote.mir.p99_s > local_link.mir.p99_s);
    assert!(
        remote.hydra.samples_per_s <= local_link.hydra.samples_per_s,
        "remote throughput must not exceed local (Fig. 16): {} vs {}",
        remote.hydra.samples_per_s,
        local_link.hydra.samples_per_s
    );

    // Hybrid pays the link only on the long tail: the hot MIR model
    // stays local and beats the fully pooled placement outright.
    let hybrid = result.scenario(Topology::Hybrid, Policy::LatencyAware);
    assert_eq!(hybrid.mir.mean_link_overhead_s, 0.0);
    assert!(hybrid.hydra.mean_link_overhead_s > 0.0);
    assert!(hybrid.mir.p50_s < pooled.mir.p50_s);
}
