//! The reproduction's contract: figure-level shape invariants.
//!
//! For every figure in the paper's evaluation (§V), assert the
//! *shape* the paper reports — who wins, by roughly what factor, and
//! where crossovers fall — on the regenerated series.  Absolute
//! numbers are covered by the per-module calibration tests; this file
//! is about the claims a reader takes away from each figure.

use cogsim_disagg::harness::{run_figure, Table};

fn table(fig: &str, idx: usize) -> Table {
    run_figure(fig).unwrap().tables.remove(idx)
}

fn series(t: &Table, name: &str) -> Vec<f64> {
    t.series(name).unwrap_or_else(|| panic!("missing series {name:?}")).to_vec()
}

/// Paper batch ladder indices: 0=1, 1=4, 2=16, 3=64, 4=256, 5=1K,
/// 6=2K, 7=4K, 8=8K, 9=16K, 10=32K.
const B1: usize = 0;
const B4: usize = 1;
const B256: usize = 4;
const B1K: usize = 5;
const B32K: usize = 10;

// ---------------------------------------------------------- Fig 4/5

#[test]
fn fig4_a100_lowest_latency_all_batches() {
    let t = table("fig4", 0);
    let (p, v, a) = (series(&t, "P100"), series(&t, "V100"), series(&t, "A100"));
    for i in 0..t.x.len() {
        assert!(a[i] <= p[i] && a[i] <= v[i], "batch index {i}");
    }
}

#[test]
fn fig4_v100_above_p100_small_batches_power9() {
    let t = table("fig4", 0);
    let (p, v) = (series(&t, "P100"), series(&t, "V100"));
    for i in B1..=3 {
        assert!(v[i] > p[i], "batch index {i}");
    }
    assert!(v[B32K] < p[B32K], "V100 must win once P100 saturates");
}

#[test]
fn fig4_p100_more_than_8x_a100_at_32k() {
    let t = table("fig4", 0);
    assert!(series(&t, "P100")[B32K] / series(&t, "A100")[B32K] > 8.0);
}

#[test]
fn fig5_v100_a100_exceed_5m_samples_per_s() {
    let t = table("fig5", 0);
    assert!(series(&t, "V100")[B32K] > 5e6);
    assert!(series(&t, "A100")[B32K] > 5e6);
    // paper anchors: 1,534 at batch 1 and 8.35M at 32K for the A100
    let a = series(&t, "A100");
    assert!((a[B1] / 1534.0 - 1.0).abs() < 0.10, "{}", a[B1]);
    assert!((a[B32K] / 8.35e6 - 1.0).abs() < 0.10, "{}", a[B32K]);
}

// ---------------------------------------------------------- Fig 6/7

#[test]
fn fig6_mi100_flat_below_1k_and_mi50_saturates() {
    let t = table("fig6", 0);
    let (mi50, mi100) = (series(&t, "MI50"), series(&t, "MI100"));
    assert!(mi100[B1K] / mi100[B1] < 1.5, "MI100 near-constant <=1K");
    assert!(mi50[B32K] / mi100[B32K] > 2.0, "MI50 saturates like the P100");
}

#[test]
fn fig7_a100_beats_mi100_throughput_everywhere() {
    let t = table("fig7", 1);
    let (a, m) = (series(&t, "A100"), series(&t, "MI100"));
    for i in 0..t.x.len() {
        assert!(a[i] > m[i], "batch index {i}");
    }
    // TDP normalisation (250 vs 290 W) helps the MI100 but must not
    // flip the verdict at the largest batch (8.35M vs 5.85M raw).
    let norm = series(&t, "MI100_tdp_norm");
    assert!(norm[B32K] < a[B32K]);
    assert!(norm[B32K] > m[B32K] * 0.8);
}

#[test]
fn fig7_single_sample_latencies_anchor() {
    // "measured single sample latencies of 0.65ms and 0.96ms"
    let t = table("fig7", 0);
    assert!((series(&t, "A100")[B1] / 0.65 - 1.0).abs() < 0.10);
    assert!((series(&t, "MI100")[B1] / 0.96 - 1.0).abs() < 0.10);
}

// --------------------------------------------------------- Fig 8/9/10

#[test]
fn fig8_every_optimized_config_2x_naive_at_batch_1() {
    let t = table("fig8", 0);
    let naive = series(&t, "PyTorch (naive)");
    for name in [
        "PyTorch+TensorRT",
        "PyTorch+CUDA Graphs",
        "PyTorch+TRT+CUDA Graphs",
        "C++ TensorRT",
    ] {
        assert!(naive[B1] / series(&t, name)[B1] > 2.0, "{name}");
    }
}

#[test]
fn fig8_trt_graphs_lowest_latency_everywhere() {
    let t = table("fig8", 0);
    let best = series(&t, "PyTorch+TRT+CUDA Graphs");
    for (name, ys) in &t.series {
        for i in 0..t.x.len() {
            assert!(best[i] <= ys[i] * 1.001, "{name} at index {i}");
        }
    }
    // anchors: 0.12 ms @1, 1.52 ms @32K
    assert!((best[B1] / 0.12 - 1.0).abs() < 0.15, "{}", best[B1]);
    assert!((best[B32K] / 1.52 - 1.0).abs() < 0.10, "{}", best[B32K]);
}

#[test]
fn fig9_trt_configs_converge_at_32k() {
    let t = table("fig9", 0);
    let trt = series(&t, "PyTorch+TensorRT")[B32K];
    let tg = series(&t, "PyTorch+TRT+CUDA Graphs")[B32K];
    let cpp = series(&t, "C++ TensorRT")[B32K];
    let hi = trt.max(tg).max(cpp);
    let lo = trt.min(tg).min(cpp);
    assert!(hi / lo < 1.10);
    // anchor: 21.6M samples/s for TRT+Graphs
    assert!((tg / 21.6e6 - 1.0).abs() < 0.10, "{tg}");
}

#[test]
fn fig10_trt_worse_than_naive_beyond_64_for_mir() {
    let t = table("fig10", 0);
    let naive = series(&t, "PyTorch (naive)");
    let trt = series(&t, "PyTorch+TensorRT");
    let graphs = series(&t, "PyTorch+CUDA Graphs");
    for i in B256..=B32K {
        assert!(trt[i] < naive[i], "torch2trt layernorm penalty at index {i}");
        assert!(graphs[i] >= naive[i] * 0.99, "CUDA Graphs best at index {i}");
    }
    // configurations converge at the largest mini-batch (naive vs graphs)
    assert!(graphs[B32K] / naive[B32K] < 1.05);
}

// -------------------------------------------------------- Fig 11-14

#[test]
fn fig11_12_micro_batch_landscape() {
    for (fig, tiles_spread) in [("fig11", 3.0), ("fig12", 6.0)] {
        let t = table(fig, 0);
        // invalid cells masked
        assert!(series(&t, "mini_1")[1].is_nan(), "{fig}: micro 4 > mini 1");
        // at mini 32K the micro choice matters a lot
        let col = series(&t, "mini_32768");
        let valid: Vec<f64> = col.iter().copied().filter(|v| !v.is_nan()).collect();
        let spread = valid.iter().cloned().fold(0.0f64, f64::max)
            / valid.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(spread > tiles_spread, "{fig}: spread {spread}");
        // at mini 16 it barely matters ("benign effects")
        let col = series(&t, "mini_16");
        let valid: Vec<f64> = col.iter().copied().filter(|v| !v.is_nan()).collect();
        let spread = valid.iter().cloned().fold(0.0f64, f64::max)
            / valid.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(spread < 2.0, "{fig}: small-mini spread {spread}");
    }
}

#[test]
fn fig13_cpp_best_except_two_largest() {
    let t = table("fig13", 0);
    let py = series(&t, "Python (optimized)");
    let cpp = series(&t, "C++ (optimized)");
    for i in 0..=8 {
        assert!(cpp[i] < py[i], "C++ wins at index {i}");
    }
    for i in 9..=10 {
        assert!(py[i] < cpp[i], "Python edges out C++ at index {i}");
    }
    // minimum latency anchor: 0.04 ms
    assert!((0.03..=0.055).contains(&cpp[B1]), "{}", cpp[B1]);
    // preferred MB strictly helps somewhere
    let pref = series(&t, "C++ (optimized, preferred MB)");
    assert!((0..t.x.len()).any(|i| pref[i] < cpp[i]));
}

#[test]
fn fig14_local_throughput_anchor() {
    let t = table("fig14", 0);
    let cpp = series(&t, "C++ (optimized)");
    // 8.14M samples/s at 16K
    assert!((cpp[9] / 8.14e6 - 1.0).abs() < 0.15, "{}", cpp[9]);
    // naive python is the slowest configuration throughout
    let naive = series(&t, "Python (naive)");
    for i in 0..t.x.len() {
        assert!(naive[i] <= cpp[i].max(series(&t, "Python (optimized)")[i]), "{i}");
    }
}

// -------------------------------------------------------- Fig 15/16

#[test]
fn fig15_remote_between_local_python_and_cpp_at_small_batch() {
    let t = table("fig15", 0);
    let py = series(&t, "local Python");
    let cpp = series(&t, "local C++");
    let remote = series(&t, "remote C++");
    for i in [B1, B4, 2] {
        assert!(remote[i] > cpp[i], "remote adds overhead at {i}");
        assert!(remote[i] < py[i], "remote C++ beats local Python at {i}");
    }
    // anchor: remote four-sample latency ~0.05 ms
    assert!((0.04..=0.065).contains(&remote[B4]), "{}", remote[B4]);
    // anchor: ~1.14 ms added at 16K
    let added = remote[9] - cpp[9];
    assert!((added / 1.14 - 1.0).abs() < 0.2, "{added}");
}

#[test]
fn fig16_remote_throughput_anchor() {
    let t = table("fig16", 0);
    let remote = series(&t, "remote C++");
    let cpp = series(&t, "local C++");
    // 6.4M samples/s at 16K remote; local exceeds remote beyond 1K
    assert!((remote[9] / 6.4e6 - 1.0).abs() < 0.15, "{}", remote[9]);
    for i in 6..=B32K {
        assert!(cpp[i] > remote[i], "local > remote at index {i}");
    }
}

// -------------------------------------------------------- Fig 17-19

#[test]
fn fig17_crossovers() {
    let t = table("fig17", 0);
    let a_best = series(&t, "A100 TRT+Graphs");
    let rdu_local = series(&t, "RDU local C++");
    let rdu_remote = series(&t, "RDU remote C++");
    // "at mini-batch sizes below 1K, the node-local RDU provides a
    // lower latency than the A100"
    for i in B1..=B1K {
        assert!(rdu_local[i] < a_best[i], "index {i}");
    }
    // "at mini-batch sizes in the range [4, 256] the measured latency
    // of the remote inference … is lower than the … A100"
    for i in B4..=B256 {
        assert!(rdu_remote[i] < a_best[i], "index {i}");
    }
    // "as the mini-batch size increases above 256, the node-local
    // performance of the A100 exceeds first remote and then
    // node-local performance of the DataScale"
    assert!(a_best[B32K] < rdu_remote[B32K]);
    assert!(a_best[B32K] < rdu_local[B32K]);
    let remote_cross = (0..11).find(|&i| a_best[i] < rdu_remote[i]).unwrap();
    let local_cross = (0..11).find(|&i| a_best[i] < rdu_local[i]).unwrap();
    assert!(remote_cross <= local_cross, "remote crosses first");
}

#[test]
fn fig18_throughput_crossover_around_1k() {
    let t = table("fig18", 0);
    let a_best = series(&t, "A100 TRT+Graphs");
    let rdu_local = series(&t, "RDU local C++");
    // below 1K the DataScale has the largest throughput
    for i in B1..=B1K {
        assert!(rdu_local[i] > a_best[i], "index {i}");
    }
    // above it the A100 takes over by 32K
    assert!(a_best[B32K] > rdu_local[B32K]);
}

#[test]
fn fig19_headline_speedups() {
    let t = table("fig19", 0);
    let naive = series(&t, "naive vs naive");
    let opt = series(&t, "optimized local vs optimized local");
    let cogsim = series(&t, "remote RDU vs optimized A100 (CogSim)");
    let trans = series(&t, "remote RDU vs optimized A100, transistor-normalised");
    // "more than 7X speedup" for the naive pair at the smallest batch
    assert!(naive[B1] > 7.0, "{}", naive[B1]);
    // optimized pair still favours the RDU >3x at batch 1
    assert!(opt[B1] > 3.0, "{}", opt[B1]);
    // "remote inference DataScale … more than 3X … for the smallest
    // mini-batch sizes" (throughput ratio incl. transistor-normalised)
    assert!(cogsim[B1] > 2.7, "{}", cogsim[B1]);
    assert!(trans[B1] > 3.0, "{}", trans[B1]);
    // "As the mini-batch sizes increase above 1K, the DataScale
    // System lags behind the A100."
    assert!(cogsim[B32K] < 1.0 && opt[B32K] < 1.0 && naive[B32K] < 1.0);
    // transistor normalisation = 1.3x
    for i in 0..11 {
        assert!((trans[i] / cogsim[i] - 54.2 / 41.7).abs() < 1e-9);
    }
}

// ------------------------------------------------------------ Fig 20

#[test]
fn fig20_mir_targets() {
    let t = table("fig20", 0);
    let rdu = series(&t, "RDU local C++");
    let a100 = series(&t, "A100 CUDA Graphs");
    let target = 100_000.0;
    // "The DataScale system reaches the target throughput bandwidth
    // at a mini-batch size of 128 while the A100 reaches it at 256"
    // (ladder powers of 4: assert RDU crosses strictly earlier).
    let rdu_cross = (0..11).find(|&i| rdu[i] >= target).expect("RDU hits target");
    let a100_cross = (0..11).find(|&i| a100[i] >= target).expect("A100 hits target");
    assert!(rdu_cross <= a100_cross, "rdu {rdu_cross} vs a100 {a100_cross}");
    // "the DataScale system reaches a maximum throughput of over 140K
    // while the A100 struggles to achieve … much larger than 100K"
    assert!(rdu[8] > 140_000.0, "{}", rdu[8]);
    let a100_max = a100.iter().cloned().fold(0.0f64, f64::max);
    assert!(a100_max < 130_000.0, "{a100_max}");
    assert!(a100_max > 100_000.0, "{a100_max}");
    // contrast with Hermit: here the RDU advantage is at LARGE batch
    assert!(rdu[8] > a100[8]);
}
