//! Differential properties of the ladder event queue.
//!
//! The ladder backing is a performance structure only: for any push
//! stream, its pop stream must be identical — same times, same
//! payloads, in the same order — to the reference `BinaryHeap` kept
//! behind `EventQueue::binary_heap()`.  The adversarial streams here
//! lean on every rule of the `(time, class, seq)` key: timestamp ties
//! across all three same-instant classes, ns-quantised deadline grids
//! that collide exactly, and randomized seeded pushes interleaved
//! with pops so the ladder's bottom/top routing, band refills, and
//! free-list reuse all get exercised against the heap.
//!
//! The grid test then pins the end-to-end consequence: with the
//! ladder on the hot path of every engine, the campaign JSON is
//! byte-identical for every workload kind at any `--threads` count.

use cogsim_disagg::cluster::Policy;
use cogsim_disagg::eventsim::equeue::{
    EventQueue, CLASS_ARRIVAL, CLASS_COMPLETION, CLASS_DEADLINE,
};
use cogsim_disagg::harness::{run_grid_threads, Axes, Fleet, Grid, Kind, Knobs, Topology};
use cogsim_disagg::util::json;
use cogsim_disagg::util::rng::Rng;

const CLASSES: [u8; 3] = [CLASS_COMPLETION, CLASS_ARRIVAL, CLASS_DEADLINE];

/// Drain both queues in lockstep; every pop must agree exactly
/// (`total_cmp` keys mean the times are bitwise-equal, so plain
/// tuple equality is the right check).
fn drain_lockstep(lad: &mut EventQueue<u64>, heap: &mut EventQueue<u64>, label: &str) {
    loop {
        let a = lad.pop();
        let b = heap.pop();
        assert_eq!(a, b, "{label}: ladder and heap pop streams diverged");
        if a.is_none() {
            return;
        }
    }
}

#[test]
fn same_instant_ties_across_all_classes_pop_identically() {
    // A barrier burst: many events share a handful of instants, with
    // classes pushed in adversarial (reversed and shuffled) order.
    // The ladder settles ties by sorting whole instants; the heap by
    // sift order — both must degrade to the same (time, class, seq)
    // total order.
    let mut lad = EventQueue::new();
    let mut heap = EventQueue::binary_heap();
    let mut payload = 0u64;
    for &t in &[0.0, 1e-9, 2.5e-3, 2.5e-3, 0.045] {
        for &class in &[CLASS_DEADLINE, CLASS_COMPLETION, CLASS_ARRIVAL, CLASS_COMPLETION] {
            for _ in 0..7 {
                lad.push_class(t, class, payload);
                heap.push_class(t, class, payload);
                payload += 1;
            }
        }
    }
    drain_lockstep(&mut lad, &mut heap, "same-instant burst");
}

#[test]
fn ns_quantised_deadline_grids_collide_identically() {
    // Batch-close deadlines quantised to a 1 ns grid collide exactly
    // with completions and arrivals quantised the same way; the pop
    // order within each colliding nanosecond is class-then-seq.
    let mut lad = EventQueue::new();
    let mut heap = EventQueue::binary_heap();
    let mut rng = Rng::new(0xde_ad11);
    for i in 0..600u64 {
        let ns = rng.below(50) as f64;
        let t = ns * 1e-9;
        let class = CLASSES[rng.below(3)];
        lad.push_class(t, class, i);
        heap.push_class(t, class, i);
        // deadline exactly on the grid point of a future nanosecond
        let d = (ns + rng.below(5) as f64) * 1e-9;
        lad.push_class(d, CLASS_DEADLINE, 1_000_000 + i);
        heap.push_class(d, CLASS_DEADLINE, 1_000_000 + i);
    }
    drain_lockstep(&mut lad, &mut heap, "ns-quantised deadlines");
}

#[test]
fn randomized_seeded_streams_with_interleaved_pops_match() {
    // Push/pop interleavings drive the ladder through every regime:
    // in-band sorted inserts, top spills, multi-band refills, and
    // drain-then-refill cycles on the spare free-list.  Times are a
    // mix of uniform spread, quantised collisions, and same-instant
    // re-pushes at the last popped time (an effect scheduling more
    // work "now", the common engine pattern).
    for seed in [1u64, 0xbeef, 0xfab5_1c3e, 42_4242] {
        let mut lad = EventQueue::new();
        let mut heap = EventQueue::binary_heap();
        let mut rng = Rng::new(seed);
        let mut now = 0.0f64;
        let mut payload = 0u64;
        for _ in 0..2_000 {
            match rng.below(4) {
                // spread push
                0 | 1 => {
                    let t = now + rng.uniform(0.0, 1e-3);
                    let class = CLASSES[rng.below(3)];
                    lad.push_class(t, class, payload);
                    heap.push_class(t, class, payload);
                    payload += 1;
                }
                // quantised push (forced ties)
                2 => {
                    let t = now + rng.below(8) as f64 * 1e-6;
                    let class = CLASSES[rng.below(3)];
                    lad.push_class(t, class, payload);
                    heap.push_class(t, class, payload);
                    payload += 1;
                }
                // pop, then schedule a same-instant follow-up
                _ => {
                    let a = lad.pop();
                    let b = heap.pop();
                    assert_eq!(a, b, "seed {seed:#x}: interleaved pop diverged");
                    if let Some((t, _)) = a {
                        now = t;
                        if rng.below(2) == 0 {
                            lad.push_class(now, CLASS_COMPLETION, payload);
                            heap.push_class(now, CLASS_COMPLETION, payload);
                            payload += 1;
                        }
                    }
                }
            }
            assert_eq!(lad.len(), heap.len(), "seed {seed:#x}: lengths diverged");
            assert_eq!(
                lad.peek_time(),
                heap.peek_time(),
                "seed {seed:#x}: peek_time diverged"
            );
        }
        drain_lockstep(&mut lad, &mut heap, "randomized stream tail");
    }
}

/// One grid covering every engine kind on a mixed fleet behind a
/// pooled fabric — the same shape the default campaign sweeps, with
/// the ladder queue on every hot path.
fn every_kind_grid() -> Grid {
    Grid {
        axes: Axes {
            kinds: Kind::ALL.to_vec(),
            topologies: vec![Topology::Pooled],
            fleets: vec![Fleet::Mixed { gpus: 2, rdus: 1 }],
            policies: vec![Policy::LatencyAware],
            rank_counts: vec![4, 8],
            fabric_oversubs: vec![1.0],
            ..Axes::default()
        },
        knobs: Knobs { timesteps: 3, horizon_s: 0.05, ..Knobs::default() },
    }
}

#[test]
fn full_grid_byte_identity_across_thread_counts() {
    // --threads is a performance knob, never a results knob: the
    // campaign JSON for all workload kinds must be byte-identical at
    // 1, 2, 8, and 0 (all cores) workers with the ladder queue in
    // every engine.
    let grid = every_kind_grid();
    let reference = json::write(&run_grid_threads(&grid, 1).to_json());
    for threads in [2, 8, 0] {
        let candidate = json::write(&run_grid_threads(&grid, threads).to_json());
        assert_eq!(
            reference, candidate,
            "--threads {threads} changed the campaign JSON"
        );
    }
}
