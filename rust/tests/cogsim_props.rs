//! Property tests for the coupled CogSim engine: the invariants that
//! must hold for every policy, fleet, and knob setting — timestep
//! conservation, time-to-solution monotonicity in swap cost and rank
//! count, overlap dominance, critical-path decomposition exactness,
//! and bit-identical campaign JSON.

use cogsim_disagg::cluster::{Backend, GpuBackend, Policy, RduBackend};
use cogsim_disagg::devices::{Api, Gpu};
use cogsim_disagg::eventsim::{Batching, CogSim, CogSimConfig};
use cogsim_disagg::harness::{run_cog_campaign, CogCampaignConfig};
use cogsim_disagg::rdu::RduApi;
use cogsim_disagg::util::json;

fn pool() -> Vec<Box<dyn Backend>> {
    vec![
        Box::new(RduBackend::disaggregated("rdu/pool0", 4, RduApi::CppOptimized)),
        Box::new(RduBackend::disaggregated("rdu/pool1", 2, RduApi::Python)),
    ]
}

fn mixed_fleet() -> Vec<Box<dyn Backend>> {
    vec![
        Box::new(GpuBackend::node_local("gpu/rank0", Gpu::a100(), Api::TrtCudaGraphs)),
        Box::new(GpuBackend::node_local("gpu/rank1", Gpu::a100(), Api::NaivePyTorch)),
        Box::new(RduBackend::disaggregated("rdu/pool0", 4, RduApi::CppOptimized)),
        Box::new(RduBackend::disaggregated("rdu/pool1", 2, RduApi::Python)),
    ]
}

fn run(policy: Policy, cfg: CogSimConfig) -> CogSim {
    let mut sim = CogSim::new(pool(), policy, cfg);
    sim.run_to_completion();
    sim
}

#[test]
fn timestep_conservation_for_every_policy_and_batching() {
    // Every rank completes exactly T steps; completed requests are
    // N·T·K (plus the MIR cadence) at the final barrier; nothing is
    // left in flight or in the batching window.
    const N: usize = 8;
    const T: usize = 5;
    const K: usize = 6;
    for policy in Policy::ALL {
        for batching in
            [Batching::Off, Batching::Window { window_s: 100e-6, max_batch: 64 }]
        {
            for mir_every in [0usize, 2] {
                let cfg = CogSimConfig {
                    ranks: N,
                    timesteps: T,
                    requests_per_step: K,
                    mir_every,
                    mir_samples: 64,
                    swap_s: 50e-6,
                    batching,
                    ..Default::default()
                };
                let mut sim = CogSim::new(mixed_fleet(), policy, cfg);
                sim.run_to_completion();
                // MIR fires on steps 0, 2, 4 when mir_every = 2
                let mir = if mir_every > 0 { N * T.div_ceil(mir_every) } else { 0 };
                let expect = (N * T * K + mir) as u64;
                assert_eq!(sim.submitted(), expect, "{policy:?}/{batching:?}/{mir_every}");
                assert_eq!(sim.completed(), sim.submitted());
                assert_eq!(sim.in_flight(), 0);
                assert_eq!(sim.batcher_pending(), 0);
                assert_eq!(sim.records().len() as u64, sim.submitted());
                assert_eq!(sim.steps().len(), T);
                // every (rank, step) pair produced its K requests
                for rank in 0..N {
                    for step in 0..T {
                        let n = sim
                            .records()
                            .iter()
                            .filter(|r| r.rank == rank && r.step == step)
                            .count();
                        let mir_here =
                            if mir_every > 0 && step % mir_every == 0 { 1 } else { 0 };
                        assert_eq!(n, K + mir_here, "rank {rank} step {step}");
                    }
                }
            }
        }
    }
}

#[test]
fn breakdown_components_sum_to_step_duration() {
    for policy in Policy::ALL {
        for (swap_s, overlap, jitter) in
            [(0.0, 0.0, 0.0), (200e-6, 0.0, 0.0), (100e-6, 0.5, 0.3e-3), (1e-3, 1.0, 0.0)]
        {
            let cfg = CogSimConfig {
                ranks: 6,
                timesteps: 6,
                swap_s,
                overlap,
                compute_jitter_s: jitter,
                ..Default::default()
            };
            let mut sim = CogSim::new(mixed_fleet(), policy, cfg);
            sim.run_to_completion();
            for s in sim.steps() {
                assert!(
                    (s.components_sum_s() - s.duration_s()).abs() < 1e-9,
                    "{policy:?} swap {swap_s} overlap {overlap} step {}: {} vs {}",
                    s.step,
                    s.components_sum_s(),
                    s.duration_s()
                );
                assert!(s.spread_s >= -1e-12);
                assert!(s.duration_s() > 0.0);
            }
        }
    }
}

#[test]
fn time_to_solution_monotone_in_swap_cost() {
    // Round-robin routing is oblivious to queue state, so the request
    // → backend mapping is identical across swap costs and a higher
    // swap charge can only slow the run down.  (State-aware policies
    // may legitimately reroute around expensive swaps.)
    let tts = |swap_s: f64| {
        let cfg = CogSimConfig { ranks: 6, timesteps: 6, swap_s, ..Default::default() };
        run(Policy::RoundRobin, cfg).time_to_solution_s()
    };
    let costs = [0.0, 20e-6, 200e-6, 2e-3];
    let times: Vec<f64> = costs.iter().map(|&c| tts(c)).collect();
    for w in times.windows(2) {
        assert!(w[1] >= w[0] - 1e-12, "TTS not monotone in swap cost: {times:?}");
    }
    assert!(times[costs.len() - 1] > times[0], "expensive swaps must actually bite");
}

#[test]
fn time_to_solution_monotone_in_rank_count() {
    // A fixed shared fleet: more ranks emit strictly more work per
    // timestep, and per-rank request streams are rank-count
    // independent (the first N ranks' draws are a prefix), so TTS can
    // only grow.
    for policy in [Policy::RoundRobin, Policy::LeastOutstanding, Policy::LatencyAware] {
        let tts = |ranks: usize| {
            let cfg = CogSimConfig { ranks, timesteps: 5, ..Default::default() };
            run(policy, cfg).time_to_solution_s()
        };
        let counts = [1usize, 2, 4, 8, 16];
        let times: Vec<f64> = counts.iter().map(|&n| tts(n)).collect();
        for w in times.windows(2) {
            assert!(
                w[1] >= w[0] - 1e-12,
                "{policy:?}: TTS not monotone in ranks: {times:?}"
            );
        }
        assert!(times[counts.len() - 1] > times[0], "{policy:?}: load must bite");
    }
}

#[test]
fn full_overlap_never_slower_than_no_overlap() {
    // With identical per-rank compute (no jitter) the emission
    // pattern under overlap f is the no-overlap pattern shifted
    // earlier by f·compute, queues start every step drained, and the
    // per-step duration is max(compute, (1-f)·compute + span) —
    // monotone in f.  Overlap 1.0 therefore dominates overlap 0.0 for
    // every policy and swap cost.
    for policy in Policy::ALL {
        for swap_s in [0.0, 500e-6] {
            let tts = |overlap: f64| {
                let cfg = CogSimConfig {
                    ranks: 6,
                    timesteps: 6,
                    overlap,
                    swap_s,
                    ..Default::default()
                };
                run(policy, cfg).time_to_solution_s()
            };
            let serial = tts(0.0);
            let half = tts(0.5);
            let full = tts(1.0);
            assert!(
                full <= serial + 1e-9,
                "{policy:?}/swap {swap_s}: overlap 1.0 ({full}) slower than 0.0 ({serial})"
            );
            assert!(
                half <= serial + 1e-9,
                "{policy:?}/swap {swap_s}: overlap 0.5 ({half}) slower than 0.0 ({serial})"
            );
            assert!(full <= half + 1e-9);
        }
    }
}

#[test]
fn identical_seeds_give_byte_identical_campaign_json() {
    let cfg = CogCampaignConfig {
        policies: vec![Policy::RoundRobin, Policy::ModelAffinity],
        timesteps: 4,
        ..Default::default()
    };
    let a = json::write(&run_cog_campaign(&cfg).to_json());
    let b = json::write(&run_cog_campaign(&cfg).to_json());
    assert_eq!(a, b, "same seed must serialise identically");

    let different = CogCampaignConfig { seed: 43, ..cfg };
    let c = json::write(&run_cog_campaign(&different).to_json());
    assert_ne!(a, c, "a different seed must change the summary");
}

#[test]
fn straggler_accounting_is_consistent() {
    let cfg = CogSimConfig {
        ranks: 8,
        timesteps: 10,
        compute_jitter_s: 0.5e-3,
        ..Default::default()
    };
    let mut sim = CogSim::new(pool(), Policy::LeastOutstanding, cfg);
    sim.run_to_completion();
    let s = sim.summary();
    assert_eq!(s.straggler_counts.len(), 8);
    assert_eq!(s.straggler_counts.iter().sum::<u64>(), 10, "one straggler per step");
    assert!(s.max_spread_s > 0.0, "jittered ranks cannot all finish together");
    for step in &s.steps {
        assert!(step.spread_s <= s.max_spread_s + 1e-15);
    }
}
