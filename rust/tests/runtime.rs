//! Integration tests: the Rust runtime executes the real AOT
//! artifacts and reproduces the Python oracle's numerics.
//!
//! Requires `make artifacts` (skipped silently otherwise).

use cogsim_disagg::runtime::Engine;
use xla::FromRawBytes as _;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

#[test]
fn engine_loads_and_executes_hermit() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::load(&dir, Some(&["hermit"])).unwrap();
    let spec = engine.spec("hermit").unwrap();
    assert_eq!(spec.input_elems(), 42);
    assert_eq!(spec.output_elems(), 30);

    let x = vec![0.1f32; 42];
    let (out, t) = engine.execute("hermit", 1, &x).unwrap();
    assert_eq!(out.len(), 30);
    assert!(out.iter().all(|v| v.is_finite()));
    assert!(t.execute.as_nanos() > 0);

    // determinism
    let (out2, _) = engine.execute("hermit", 1, &x).unwrap();
    assert_eq!(out, out2);
}

#[test]
fn engine_batch_consistency() {
    // The same sample must produce the same output regardless of the
    // compiled batch size it rides in (padding must not leak).
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::load(&dir, Some(&["hermit"])).unwrap();
    let x: Vec<f32> = (0..42).map(|i| (i as f32) * 0.01 - 0.2).collect();

    let (out1, _) = engine.execute("hermit", 1, &x).unwrap();
    let mut x4 = vec![0f32; 4 * 42];
    x4[..42].copy_from_slice(&x);
    let (out4, _) = engine.execute("hermit", 4, &x4).unwrap();
    for i in 0..30 {
        assert!((out1[i] - out4[i]).abs() < 1e-4, "i={i} {} vs {}", out1[i], out4[i]);
    }
}

#[test]
fn execute_padded_roundtrip() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::load(&dir, Some(&["hermit"])).unwrap();
    // 3 samples -> padded into the 4-batch executable
    let x: Vec<f32> = (0..3 * 42).map(|i| (i % 17) as f32 * 0.05).collect();
    let (out, _) = engine.execute_padded("hermit", &x).unwrap();
    assert_eq!(out.len(), 3 * 30);

    // each row matches its batch-1 execution
    for s in 0..3 {
        let (row, _) = engine.execute("hermit", 1, &x[s * 42..(s + 1) * 42]).unwrap();
        for i in 0..30 {
            assert!((row[i] - out[s * 30 + i]).abs() < 1e-4);
        }
    }
}

#[test]
fn padding_waste_accounting() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::load(&dir, Some(&["hermit"])).unwrap();
    assert_eq!(engine.padding_waste("hermit", 1).unwrap(), 0.0);
    assert_eq!(engine.padding_waste("hermit", 4).unwrap(), 0.0);
    let w3 = engine.padding_waste("hermit", 3).unwrap();
    assert!((w3 - 0.25).abs() < 1e-12, "3 of 4 -> 25% waste, got {w3}");
}

#[test]
fn mir_executes_and_is_volume_fraction() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::load(&dir, Some(&["mir"])).unwrap();
    let spec = engine.spec("mir").unwrap();
    assert_eq!(spec.input_elems(), 48 * 48);
    let x = vec![0.5f32; 48 * 48];
    let (out, _) = engine.execute("mir", 1, &x).unwrap();
    assert_eq!(out.len(), 48 * 48);
    // sigmoid output: volume fractions
    assert!(out.iter().all(|&v| (0.0..=1.0).contains(&v)));
}

#[test]
fn wrong_input_sizes_are_rejected() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::load(&dir, Some(&["hermit"])).unwrap();
    assert!(engine.execute("hermit", 1, &[0.0; 10]).is_err());
    assert!(engine.execute("hermit", 3, &[0.0; 3 * 42]).is_err()); // 3 not in ladder
    assert!(engine.execute("nope", 1, &[0.0; 42]).is_err());
}

#[test]
fn cross_language_numerics_golden() {
    // The authoritative three-layer check: Python's Pallas forward
    // (saved at AOT time) must match Rust's PJRT execution bit-for-bit
    // modulo f32 reassociation (1e-5).
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::load(&dir, None).unwrap();
    for model in ["hermit", "mir", "mir_noln"] {
        let check = xla::Literal::read_npz_by_name(
            dir.join(format!("{model}.selfcheck.npz")),
            &(),
            &["x", "y"],
        )
        .unwrap();
        let x: Vec<f32> = check[0].to_vec().unwrap();
        let y: Vec<f32> = check[1].to_vec().unwrap();
        let spec = engine.spec(model).unwrap();
        let batch = x.len() / spec.input_elems();
        let (out, _) = engine.execute(model, batch, &x).unwrap();
        assert_eq!(out.len(), y.len(), "{model}");
        let max_err = out
            .iter()
            .zip(&y)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(max_err < 1e-4, "{model}: max |rust - python| = {max_err}");
    }
}
