//! Properties of the declarative scenario grid's new heterogeneous
//! **fleet axis** — mixed GPU+RDU pools swept through all three
//! workload kinds from one config — plus the pinned hybrid-pool
//! headline.
//!
//! Every numeric assertion below (the ±2 % pinned TTS values, the
//! affinity swap counts, the conservation volumes) was computed
//! out-of-band with the `python/sim` transliteration of the whole
//! pipeline, the same code that generates the committed goldens
//! byte-exactly.

use std::collections::{BTreeMap, BTreeSet};

use cogsim_disagg::cluster::Policy;
use cogsim_disagg::eventsim::{ArrivalProcess, Batching, CogSim, CogSimConfig, EventSim,
                              EventSimConfig};
use cogsim_disagg::harness::{
    build_fabric_spec, build_fleet, run_cell, run_grid, Axes, CellSummary, Fleet, Grid, Kind,
    Knobs, Scenario, Topology,
};
use cogsim_disagg::netsim::Link;

const MIXED: Fleet = Fleet::Mixed { gpus: 4, rdus: 2 };

/// One cog cell on the pooled topology (the fleet-axis workhorse).
fn cog_cell(fleet: Fleet, policy: Policy, ranks: usize, swap_s: f64, oversub: f64) -> Scenario {
    Scenario {
        kind: Kind::Cog,
        topology: Topology::Pooled,
        fleet,
        policy,
        ranks,
        arrival: ArrivalProcess::Synchronized { period_s: 0.02, jitter_s: 0.0 },
        window_us: 0.0,
        models: 8,
        swap_s,
        overlap: 0.0,
        oversub,
    }
}

fn cog_tts(fleet: Fleet, ranks: usize) -> f64 {
    let cell = cog_cell(fleet, Policy::LatencyAware, ranks, 0.0, 1.0);
    match run_cell(&cell, &Knobs::default()).summary {
        CellSummary::Cog(s) => s.time_to_solution_s,
        _ => unreachable!(),
    }
}

#[test]
fn one_config_runs_the_mixed_fleet_in_all_three_kinds_and_conserves() {
    // One declarative grid, the 4xGPU+2xRDU pool, three engines.
    // Volumes (python/sim): analytic routes every submitted sample;
    // event sees 11 bursts x 8 ranks x 6 = 528 requests; cog sees
    // 8 ranks x 8 steps x 6 = 384 — all completed, nothing dropped.
    let grid = Grid {
        axes: Axes {
            kinds: Kind::ALL.to_vec(),
            topologies: vec![Topology::Pooled],
            fleets: vec![MIXED],
            policies: vec![Policy::LeastOutstanding],
            rank_counts: vec![8],
            fabric_oversubs: vec![2.0],
            ..Axes::default()
        },
        knobs: Knobs::default(),
    };
    let result = run_grid(&grid);
    assert_eq!(result.cells.len(), 3, "one cell per kind");

    let analytic = result.cells[0].analytic().expect("kind order: analytic first");
    assert_eq!(analytic.backends.len(), 6, "4 GPUs + 2 RDUs");
    let routed: u64 = analytic.backends.iter().map(|b| b.samples).sum();
    assert_eq!(routed, analytic.hydra.samples + analytic.mir.samples, "sample conservation");
    assert!(analytic.hydra.mean_link_overhead_s > 0.0, "mixed pool is remote");

    let event = result.cells[1].event().expect("kind order: event second");
    assert_eq!(event.requests, 11 * 8 * 6, "11 bursts x 8 ranks x 6 requests");
    assert!(event.mean_link_overhead_s > 0.0);

    let cog = result.cells[2].cog().expect("kind order: cog third");
    assert_eq!(cog.requests, 8 * 8 * 6, "8 ranks x 8 steps x 6 requests");
    assert_eq!(cog.timesteps, 8);
    assert!(cog.total_network_s > 0.0, "mixed pool rides the fabric");
}

#[test]
fn mixed_fleet_event_run_conserves_and_exercises_every_member() {
    // Drive the event engine directly on the mixed pool so we can see
    // per-record routing: every request completes and every pool
    // member — GPU and RDU alike — serves traffic under
    // least-outstanding (python/sim: backend request counts
    // {0:66, 1:66, 2:55, 3:55, 4:198, 5:88}).
    let (backends, tier) = build_fleet(Topology::Pooled, 8, MIXED, &Link::infiniband_cx6());
    assert_eq!(backends.len(), 6);
    let spec = build_fabric_spec(Topology::Pooled, 8, MIXED, 2.0).expect("pooled has a fabric");
    let cfg = EventSimConfig { ranks: 8, ..Default::default() };
    let mut sim = EventSim::with_fabric(
        backends,
        Policy::LeastOutstanding,
        cfg,
        tier.hermit,
        tier.mir,
        spec,
    );
    sim.run_to_completion();
    assert_eq!(sim.submitted(), 528);
    assert_eq!(sim.completed(), sim.submitted());
    assert_eq!(sim.in_flight(), 0);
    let mut per_backend = vec![0u64; 6];
    for r in sim.records() {
        per_backend[r.backend] += 1;
        assert!(r.complete_s.is_finite());
        assert!(r.link_overhead_s > 0.0, "every pool member is remote");
    }
    assert!(per_backend.iter().all(|&n| n > 0), "idle pool member: {per_backend:?}");
    assert_eq!(per_backend.iter().sum::<u64>(), 528);
}

#[test]
fn affinity_routing_bounds_distinct_models_per_backend() {
    // The residency property on the mixed fleet: under sticky
    // model-affinity routing each model is pinned to exactly one
    // backend for the whole run, so (a) the model→backend mapping
    // never changes, (b) no backend ever swaps in more than
    // min(models, residency_slots · backends) distinct models, and
    // (c) with enough aggregate slots every model swaps in exactly
    // once — python/sim: 8 swaps for 8 models, vs 183 under
    // round-robin's continuous thrash.
    let run = |policy: Policy| {
        let (backends, tier) =
            build_fleet(Topology::Pooled, 8, MIXED, &Link::infiniband_cx6());
        let spec = build_fabric_spec(Topology::Pooled, 8, MIXED, 1.0).unwrap();
        let cfg = CogSimConfig {
            ranks: 8,
            models: 8,
            swap_s: 2e-3,
            residency_slots: 4,
            batching: Batching::Off,
            ..Default::default()
        };
        let mut sim = CogSim::with_fabric(backends, policy, cfg, tier.hermit, tier.mir, spec);
        sim.run_to_completion();
        sim
    };

    let sim = run(Policy::ModelAffinity);
    let n_backends = 6usize;
    let models = 8u64;
    let slots = 4u64;
    let mut model_backend: BTreeMap<String, usize> = BTreeMap::new();
    let mut distinct: Vec<BTreeSet<String>> = vec![BTreeSet::new(); n_backends];
    for r in sim.records() {
        if let Some(&prev) = model_backend.get(&r.model) {
            assert_eq!(prev, r.backend, "affinity mapping moved for {}", r.model);
        }
        model_backend.insert(r.model.clone(), r.backend);
        distinct[r.backend].insert(r.model.clone());
    }
    let bound = models.min(slots * n_backends as u64);
    for (b, set) in distinct.iter().enumerate() {
        assert!(
            (set.len() as u64) <= bound,
            "backend {b} swapped {} distinct models, bound {bound}",
            set.len()
        );
    }
    assert_eq!(model_backend.len() as u64, models, "every model was sighted");
    assert_eq!(sim.swaps(), models, "each pinned model swaps in exactly once");

    // contrast: blind round-robin bounces models across the pool and
    // re-pays the swap continuously
    let rr = run(Policy::RoundRobin);
    assert!(
        rr.swaps() > 2 * sim.swaps(),
        "round-robin must thrash: {} vs affinity {}",
        rr.swaps(),
        sim.swaps()
    );
}

#[test]
fn hybrid_pool_sits_between_pure_pools_at_32_ranks() {
    // The fleet-axis headline, pinned (python/sim, ±2%): at 32 ranks
    // on the non-blocking fabric, a 6-member pure-RDU pool clears the
    // burst fastest (28.56 ms), a pure-GPU pool of the same size is
    // slowest (46.18 ms), and the 4xGPU+2xRDU hybrid lands strictly
    // between (36.77 ms) — while the default 2-member pool trails
    // them all (52.99 ms).  Adding accelerators of *either*
    // architecture to the pool beats starving it, and latency-aware
    // routing exploits the fast RDU members in the mix.
    let within = |x: f64, target: f64| (x / target - 1.0).abs() < 0.02;

    let default32 = cog_tts(Fleet::DefaultPool, 32);
    let pure_rdu32 = cog_tts(Fleet::Mixed { gpus: 0, rdus: 6 }, 32);
    let pure_gpu32 = cog_tts(Fleet::Mixed { gpus: 6, rdus: 0 }, 32);
    let hybrid32 = cog_tts(MIXED, 32);

    assert!(within(default32, 52.99e-3), "default pool at 32 ranks: {default32}");
    assert!(within(pure_rdu32, 28.56e-3), "pure-RDU pool at 32 ranks: {pure_rdu32}");
    assert!(within(pure_gpu32, 46.18e-3), "pure-GPU pool at 32 ranks: {pure_gpu32}");
    assert!(within(hybrid32, 36.77e-3), "hybrid pool at 32 ranks: {hybrid32}");

    assert!(pure_rdu32 < hybrid32, "pure RDUs beat the hybrid mix");
    assert!(hybrid32 < pure_gpu32, "hybrid beats pure GPUs");
    assert!(pure_gpu32 < default32, "any 6-member pool beats the starved pair");

    // the low-rank regime keeps the same ordering, just closer
    let pure_rdu4 = cog_tts(Fleet::Mixed { gpus: 0, rdus: 6 }, 4);
    let pure_gpu4 = cog_tts(Fleet::Mixed { gpus: 6, rdus: 0 }, 4);
    let hybrid4 = cog_tts(MIXED, 4);
    assert!(within(hybrid4, 18.90e-3), "hybrid pool at 4 ranks: {hybrid4}");
    assert!(pure_rdu4 < hybrid4 && hybrid4 < pure_gpu4);
}

#[test]
fn fleet_axis_sweeps_alongside_oversubscription() {
    // The axis composes with the existing grid: fleets × oversubs
    // expand only where a pool exists, and every mixed cell stays
    // monotone in oversubscription like the default pool does.
    let grid = Grid {
        axes: Axes {
            kinds: vec![Kind::Cog],
            topologies: vec![Topology::Local, Topology::Pooled],
            fleets: vec![Fleet::DefaultPool, MIXED],
            policies: vec![Policy::LeastOutstanding],
            rank_counts: vec![16],
            fabric_oversubs: vec![1.0, 8.0],
            ..Axes::default()
        },
        knobs: Knobs { timesteps: 4, ..Knobs::default() },
    };
    let result = run_grid(&grid);
    // local collapses both axes: 1 cell; pooled: 2 fleets x 2 oversubs
    assert_eq!(result.cells.len(), 1 + 4);
    for fleet in [Fleet::DefaultPool, MIXED] {
        let tts = |oversub: f64| {
            result
                .find(|s| {
                    s.topology == Topology::Pooled && s.fleet == fleet && s.oversub == oversub
                })
                .and_then(|c| c.cog().map(|s| s.time_to_solution_s))
                .expect("pooled cell ran")
        };
        assert!(
            tts(8.0) >= tts(1.0) - 1e-12,
            "{}: starving the fabric cannot speed the pool up",
            fleet.key()
        );
    }
}
