//! Control-plane property suite: the differential, chaos, and golden
//! tests for dynamic fleets and failure injection.
//!
//! Three layers, mirroring `python/sim/verify.py`'s `control_plane`
//! phase (every numeric expectation here was validated out-of-band
//! against the line-faithful transliteration):
//!
//! * **Differential** — an armed-but-empty control plane must be
//!   bit-identical to the legacy static run for every workload kind,
//!   every arrival process, and every `--threads` value.  This is
//!   what keeps the three committed campaign goldens stable while the
//!   control plane exists in the code path.
//! * **Chaos** — randomized seeded event traces (leaves, joins,
//!   degrades, restores, rank failures at random times) must preserve
//!   the conservation laws, produce finite summaries, and rerun
//!   byte-identically at the same seed.
//! * **Golden** — the seven-cell control campaign reproduces
//!   `rust/tests/golden/control_summary.json` byte for byte and pins
//!   the headline: pooled degrades more gracefully than node-local
//!   under a one-backend loss, and the reactive autoscaler holds TTS
//!   within [`AUTOSCALER_BOUND`] of the static optimum.

use std::path::PathBuf;

use cogsim_disagg::cluster::{Backend, Policy, RduBackend};
use cogsim_disagg::eventsim::{
    ArrivalProcess, Batching, CogSim, CogSimConfig, CogSummary, EventSim, EventSimConfig,
    EventSummary, FleetAction, FleetEvent,
};
use cogsim_disagg::fabric::{FabricSpec, Topology as FabricTopology};
use cogsim_disagg::harness::report::AUTOSCALER_BOUND;
use cogsim_disagg::harness::{
    run_cell, run_cell_ctl, run_control_campaign, run_grid_threads, Axes,
    ControlCampaignConfig, ControlSpec, Fleet, Grid, Kind, Knobs, Topology,
};
use cogsim_disagg::rdu::RduApi;
use cogsim_disagg::util::json;
use cogsim_disagg::util::rng::Rng;

// ------------------------------------------------------- fixtures
//
// The same two-backend heterogeneous pool, tiers, and configs the
// python/sim verifier uses — the expectations below are pinned
// against those exact runs.

fn pool() -> Vec<Box<dyn Backend>> {
    vec![
        Box::new(RduBackend::disaggregated("rdu/pool0", 4, RduApi::CppOptimized)),
        Box::new(RduBackend::disaggregated("rdu/pool1", 2, RduApi::Python)),
    ]
}

fn ccfg() -> CogSimConfig {
    CogSimConfig {
        ranks: 4,
        timesteps: 8,
        compute_s: 2e-3,
        compute_jitter_s: 0.0,
        requests_per_step: 6,
        models: 8,
        samples_per_request: (2, 3),
        mir_every: 0,
        mir_samples: 512,
        overlap: 0.0,
        swap_s: 0.0,
        residency_slots: 4,
        batching: Batching::Off,
        seed: 42,
    }
}

fn ecfg(arrival: ArrivalProcess, horizon_s: f64) -> EventSimConfig {
    EventSimConfig {
        ranks: 4,
        materials: 8,
        samples_per_request: (2, 3),
        requests_per_burst: 6,
        mir_every: 0,
        mir_samples: 512,
        arrival,
        batching: Batching::Off,
        horizon_s,
        seed: 42,
    }
}

/// Pooled fabric over the two-backend pool: 4 hosts share the uplink
/// to 2 remote accels at the given oversubscription.
fn fab(ranks: usize, oversub: f64) -> FabricSpec {
    FabricSpec {
        topology: FabricTopology::pooled(ranks, 2, oversub),
        accel_of_backend: vec![0, 1],
    }
}

fn cog(fabric: Option<FabricSpec>, cfg: CogSimConfig) -> CogSim {
    match fabric {
        Some(spec) => CogSim::with_fabric(
            pool(),
            Policy::LeastOutstanding,
            cfg,
            vec![0, 1],
            vec![0, 1],
            spec,
        ),
        None => CogSim::with_tiers(pool(), Policy::LeastOutstanding, cfg, vec![0, 1], vec![0, 1]),
    }
}

fn esim(cfg: EventSimConfig) -> EventSim {
    EventSim::with_tiers(pool(), Policy::LeastOutstanding, cfg, vec![0, 1], vec![0, 1])
}

fn ev(at_s: f64, action: FleetAction) -> FleetEvent {
    FleetEvent { at_s, action }
}

fn assert_cog_finite(s: &CogSummary, ctx: &str) {
    for (name, x) in [
        ("tts", s.time_to_solution_s),
        ("mean_step", s.mean_step_s),
        ("compute", s.total_compute_s),
        ("queue", s.total_queue_s),
        ("swap", s.total_swap_s),
        ("network", s.total_network_s),
        ("contention", s.total_contention_s),
        ("service", s.total_service_s),
        ("swap_time", s.swap_time_s),
        ("max_spread", s.max_spread_s),
        ("mean_active", s.mean_active_backends),
        ("lat_mean", s.latency.mean_s),
        ("lat_p50", s.latency.p50_s),
        ("lat_p99", s.latency.p99_s),
        ("lat_p999", s.latency.p999_s),
        ("lat_max", s.latency.max_s),
    ] {
        assert!(x.is_finite(), "{ctx}: {name} = {x} not finite");
    }
    for st in &s.steps {
        assert!(st.duration_s().is_finite() && st.spread_s.is_finite(), "{ctx}: step");
    }
}

fn assert_event_finite(s: &EventSummary, ctx: &str) {
    for (name, x) in [
        ("mean_batch_samples", s.mean_batch_samples),
        ("link_overhead", s.mean_link_overhead_s),
        ("contention", s.mean_contention_s),
        ("samples_per_s", s.samples_per_s),
        ("makespan", s.makespan_s),
        ("slowdown", s.slowdown_max),
        ("lat_mean", s.latency.mean_s),
        ("lat_p50", s.latency.p50_s),
        ("lat_p99", s.latency.p99_s),
        ("lat_p999", s.latency.p999_s),
        ("lat_max", s.latency.max_s),
    ] {
        assert!(x.is_finite(), "{ctx}: {name} = {x} not finite");
    }
}

// --------------------------------------------------- differential

#[test]
fn armed_empty_trace_is_identical_to_static_run_every_arrival_process() {
    // with_control(&[]) must add nothing: the control plane's mere
    // presence cannot perturb the event stream.
    for arrival in [
        ArrivalProcess::Synchronized { period_s: 0.02, jitter_s: 0.0 },
        ArrivalProcess::Poisson { rate_per_rank: 800.0 },
        ArrivalProcess::ClosedLoop { think_s: 2e-3 },
    ] {
        let mut a = esim(ecfg(arrival, 0.05));
        a.run_to_completion();
        let mut b = esim(ecfg(arrival, 0.05));
        b.with_control(&[]);
        b.run_to_completion();
        assert_eq!(a.summary(), b.summary(), "{arrival:?}");
        assert_eq!(a.records(), b.records(), "{arrival:?}");
        assert_eq!(a.events_processed(), b.events_processed(), "{arrival:?}");
    }
}

#[test]
fn armed_empty_control_plane_is_identical_to_static_cog_run() {
    let mut a = cog(None, ccfg());
    a.run_to_completion();
    let mut b = cog(None, ccfg());
    b.with_control(&[], None);
    b.run_to_completion();
    assert_eq!(a.summary(), b.summary());
    assert_eq!(a.records(), b.records());
    assert_eq!(a.events_processed(), b.events_processed());
}

/// A compact three-kind grid: every workload kind, both topologies,
/// the control axis carrying both a static and a dynamic schedule.
fn mixed_grid() -> Grid {
    Grid {
        axes: Axes {
            kinds: vec![Kind::Analytic, Kind::Event, Kind::Cog],
            topologies: vec![Topology::Local, Topology::Pooled],
            fleets: vec![Fleet::Mixed { gpus: 4, rdus: 0 }],
            policies: vec![Policy::LeastOutstanding],
            rank_counts: vec![4],
            arrivals: vec![ArrivalProcess::Synchronized { period_s: 0.02, jitter_s: 0.0 }],
            windows_us: vec![0.0],
            models_per_rank: vec![8],
            swap_costs_s: vec![0.0],
            overlaps: vec![0.0],
            fabric_oversubs: vec![2.0],
            controls: vec![
                ControlSpec::static_(),
                ControlSpec::parse("leave:0@10300").unwrap(),
            ],
        },
        knobs: Knobs { timesteps: 4, horizon_s: 0.05, ..Knobs::default() },
    }
}

#[test]
fn grid_json_is_byte_identical_at_every_thread_count() {
    // Dynamic control cells are ordinary cells: individually
    // deterministic and collected in expansion order, so the whole
    // document — static and chaos cells alike — is byte-identical at
    // any worker count.
    let grid = mixed_grid();
    let reference = json::write(&run_grid_threads(&grid, 1).to_json());
    for threads in [2usize, 8, 0] {
        let doc = json::write(&run_grid_threads(&grid, threads).to_json());
        assert_eq!(doc, reference, "threads = {threads}");
    }
}

#[test]
fn static_cells_are_unaffected_by_a_dynamic_control_axis_in_the_grid() {
    // The differential at the grid level: adding a dynamic schedule
    // to the control axis must not move a single byte of the static
    // cells' summaries — exactly the property that keeps the three
    // committed campaign goldens (which run the static axis only)
    // valid forever.
    let with_dynamic = mixed_grid();
    let mut static_only = mixed_grid();
    static_only.axes.controls = vec![ControlSpec::static_()];

    let a = run_grid_threads(&static_only, 0);
    let b = run_grid_threads(&with_dynamic, 0);
    let b_static: Vec<_> = b.cells.iter().filter(|c| c.scenario.control == 0).collect();
    assert_eq!(a.cells.len(), b_static.len());
    for (x, y) in a.cells.iter().zip(&b_static) {
        assert_eq!(format!("{:?}", x.scenario), format!("{:?}", y.scenario));
        assert_eq!(format!("{:?}", x.summary), format!("{:?}", y.summary));
    }
    // ... and the dynamic cells actually ran, on the kinds with a
    // clock: the analytic closed form has no timeline for timed
    // events, so its control axis collapses to the static schedule
    let dynamic: Vec<_> = b.cells.iter().filter(|c| c.scenario.control == 1).collect();
    assert!(!dynamic.is_empty(), "dynamic schedule must expand into cells");
    assert!(
        dynamic.iter().all(|c| c.scenario.kind != Kind::Analytic),
        "analytic kind must collapse the control axis"
    );
    assert!(dynamic.iter().any(|c| c.scenario.kind == Kind::Event));
    assert!(dynamic.iter().any(|c| c.scenario.kind == Kind::Cog));
}

#[test]
fn run_cell_and_run_cell_ctl_static_are_the_same_path() {
    let mut grid = mixed_grid();
    grid.axes.controls = vec![ControlSpec::static_()];
    for sc in grid.cells() {
        let a = run_cell(&sc, &grid.knobs);
        let b = run_cell_ctl(&sc, &grid.knobs, &ControlSpec::static_());
        assert_eq!(format!("{:?}", a.summary), format!("{:?}", b.summary));
    }
}

// --------------------------------------------------------- chaos

/// Mirror of the python verifier's `chaos_trace`: same per-rank RNG
/// stream derivation, same draw order, so the traces — and therefore
/// every expectation — are identical across the two implementations.
fn chaos_trace(seed: u64, horizon_s: f64, n_backends: usize, n_ranks: usize) -> Vec<FleetEvent> {
    let mut rng = Rng::new(seed ^ 1u64.wrapping_mul(0x9E3779B97F4A7C15));
    let n = rng.range(3, 8);
    let mut trace = Vec::new();
    for _ in 0..n {
        let at_s = rng.uniform(0.0, horizon_s);
        let action = match rng.below(5) {
            0 => FleetAction::BackendLeave(rng.below(n_backends)),
            1 => FleetAction::BackendJoin(rng.below(n_backends)),
            2 => FleetAction::LinkDegrade(0.1 + 0.8 * rng.uniform(0.0, 1.0)),
            3 => FleetAction::LinkRestore,
            _ => FleetAction::RankFail(rng.below(n_ranks)),
        };
        trace.push(ev(at_s, action));
    }
    trace
}

#[test]
fn cog_chaos_conserves_and_reruns_identically() {
    for seed in [1u64, 7, 99] {
        let trace = chaos_trace(seed, 20e-3, 2, 4);
        let mut summaries: Vec<CogSummary> = Vec::new();
        for _ in 0..2 {
            let mut sim = cog(Some(fab(4, 2.0)), CogSimConfig { timesteps: 4, ..ccfg() });
            sim.with_control(&trace, None);
            sim.run_to_completion();
            let s = sim.summary();
            // conservation: every submitted request is either
            // completed (finite record), parked with no live backend,
            // or still coalescing — nothing is silently dropped
            let finished =
                sim.records().iter().filter(|r| r.complete_s.is_finite()).count() as u64;
            assert_eq!(
                sim.submitted(),
                finished + sim.parked() + sim.batcher_pending(),
                "seed {seed}"
            );
            // exactly-once re-dispatch: one retry per orphan, never more
            assert_eq!(s.retries, sim.orphaned(), "seed {seed}");
            assert_cog_finite(&s, &format!("cog chaos seed {seed}"));
            summaries.push(s);
        }
        assert_eq!(summaries[0], summaries[1], "seed {seed}: rerun must be identical");
    }
}

#[test]
fn event_chaos_conserves_and_reruns_identically() {
    for seed in [1u64, 7, 99] {
        let trace = chaos_trace(seed + 1000, 40e-3, 2, 4);
        let mut summaries: Vec<EventSummary> = Vec::new();
        for _ in 0..2 {
            let mut sim =
                esim(ecfg(ArrivalProcess::Poisson { rate_per_rank: 800.0 }, 0.05));
            sim.with_control(&trace);
            sim.run_to_completion();
            let s = sim.summary();
            assert_eq!(
                s.submitted,
                s.requests + s.failed + sim.batcher_pending(),
                "seed {seed}"
            );
            // at drain the only incomplete requests are the parked ones
            assert_eq!(s.failed, sim.parked(), "seed {seed}");
            assert_eq!(s.retries, sim.orphaned(), "seed {seed}");
            assert_eq!(sim.in_flight(), 0, "seed {seed}");
            assert_event_finite(&s, &format!("event chaos seed {seed}"));
            summaries.push(s);
        }
        assert_eq!(summaries[0], summaries[1], "seed {seed}: rerun must be identical");
    }
}

#[test]
fn repeated_leave_join_of_the_same_backend_is_idempotent() {
    // Doubled leaves and joins are no-ops, not state corruption: the
    // run completes every step with nothing lost.
    let mut sim = cog(None, ccfg());
    sim.with_control(
        &[
            ev(2.2e-3, FleetAction::BackendLeave(0)),
            ev(2.2e-3, FleetAction::BackendLeave(0)),
            ev(6e-3, FleetAction::BackendJoin(0)),
            ev(6e-3, FleetAction::BackendJoin(0)),
            ev(9e-3, FleetAction::BackendLeave(0)),
            ev(12e-3, FleetAction::BackendJoin(0)),
        ],
        None,
    );
    sim.run_to_completion();
    let s = sim.summary();
    assert_eq!(s.failed, 0);
    assert_eq!(s.requests, s.submitted);
    assert_eq!(sim.steps().len(), 8);
    assert_eq!(s.retries, sim.orphaned());
    assert!(sim.backend_active(0) && sim.backend_active(1));
}

#[test]
fn degrade_restore_roundtrip_completes_cleanly() {
    let mut base = cog(Some(fab(4, 2.0)), ccfg());
    base.run_to_completion();
    let mut sim = cog(Some(fab(4, 2.0)), ccfg());
    sim.with_control(
        &[ev(6e-3, FleetAction::LinkDegrade(0.25)), ev(20e-3, FleetAction::LinkRestore)],
        None,
    );
    sim.run_to_completion();
    let s = sim.summary();
    assert_eq!(s.failed, 0);
    assert_eq!(s.retries, 0, "a brown-out orphans nothing");
    assert_eq!(sim.steps().len(), 8);
    // a quartered fabric can only slow the run down
    assert!(
        s.time_to_solution_s >= base.summary().time_to_solution_s - 1e-12,
        "degrade {} vs static {}",
        s.time_to_solution_s,
        base.summary().time_to_solution_s
    );
    assert_cog_finite(&s, "degrade/restore");
}

// ------------------------------------------------------- autoscaler

#[test]
fn autoscaler_respects_limits_and_loses_no_work() {
    // the two-backend pool caps max_active at the tier size
    let auto = ControlSpec::parse("auto:2:1-2:100:1000").unwrap();
    let mut sim = cog(Some(fab(4, 2.0)), ccfg());
    sim.with_control(&auto.trace, auto.autoscaler);
    // backends past `initial` start parked
    assert_eq!(sim.active_count(), 2);
    sim.run_to_completion();
    let s = sim.summary();
    assert_eq!(s.failed, 0, "scaling must not lose work");
    assert_eq!(sim.steps().len(), 8);
    assert!(
        s.mean_active_backends >= 1.0 && s.mean_active_backends <= 2.0,
        "trajectory {} outside [min_active, initial]",
        s.mean_active_backends
    );
}

// ---------------------------------------------- campaign + golden

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust")
        .join("tests")
        .join("golden")
        .join("control_summary.json")
}

fn control_json() -> String {
    json::write(&run_control_campaign(&ControlCampaignConfig::default()).to_json())
}

#[test]
fn control_campaign_summary_matches_committed_golden() {
    // Same protocol as `campaign_golden.rs`: byte-compare against the
    // committed file; regeneration only under GOLDEN_BOOTSTRAP=1.
    let actual = control_json();
    assert_eq!(actual, control_json(), "two identical runs must serialise identically");
    let path = golden_path();
    if path.exists() {
        let golden = std::fs::read_to_string(&path).unwrap();
        assert_eq!(
            actual, golden,
            "control summary drifted from {path:?}; if intentional, delete the \
             golden and rerun with GOLDEN_BOOTSTRAP=1"
        );
    } else {
        assert!(
            std::env::var("GOLDEN_BOOTSTRAP").as_deref() == Ok("1"),
            "golden file {path:?} is missing; goldens are committed artifacts — \
             rerun with GOLDEN_BOOTSTRAP=1 to bootstrap it deliberately"
        );
        std::fs::write(&path, &actual).unwrap();
        assert_eq!(control_json(), std::fs::read_to_string(&path).unwrap());
    }
}

#[test]
fn control_campaign_headline_pins() {
    let r = run_control_campaign(&ControlCampaignConfig::default());

    // the resilience headline: losing 1 of 4 devices costs both
    // topologies time, but the pooled fleet — where the survivors are
    // a shared resource every rank can reach — absorbs it better
    // than node-local GPUs
    let ll = r.loss_ratio("local");
    let lp = r.loss_ratio("pooled");
    assert!(1.0 < lp && lp < ll, "loss ratios: pooled {lp} vs local {ll}");

    // the loss cells exercise real machinery: in-flight work was
    // orphaned and re-dispatched, not quietly dropped
    assert!(r.cell("local/leave").summary.retries > 0);
    assert!(r.cell("pooled/leave").summary.retries > 0);
    assert_eq!(r.cell("pooled/rankfail").summary.rank_restarts, 1);

    // the autoscaler sheds idle capacity yet holds the TTS bound
    let auto = r.autoscaler_factor();
    assert!(
        auto <= AUTOSCALER_BOUND,
        "autoscaler factor {auto} above bound {AUTOSCALER_BOUND}"
    );
    assert!(
        r.cell("pooled/auto").summary.mean_active_backends
            < r.cell("pooled/static").summary.mean_active_backends
    );

    // every cell finishes all its work — failures reroute, they
    // don't lose requests
    for c in &r.cells {
        assert_eq!(c.summary.failed, 0, "{}", c.label);
        assert_eq!(c.summary.timesteps, 8, "{}", c.label);
        assert_cog_finite(&c.summary, &c.label);
    }
}
