//! Property tests for the flight recorder: the invariants the trace
//! is allowed to claim — device busy intervals never overlap and
//! integrate to the engine's own busy accumulator, per-request spans
//! tile the queued→completion interval with no gaps, the armed merged
//! timeline is byte-identical at every thread count, and a recorder
//! (armed or disarmed) never perturbs the simulated results.

use std::collections::BTreeMap;

use cogsim_disagg::cluster::Policy;
use cogsim_disagg::eventsim::{
    ArrivalProcess, Batching, CogSim, CogSimConfig, EventSim, EventSimConfig,
};
use cogsim_disagg::harness::{
    build_fabric_spec, build_fleet, run_grid_threads_full, try_run_cell_full, Axes, ControlSpec,
    Fleet, Grid, Kind, Knobs, Scenario, Topology,
};
use cogsim_disagg::netsim::Link;
use cogsim_disagg::trace::Phase;
use cogsim_disagg::util::json::{self, Value};

/// The `repro trace` shape: a pooled cog cell whose every dispatch
/// crosses the fabric (so device occupancy comes from the exclusive
/// `occupy` path) with a real residency swap cost.
fn pooled_cog(ranks: usize) -> Scenario {
    Scenario {
        kind: Kind::Cog,
        topology: Topology::Pooled,
        fleet: Fleet::DefaultPool,
        policy: Policy::LeastOutstanding,
        ranks,
        arrival: ArrivalProcess::Synchronized { period_s: 0.02, jitter_s: 0.0 },
        window_us: 0.0,
        models: 8,
        swap_s: 200e-6,
        overlap: 0.0,
        oversub: 2.0,
        control: 0,
    }
}

#[test]
fn busy_intervals_never_overlap_and_integrate_to_device_busy() {
    let run = try_run_cell_full(&pooled_cog(16), &Knobs::default(), &ControlSpec::static_(), true)
        .expect("pooled cog cell runs");
    let rec = run.recorder.as_ref().expect("armed run keeps its recorder");
    assert!(rec.devices() > 0);
    assert_eq!(
        rec.devices(),
        run.device_busy_s.len(),
        "recorder and engine disagree on device count"
    );
    let mut total = 0.0;
    for d in 0..rec.devices() {
        let busy = rec.busy_intervals(d);
        let mut integral = 0.0;
        for b in busy {
            assert!(b.t1_s >= b.t0_s, "negative busy interval on device {d}");
            assert!(b.requests > 0, "empty batch occupied device {d}");
            integral += b.t1_s - b.t0_s;
        }
        for w in busy.windows(2) {
            assert!(
                w[1].t0_s >= w[0].t1_s - 1e-12,
                "device {d} double-booked: [{:.9}, {:.9}] begins before [{:.9}, {:.9}] ends",
                w[1].t0_s,
                w[1].t1_s,
                w[0].t0_s,
                w[0].t1_s,
            );
        }
        assert!(
            (integral - rec.busy_integral_s(d)).abs() < 1e-9,
            "device {d}: interval sum {integral} vs recorder integral {}",
            rec.busy_integral_s(d),
        );
        assert!(
            (rec.busy_integral_s(d) - run.device_busy_s[d]).abs() < 1e-9,
            "device {d}: recorder integral {} vs engine busy accumulator {}",
            rec.busy_integral_s(d),
            run.device_busy_s[d],
        );
        total += integral;
    }
    assert!(total > 0.0, "a 16-rank cog cell never occupied a device");
}

#[test]
fn request_spans_tile_the_queued_to_completion_interval() {
    let run = try_run_cell_full(&pooled_cog(8), &Knobs::default(), &ControlSpec::static_(), true)
        .expect("pooled cog cell runs");
    let rec = run.recorder.as_ref().expect("armed run keeps its recorder");

    // group per request, preserving emit order (chronological per id)
    let mut by_id: BTreeMap<usize, Vec<_>> = BTreeMap::new();
    for s in rec.spans() {
        by_id.entry(s.id).or_default().push(*s);
    }
    assert!(!by_id.is_empty(), "no request spans recorded");

    let mut gate_total = 0.0;
    for (id, spans) in &by_id {
        // the fabric path emits the full six-phase lifecycle
        let phases: Vec<Phase> = spans.iter().map(|s| s.phase).collect();
        assert_eq!(
            phases,
            [
                Phase::Queued,
                Phase::XferIn,
                Phase::Gate,
                Phase::Wait,
                Phase::Exec,
                Phase::XferOut,
            ],
            "request {id}: unexpected phase sequence"
        );
        for s in spans {
            assert!(s.t1_s >= s.t0_s - 1e-12, "request {id}: negative {:?} span", s.phase);
            assert!(s.t0_s >= 0.0 && s.t1_s <= rec.horizon_s() + 1e-9);
            if s.phase == Phase::Gate {
                gate_total += s.t1_s - s.t0_s;
            }
        }
        for w in spans.windows(2) {
            assert!(
                (w[1].t0_s - w[0].t1_s).abs() < 1e-9,
                "request {id}: gap between {:?} (ends {:.9}) and {:?} (starts {:.9})",
                w[0].phase,
                w[0].t1_s,
                w[1].phase,
                w[1].t0_s,
            );
        }
    }
    assert!(
        (gate_total - rec.gate_wait_total_s()).abs() < 1e-9,
        "gate spans sum to {gate_total}, recorder says {}",
        rec.gate_wait_total_s(),
    );

    // ... and the recorder's books reconcile with the summary the
    // goldens pin: same request count, one occupancy interval and one
    // histogram entry per dispatched batch, same residency misses.
    let cog = run.result.cog().expect("cog cell yields a cog summary");
    assert_eq!(by_id.len() as u64, cog.requests, "span ids vs completed requests");
    assert_eq!(rec.swap_misses(), cog.swaps, "recorder misses vs summary swaps");
    let hist_batches: u64 = rec.batch_histogram().values().sum();
    assert_eq!(hist_batches, cog.batches, "occupancy histogram vs dispatched batches");
    let occupies: u64 = (0..rec.devices()).map(|d| rec.busy_intervals(d).len() as u64).sum();
    assert_eq!(occupies, cog.batches, "busy intervals vs dispatched batches");
}

/// A small mixed grid (event + cog, two policies, two rank counts)
/// whose cells take visibly different wall times, so a parallel run
/// genuinely interleaves completions.
fn small_grid() -> Grid {
    let mut axes = Axes::default();
    axes.kinds = vec![Kind::Event, Kind::Cog];
    axes.topologies = vec![Topology::Pooled];
    axes.policies = vec![Policy::RoundRobin, Policy::LeastOutstanding];
    axes.rank_counts = vec![4, 8];
    axes.fabric_oversubs = vec![4.0];
    axes.swap_costs_s = vec![200e-6];
    let mut knobs = Knobs::default();
    knobs.timesteps = 4;
    knobs.horizon_s = 0.05;
    Grid { axes, knobs }
}

fn merged_trace_json(grid: &Grid, threads: usize) -> String {
    let (result, _timings, recorders) = run_grid_threads_full(grid, threads, true).split();
    assert_eq!(recorders.len(), result.cells.len());
    let mut events = Vec::new();
    for (i, rec) in recorders.iter().enumerate() {
        let rec = rec.as_ref().expect("every engine-backed cell returns a recorder when armed");
        events.extend(rec.chrome_trace(&result.cells[i].scenario.cell_key(), i as u64 * 8));
    }
    assert!(!events.is_empty());
    let mut doc = BTreeMap::new();
    doc.insert("traceEvents".to_string(), Value::Array(events));
    json::write(&Value::Object(doc))
}

#[test]
fn armed_merged_trace_is_byte_identical_at_every_thread_count() {
    let grid = small_grid();
    let sequential = merged_trace_json(&grid, 1);
    for threads in [2, 8, 0] {
        let parallel = merged_trace_json(&grid, threads);
        assert_eq!(
            sequential, parallel,
            "merged trace differs between 1 worker and {threads} workers"
        );
    }
}

#[test]
fn arming_the_recorder_never_changes_the_summary_document() {
    let grid = small_grid();
    let disarmed = json::write(&run_grid_threads_full(&grid, 2, false).split().0.to_json());
    let armed = json::write(&run_grid_threads_full(&grid, 2, true).split().0.to_json());
    assert_eq!(disarmed, armed, "an armed recorder perturbed the golden-pinned document");
}

// ------------------------------------------- engine-level differential

/// 0 = no recorder (the exact legacy path), 1 = recorder attached but
/// disarmed, 2 = armed.
fn event_summary(fabric: bool, mode: u8) -> String {
    let (backends, tier) = build_fleet(Topology::Pooled, 6, Fleet::DefaultPool, &Link::infiniband_cx6());
    let cfg = EventSimConfig {
        ranks: 6,
        materials: 8,
        samples_per_request: (2, 3),
        requests_per_burst: 4,
        mir_every: 2,
        mir_samples: 64,
        arrival: ArrivalProcess::Poisson { rate_per_rank: 900.0 },
        batching: Batching::Window { window_s: 100e-6, max_batch: 64 },
        horizon_s: 0.05,
        seed: 7,
    };
    let mut sim = if fabric {
        let spec = build_fabric_spec(Topology::Pooled, 6, Fleet::DefaultPool, 4.0)
            .expect("pooled topology has a fabric");
        EventSim::with_fabric(backends, Policy::LeastOutstanding, cfg, tier.hermit, tier.mir, spec)
    } else {
        // same remote fleet, fixed-charge link model: the legacy path
        EventSim::with_tiers(backends, Policy::LeastOutstanding, cfg, tier.hermit, tier.mir)
    };
    match mode {
        1 => sim.attach_disarmed_recorder(),
        2 => sim.arm_trace(),
        _ => {}
    }
    sim.run_to_completion();
    format!("{:?}", sim.summary())
}

fn cog_summary(mode: u8) -> String {
    let (backends, tier) = build_fleet(Topology::Pooled, 6, Fleet::DefaultPool, &Link::infiniband_cx6());
    let cfg = CogSimConfig {
        ranks: 6,
        timesteps: 4,
        compute_s: 2e-3,
        compute_jitter_s: 0.0,
        requests_per_step: 4,
        models: 8,
        samples_per_request: (2, 3),
        mir_every: 2,
        mir_samples: 64,
        overlap: 0.25,
        swap_s: 200e-6,
        residency_slots: 4,
        batching: Batching::Off,
        seed: 7,
    };
    let spec = build_fabric_spec(Topology::Pooled, 6, Fleet::DefaultPool, 4.0)
        .expect("pooled topology has a fabric");
    let mut sim =
        CogSim::with_fabric(backends, Policy::LeastOutstanding, cfg, tier.hermit, tier.mir, spec);
    match mode {
        1 => sim.attach_disarmed_recorder(),
        2 => sim.arm_trace(),
        _ => {}
    }
    sim.run_to_completion();
    format!("{:?}", sim.summary())
}

#[test]
fn disarmed_recorder_is_byte_identical_to_the_legacy_path() {
    for fabric in [true, false] {
        let legacy = event_summary(fabric, 0);
        assert_eq!(
            legacy,
            event_summary(fabric, 1),
            "disarmed recorder changed the event summary (fabric: {fabric})"
        );
        assert_eq!(
            legacy,
            event_summary(fabric, 2),
            "armed recorder changed the event summary (fabric: {fabric})"
        );
    }
    let legacy = cog_summary(0);
    assert_eq!(legacy, cog_summary(1), "disarmed recorder changed the cog summary");
    assert_eq!(legacy, cog_summary(2), "armed recorder changed the cog summary");
}
