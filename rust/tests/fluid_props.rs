//! Property and cross-validation suite for the fluid tier and the
//! fitted surrogate.
//!
//! The fluid tier is only useful if its error against the
//! event-for-event engine is *known and pinned*, so this suite is the
//! contract:
//!
//! * **collapse** — in the contention-free single-rank limit the fluid
//!   solution equals the analytic closed form to 1e-9;
//! * **monotonicity** — TTS never improves when the fabric is starved
//!   (oversubscription) or the machine grows (ranks, at window 0);
//! * **fluid vs event** — ≤ 15 % TTS error on the uncongested half
//!   (swap-free or ≤ 2:1 oversubscribed cells) of the default coupled
//!   grid, every cell (measured worst case: 12.9 %), re-validated by
//!   the scale campaign's event-engine anchor cells at 64 and 256
//!   ranks (measured ~0.1 % on the swap-free anchors);
//! * **surrogate** — exact on training cells, ≤ 5 % on the pinned
//!   held-out interior slice (measured worst case: 1.4 %; the
//!   model-affinity policy is excluded — its first-touch multinomial
//!   assignment makes TTS non-smooth between grid nodes).

use cogsim_disagg::cluster::{Backend, GpuBackend, Policy};
use cogsim_disagg::devices::{profiles, Api, Gpu};
use cogsim_disagg::fluid::{
    run_scale_anchors, run_scale_campaign, solve_cell, ScaleCampaignConfig,
};
use cogsim_disagg::harness::{
    run_cog_campaign, run_cog_scenario, CogCampaignConfig, Fleet, Knobs, Topology,
};
use cogsim_disagg::surrogate::fit_cog_campaign;

/// The fluid knobs matching a [`CogCampaignConfig`] (the cross-checks
/// must feed both engines identical parameters).
fn knobs_of(cfg: &CogCampaignConfig) -> Knobs {
    Knobs {
        samples_per_request: cfg.samples_per_request,
        requests_per_step: cfg.requests_per_step,
        max_batch: cfg.max_batch,
        timesteps: cfg.timesteps,
        compute_s: cfg.compute_s,
        residency_slots: cfg.residency_slots,
        ..Knobs::default()
    }
}

#[test]
fn collapses_to_the_analytic_closed_form_in_the_contention_free_limit() {
    // one rank, one model, one request per step, fixed batch size, no
    // swaps, no overlap, no window: every steady-state correction
    // vanishes and the step is exactly compute + backend latency
    let knobs = Knobs {
        samples_per_request: (3, 3),
        requests_per_step: 1,
        timesteps: 8,
        compute_s: 2e-3,
        residency_slots: 4,
        ..Knobs::default()
    };
    let s = solve_cell(
        Topology::Local,
        Fleet::DefaultPool,
        Policy::RoundRobin,
        1,   // ranks
        1,   // models
        0.0, // swap
        0.0, // overlap
        1.0, // oversub
        0.0, // window_us
        &knobs,
    );
    let be = GpuBackend::node_local("gpu/local", Gpu::a100(), Api::TrtCudaGraphs);
    let profile = profiles::hermit();
    let step = knobs.compute_s.max(knobs.compute_s + be.latency_s(&profile, 3));
    let expected = step * knobs.timesteps as f64;
    assert!(
        (s.time_to_solution_s - expected).abs() <= 1e-9,
        "fluid {} vs analytic {}",
        s.time_to_solution_s,
        expected
    );
    assert_eq!(s.total_queue_s, 0.0);
    assert_eq!(s.total_swap_s, 0.0);
    assert!(s.converged);
}

#[test]
fn tts_is_monotone_in_oversubscription() {
    let knobs = knobs_of(&CogCampaignConfig::default());
    for policy in Policy::ALL {
        for swap_s in [0.0, 2e-3] {
            let mut last = 0.0;
            for oversub in [1.0, 2.0, 3.0, 4.0, 6.0, 8.0] {
                let s = solve_cell(
                    Topology::Pooled,
                    Fleet::DefaultPool,
                    policy,
                    32,
                    8,
                    swap_s,
                    0.0,
                    oversub,
                    0.0,
                    &knobs,
                );
                assert!(
                    s.time_to_solution_s >= last - 1e-12,
                    "{policy:?} swap {swap_s}: TTS {} at {oversub}:1 beats {last}",
                    s.time_to_solution_s
                );
                last = s.time_to_solution_s;
            }
        }
    }
}

#[test]
fn tts_is_monotone_in_ranks_at_window_zero() {
    // more ranks on the same pool = more load; at window 0 there is
    // no batching economy of scale to offset it
    let knobs = knobs_of(&CogCampaignConfig::default());
    for policy in [Policy::RoundRobin, Policy::LeastOutstanding, Policy::LatencyAware] {
        let mut last = 0.0;
        for ranks in [4, 8, 16, 32, 64, 256] {
            let s = solve_cell(
                Topology::Pooled,
                Fleet::DefaultPool,
                policy,
                ranks,
                8,
                2e-3,
                0.0,
                4.0,
                0.0,
                &knobs,
            );
            assert!(
                s.time_to_solution_s >= last - 1e-12,
                "{policy:?}: TTS {} at {ranks} ranks beats {last}",
                s.time_to_solution_s
            );
            last = s.time_to_solution_s;
        }
    }
}

#[test]
fn fluid_tts_tracks_the_event_engine_on_the_uncongested_half() {
    // The pinned cross-validation bound: on every cell of the default
    // coupled grid that is swap-free or at most 2:1 oversubscribed,
    // the fluid TTS is within 15 % of the event-for-event engine
    // (measured worst case 12.9 %; the congested+swapping corner
    // cells reach ~13.4 % and are deliberately not part of the
    // contract — the fluid tier is a scale-out explorer, not a
    // congestion-collapse model).
    let cfg = CogCampaignConfig::default();
    let knobs = knobs_of(&cfg);
    let result = run_cog_campaign(&cfg);
    let mut checked = 0;
    for sc in &result.scenarios {
        if !(sc.swap_s == 0.0 || sc.oversub <= 2.0) {
            continue;
        }
        let fluid = solve_cell(
            sc.topology,
            Fleet::DefaultPool,
            sc.policy,
            sc.ranks,
            sc.models,
            sc.swap_s,
            sc.overlap,
            sc.oversub,
            cfg.window_us,
            &knobs,
        );
        let err = fluid.time_to_solution_s / sc.summary.time_to_solution_s - 1.0;
        assert!(
            err.abs() <= 0.15,
            "{:?}/{:?}/r{}/ov{}/sw{}: fluid {:.3}ms vs event {:.3}ms ({:+.1}%)",
            sc.topology,
            sc.policy,
            sc.ranks,
            sc.oversub,
            sc.swap_s,
            fluid.time_to_solution_s * 1e3,
            sc.summary.time_to_solution_s * 1e3,
            err * 1e2
        );
        checked += 1;
    }
    assert!(checked >= 40, "the uncongested half must cover the grid ({checked} cells)");
}

#[test]
fn event_engine_anchors_hold_the_tts_bound_beyond_the_campaign_grid() {
    // The scale campaign's anchor cells: the coupled event engine
    // re-runs the swap-free pooled cell at the campaign's 4:1
    // oversubscription at 64 and 256 ranks — rank counts the
    // cross-validation grid above never reaches — and the fluid TTS
    // must stay inside the same pinned 15 % contract.  Affordable on
    // the event engine's scale-out hot path (ladder queue, lazy bulk
    // arrivals, coalesced fabric wakes); measured agreement on these
    // cells is ~0.1 %, so a 2 % trip wire guards against silent
    // model drift long before the contract bound.
    let cfg = ScaleCampaignConfig::default();
    let anchors = run_scale_anchors(&cfg);
    assert_eq!(anchors.len(), 2, "default anchors at 64 and 256 ranks");
    for a in &anchors {
        assert!(a.ranks > 32, "anchors must extend past the campaign grid ({})", a.ranks);
        assert_eq!(a.swap_s, 0.0, "anchors are swap-free by contract");
        assert!(
            a.within_bound(),
            "anchor r{}: fluid {:.3}ms vs event {:.3}ms ({:+.2}%) breaks the 15% contract",
            a.ranks,
            a.fluid_tts_s * 1e3,
            a.event_tts_s * 1e3,
            a.tts_error() * 1e2
        );
        assert!(
            a.tts_error().abs() <= 0.02,
            "anchor r{}: {:+.2}% drifted from the measured ~0.1% agreement",
            a.ranks,
            a.tts_error() * 1e2
        );
    }
}

#[test]
fn surrogate_is_exact_on_training_cells() {
    let cfg = CogCampaignConfig::default();
    let result = run_cog_campaign(&cfg);
    let sur = fit_cog_campaign(&result);
    assert!(sur.table_count() > 0, "default grid must yield complete tables");
    for sc in &result.scenarios {
        let (tts, p99) = sur
            .predict(
                sc.topology.key(),
                sc.policy.key(),
                sc.models,
                sc.overlap,
                sc.ranks as f64,
                sc.oversub,
                sc.swap_s * 1e6,
                cfg.window_us,
                "default",
                "static",
            )
            .expect("training cell must be covered");
        let rel = |a: f64, b: f64| (a / b - 1.0).abs();
        assert!(
            rel(tts, sc.summary.time_to_solution_s) <= 1e-12,
            "training node must reproduce exactly: {tts} vs {}",
            sc.summary.time_to_solution_s
        );
        assert!(rel(p99, sc.summary.latency.p99_s) <= 1e-12);
    }
}

#[test]
fn surrogate_holds_the_pinned_heldout_interior_bound() {
    // The pinned generalisation bound: ≤ 5 % TTS error on held-out
    // interior cells (ranks/oversub/swap strictly inside the training
    // hull; measured worst case 1.4 %).  Model-affinity is excluded:
    // its first-touch multinomial assignment makes TTS jump between
    // grid nodes (measured ~10 % — interpolation is the wrong tool
    // there, and the table says so by exclusion).
    let cfg = CogCampaignConfig::default();
    let sur = fit_cog_campaign(&run_cog_campaign(&cfg));
    let mut held_out = Vec::new();
    for policy in [Policy::RoundRobin, Policy::LeastOutstanding, Policy::LatencyAware] {
        for swap_s in [0.0, 2e-3] {
            held_out.push((policy, 16usize, 3.0f64, swap_s));
        }
    }
    held_out.push((Policy::RoundRobin, 32, 1.0, 1e-3));
    for (policy, ranks, oversub, swap_s) in held_out {
        let truth = run_cog_scenario(
            Topology::Pooled,
            policy,
            ranks,
            8,
            swap_s,
            0.0,
            oversub,
            &cfg,
        );
        let (tts, _) = sur
            .predict(
                "pooled",
                policy.key(),
                8,
                0.0,
                ranks as f64,
                oversub,
                swap_s * 1e6,
                cfg.window_us,
                "default",
                "static",
            )
            .expect("pooled table is complete");
        let err = tts / truth.summary.time_to_solution_s - 1.0;
        assert!(
            err.abs() <= 0.05,
            "{policy:?}/r{ranks}/ov{oversub}/sw{swap_s}: surrogate {:.3}ms vs event {:.3}ms \
             ({:+.1}%)",
            tts * 1e3,
            truth.summary.time_to_solution_s * 1e3,
            err * 1e2
        );
    }
}

#[test]
fn scale_campaign_pins_the_crossover_trajectory_and_stays_fast() {
    // The committed scale golden's headline, asserted structurally:
    // at 64 ranks a 256-member pool catches node-local GPUs, at 256
    // ranks it takes 512, and from 1024 ranks node-local wins
    // everywhere within the swept pool budget.  The whole
    // leadership-class campaign (40 cells to 16384 ranks) must stay
    // far under the 5 s acceptance budget — that speed is the fluid
    // tier's reason to exist.
    let started = std::time::Instant::now();
    let result = run_scale_campaign(&ScaleCampaignConfig::default());
    let elapsed = started.elapsed();
    assert!(
        elapsed.as_secs_f64() < 5.0,
        "scale campaign took {:.2}s (budget 5s)",
        elapsed.as_secs_f64()
    );
    let crossover = |ranks: usize| result.row(ranks).expect("swept rank count").crossover_pool;
    assert_eq!(crossover(64), Some(256));
    assert_eq!(crossover(256), Some(512));
    for ranks in [1024, 4096, 16384] {
        assert_eq!(crossover(ranks), None, "{ranks} ranks: node-local must win");
    }
    // the trajectory is monotone in the meaningful sense: the pool
    // needed to match local never shrinks as the machine grows
    let p64 = crossover(64).unwrap();
    let p256 = crossover(256).unwrap();
    assert!(p64 <= p256);
}
