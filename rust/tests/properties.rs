//! Property-based tests (in-tree proptest substitute: seeded random
//! generation + many iterations + seed reported on failure).
//!
//! Invariants covered:
//! * batcher — conservation (every enqueued sample drains exactly
//!   once), FIFO per instance, max_batch respected, readiness
//!   monotone in time;
//! * wire protocol — request/response round-trip over arbitrary
//!   payloads, frame boundaries under concatenation;
//! * JSON — parse(write(v)) == v for arbitrary values;
//! * device models — monotonicity and positivity over the whole
//!   (device, api, batch) space;
//! * RDU — latency positive, monotone in mini-batch at fixed micro,
//!   spill never *reduces* a stage time.

use std::time::{Duration, Instant};

use cogsim_disagg::coordinator::batcher::{BatcherConfig, DynamicBatcher, PendingRequest, Priority};
use cogsim_disagg::devices::{profiles, Api, Gpu, GpuModel};
use cogsim_disagg::net::protocol::{self, Request, Response};
use cogsim_disagg::rdu::{RduApi, RduModel};
use cogsim_disagg::util::json::{self, Value};
use cogsim_disagg::util::rng::Rng;

const CASES: u64 = 200;

// ------------------------------------------------------------ batcher

#[test]
fn prop_batcher_conserves_samples() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed);
        let t0 = Instant::now();
        let target = rng.range(1, 64);
        let mut b = DynamicBatcher::new(BatcherConfig {
            target_batch: target,
            max_wait: Duration::from_micros(rng.range(0, 500) as u64),
            deferred_max_wait: std::time::Duration::from_millis(50),
            max_batch: target * rng.range(1, 4),
        });

        let n_requests = rng.range(1, 40);
        let mut enqueued = 0usize;
        let instances = ["a", "b", "c"];
        for id in 0..n_requests {
            let samples = rng.range(1, 32);
            enqueued += samples;
            let inst = rng.choice(&instances);
            b.enqueue(
                inst,
                PendingRequest { id: id as u64, input: vec![0.0; samples], samples, arrived: t0, priority: Priority::Critical },
            );
        }
        assert_eq!(b.queued_total(), enqueued, "seed {seed}");

        // drain to exhaustion far in the future (all deadlines passed)
        let late = t0 + Duration::from_secs(10);
        let mut drained = 0usize;
        let mut seen_ids = std::collections::BTreeSet::new();
        for _ in 0..1000 {
            let batches = b.drain_ready(late);
            if batches.is_empty() {
                break;
            }
            for batch in batches {
                assert!(batch.total_samples > 0, "seed {seed}");
                drained += batch.total_samples;
                for r in &batch.requests {
                    assert!(seen_ids.insert(r.id), "seed {seed}: duplicate id {}", r.id);
                }
            }
        }
        assert_eq!(drained, enqueued, "seed {seed}: conservation");
        assert_eq!(seen_ids.len(), n_requests, "seed {seed}: every request exactly once");
        assert_eq!(b.queued_total(), 0, "seed {seed}");
    }
}

#[test]
fn prop_batcher_fifo_per_instance() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0xF1F0);
        let t0 = Instant::now();
        let mut b = DynamicBatcher::new(BatcherConfig {
            target_batch: rng.range(1, 16),
            max_wait: Duration::ZERO,
            deferred_max_wait: std::time::Duration::from_millis(50),
            max_batch: rng.range(16, 64),
        });
        for id in 0..rng.range(2, 30) {
            b.enqueue(
                "x",
                PendingRequest {
                    id: id as u64,
                    input: vec![0.0; 1],
                    samples: rng.range(1, 8),
                    arrived: t0,
                    priority: Priority::Critical,
                },
            );
        }
        let mut last = -1i64;
        let late = t0 + Duration::from_secs(1);
        loop {
            let batches = b.drain_ready(late);
            if batches.is_empty() {
                break;
            }
            for batch in batches {
                for r in &batch.requests {
                    assert!(
                        (r.id as i64) > last,
                        "seed {seed}: FIFO violated ({} after {last})",
                        r.id
                    );
                    last = r.id as i64;
                }
            }
        }
    }
}

#[test]
fn prop_batcher_max_batch_respected() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0xBA7C);
        let t0 = Instant::now();
        let target = rng.range(1, 32);
        let max_batch = target * rng.range(1, 4);
        let mut b = DynamicBatcher::new(BatcherConfig {
            target_batch: target,
            max_wait: Duration::ZERO,
            deferred_max_wait: std::time::Duration::from_millis(50),
            max_batch,
        });
        let mut oversized = false;
        for id in 0..rng.range(1, 30) {
            let samples = rng.range(1, 48);
            oversized |= samples > max_batch;
            b.enqueue(
                "x",
                PendingRequest { id: id as u64, input: vec![], samples, arrived: t0, priority: Priority::Critical },
            );
        }
        let late = t0 + Duration::from_secs(1);
        loop {
            let batches = b.drain_ready(late);
            if batches.is_empty() {
                break;
            }
            for batch in batches {
                // a single over-max request is allowed through alone;
                // multi-request batches must respect the cap
                if batch.requests.len() > 1 {
                    assert!(
                        batch.total_samples <= max_batch,
                        "seed {seed}: {} > {max_batch}",
                        batch.total_samples
                    );
                }
            }
        }
        let _ = oversized;
    }
}

#[test]
fn prop_batcher_readiness_monotone_in_time() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x7135);
        let t0 = Instant::now();
        let wait = Duration::from_micros(rng.range(1, 1000) as u64);
        let mut b = DynamicBatcher::new(BatcherConfig {
            target_batch: 1_000_000, // size trigger never fires
            max_wait: wait,
            deferred_max_wait: std::time::Duration::from_millis(50),
            max_batch: 1_000_000,
        });
        b.enqueue(
            "x",
            PendingRequest { id: 0, input: vec![], samples: rng.range(1, 9), arrived: t0, priority: Priority::Critical },
        );
        // strictly before the deadline: not ready; at/after: ready
        assert!(!b.has_ready(t0), "seed {seed}");
        assert!(b.has_ready(t0 + wait), "seed {seed}");
        assert!(b.has_ready(t0 + wait * 2), "seed {seed}");
    }
}

// ----------------------------------------------------------- protocol

#[test]
fn prop_protocol_roundtrip_arbitrary() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x9a0c);
        let model: String = (0..rng.range(1, 24))
            .map(|_| (b'a' + rng.below(26) as u8) as char)
            .collect();
        let n = rng.range(0, 256);
        let payload: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let req = Request {
            id: rng.next_u64(),
            model: model.clone(),
            priority: (rng.below(2)) as u8,
            n_samples: rng.range(0, 1 << 20) as u32,
            payload: payload.clone(),
        };
        let bytes = protocol::encode_request(&req);
        let got = protocol::read_request(&mut &bytes[..]).unwrap().unwrap();
        assert_eq!(got, req, "seed {seed}");

        let resp = Response::ok(req.id, &payload);
        let rbytes = protocol::encode_response(&resp);
        let rgot = protocol::read_response(&mut &rbytes[..]).unwrap().unwrap();
        assert_eq!(rgot.rows().unwrap(), payload, "seed {seed}");
    }
}

#[test]
fn prop_protocol_frames_self_delimit() {
    // concatenated frames parse back one by one with nothing left over
    for seed in 0..50 {
        let mut rng = Rng::new(seed ^ 0xCAFE);
        let k = rng.range(2, 6);
        let reqs: Vec<Request> = (0..k)
            .map(|i| Request {
                id: i as u64,
                model: "m".into(),
                priority: 0,
                n_samples: 1,
                payload: (0..rng.range(0, 64)).map(|_| rng.f32()).collect(),
            })
            .collect();
        let mut stream = Vec::new();
        for r in &reqs {
            stream.extend_from_slice(&protocol::encode_request(r));
        }
        let mut cursor = &stream[..];
        for (i, expect) in reqs.iter().enumerate() {
            let got = protocol::read_request(&mut cursor).unwrap().unwrap();
            assert_eq!(&got, expect, "seed {seed} frame {i}");
        }
        assert!(protocol::read_request(&mut cursor).unwrap().is_none(), "seed {seed}");
    }
}

// --------------------------------------------------------------- JSON

fn arbitrary_json(rng: &mut Rng, depth: usize) -> Value {
    match if depth == 0 { rng.below(4) } else { rng.below(6) } {
        0 => Value::Null,
        1 => Value::Bool(rng.f64() < 0.5),
        2 => {
            // representable round-trip numbers: keep them simple
            Value::Number((rng.normal() * 1e6).round())
        }
        3 => Value::String(
            (0..rng.range(0, 12))
                .map(|_| (b' ' + rng.below(94) as u8) as char)
                .collect(),
        ),
        4 => Value::Array(
            (0..rng.range(0, 5))
                .map(|_| arbitrary_json(rng, depth - 1))
                .collect(),
        ),
        _ => {
            let mut map = std::collections::BTreeMap::new();
            for i in 0..rng.range(0, 5) {
                map.insert(format!("k{i}"), arbitrary_json(rng, depth - 1));
            }
            Value::Object(map)
        }
    }
}

#[test]
fn prop_json_roundtrip() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x15de);
        let v = arbitrary_json(&mut rng, 3);
        let text = json::write(&v);
        let back = json::parse(&text).unwrap_or_else(|e| panic!("seed {seed}: {e}\n{text}"));
        assert_eq!(back, v, "seed {seed}");
    }
}

// ------------------------------------------------------ device models

#[test]
fn prop_gpu_latency_positive_and_monotone() {
    let gpus = [Gpu::p100(), Gpu::v100(), Gpu::a100(), Gpu::mi50(), Gpu::mi100()];
    for gpu in &gpus {
        for api in Api::ALL {
            for profile in [profiles::hermit(), profiles::mir(), profiles::mir_noln()] {
                let m = GpuModel::new(gpu.clone(), api, profile);
                let mut prev = 0.0;
                for b in [1usize, 2, 3, 5, 8, 13, 100, 999, 4096, 30000, 32768] {
                    let l = m.latency_s(b);
                    assert!(l > 0.0 && l.is_finite(), "{} {:?} {b}", gpu.name, api);
                    assert!(l >= prev, "{} {:?} {b}: {l} < {prev}", gpu.name, api);
                    prev = l;
                }
            }
        }
    }
}

#[test]
fn prop_gpu_throughput_bounded_by_peak() {
    // throughput can never exceed peak FLOPs / model FLOPs
    for gpu in [Gpu::p100(), Gpu::a100(), Gpu::mi100()] {
        for api in Api::ALL {
            let p = profiles::hermit();
            let bound = gpu.peak_half_tflops * 1e12 / p.flops_per_sample;
            let m = GpuModel::new(gpu.clone(), api, p);
            for b in [1usize, 256, 32768] {
                assert!(m.throughput(b) < bound, "{} {:?} {b}", gpu.name, api);
            }
        }
    }
}

// ---------------------------------------------------------------- RDU

#[test]
fn prop_rdu_latency_monotone_in_mini_at_fixed_micro() {
    for seed in 0..50u64 {
        let mut rng = Rng::new(seed ^ 0x0d0);
        let tiles = rng.range(1, 4);
        let api = *rng.choice(&RduApi::ALL);
        let m = RduModel::new(profiles::hermit(), tiles, api);
        let micro = 1 << rng.below(8);
        let mut prev = 0.0;
        for shift in 0..10 {
            let mini = micro << shift;
            let l = m.latency_s(mini, micro);
            assert!(l > prev, "seed {seed}: mini {mini} micro {micro}");
            prev = l;
        }
    }
}

#[test]
fn prop_rdu_best_micro_is_optimal() {
    // best_micro must actually minimise over the candidate set
    for seed in 0..50u64 {
        let mut rng = Rng::new(seed ^ 0xbe57);
        let m = RduModel::new(profiles::hermit(), rng.range(1, 4), RduApi::CppOptimized);
        let mini = 1 << rng.below(16);
        let best = m.best_micro(mini);
        let best_l = m.latency_s(mini, best);
        for micro in RduModel::micro_candidates(mini, false) {
            assert!(
                best_l <= m.latency_s(mini, micro) + 1e-15,
                "seed {seed}: mini {mini}, micro {micro} beats 'best' {best}"
            );
        }
    }
}

#[test]
fn prop_rdu_throughput_saturates_not_explodes() {
    // throughput grows with mini-batch but stays below the fabric's
    // streaming bound (1/t_sample)
    let m = RduModel::new(profiles::hermit(), 4, RduApi::CppOptimized);
    let bound = 9.9e6 * 1.01;
    let mut prev = 0.0;
    for b in [1usize, 16, 256, 4096, 32768] {
        let t = m.throughput_best(b);
        assert!(t > prev, "batch {b}");
        assert!(t < bound, "batch {b}: {t}");
        prev = t;
    }
}
