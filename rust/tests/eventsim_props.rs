//! Property tests for the discrete-event simulator: the invariants
//! that must hold for *every* seed, arrival process, and batching
//! configuration — event-time monotonicity, request conservation,
//! batching-window/max-batch bounds, and bit-identical determinism.

use cogsim_disagg::cluster::{Backend, GpuBackend, Policy, RduBackend};
use cogsim_disagg::devices::{Api, Gpu};
use cogsim_disagg::eventsim::{ArrivalProcess, Batching, EventSim, EventSimConfig};
use cogsim_disagg::harness::{run_event_campaign, EventCampaignConfig};
use cogsim_disagg::rdu::RduApi;
use cogsim_disagg::util::json;

fn mixed_fleet() -> Vec<Box<dyn Backend>> {
    vec![
        Box::new(GpuBackend::node_local("gpu/rank0", Gpu::a100(), Api::TrtCudaGraphs)),
        Box::new(GpuBackend::node_local("gpu/rank1", Gpu::a100(), Api::NaivePyTorch)),
        Box::new(RduBackend::disaggregated("rdu/pool0", 4, RduApi::CppOptimized)),
        Box::new(RduBackend::disaggregated("rdu/pool1", 2, RduApi::Python)),
    ]
}

fn arrivals() -> [ArrivalProcess; 3] {
    [
        ArrivalProcess::Synchronized { period_s: 0.01, jitter_s: 50e-6 },
        ArrivalProcess::Poisson { rate_per_rank: 1500.0 },
        ArrivalProcess::ClosedLoop { think_s: 1e-3 },
    ]
}

fn batchings() -> [Batching; 2] {
    [Batching::Off, Batching::Window { window_s: 100e-6, max_batch: 64 }]
}

#[test]
fn event_time_monotonicity() {
    // dispatch times are non-decreasing in dispatch order, and every
    // record keeps arrival <= dispatch < completion
    for arrival in arrivals() {
        for batching in batchings() {
            for seed in [1u64, 99] {
                let cfg = EventSimConfig {
                    ranks: 12,
                    arrival,
                    batching,
                    horizon_s: 0.05,
                    seed,
                    ..Default::default()
                };
                let mut sim = EventSim::new(mixed_fleet(), Policy::LeastOutstanding, cfg);
                sim.run_to_completion();
                let recs = sim.records();
                assert!(!recs.is_empty(), "{arrival:?}/{batching:?}");
                for pair in recs.windows(2) {
                    assert!(
                        pair[1].dispatch_s >= pair[0].dispatch_s,
                        "{arrival:?}/{batching:?}: dispatch went backwards"
                    );
                }
                for r in recs {
                    assert!(r.arrival_s <= r.dispatch_s, "waited negative time");
                    assert!(r.complete_s > r.dispatch_s, "zero/negative service");
                    assert!(r.latency_s() > 0.0 && r.latency_s().is_finite());
                }
            }
        }
    }
}

#[test]
fn request_conservation_at_the_horizon_and_at_drain() {
    for arrival in arrivals() {
        for batching in batchings() {
            let cfg = EventSimConfig {
                ranks: 16,
                arrival,
                batching,
                horizon_s: 0.06,
                seed: 3,
                ..Default::default()
            };
            let mut sim = EventSim::new(mixed_fleet(), Policy::LatencyAware, cfg);
            // stop mid-run: submitted splits exactly into completed,
            // in flight on a backend, and waiting in the batcher
            sim.run_until(0.03);
            assert_eq!(
                sim.submitted(),
                sim.completed() + sim.in_flight() + sim.batcher_pending(),
                "{arrival:?}/{batching:?} mid-run"
            );
            // drain: everything submitted must complete
            sim.run_to_completion();
            assert!(sim.submitted() > 0);
            assert_eq!(sim.completed(), sim.submitted(), "{arrival:?}/{batching:?}");
            assert_eq!(sim.in_flight(), 0);
            assert_eq!(sim.batcher_pending(), 0);
            assert_eq!(sim.records().len() as u64, sim.submitted());
        }
    }
}

#[test]
fn batches_respect_max_batch_and_window() {
    const WINDOW_S: f64 = 100e-6;
    const MAX_BATCH: usize = 64;
    for arrival in arrivals() {
        let cfg = EventSimConfig {
            ranks: 24,
            samples_per_request: (1, 3),
            arrival,
            batching: Batching::Window { window_s: WINDOW_S, max_batch: MAX_BATCH },
            horizon_s: 0.05,
            seed: 11,
            ..Default::default()
        };
        let mut sim = EventSim::new(mixed_fleet(), Policy::LeastOutstanding, cfg);
        sim.run_to_completion();
        let mut coalesced = false;
        for r in sim.records() {
            // every request is smaller than max_batch, so no batch may
            // ever exceed the cap
            assert!(
                r.batch_samples <= MAX_BATCH,
                "{arrival:?}: batch of {} samples",
                r.batch_samples
            );
            // the window bound: deadline wake-ups land exactly on the
            // ns-quantised deadline, so the only slack is the ns
            // rounding of the arrival instant itself
            assert!(
                r.batch_wait_s() <= WINDOW_S + 1e-9,
                "{arrival:?}: request held {}s past its window",
                r.batch_wait_s() - WINDOW_S
            );
            coalesced |= r.batch_samples > r.samples;
        }
        assert!(coalesced, "{arrival:?}: 24 ranks must co-batch at least once");
    }
}

#[test]
fn identical_seeds_give_byte_identical_summaries() {
    let cfg = EventCampaignConfig {
        rank_counts: vec![8],
        horizon_s: 0.04,
        ..Default::default()
    };
    let a = json::write(&run_event_campaign(&cfg).to_json());
    let b = json::write(&run_event_campaign(&cfg).to_json());
    assert_eq!(a, b, "same seed must serialise identically");

    let different = EventCampaignConfig { seed: 43, ..cfg };
    let c = json::write(&run_event_campaign(&different).to_json());
    assert_ne!(a, c, "a different seed must change the summary");
}

#[test]
fn batch_close_ties_admit_same_instant_arrivals() {
    // Regression for the batch-close/arrival tie: pick a burst period
    // that is *exactly* the batching window (both powers of two, so
    // every burst time and every ns-quantised deadline is exact in
    // f64 and they collide bit-for-bit).  Burst k's window expires at
    // the very instant burst k+1 arrives; the event queue must order
    // the arrivals before the deadline, so odd bursts ride the
    // closing batch with zero wait while even bursts wait the full
    // window.  Before the class-tiered event queue this ordering
    // depended on when the wake-up happened to be scheduled (and an
    // epsilon kept the deadline 2 ns late); now it is pinned.
    const P: f64 = 0.015625; // 2^-6 s: exact in f64 and in ns
    let cfg = EventSimConfig {
        ranks: 4,
        materials: 2,
        arrival: ArrivalProcess::Synchronized { period_s: P, jitter_s: 0.0 },
        batching: Batching::Window { window_s: P, max_batch: 1 << 20 },
        horizon_s: 0.05, // bursts at 0, P, 2P, 3P
        seed: 9,
        ..Default::default()
    };
    let mut sim = EventSim::new(mixed_fleet(), Policy::LeastOutstanding, cfg);
    sim.run_to_completion();
    assert_eq!(sim.completed(), sim.submitted());
    assert_eq!(sim.submitted(), 4 * 4 * 6, "4 bursts x 4 ranks x 6 requests");
    let mut odd_burst_riders = 0;
    for r in sim.records() {
        let burst = (r.arrival_s / P).round() as usize;
        assert!((r.arrival_s - burst as f64 * P).abs() < 1e-15, "exact burst times");
        if burst % 2 == 0 {
            // even bursts open the window and wait it out fully
            assert!(
                (r.batch_wait_s() - P).abs() < 1e-12,
                "burst {burst}: waited {} not the window",
                r.batch_wait_s()
            );
        } else {
            // odd bursts arrive at the closing instant and ride along
            assert!(
                r.batch_wait_s().abs() < 1e-12,
                "burst {burst}: rider waited {}",
                r.batch_wait_s()
            );
            odd_burst_riders += 1;
            assert!(
                r.batch_samples > r.samples,
                "burst {burst}: rider must share its batch with the opener"
            );
        }
    }
    assert_eq!(odd_burst_riders, 2 * 4 * 6, "bursts 1 and 3 ride");
    // pairing halves the batch count: one batch per material per
    // burst pair
    assert_eq!(sim.batches(), 2 * 2, "2 burst pairs x 2 materials");
}

#[test]
fn zero_window_batches_like_off_but_through_the_deadline_path() {
    // window_s = 0: every request's deadline expires at its own
    // arrival instant.  The arrival-path drain must NOT fire it (size
    // trigger only); the same-instant deadline wake-up must.  All
    // same-instant same-material requests therefore still coalesce —
    // deterministically — instead of dispatching one-by-one.
    let cfg = EventSimConfig {
        ranks: 8,
        materials: 2,
        arrival: ArrivalProcess::Synchronized { period_s: 0.01, jitter_s: 0.0 },
        batching: Batching::Window { window_s: 0.0, max_batch: 1 << 20 },
        horizon_s: 0.025,
        seed: 3,
        ..Default::default()
    };
    let mut sim = EventSim::new(mixed_fleet(), Policy::LeastOutstanding, cfg);
    sim.run_to_completion();
    assert_eq!(sim.completed(), sim.submitted());
    for r in sim.records() {
        assert!(r.batch_wait_s().abs() < 1e-12, "zero window adds no wait");
    }
    // all of a burst's same-material requests ride one batch: 3
    // bursts x 2 materials
    assert_eq!(sim.batches(), 3 * 2, "{} batches", sim.batches());
    assert!(sim.records().iter().any(|r| r.batch_samples > r.samples));
}

#[test]
fn backends_see_only_their_tier() {
    // hermit pinned to the pool (2, 3), mir to the GPUs (0, 1)
    let cfg = EventSimConfig {
        ranks: 4,
        mir_every: 2,
        mir_samples: 64,
        horizon_s: 0.05,
        batching: Batching::Window { window_s: 50e-6, max_batch: 128 },
        ..Default::default()
    };
    let mut sim =
        EventSim::with_tiers(mixed_fleet(), Policy::LatencyAware, cfg, vec![2, 3], vec![0, 1]);
    sim.run_to_completion();
    assert!(sim.records().iter().any(|r| r.model == "mir"));
    for r in sim.records() {
        if r.model.starts_with("mir") {
            assert!(r.backend < 2);
        } else {
            assert!(r.backend >= 2);
        }
    }
}

#[test]
fn open_loop_volume_is_service_independent() {
    // Poisson and synchronized arrivals are open loop: the submitted
    // count must not depend on policy, batching, or fleet speed.
    for arrival in [
        ArrivalProcess::Synchronized { period_s: 0.01, jitter_s: 0.0 },
        ArrivalProcess::Poisson { rate_per_rank: 1000.0 },
    ] {
        let mut volumes = Vec::new();
        for policy in [Policy::RoundRobin, Policy::LatencyAware] {
            for batching in batchings() {
                let cfg = EventSimConfig {
                    ranks: 6,
                    arrival,
                    batching,
                    horizon_s: 0.05,
                    seed: 5,
                    ..Default::default()
                };
                let mut sim = EventSim::new(mixed_fleet(), policy, cfg);
                sim.run_to_completion();
                volumes.push(sim.submitted());
            }
        }
        assert!(volumes.iter().all(|&v| v == volumes[0]), "{arrival:?}: {volumes:?}");
    }
}
