//! Property tests for the discrete-event simulator: the invariants
//! that must hold for *every* seed, arrival process, and batching
//! configuration — event-time monotonicity, request conservation,
//! batching-window/max-batch bounds, and bit-identical determinism.

use cogsim_disagg::cluster::{Backend, GpuBackend, Policy, RduBackend};
use cogsim_disagg::devices::{Api, Gpu};
use cogsim_disagg::eventsim::{ArrivalProcess, Batching, EventSim, EventSimConfig};
use cogsim_disagg::harness::campaign::{run_event_campaign, EventCampaignConfig};
use cogsim_disagg::rdu::RduApi;
use cogsim_disagg::util::json;

fn mixed_fleet() -> Vec<Box<dyn Backend>> {
    vec![
        Box::new(GpuBackend::node_local("gpu/rank0", Gpu::a100(), Api::TrtCudaGraphs)),
        Box::new(GpuBackend::node_local("gpu/rank1", Gpu::a100(), Api::NaivePyTorch)),
        Box::new(RduBackend::disaggregated("rdu/pool0", 4, RduApi::CppOptimized)),
        Box::new(RduBackend::disaggregated("rdu/pool1", 2, RduApi::Python)),
    ]
}

fn arrivals() -> [ArrivalProcess; 3] {
    [
        ArrivalProcess::Synchronized { period_s: 0.01, jitter_s: 50e-6 },
        ArrivalProcess::Poisson { rate_per_rank: 1500.0 },
        ArrivalProcess::ClosedLoop { think_s: 1e-3 },
    ]
}

fn batchings() -> [Batching; 2] {
    [Batching::Off, Batching::Window { window_s: 100e-6, max_batch: 64 }]
}

#[test]
fn event_time_monotonicity() {
    // dispatch times are non-decreasing in dispatch order, and every
    // record keeps arrival <= dispatch < completion
    for arrival in arrivals() {
        for batching in batchings() {
            for seed in [1u64, 99] {
                let cfg = EventSimConfig {
                    ranks: 12,
                    arrival,
                    batching,
                    horizon_s: 0.05,
                    seed,
                    ..Default::default()
                };
                let mut sim = EventSim::new(mixed_fleet(), Policy::LeastOutstanding, cfg);
                sim.run_to_completion();
                let recs = sim.records();
                assert!(!recs.is_empty(), "{arrival:?}/{batching:?}");
                for pair in recs.windows(2) {
                    assert!(
                        pair[1].dispatch_s >= pair[0].dispatch_s,
                        "{arrival:?}/{batching:?}: dispatch went backwards"
                    );
                }
                for r in recs {
                    assert!(r.arrival_s <= r.dispatch_s, "waited negative time");
                    assert!(r.complete_s > r.dispatch_s, "zero/negative service");
                    assert!(r.latency_s() > 0.0 && r.latency_s().is_finite());
                }
            }
        }
    }
}

#[test]
fn request_conservation_at_the_horizon_and_at_drain() {
    for arrival in arrivals() {
        for batching in batchings() {
            let cfg = EventSimConfig {
                ranks: 16,
                arrival,
                batching,
                horizon_s: 0.06,
                seed: 3,
                ..Default::default()
            };
            let mut sim = EventSim::new(mixed_fleet(), Policy::LatencyAware, cfg);
            // stop mid-run: submitted splits exactly into completed,
            // in flight on a backend, and waiting in the batcher
            sim.run_until(0.03);
            assert_eq!(
                sim.submitted(),
                sim.completed() + sim.in_flight() + sim.batcher_pending(),
                "{arrival:?}/{batching:?} mid-run"
            );
            // drain: everything submitted must complete
            sim.run_to_completion();
            assert!(sim.submitted() > 0);
            assert_eq!(sim.completed(), sim.submitted(), "{arrival:?}/{batching:?}");
            assert_eq!(sim.in_flight(), 0);
            assert_eq!(sim.batcher_pending(), 0);
            assert_eq!(sim.records().len() as u64, sim.submitted());
        }
    }
}

#[test]
fn batches_respect_max_batch_and_window() {
    const WINDOW_S: f64 = 100e-6;
    const MAX_BATCH: usize = 64;
    for arrival in arrivals() {
        let cfg = EventSimConfig {
            ranks: 24,
            samples_per_request: (1, 3),
            arrival,
            batching: Batching::Window { window_s: WINDOW_S, max_batch: MAX_BATCH },
            horizon_s: 0.05,
            seed: 11,
            ..Default::default()
        };
        let mut sim = EventSim::new(mixed_fleet(), Policy::LeastOutstanding, cfg);
        sim.run_to_completion();
        let mut coalesced = false;
        for r in sim.records() {
            // every request is smaller than max_batch, so no batch may
            // ever exceed the cap
            assert!(
                r.batch_samples <= MAX_BATCH,
                "{arrival:?}: batch of {} samples",
                r.batch_samples
            );
            // the window bound: dispatched within window of arrival
            // (+5 ns slack for the ns-quantised deadline wake-up)
            assert!(
                r.batch_wait_s() <= WINDOW_S + 5e-9,
                "{arrival:?}: request held {}s past its window",
                r.batch_wait_s() - WINDOW_S
            );
            coalesced |= r.batch_samples > r.samples;
        }
        assert!(coalesced, "{arrival:?}: 24 ranks must co-batch at least once");
    }
}

#[test]
fn identical_seeds_give_byte_identical_summaries() {
    let cfg = EventCampaignConfig {
        rank_counts: vec![8],
        horizon_s: 0.04,
        ..Default::default()
    };
    let a = json::write(&run_event_campaign(&cfg).to_json());
    let b = json::write(&run_event_campaign(&cfg).to_json());
    assert_eq!(a, b, "same seed must serialise identically");

    let different = EventCampaignConfig { seed: 43, ..cfg };
    let c = json::write(&run_event_campaign(&different).to_json());
    assert_ne!(a, c, "a different seed must change the summary");
}

#[test]
fn backends_see_only_their_tier() {
    // hermit pinned to the pool (2, 3), mir to the GPUs (0, 1)
    let cfg = EventSimConfig {
        ranks: 4,
        mir_every: 2,
        mir_samples: 64,
        horizon_s: 0.05,
        batching: Batching::Window { window_s: 50e-6, max_batch: 128 },
        ..Default::default()
    };
    let mut sim =
        EventSim::with_tiers(mixed_fleet(), Policy::LatencyAware, cfg, vec![2, 3], vec![0, 1]);
    sim.run_to_completion();
    assert!(sim.records().iter().any(|r| r.model == "mir"));
    for r in sim.records() {
        if r.model.starts_with("mir") {
            assert!(r.backend < 2);
        } else {
            assert!(r.backend >= 2);
        }
    }
}

#[test]
fn open_loop_volume_is_service_independent() {
    // Poisson and synchronized arrivals are open loop: the submitted
    // count must not depend on policy, batching, or fleet speed.
    for arrival in [
        ArrivalProcess::Synchronized { period_s: 0.01, jitter_s: 0.0 },
        ArrivalProcess::Poisson { rate_per_rank: 1000.0 },
    ] {
        let mut volumes = Vec::new();
        for policy in [Policy::RoundRobin, Policy::LatencyAware] {
            for batching in batchings() {
                let cfg = EventSimConfig {
                    ranks: 6,
                    arrival,
                    batching,
                    horizon_s: 0.05,
                    seed: 5,
                    ..Default::default()
                };
                let mut sim = EventSim::new(mixed_fleet(), policy, cfg);
                sim.run_to_completion();
                volumes.push(sim.submitted());
            }
        }
        assert!(volumes.iter().all(|&v| v == volumes[0]), "{arrival:?}: {volumes:?}");
    }
}
