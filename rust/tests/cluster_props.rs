//! Property tests for the cluster layer, the RDU config landscape and
//! the batch-ladder padding rules (in-tree proptest substitute:
//! seeded random generation + many iterations + seed in the failure
//! message).
//!
//! Invariants covered:
//! * cluster routing — under ANY policy, total routed samples equals
//!   total submitted samples (nothing lost, nothing duplicated),
//!   queues never go negative, and advancing past the makespan
//!   drains every backend;
//! * RDU — every `config_valid` (mini, micro) combination yields a
//!   positive, finite latency, monotone in the mini-batch at fixed
//!   micro-batch;
//! * padding — `batch_for` always picks the *smallest* ladder rung
//!   that fits (padding never exceeds the next rung), and the padded
//!   execution path returns exactly the requested rows.

use cogsim_disagg::cluster::{Backend, Cluster, GpuBackend, Policy, RduBackend};
use cogsim_disagg::devices::{profiles, Api, Gpu};
use cogsim_disagg::rdu::{RduApi, RduModel};
use cogsim_disagg::runtime::Engine;
use cogsim_disagg::util::rng::Rng;

const CASES: u64 = 100;

fn random_fleet(rng: &mut Rng) -> Vec<Box<dyn Backend>> {
    let n = rng.range(1, 5);
    (0..n)
        .map(|i| -> Box<dyn Backend> {
            if rng.below(2) == 0 {
                let gpu = match rng.below(3) {
                    0 => Gpu::a100(),
                    1 => Gpu::v100(),
                    _ => Gpu::mi100(),
                };
                let api = *rng.choice(&Api::ALL);
                Box::new(GpuBackend::node_local(format!("gpu{i}"), gpu, api))
            } else {
                let tiles = rng.range(1, 4);
                let api = *rng.choice(&RduApi::ALL);
                Box::new(RduBackend::disaggregated(format!("rdu{i}"), tiles, api))
            }
        })
        .collect()
}

#[test]
fn prop_cluster_conserves_samples_under_any_policy() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed);
        let policy = *rng.choice(&Policy::ALL);
        let mut cluster = Cluster::new(random_fleet(&mut rng), policy);
        let n_backends = cluster.len();

        let profiles_pool = [profiles::hermit(), profiles::mir_noln()];
        let mut submitted_samples = 0u64;
        let n_requests = rng.range(1, 60);
        for i in 0..n_requests {
            let profile = rng.choice(&profiles_pool).clone();
            let samples = rng.range(1, 300);
            let instance = format!("inst{}", rng.below(6));
            // occasionally advance virtual time mid-stream
            if rng.below(5) == 0 {
                let t = cluster.clock_s() + rng.uniform(0.0, 0.01);
                cluster.advance_to(t);
            }
            let routed = cluster.submit(&instance, &profile, samples);
            assert!(routed.backend < n_backends, "seed {seed} req {i}");
            assert!(routed.latency_s > 0.0 && routed.latency_s.is_finite(), "seed {seed}");
            assert!(routed.wait_s >= 0.0, "seed {seed}");
            assert!(routed.latency_s >= routed.wait_s + routed.link_overhead_s, "seed {seed}");
            submitted_samples += samples as u64;
        }

        assert_eq!(cluster.routed_samples(), submitted_samples, "seed {seed}: conservation");
        assert_eq!(cluster.routed_requests(), n_requests as u64, "seed {seed}");
        let report = cluster.report();
        let by_backend: u64 = report.iter().map(|r| r.samples).sum();
        assert_eq!(by_backend, submitted_samples, "seed {seed}: per-backend split");
        for r in &report {
            assert!(r.queue_s >= 0.0, "seed {seed}: negative queue on {}", r.name);
        }

        // draining past the makespan empties every queue
        let makespan = cluster.makespan_s();
        cluster.advance_to(makespan + 1.0);
        for r in cluster.report() {
            assert_eq!(r.queue_s, 0.0, "seed {seed}: {} not drained", r.name);
        }
    }
}

#[test]
fn prop_affinity_is_sticky_under_random_traffic() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0xAFF1);
        let mut cluster = Cluster::new(random_fleet(&mut rng), Policy::ModelAffinity);
        let p = profiles::hermit();
        let mut first_choice: std::collections::BTreeMap<String, usize> =
            std::collections::BTreeMap::new();
        for _ in 0..rng.range(5, 40) {
            let instance = format!("hermit/mat{}", rng.below(5));
            let routed = cluster.submit(&instance, &p, rng.range(1, 64));
            match first_choice.get(&instance) {
                Some(&idx) => assert_eq!(routed.backend, idx, "seed {seed}: {instance}"),
                None => {
                    first_choice.insert(instance, routed.backend);
                }
            }
        }
    }
}

// ---------------------------------------------------------------- RDU

#[test]
fn prop_rdu_valid_configs_never_negative_or_nonmonotone() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x0D0D);
        let tiles = rng.range(1, 4);
        let api = *rng.choice(&RduApi::ALL);
        let profile = if rng.below(2) == 0 { profiles::hermit() } else { profiles::mir_noln() };
        let m = RduModel::new(profile, tiles, api);

        let micro = 1usize << rng.below(11);
        let mut prev = 0.0f64;
        for shift in 0..8 {
            let mini = micro << shift;
            assert!(m.config_valid(mini, micro), "seed {seed}");
            let l = m.latency_s(mini, micro);
            assert!(l > 0.0 && l.is_finite(), "seed {seed}: mini {mini} micro {micro} -> {l}");
            assert!(l > prev, "seed {seed}: non-monotone at mini {mini} micro {micro}");
            prev = l;
        }
        // invalid combinations are rejected, not silently computed
        assert!(!m.config_valid(micro, micro * 2), "seed {seed}: micro > mini");
        assert!(!m.config_valid(4, 0), "seed {seed}: zero micro");
    }
}

// ------------------------------------------------------------ padding

#[test]
fn prop_batch_for_picks_smallest_fitting_rung() {
    let engine = Engine::sim_reference();
    let spec = engine.spec("hermit").unwrap().clone();
    let ladder = spec.batch_ladder();
    let max = *ladder.last().unwrap();
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x9AD);
        let n = rng.range(1, 3 * max);
        let chunk = n.min(max);
        let chosen = spec.batch_for(chunk);
        // reference: linear scan for the smallest rung >= chunk
        let reference = ladder
            .iter()
            .copied()
            .find(|&b| b >= chunk)
            .unwrap_or(max);
        assert_eq!(chosen, reference, "seed {seed}: n {n}");
        // padding never exceeds the next ladder rung
        assert!(chosen >= chunk || chunk > max, "seed {seed}");
        for &rung in &ladder {
            if rung >= chunk {
                assert!(chosen <= rung, "seed {seed}: overshot the next rung");
            }
        }
    }
}

#[test]
fn prop_padding_waste_is_bounded_by_ladder_geometry() {
    let engine = Engine::sim_reference();
    // ladder 1,4,16,64,256,1024: worst fit is rung/4 + 1 -> <75% waste
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x4AD);
        let n = rng.range(1, 5000);
        let waste = engine.padding_waste("hermit", n).unwrap();
        assert!((0.0..0.75).contains(&waste), "seed {seed}: n {n} waste {waste}");
    }
    // exact fits are free
    for n in [1usize, 4, 16, 64, 256, 1024, 2048] {
        assert_eq!(engine.padding_waste("hermit", n).unwrap(), 0.0, "n {n}");
    }
}

#[test]
fn prop_execute_padded_returns_exactly_n_rows() {
    let engine = Engine::sim_reference();
    let spec = engine.spec("hermit").unwrap().clone();
    let (in_el, out_el) = (spec.input_elems(), spec.output_elems());
    for seed in 0..40 {
        let mut rng = Rng::new(seed ^ 0xE0E);
        let n = rng.range(1, 50);
        let x = rng.normal_vec(n * in_el);
        let (out, _) = engine.execute_padded("hermit", &x).unwrap();
        assert_eq!(out.len(), n * out_el, "seed {seed}");
        // each row matches its solo execution (padding never leaks)
        let probe = rng.below(n);
        let (row, _) = engine
            .execute("hermit", 1, &x[probe * in_el..(probe + 1) * in_el])
            .unwrap();
        assert_eq!(
            &out[probe * out_el..(probe + 1) * out_el],
            &row[..],
            "seed {seed} row {probe}"
        );
    }
}
