//! Differential test: in the contention-free limit the coupled
//! CogSim engine must agree with the analytic virtual-time `Cluster`
//! — request for request, backend for backend, to 1e-9 seconds — and
//! each timestep's makespan must be exactly `compute_s` plus the
//! analytic latency of its K-request burst.
//!
//! The limit: **one rank** (no cross-rank contention), **one model**
//! (no residency pressure), **zero swap cost**, **zero overlap**
//! (requests are emitted only when compute ends, so each step's burst
//! finds the queues the previous burst fully drained), **batching
//! off** (every request dispatches alone, at its emission instant).
//! Then both models compute latency as `wait + link + execute`
//! through the *same* `Backend` methods and the *same* policy
//! selection, so they must coincide.  Any divergence means the
//! coupled engine's barrier, residency, or queue accounting drifted
//! from the analytic semantics.

use cogsim_disagg::cluster::{Backend, Cluster, GpuBackend, Policy, RduBackend};
use cogsim_disagg::devices::{profiles, Api, Gpu};
use cogsim_disagg::eventsim::{Batching, CogSim, CogSimConfig};
use cogsim_disagg::rdu::RduApi;

const COMPUTE_S: f64 = 2e-3;
const TIMESTEPS: usize = 6;
const K: usize = 6;

/// Two identical backends so every policy has a real choice to make.
fn gpu_fleet() -> Vec<Box<dyn Backend>> {
    (0..2)
        .map(|i| {
            Box::new(GpuBackend::node_local(
                format!("gpu/rank{i}"),
                Gpu::a100(),
                Api::TrtCudaGraphs,
            )) as Box<dyn Backend>
        })
        .collect()
}

fn rdu_fleet() -> Vec<Box<dyn Backend>> {
    (0..2)
        .map(|i| {
            Box::new(RduBackend::disaggregated(format!("rdu/pool{i}"), 4, RduApi::CppOptimized))
                as Box<dyn Backend>
        })
        .collect()
}

/// Run the coupled sim in the contention-free limit and replay the
/// same request sequence through the analytic cluster.
fn assert_cogsim_matches_analytic(
    fleet_name: &str,
    cog_fleet: Vec<Box<dyn Backend>>,
    analytic_fleet: Vec<Box<dyn Backend>>,
    policy: Policy,
) {
    let cfg = CogSimConfig {
        ranks: 1,
        timesteps: TIMESTEPS,
        compute_s: COMPUTE_S,
        compute_jitter_s: 0.0,
        requests_per_step: K,
        models: 1,
        samples_per_request: (2, 3),
        mir_every: 0,
        overlap: 0.0,
        swap_s: 0.0,
        residency_slots: 1,
        batching: Batching::Off,
        seed: 7,
        ..Default::default()
    };
    let mut sim = CogSim::new(cog_fleet, policy, cfg);
    sim.run_to_completion();
    assert_eq!(sim.steps().len(), TIMESTEPS);
    assert_eq!(sim.records().len(), TIMESTEPS * K);

    let mut cluster = Cluster::new(analytic_fleet, policy);
    let profile = profiles::hermit();
    // analytic max latency per step, for the makespan identity
    let mut step_max = vec![0.0f64; TIMESTEPS];
    for (i, rec) in sim.records().iter().enumerate() {
        assert_eq!(rec.model, "hermit/mat0", "one model in the mix");
        assert_eq!(rec.batch_samples, rec.samples, "batching off dispatches alone");
        assert_eq!(
            rec.dispatch_s, rec.emit_s,
            "{fleet_name}/{policy:?} req {i}: batching off must dispatch on emission"
        );
        assert_eq!(rec.swap_s, 0.0, "zero swap cost");
        cluster.advance_to(rec.dispatch_s);
        let routed = cluster.submit(&rec.model, &profile, rec.samples);
        assert_eq!(
            routed.backend, rec.backend,
            "{fleet_name}/{policy:?} req {i}: routed to different backends"
        );
        assert!(
            (routed.latency_s - rec.latency_s()).abs() < 1e-9,
            "{fleet_name}/{policy:?} req {i}: analytic {} vs coupled {}",
            routed.latency_s,
            rec.latency_s()
        );
        assert!(
            (routed.wait_s - rec.wait_s).abs() < 1e-12,
            "{fleet_name}/{policy:?} req {i}: queue wait diverged"
        );
        step_max[rec.step] = step_max[rec.step].max(routed.latency_s);
    }

    // Per-timestep makespan identity: the barrier-to-barrier duration
    // is exactly the physics compute plus the analytic latency of the
    // burst's slowest request.
    for (t, step) in sim.steps().iter().enumerate() {
        let expect = COMPUTE_S + step_max[t];
        assert!(
            (step.duration_s() - expect).abs() < 1e-9,
            "{fleet_name}/{policy:?} step {t}: duration {} vs compute + analytic {}",
            step.duration_s(),
            expect
        );
        // every step's burst starts on drained queues
        assert!(
            (step.compute_s - COMPUTE_S).abs() < 1e-12,
            "{fleet_name}/{policy:?} step {t}: critical-path compute share"
        );
        assert!(step.swap_s == 0.0);
    }
    // the coupled figure of merit follows: TTS = sum of the steps
    let tts: f64 = sim.steps().iter().map(|s| s.duration_s()).sum();
    assert!((sim.time_to_solution_s() - tts).abs() < 1e-9);
}

#[test]
fn gpu_fleet_matches_analytic_for_every_policy() {
    for policy in Policy::ALL {
        assert_cogsim_matches_analytic("gpu", gpu_fleet(), gpu_fleet(), policy);
    }
}

#[test]
fn rdu_fleet_matches_analytic_for_every_policy() {
    for policy in Policy::ALL {
        assert_cogsim_matches_analytic("rdu", rdu_fleet(), rdu_fleet(), policy);
    }
}

#[test]
fn each_step_burst_finds_drained_queues() {
    // The limit's precondition, asserted directly: with zero overlap
    // the first-dispatched request of every timestep waits on nothing.
    let cfg = CogSimConfig {
        ranks: 1,
        timesteps: TIMESTEPS,
        compute_s: COMPUTE_S,
        requests_per_step: K,
        models: 1,
        overlap: 0.0,
        swap_s: 0.0,
        batching: Batching::Off,
        seed: 7,
        ..Default::default()
    };
    let mut sim = CogSim::new(rdu_fleet(), Policy::LeastOutstanding, cfg);
    sim.run_to_completion();
    for t in 0..TIMESTEPS {
        let first = sim
            .records()
            .iter()
            .find(|r| r.step == t)
            .expect("every step has records");
        assert_eq!(first.wait_s, 0.0, "step {t}: queues must be drained at the barrier");
    }
}

#[test]
fn contention_breaks_the_identity_as_expected() {
    // Sanity check on the limit itself: with many ranks bursting into
    // a two-backend pool, per-step makespan must exceed compute plus
    // a single idle-latency — i.e. the differential limit above is
    // genuinely the contention-free special case.
    let cfg = CogSimConfig {
        ranks: 32,
        timesteps: 3,
        compute_s: COMPUTE_S,
        requests_per_step: K,
        models: 1,
        overlap: 0.0,
        swap_s: 0.0,
        batching: Batching::Off,
        seed: 7,
        ..Default::default()
    };
    let mut sim = CogSim::new(rdu_fleet(), Policy::LeastOutstanding, cfg);
    sim.run_to_completion();
    let idle = {
        let fleet = rdu_fleet();
        let p = profiles::hermit();
        fleet[0].latency_s(&p, 3)
    };
    for step in sim.steps() {
        assert!(
            step.duration_s() > COMPUTE_S + 2.0 * idle,
            "step {}: {} vs compute + idle {}",
            step.step,
            step.duration_s(),
            COMPUTE_S + idle
        );
    }
}
