//! Fabric correctness: the degenerate 1-flow limit against
//! `Link::rtt_overhead_s`, hand-computed max-min allocations on the
//! real pooled topology, and the conservation/monotonicity
//! properties the campaign's oversubscription knob relies on.

use cogsim_disagg::cluster::{Backend, Policy, RduBackend};
use cogsim_disagg::eventsim::{
    ArrivalProcess, CogSim, CogSimConfig, EventSim, EventSimConfig,
};
use cogsim_disagg::fabric::{max_min_rates, FabricEngine, FabricSpec, Topology};
use cogsim_disagg::netsim::{dir_payload_bytes, payload_bytes, Link};
use cogsim_disagg::rdu::RduApi;

const HERMIT_IN: usize = 42;
const HERMIT_OUT: usize = 30;

fn one_rdu() -> Vec<Box<dyn Backend>> {
    vec![Box::new(RduBackend::disaggregated("rdu/pool0", 4, RduApi::CppOptimized))]
}

fn pool() -> Vec<Box<dyn Backend>> {
    vec![
        Box::new(RduBackend::disaggregated("rdu/pool0", 4, RduApi::CppOptimized)),
        Box::new(RduBackend::disaggregated("rdu/pool1", 2, RduApi::Python)),
    ]
}

fn spec(hosts: usize, accels: usize, oversub: f64) -> FabricSpec {
    FabricSpec {
        topology: Topology::pooled(hosts, accels, oversub),
        accel_of_backend: (0..accels).collect(),
    }
}

// ------------------------------------------------ degenerate limit

/// One flow alone on a 1:1 fabric: the two directed transfers plus
/// their fixed tails must reassemble `Link::rtt_overhead_s` to 1e-9.
#[test]
fn one_flow_limit_reproduces_link_rtt_overhead() {
    let link = Link::infiniband_cx6();
    let topo = Topology::pooled(4, 2, 1.0);
    for batch in [1usize, 4, 64, 1024, 16384] {
        let (bytes_in, bytes_out) = dir_payload_bytes(HERMIT_IN, HERMIT_OUT, batch);
        let mut eng = FabricEngine::new(topo.clone());

        let mut elapsed = 0.0;
        for bytes in [bytes_in, bytes_out] {
            let path = eng.topology().request_path(0, 1);
            eng.start(elapsed, path, bytes);
            let t = eng.next_completion_s().unwrap();
            assert_eq!(eng.take_completed(t).len(), 1);
            elapsed = t + topo.dir_fixed_s(1);
        }
        let expect = link.rtt_overhead_s(payload_bytes(HERMIT_IN, HERMIT_OUT, batch));
        assert!(
            (elapsed - expect).abs() < 1e-9,
            "batch {batch}: fabric {elapsed} vs link {expect}"
        );
    }
}

/// The coupled engine in the sequential regime (1 rank, 1 request
/// per step, no swap, no overlap, batching off): the fabric path
/// must reproduce the legacy fixed-charge engine request for request
/// to 1e-9 — which is exactly why `cogsim_vs_analytic` keeps
/// holding.
#[test]
fn cogsim_fabric_degenerates_to_legacy_in_the_one_flow_limit() {
    let cfg = CogSimConfig {
        ranks: 1,
        timesteps: 6,
        requests_per_step: 1,
        models: 1,
        swap_s: 0.0,
        overlap: 0.0,
        ..Default::default()
    };
    let mut legacy = CogSim::new(one_rdu(), Policy::RoundRobin, cfg);
    legacy.run_to_completion();
    let mut fabric = CogSim::with_fabric(
        one_rdu(),
        Policy::RoundRobin,
        cfg,
        vec![0],
        vec![0],
        spec(1, 1, 1.0),
    );
    fabric.run_to_completion();

    assert_eq!(legacy.records().len(), fabric.records().len());
    assert!(!legacy.records().is_empty());
    for (l, f) in legacy.records().iter().zip(fabric.records()) {
        assert_eq!(l.model, f.model);
        assert!((l.emit_s - f.emit_s).abs() < 1e-9, "{} vs {}", l.emit_s, f.emit_s);
        assert!(
            (l.complete_s - f.complete_s).abs() < 1e-9,
            "complete {} vs {}",
            l.complete_s,
            f.complete_s
        );
        assert!((l.latency_s() - f.latency_s()).abs() < 1e-9);
        // the measured transfer equals the degenerate link charge
        assert!((l.link_s - f.link_s).abs() < 1e-9, "{} vs {}", l.link_s, f.link_s);
        assert!(f.contention_s.abs() < 1e-9, "no sharing, no contention");
    }
    assert!(
        (legacy.time_to_solution_s() - fabric.time_to_solution_s()).abs() < 1e-9,
        "TTS {} vs {}",
        legacy.time_to_solution_s(),
        fabric.time_to_solution_s()
    );
}

/// Same degenerate limit for the open event engine: a closed loop
/// with one rank keeps exactly one transfer on the wire at a time.
#[test]
fn eventsim_fabric_degenerates_to_legacy_closed_loop() {
    let cfg = EventSimConfig {
        ranks: 1,
        arrival: ArrivalProcess::ClosedLoop { think_s: 2e-3 },
        horizon_s: 0.05,
        ..Default::default()
    };
    let mut legacy = EventSim::new(one_rdu(), Policy::RoundRobin, cfg);
    legacy.run_to_completion();
    let mut fabric = EventSim::with_fabric(
        one_rdu(),
        Policy::RoundRobin,
        cfg,
        vec![0],
        vec![0],
        spec(1, 1, 1.0),
    );
    fabric.run_to_completion();

    assert_eq!(legacy.submitted(), fabric.submitted());
    assert!(legacy.submitted() > 0);
    assert_eq!(legacy.records().len(), fabric.records().len());
    for (l, f) in legacy.records().iter().zip(fabric.records()) {
        assert!((l.arrival_s - f.arrival_s).abs() < 1e-9);
        assert!(
            (l.complete_s - f.complete_s).abs() < 1e-9,
            "complete {} vs {}",
            l.complete_s,
            f.complete_s
        );
        assert!((l.link_overhead_s - f.link_overhead_s).abs() < 1e-9);
        assert!(f.contention_s.abs() < 1e-9);
    }
}

// ------------------------------------- hand-computed fair sharing

/// Two, three, and four flows on the real pooled topology, pushing
/// the bottleneck from the accelerator NIC to the oversubscribed
/// uplink.
#[test]
fn hand_computed_shares_nic_vs_uplink_bottleneck() {
    let nic = Link::infiniband_cx6().eff_bandwidth;

    // 1:1, 2 flows to the same accel: its rx NIC is the bottleneck.
    let topo = Topology::pooled(4, 2, 1.0);
    let flows =
        vec![topo.request_path(0, 0), topo.request_path(1, 0)];
    let rates = max_min_rates(topo.capacities(), &flows);
    assert_eq!(rates, vec![nic / 2.0, nic / 2.0]);

    // 1:1, 3 flows split 2-vs-1 over the two accels: accel 0's NIC
    // halves its two flows, accel 1's lone flow keeps the full NIC
    // (the shared downlink has 2x NIC capacity — not the bottleneck).
    let flows = vec![
        topo.request_path(0, 0),
        topo.request_path(1, 0),
        topo.request_path(2, 1),
    ];
    let rates = max_min_rates(topo.capacities(), &flows);
    assert_eq!(rates, vec![nic / 2.0, nic / 2.0, nic]);

    // 8:1, 4 flows: the accel-leaf downlink (2·nic/8 = nic/4) is now
    // the bottleneck for everyone — each flow gets nic/16,
    // regardless of which accel it targets.
    let topo = Topology::pooled(4, 2, 8.0);
    let flows = vec![
        topo.request_path(0, 0),
        topo.request_path(1, 0),
        topo.request_path(2, 1),
        topo.request_path(3, 1),
    ];
    let rates = max_min_rates(topo.capacities(), &flows);
    for (i, &r) in rates.iter().enumerate() {
        assert!((r - nic / 16.0).abs() < 1e-6, "flow {i}: {r} vs {}", nic / 16.0);
    }
}

// --------------------------------- conservation and monotonicity

#[test]
fn fabric_conserves_requests_and_measures_sane_transfers() {
    let cfg = EventSimConfig { ranks: 24, horizon_s: 0.045, ..Default::default() };
    let mut sim = EventSim::with_fabric(
        pool(),
        Policy::LeastOutstanding,
        cfg,
        vec![0, 1],
        vec![0, 1],
        spec(24, 2, 4.0),
    );
    sim.run_to_completion();
    assert_eq!(sim.completed(), sim.submitted());
    assert_eq!(sim.in_flight(), 0);
    assert_eq!(sim.batcher_pending(), 0);
    let ideal = Link::infiniband_cx6();
    for r in sim.records() {
        assert!(r.complete_s.is_finite());
        // measured transfer can never beat the uncontended round trip
        let floor = ideal.rtt_overhead_s(payload_bytes(
            HERMIT_IN,
            HERMIT_OUT,
            r.batch_samples,
        ));
        assert!(
            r.link_overhead_s >= floor - 1e-12,
            "measured {} under the uncontended floor {floor}",
            r.link_overhead_s
        );
        assert!((r.contention_s - (r.link_overhead_s - floor)).abs() < 1e-9);
    }
}

/// The acceptance property behind the campaign knob: cutting
/// bisection bandwidth never speeds the burst up — mean transfer
/// time, mean completion, and makespan are monotone non-decreasing
/// in the oversubscription factor.  (Pointwise per-request
/// monotonicity is *not* claimed: a slower fabric spreads arrivals,
/// which can shorten an individual request's backend queue.)
#[test]
fn completion_times_monotone_in_oversubscription() {
    let run = |oversub: f64| -> (f64, f64, f64) {
        let cfg = EventSimConfig { ranks: 16, horizon_s: 0.045, ..Default::default() };
        let mut sim = EventSim::with_fabric(
            pool(),
            Policy::RoundRobin,
            cfg,
            vec![0, 1],
            vec![0, 1],
            spec(16, 2, oversub),
        );
        sim.run_to_completion();
        let n = sim.records().len() as f64;
        let mean_complete = sim.records().iter().map(|r| r.complete_s).sum::<f64>() / n;
        let makespan = sim
            .records()
            .iter()
            .map(|r| r.complete_s)
            .fold(0.0f64, f64::max);
        (sim.summary().mean_link_overhead_s, mean_complete, makespan)
    };
    let mut last = (0.0, 0.0, 0.0);
    for oversub in [1.0, 2.0, 4.0, 8.0] {
        let (link, mean_c, makespan) = run(oversub);
        assert!(
            link >= last.0 - 1e-12,
            "oversub {oversub}: mean transfer {link} < {}",
            last.0
        );
        assert!(
            mean_c >= last.1 - 1e-12,
            "oversub {oversub}: mean completion {mean_c} < {}",
            last.1
        );
        assert!(
            makespan >= last.2 - 1e-12,
            "oversub {oversub}: makespan {makespan} < {}",
            last.2
        );
        last = (link, mean_c, makespan);
    }
}
