//! Failure injection: the serving stack under abuse.
//!
//! A disaggregated accelerator is shared infrastructure — a
//! misbehaving MPI rank must not take it down for the others.  These
//! tests throw malformed frames, truncated writes, abrupt
//! disconnects and concurrent abuse at a live server and assert the
//! coordinator keeps serving everyone else.

use std::io::Write;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use cogsim_disagg::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, Registry,
};
use cogsim_disagg::net::protocol;
use cogsim_disagg::net::{Client, Server};
use cogsim_disagg::runtime::Engine;
use cogsim_disagg::util::rng::Rng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn start_server() -> Option<(Arc<Coordinator>, Server)> {
    let dir = artifacts_dir()?;
    let engine = Engine::load(&dir, Some(&["hermit"])).unwrap();
    let mut registry = Registry::new();
    registry.register_materials("hermit", 2);
    let config = CoordinatorConfig {
        batcher: BatcherConfig {
            target_batch: 64,
            max_wait: Duration::from_micros(200),
            deferred_max_wait: Duration::from_millis(20),
            max_batch: 1024,
        },
        workers: 1,
    };
    let c = Arc::new(Coordinator::start(engine, registry, config).unwrap());
    let s = Server::serve(Arc::clone(&c), "127.0.0.1:0").unwrap();
    Some((c, s))
}

fn healthy_roundtrip(addr: std::net::SocketAddr) {
    let client = Client::connect(addr).unwrap();
    let mut rng = Rng::new(1);
    let out = client.infer("hermit/mat0", 1, &rng.normal_vec(42)).unwrap();
    assert_eq!(out.len(), 30);
}

#[test]
fn garbage_bytes_dont_kill_the_server() {
    let Some((_c, server)) = start_server() else { return };
    let addr = server.addr();

    // a client that speaks pure garbage
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(b"GET / HTTP/1.1\r\nHost: nope\r\n\r\n").unwrap();
        // server should drop us; either way, don't hang
        let _ = s.set_read_timeout(Some(Duration::from_millis(200)));
    }
    // healthy clients keep working
    healthy_roundtrip(addr);
    server.shutdown();
}

#[test]
fn truncated_frame_then_disconnect() {
    let Some((_c, server)) = start_server() else { return };
    let addr = server.addr();

    {
        let req = protocol::Request {
            id: 1,
            model: "hermit/mat0".into(),
            priority: 0,
            n_samples: 4,
            payload: vec![0.0; 4 * 42],
        };
        let bytes = protocol::encode_request(&req);
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(&bytes[..bytes.len() / 2]).unwrap();
        // abrupt close mid-frame
    }
    healthy_roundtrip(addr);
    server.shutdown();
}

#[test]
fn disconnect_with_requests_in_flight() {
    let Some((_c, server)) = start_server() else { return };
    let addr = server.addr();

    {
        let client = Client::connect(addr).unwrap();
        let mut rng = Rng::new(3);
        // submit a pile and vanish without reading responses
        for _ in 0..16 {
            let _ = client.submit("hermit/mat0", 4, &rng.normal_vec(4 * 42)).unwrap();
        }
        drop(client);
    }
    std::thread::sleep(Duration::from_millis(100));
    healthy_roundtrip(addr);
    server.shutdown();
}

#[test]
fn oversized_header_rejected_cleanly() {
    let Some((_c, server)) = start_server() else { return };
    let addr = server.addr();

    {
        let mut s = TcpStream::connect(addr).unwrap();
        // valid magic + opcode, then a payload length over the cap
        let mut buf = Vec::new();
        buf.extend_from_slice(&protocol::MAGIC);
        buf.push(1);
        buf.extend_from_slice(&9u64.to_le_bytes());
        buf.extend_from_slice(&1u16.to_le_bytes());
        buf.push(b'm');
        buf.push(0); // priority
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.extend_from_slice(&u32::MAX.to_le_bytes()); // absurd length
        s.write_all(&buf).unwrap();
    }
    healthy_roundtrip(addr);
    server.shutdown();
}

#[test]
fn mixed_abuse_under_load() {
    // concurrent: 2 honest ranks + 2 abusers; the honest ranks must
    // complete every request.
    let Some((_c, server)) = start_server() else { return };
    let addr = server.addr();

    let honest: Vec<_> = (0..2)
        .map(|rank| {
            std::thread::spawn(move || {
                let client = Client::connect(addr).unwrap();
                let mut rng = Rng::new(50 + rank);
                for _ in 0..12 {
                    let out = client
                        .infer(&format!("hermit/mat{rank}"), 2, &rng.normal_vec(2 * 42))
                        .unwrap();
                    assert_eq!(out.len(), 2 * 30);
                }
            })
        })
        .collect();
    let abusers: Vec<_> = (0..2)
        .map(|i| {
            std::thread::spawn(move || {
                for k in 0..6 {
                    if let Ok(mut s) = TcpStream::connect(addr) {
                        let junk = vec![0xAAu8; 64 * (i + 1) + k];
                        let _ = s.write_all(&junk);
                    }
                }
            })
        })
        .collect();
    for h in honest {
        h.join().unwrap();
    }
    for a in abusers {
        a.join().unwrap();
    }
    server.shutdown();
}

#[test]
fn coordinator_drains_queue_on_shutdown() {
    let Some((c, server)) = start_server() else { return };
    let client = Client::connect(server.addr()).unwrap();
    let mut rng = Rng::new(9);
    // leave a request pending then shut down: it must still answer
    let rx = client.submit("hermit/mat0", 2, &rng.normal_vec(2 * 42)).unwrap();
    let rows = client.recv(rx).unwrap();
    assert_eq!(rows.len(), 60);
    server.shutdown();
    drop(client);
    match Arc::try_unwrap(c) {
        Ok(c) => c.shutdown(), // graceful drain path
        Err(_) => {}
    }
}
