//! Failure injection: the serving stack under abuse, and the
//! simulators under control-plane failures.
//!
//! A disaggregated accelerator is shared infrastructure — a
//! misbehaving MPI rank must not take it down for the others.  The
//! first half throws malformed frames, truncated writes, abrupt
//! disconnects and concurrent abuse at a live server and asserts the
//! coordinator keeps serving everyone else.  The second half wires
//! the same failure classes into the virtual-time engines: a backend
//! lost mid-run must not panic the simulation, its orphaned batches
//! are re-dispatched exactly once, and retried completions are
//! accounted separately from first-attempt latencies.

use std::io::Write;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use cogsim_disagg::cluster::{Backend, Policy, RduBackend};
use cogsim_disagg::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, Registry,
};
use cogsim_disagg::eventsim::{
    ArrivalProcess, Batching, CogSim, CogSimConfig, EventSim, EventSimConfig, FleetAction,
    FleetEvent,
};
use cogsim_disagg::fabric::{FabricSpec, Topology as FabricTopology};
use cogsim_disagg::net::protocol;
use cogsim_disagg::net::{Client, Server};
use cogsim_disagg::rdu::RduApi;
use cogsim_disagg::runtime::Engine;
use cogsim_disagg::util::rng::Rng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn start_server() -> Option<(Arc<Coordinator>, Server)> {
    let dir = artifacts_dir()?;
    let engine = Engine::load(&dir, Some(&["hermit"])).unwrap();
    let mut registry = Registry::new();
    registry.register_materials("hermit", 2);
    let config = CoordinatorConfig {
        batcher: BatcherConfig {
            target_batch: 64,
            max_wait: Duration::from_micros(200),
            deferred_max_wait: Duration::from_millis(20),
            max_batch: 1024,
        },
        workers: 1,
    };
    let c = Arc::new(Coordinator::start(engine, registry, config).unwrap());
    let s = Server::serve(Arc::clone(&c), "127.0.0.1:0").unwrap();
    Some((c, s))
}

fn healthy_roundtrip(addr: std::net::SocketAddr) {
    let client = Client::connect(addr).unwrap();
    let mut rng = Rng::new(1);
    let out = client.infer("hermit/mat0", 1, &rng.normal_vec(42)).unwrap();
    assert_eq!(out.len(), 30);
}

#[test]
fn garbage_bytes_dont_kill_the_server() {
    let Some((_c, server)) = start_server() else { return };
    let addr = server.addr();

    // a client that speaks pure garbage
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(b"GET / HTTP/1.1\r\nHost: nope\r\n\r\n").unwrap();
        // server should drop us; either way, don't hang
        let _ = s.set_read_timeout(Some(Duration::from_millis(200)));
    }
    // healthy clients keep working
    healthy_roundtrip(addr);
    server.shutdown();
}

#[test]
fn truncated_frame_then_disconnect() {
    let Some((_c, server)) = start_server() else { return };
    let addr = server.addr();

    {
        let req = protocol::Request {
            id: 1,
            model: "hermit/mat0".into(),
            priority: 0,
            n_samples: 4,
            payload: vec![0.0; 4 * 42],
        };
        let bytes = protocol::encode_request(&req);
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(&bytes[..bytes.len() / 2]).unwrap();
        // abrupt close mid-frame
    }
    healthy_roundtrip(addr);
    server.shutdown();
}

#[test]
fn disconnect_with_requests_in_flight() {
    let Some((_c, server)) = start_server() else { return };
    let addr = server.addr();

    {
        let client = Client::connect(addr).unwrap();
        let mut rng = Rng::new(3);
        // submit a pile and vanish without reading responses
        for _ in 0..16 {
            let _ = client.submit("hermit/mat0", 4, &rng.normal_vec(4 * 42)).unwrap();
        }
        drop(client);
    }
    std::thread::sleep(Duration::from_millis(100));
    healthy_roundtrip(addr);
    server.shutdown();
}

#[test]
fn oversized_header_rejected_cleanly() {
    let Some((_c, server)) = start_server() else { return };
    let addr = server.addr();

    {
        let mut s = TcpStream::connect(addr).unwrap();
        // valid magic + opcode, then a payload length over the cap
        let mut buf = Vec::new();
        buf.extend_from_slice(&protocol::MAGIC);
        buf.push(1);
        buf.extend_from_slice(&9u64.to_le_bytes());
        buf.extend_from_slice(&1u16.to_le_bytes());
        buf.push(b'm');
        buf.push(0); // priority
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.extend_from_slice(&u32::MAX.to_le_bytes()); // absurd length
        s.write_all(&buf).unwrap();
    }
    healthy_roundtrip(addr);
    server.shutdown();
}

#[test]
fn mixed_abuse_under_load() {
    // concurrent: 2 honest ranks + 2 abusers; the honest ranks must
    // complete every request.
    let Some((_c, server)) = start_server() else { return };
    let addr = server.addr();

    let honest: Vec<_> = (0..2)
        .map(|rank| {
            std::thread::spawn(move || {
                let client = Client::connect(addr).unwrap();
                let mut rng = Rng::new(50 + rank);
                for _ in 0..12 {
                    let out = client
                        .infer(&format!("hermit/mat{rank}"), 2, &rng.normal_vec(2 * 42))
                        .unwrap();
                    assert_eq!(out.len(), 2 * 30);
                }
            })
        })
        .collect();
    let abusers: Vec<_> = (0..2)
        .map(|i| {
            std::thread::spawn(move || {
                for k in 0..6 {
                    if let Ok(mut s) = TcpStream::connect(addr) {
                        let junk = vec![0xAAu8; 64 * (i + 1) + k];
                        let _ = s.write_all(&junk);
                    }
                }
            })
        })
        .collect();
    for h in honest {
        h.join().unwrap();
    }
    for a in abusers {
        a.join().unwrap();
    }
    server.shutdown();
}

#[test]
fn coordinator_drains_queue_on_shutdown() {
    let Some((c, server)) = start_server() else { return };
    let client = Client::connect(server.addr()).unwrap();
    let mut rng = Rng::new(9);
    // leave a request pending then shut down: it must still answer
    let rx = client.submit("hermit/mat0", 2, &rng.normal_vec(2 * 42)).unwrap();
    let rows = client.recv(rx).unwrap();
    assert_eq!(rows.len(), 60);
    server.shutdown();
    drop(client);
    match Arc::try_unwrap(c) {
        Ok(c) => c.shutdown(), // graceful drain path
        Err(_) => {}
    }
}

// ----------------------------------------------------------------
// Simulator failure injection: the same backend-loss class, in
// virtual time.  Configurations mirror python/sim/verify.py's
// validated `control_plane` phase byte for byte.

fn sim_pool() -> Vec<Box<dyn Backend>> {
    vec![
        Box::new(RduBackend::disaggregated("rdu/pool0", 4, RduApi::CppOptimized)),
        Box::new(RduBackend::disaggregated("rdu/pool1", 2, RduApi::Python)),
    ]
}

fn sim_ccfg() -> CogSimConfig {
    CogSimConfig {
        ranks: 4,
        timesteps: 8,
        compute_s: 2e-3,
        compute_jitter_s: 0.0,
        requests_per_step: 6,
        models: 8,
        samples_per_request: (2, 3),
        mir_every: 0,
        mir_samples: 512,
        overlap: 0.0,
        swap_s: 0.0,
        residency_slots: 4,
        batching: Batching::Off,
        seed: 42,
    }
}

fn sim_cog(cfg: CogSimConfig) -> CogSim {
    CogSim::with_tiers(sim_pool(), Policy::LeastOutstanding, cfg, vec![0, 1], vec![0, 1])
}

fn leave(at_s: f64, idx: usize) -> FleetEvent {
    FleetEvent { at_s, action: FleetAction::BackendLeave(idx) }
}

#[test]
fn simulated_backend_loss_mid_run_does_not_panic_and_survivors_absorb_it() {
    // t = 2.2 ms lands inside the first step's inference window, so
    // backend 0 dies with batches in flight.
    let mut sim = sim_cog(sim_ccfg());
    sim.with_control(&[leave(2.2e-3, 0)], None);
    sim.run_to_completion();
    let s = sim.summary();

    // the loss orphaned real in-flight work and every orphan was
    // re-dispatched exactly once — no loss, no duplicate completions
    assert!(sim.orphaned() > 0, "leave must orphan in-flight work");
    assert_eq!(sim.orphaned(), sim.retries());
    assert_eq!(s.failed, 0, "survivors must absorb the loss");
    assert_eq!(s.requests, s.submitted);
    assert_eq!(sim.steps().len(), 8);
    assert_eq!(sim.in_flight(), 0);

    // fleet membership is tracked and retries land on survivors only
    assert!(!sim.backend_active(0) && sim.backend_active(1));
    assert!(sim.records().iter().all(|r| r.backend != 0 || !r.retried));
    assert!(sim.records().iter().all(|r| r.complete_s.is_finite()));
}

#[test]
fn simulated_retries_are_excluded_from_first_attempt_latencies() {
    let mut sim = sim_cog(sim_ccfg());
    sim.with_control(&[leave(2.2e-3, 0)], None);
    sim.run_to_completion();
    let s = sim.summary();

    // exactly one record per retried request, updated in place —
    // and the latency distribution counts first attempts only
    let retried = sim.records().iter().filter(|r| r.retried).count() as u64;
    assert_eq!(retried, sim.retries());
    assert!(retried > 0);
    assert_eq!(s.latency.count, s.requests - retried);
    // the retry's completion fields describe the successful attempt,
    // so its end-to-end latency is real — just not a first-attempt
    // observation
    for r in sim.records().iter().filter(|r| r.retried) {
        assert!(r.latency_s() > 0.0);
    }
}

#[test]
fn simulated_backend_loss_on_the_fabric_path_conserves() {
    // same loss with remote transfers carried by the shared fabric:
    // the dead backend's flows are cancelled, not leaked, so the run
    // still drains to in_flight = 0
    let spec = FabricSpec {
        topology: FabricTopology::pooled(4, 2, 2.0),
        accel_of_backend: vec![0, 1],
    };
    let mut sim = CogSim::with_fabric(
        sim_pool(),
        Policy::LeastOutstanding,
        sim_ccfg(),
        vec![0, 1],
        vec![0, 1],
        spec,
    );
    sim.with_control(&[leave(2.2e-3, 0)], None);
    sim.run_to_completion();
    assert_eq!(sim.orphaned(), sim.retries());
    assert_eq!(sim.in_flight(), 0);
    assert_eq!(sim.summary().failed, 0);
    assert_eq!(sim.steps().len(), 8);
}

#[test]
fn simulated_full_tier_loss_parks_work_until_a_join_revives_it() {
    // both backends die with the step in flight; everything parks.
    // A later join must flush the parked queue and finish the run.
    let mut sim = sim_cog(CogSimConfig { timesteps: 2, ..sim_ccfg() });
    sim.with_control(
        &[
            leave(2.2e-3, 0),
            leave(2.2e-3, 1),
            FleetEvent { at_s: 5e-3, action: FleetAction::BackendJoin(0) },
        ],
        None,
    );
    sim.run_to_completion();
    assert_eq!(sim.summary().failed, 0, "join must flush parked work");
    assert_eq!(sim.steps().len(), 2);
    assert_eq!(sim.parked(), 0);
}

#[test]
fn simulated_rank_failure_replays_the_in_flight_timestep() {
    let mut base = sim_cog(sim_ccfg());
    base.run_to_completion();
    let mut sim = sim_cog(sim_ccfg());
    sim.with_control(
        &[FleetEvent { at_s: 2.2e-3, action: FleetAction::RankFail(1) }],
        None,
    );
    sim.run_to_completion();

    // checkpoint/restart: the failed rank replays its step, so the
    // run still completes all 8 barriers — but the replayed burst is
    // re-submitted (wasted work is counted, not hidden) and the
    // restart costs wall-clock
    assert_eq!(sim.rank_restarts(), 1);
    assert_eq!(sim.steps().len(), 8);
    assert!(sim.submitted() > (8 * 4 * 6) as u64, "replay re-submits the lost burst");
    assert!(sim.time_to_solution_s() > base.time_to_solution_s());
}

#[test]
fn simulated_event_stream_backend_loss_conserves_under_open_loop_load() {
    // the open-loop engine under the same loss: orphans re-dispatch
    // exactly once, incomplete work is exactly the parked set, and
    // the retried completions stay out of the first-attempt tail
    let cfg = EventSimConfig {
        ranks: 4,
        materials: 8,
        samples_per_request: (2, 3),
        requests_per_burst: 6,
        mir_every: 0,
        mir_samples: 512,
        arrival: ArrivalProcess::Poisson { rate_per_rank: 800.0 },
        batching: Batching::Off,
        horizon_s: 0.05,
        seed: 42,
    };
    let mut sim =
        EventSim::with_tiers(sim_pool(), Policy::LeastOutstanding, cfg, vec![0, 1], vec![0, 1]);
    sim.with_control(&[leave(10e-3, 0)]);
    sim.run_to_completion();
    let s = sim.summary();
    assert_eq!(sim.orphaned(), sim.retries());
    assert_eq!(sim.in_flight(), 0);
    assert_eq!(s.submitted, s.requests + s.failed + sim.batcher_pending());
    assert_eq!(s.failed, sim.parked());
    let retried = sim.records().iter().filter(|r| r.retried).count() as u64;
    assert_eq!(retried, s.retries);
    assert_eq!(s.latency.count as u64 + retried, s.requests);
}
