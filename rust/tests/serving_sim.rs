//! Deterministic end-to-end serving integration tests on the
//! simulated engine: the full coordinator + TCP server/client stack
//! without AOT artifacts or PJRT, so these run on every checkout.
//!
//! Covers the paper's serving loop end to end: a seeded
//! `HydraWorkload` timestep is driven through `net::client` against a
//! live `net::server` on a loopback port, every request must complete
//! with correctly-sized output rows, and `CoordinatorStats` sample
//! counts must balance exactly (no lost or duplicated samples).

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use cogsim_disagg::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, Registry, RoutingPolicy,
};
use cogsim_disagg::net::{Client, Server};
use cogsim_disagg::runtime::{Engine, Manifest};
use cogsim_disagg::util::rng::Rng;
use cogsim_disagg::workload::HydraWorkload;

fn config() -> CoordinatorConfig {
    CoordinatorConfig {
        batcher: BatcherConfig {
            target_batch: 64,
            max_wait: Duration::from_micros(200),
            deferred_max_wait: Duration::from_millis(50),
            max_batch: 1024,
        },
        workers: 1,
    }
}

fn start_sim_coordinator(materials: usize) -> Arc<Coordinator> {
    let engine = Engine::sim_reference();
    let mut registry = Registry::new();
    registry.register_materials("hermit", materials);
    registry.register("mir", "mir");
    Arc::new(Coordinator::start(engine, registry, config()).unwrap())
}

#[test]
fn hydra_timestep_end_to_end_over_tcp() {
    let materials = 8;
    let c = start_sim_coordinator(materials);
    let server = Server::serve(Arc::clone(&c), "127.0.0.1:0").unwrap();
    let addr = server.addr();

    let workload = HydraWorkload {
        ranks: 2,
        zones_per_rank: 100,
        materials,
        inferences_per_zone: (2, 3),
        seed: 11,
    };
    let requests = workload.timestep(0);
    assert!(!requests.is_empty());
    let total_samples: usize = requests.iter().map(|r| r.samples).sum();

    // one client per rank, every request pipelined (the paper's
    // throughput mode), inputs seeded per request index
    let client_a = Client::connect(addr).unwrap();
    let client_b = Client::connect(addr).unwrap();
    let inputs: Vec<Vec<f32>> = requests
        .iter()
        .enumerate()
        .map(|(i, r)| Rng::new(1000 + i as u64).normal_vec(r.samples * 42))
        .collect();
    let rxs: Vec<_> = requests
        .iter()
        .zip(&inputs)
        .map(|(req, x)| {
            let client = if req.rank == 0 { &client_a } else { &client_b };
            client.submit(&req.model, req.samples, x).unwrap()
        })
        .collect();

    // every request completes with correctly-sized, finite rows
    let mut received_rows = 0usize;
    for ((req, x), rx) in requests.iter().zip(&inputs).zip(rxs) {
        let client = if req.rank == 0 { &client_a } else { &client_b };
        let rows = client.recv(rx).unwrap();
        assert_eq!(rows.len(), req.samples * 30, "{}", req.model);
        assert!(rows.iter().all(|v| v.is_finite()));
        received_rows += rows.len();

        // remote result == in-process result (sim engine is
        // deterministic, so the TCP path must be bit-transparent)
        let local = c.infer(&req.model, x.clone()).unwrap();
        assert_eq!(rows, local, "{}", req.model);
    }
    assert_eq!(received_rows, total_samples * 30);

    // sample accounting balances: nothing lost, nothing duplicated.
    // (each request was submitted twice: once via TCP, once via the
    // in-process comparison call)
    let stats = &c.stats;
    assert_eq!(stats.errors.load(Ordering::Relaxed), 0);
    assert_eq!(
        stats.requests.load(Ordering::Relaxed),
        2 * requests.len() as u64
    );
    assert_eq!(
        stats.samples.load(Ordering::Relaxed),
        2 * total_samples as u64
    );
    // per-model routing accounting agrees with the submitted volume
    let routed: u64 = c.routed_samples().values().sum();
    assert_eq!(routed, 2 * total_samples as u64);

    server.shutdown();
}

#[test]
fn mir_and_hermit_share_the_server() {
    let c = start_sim_coordinator(2);
    let server = Server::serve(Arc::clone(&c), "127.0.0.1:0").unwrap();
    let client = Client::connect(server.addr()).unwrap();

    let mut rng = Rng::new(3);
    let hermit_x = rng.normal_vec(3 * 42);
    let mir_x: Vec<f32> = (0..2 * 48 * 48).map(|i| (i % 7) as f32 / 7.0).collect();

    let rx_h = client.submit("hermit/mat1", 3, &hermit_x).unwrap();
    let rx_m = client.submit("mir", 2, &mir_x).unwrap();
    let mir_rows = client.recv(rx_m).unwrap();
    let hermit_rows = client.recv(rx_h).unwrap();
    assert_eq!(hermit_rows.len(), 3 * 30);
    assert_eq!(mir_rows.len(), 2 * 48 * 48);
    // MIR head is a sigmoid: volume fractions
    assert!(mir_rows.iter().all(|&v| (0.0..=1.0).contains(&v)));

    server.shutdown();
}

#[test]
fn errors_propagate_and_connection_survives() {
    let c = start_sim_coordinator(1);
    let server = Server::serve(Arc::clone(&c), "127.0.0.1:0").unwrap();
    let client = Client::connect(server.addr()).unwrap();

    let err = client.infer("no/such/model", 1, &[0.0; 42]).unwrap_err();
    assert!(format!("{err:#}").contains("no/such/model"), "{err:#}");
    let err = client.infer("hermit/mat0", 2, &[0.0; 42]).unwrap_err();
    assert!(format!("{err:#}").contains("samples"), "{err:#}");

    let ok = client.infer("hermit/mat0", 1, &[0.1; 42]).unwrap();
    assert_eq!(ok.len(), 30);
    assert_eq!(c.stats.errors.load(Ordering::Relaxed), 0, "rejections are not engine errors");
    server.shutdown();
}

#[test]
fn replica_routing_spreads_requests_and_stays_transparent() {
    // one logical instance backed by two identically-shaped engine
    // models; round-robin replica routing must spread the load while
    // returning identical rows for identical inputs
    let manifest = Manifest::synthetic_named(&[("hermit_a", 42, 30), ("hermit_b", 42, 30)]);
    let engine = Engine::simulated(manifest, None).unwrap();
    let mut registry = Registry::new();
    registry
        .register_replicated("hermit/mat0", ["hermit_a", "hermit_b"])
        .unwrap();
    let c = Arc::new(
        Coordinator::start_with_router(engine, registry, config(), RoutingPolicy::RoundRobin)
            .unwrap(),
    );
    let server = Server::serve(Arc::clone(&c), "127.0.0.1:0").unwrap();
    let client = Client::connect(server.addr()).unwrap();

    let mut rng = Rng::new(17);
    let x = rng.normal_vec(42);
    let baseline = client.infer("hermit/mat0", 1, &x).unwrap();
    for _ in 0..9 {
        let rows = client.infer("hermit/mat0", 1, &x).unwrap();
        assert_eq!(rows, baseline, "replica choice must be invisible");
    }

    let routed = c.routed_samples();
    let a = routed.get("hermit_a").copied().unwrap_or(0);
    let b = routed.get("hermit_b").copied().unwrap_or(0);
    assert_eq!(a + b, 10, "{routed:?}");
    assert!(a > 0 && b > 0, "round-robin must use both replicas: {routed:?}");

    server.shutdown();
}

#[test]
fn replica_shape_mismatch_is_rejected_at_startup() {
    let manifest = Manifest::synthetic_named(&[("hermit_a", 42, 30), ("wide", 42, 31)]);
    let engine = Engine::simulated(manifest, None).unwrap();
    let mut registry = Registry::new();
    registry
        .register_replicated("hermit/mat0", ["hermit_a", "wide"])
        .unwrap();
    let err =
        Coordinator::start_with_router(engine, registry, config(), RoutingPolicy::RoundRobin)
            .unwrap_err();
    assert!(format!("{err:#}").contains("shape"), "{err:#}");
}

#[test]
fn least_outstanding_routing_balances_samples() {
    let manifest = Manifest::synthetic_named(&[
        ("hermit_a", 42, 30),
        ("hermit_b", 42, 30),
        ("blocker", 48 * 48, 48 * 48),
    ]);
    let engine = Engine::simulated(manifest, None).unwrap();
    let mut registry = Registry::new();
    registry
        .register_replicated("hermit/mat0", ["hermit_a", "hermit_b"])
        .unwrap();
    registry.register("blocker", "blocker");
    let c = Coordinator::start_with_router(
        engine,
        registry,
        config(),
        RoutingPolicy::LeastOutstanding,
    )
    .unwrap();

    // occupy the single worker with a long-running batch so the whole
    // burst below is *routed* before anything executes — the
    // least-outstanding counters then alternate deterministically:
    // a, b, a, b, …  (`batches` increments when the worker *starts*
    // executing, so polling it guarantees the worker is busy)
    let rx_blocker = c.submit("blocker", vec![0.3f32; 1024 * 48 * 48]).unwrap();
    for _ in 0..2000 {
        if c.stats.batches.load(Ordering::Relaxed) >= 1 {
            break;
        }
        std::thread::sleep(Duration::from_millis(1));
    }

    let mut rng = Rng::new(23);
    let rxs: Vec<_> = (0..12)
        .map(|_| c.submit("hermit/mat0", rng.normal_vec(2 * 42)).unwrap())
        .collect();
    for rx in rxs {
        let out = rx.recv().unwrap().unwrap();
        assert_eq!(out.len(), 2 * 30);
    }
    assert_eq!(rx_blocker.recv().unwrap().unwrap().len(), 1024 * 48 * 48);

    let routed = c.routed_samples();
    let a = routed.get("hermit_a").copied().unwrap_or(0);
    let b = routed.get("hermit_b").copied().unwrap_or(0);
    assert_eq!(a + b, 24, "{routed:?}");
    assert!(
        a > 0 && b > 0,
        "least-outstanding must spread a concurrent burst: {routed:?}"
    );
}
