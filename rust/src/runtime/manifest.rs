//! Typed view of `artifacts/manifest.json` — the contract between the
//! Python compile path and this runtime.  Field layout mirrors
//! `python/compile/aot.py::lower_model`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Value};

/// One named parameter in calling-convention order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-lowered (model, mini-batch) artifact.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BatchArtifact {
    pub batch: usize,
    pub hlo_file: String,
}

/// Everything the runtime needs to know about one model.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelSpec {
    pub name: String,
    /// Per-sample input shape (excludes the batch dimension).
    pub input_shape: Vec<usize>,
    /// Per-sample output shape (excludes the batch dimension).
    pub output_shape: Vec<usize>,
    pub params: Vec<ParamSpec>,
    pub weights_file: String,
    pub weights_sha256: String,
    pub batches: Vec<BatchArtifact>,
    pub param_count: usize,
}

impl ModelSpec {
    /// Elements per input sample.
    pub fn input_elems(&self) -> usize {
        self.input_shape.iter().product()
    }

    /// Elements per output sample.
    pub fn output_elems(&self) -> usize {
        self.output_shape.iter().product()
    }

    /// The compiled mini-batch ladder, ascending.
    pub fn batch_ladder(&self) -> Vec<usize> {
        let mut ladder: Vec<usize> = self.batches.iter().map(|b| b.batch).collect();
        ladder.sort_unstable();
        ladder
    }

    /// The smallest compiled batch size that fits `n` samples, or the
    /// largest available if `n` exceeds the ladder (caller then splits).
    pub fn batch_for(&self, n: usize) -> usize {
        let ladder = self.batch_ladder();
        for &b in &ladder {
            if b >= n {
                return b;
            }
        }
        *ladder.last().expect("model has no compiled batches")
    }
}

/// The full artifact manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dtype: String,
    pub seed: u64,
    pub models: BTreeMap<String, ModelSpec>,
    /// Directory the manifest was loaded from (HLO/weights paths are
    /// resolved relative to it).
    pub dir: PathBuf,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let root = json::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;

        let dtype = field_str(&root, "dtype")?.to_string();
        if dtype != "f32" {
            bail!("manifest dtype {dtype:?} unsupported (runtime executes f32)");
        }
        let seed = field_f64(&root, "seed")? as u64;

        let mut models = BTreeMap::new();
        let model_obj = root
            .get("models")
            .and_then(Value::as_object)
            .ok_or_else(|| anyhow!("manifest missing models object"))?;
        for (name, entry) in model_obj {
            models.insert(name.clone(), parse_model(name, entry)?);
        }
        if models.is_empty() {
            bail!("manifest contains no models");
        }
        Ok(Manifest { dtype, seed, models, dir })
    }

    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("model {name:?} not in manifest (have: {:?})",
                self.models.keys().collect::<Vec<_>>()))
    }

    /// Absolute path of a model's HLO artifact for one batch size.
    pub fn hlo_path(&self, model: &str, batch: usize) -> Result<PathBuf> {
        let spec = self.model(model)?;
        let artifact = spec
            .batches
            .iter()
            .find(|b| b.batch == batch)
            .ok_or_else(|| anyhow!("model {model:?} has no batch-{batch} artifact"))?;
        Ok(self.dir.join(&artifact.hlo_file))
    }

    /// Absolute path of a model's weights npz.
    pub fn weights_path(&self, model: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.model(model)?.weights_file))
    }

    /// An in-memory manifest with the paper's three models (Hermit
    /// 42→30, MIR and MIR-no-layernorm 48×48→48×48) on the default
    /// compiled-batch ladder — the contract the simulated engine
    /// executes when no AOT artifacts are present.
    pub fn synthetic() -> Manifest {
        Self::synthetic_named(&[
            ("hermit", 42, 30),
            ("mir", 48 * 48, 48 * 48),
            ("mir_noln", 48 * 48, 48 * 48),
        ])
    }

    /// An in-memory manifest for arbitrary `(name, input_elems,
    /// output_elems)` models (tests use this to shape replica sets).
    pub fn synthetic_named(models: &[(&str, usize, usize)]) -> Manifest {
        let mut map = BTreeMap::new();
        for &(name, in_el, out_el) in models {
            let batches: Vec<BatchArtifact> = [1usize, 4, 16, 64, 256, 1024]
                .iter()
                .map(|&batch| BatchArtifact {
                    batch,
                    hlo_file: format!("{name}_b{batch}.hlo.txt"),
                })
                .collect();
            let param_count = crate::devices::profiles::by_name(name)
                .map(|p| p.param_count)
                .unwrap_or(0);
            map.insert(
                name.to_string(),
                ModelSpec {
                    name: name.to_string(),
                    input_shape: vec![in_el],
                    output_shape: vec![out_el],
                    params: Vec::new(),
                    weights_file: format!("{name}.weights.npz"),
                    weights_sha256: String::new(),
                    batches,
                    param_count,
                },
            );
        }
        Manifest {
            dtype: "f32".to_string(),
            seed: 0,
            models: map,
            dir: PathBuf::from("<synthetic>"),
        }
    }
}

fn field_str<'v>(v: &'v Value, key: &str) -> Result<&'v str> {
    v.get(key)
        .and_then(Value::as_str)
        .ok_or_else(|| anyhow!("manifest missing string field {key:?}"))
}

fn field_f64(v: &Value, key: &str) -> Result<f64> {
    v.get(key)
        .and_then(Value::as_f64)
        .ok_or_else(|| anyhow!("manifest missing numeric field {key:?}"))
}

fn shape_vec(v: &Value, key: &str) -> Result<Vec<usize>> {
    v.get(key)
        .and_then(Value::as_array)
        .ok_or_else(|| anyhow!("manifest missing array field {key:?}"))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| anyhow!("non-integer dim in {key:?}")))
        .collect()
}

fn parse_model(name: &str, entry: &Value) -> Result<ModelSpec> {
    let params: Vec<ParamSpec> = entry
        .get("params")
        .and_then(Value::as_array)
        .ok_or_else(|| anyhow!("model {name:?}: missing params"))?
        .iter()
        .map(|p| {
            Ok(ParamSpec {
                name: field_str(p, "name")?.to_string(),
                shape: shape_vec(p, "shape")?,
            })
        })
        .collect::<Result<_>>()?;

    // Contract with aot.py: lexicographic name order == calling order.
    let mut sorted = params.clone();
    sorted.sort_by(|a, b| a.name.cmp(&b.name));
    if sorted != params {
        bail!("model {name:?}: param names not in calling order");
    }

    let batches: Vec<BatchArtifact> = entry
        .get("batches")
        .and_then(Value::as_array)
        .ok_or_else(|| anyhow!("model {name:?}: missing batches"))?
        .iter()
        .map(|b| {
            Ok(BatchArtifact {
                batch: b
                    .get("batch")
                    .and_then(Value::as_usize)
                    .ok_or_else(|| anyhow!("bad batch entry"))?,
                hlo_file: field_str(b, "hlo_file")?.to_string(),
            })
        })
        .collect::<Result<_>>()?;
    if batches.is_empty() {
        bail!("model {name:?}: empty batch ladder");
    }

    Ok(ModelSpec {
        name: name.to_string(),
        input_shape: shape_vec(entry, "input_shape")?,
        output_shape: shape_vec(entry, "output_shape")?,
        params,
        weights_file: field_str(entry, "weights_file")?.to_string(),
        weights_sha256: field_str(entry, "weights_sha256")?.to_string(),
        batches,
        param_count: field_f64(entry, "param_count")? as usize,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest() -> &'static str {
        r#"{
          "dtype": "f32", "seed": 0,
          "models": {
            "toy": {
              "input_shape": [42], "output_shape": [30],
              "params": [
                {"name": "p000_w", "shape": [42, 19]},
                {"name": "p001_b", "shape": [19]}
              ],
              "weights_file": "toy.weights.npz",
              "weights_sha256": "ab",
              "batches": [
                {"batch": 1, "hlo_file": "toy_b1.hlo.txt", "hlo_bytes": 10},
                {"batch": 16, "hlo_file": "toy_b16.hlo.txt", "hlo_bytes": 10},
                {"batch": 4, "hlo_file": "toy_b4.hlo.txt", "hlo_bytes": 10}
              ],
              "param_count": 817
            }
          }
        }"#
    }

    fn load_sample() -> Manifest {
        let dir = std::env::temp_dir().join(format!("cogsim-manifest-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), sample_manifest()).unwrap();
        Manifest::load(&dir).unwrap()
    }

    #[test]
    fn parses_model_spec() {
        let m = load_sample();
        let spec = m.model("toy").unwrap();
        assert_eq!(spec.input_elems(), 42);
        assert_eq!(spec.output_elems(), 30);
        assert_eq!(spec.param_count, 817);
        assert_eq!(spec.params[0].elements(), 42 * 19);
    }

    #[test]
    fn ladder_sorted_and_batch_for() {
        let m = load_sample();
        let spec = m.model("toy").unwrap();
        assert_eq!(spec.batch_ladder(), vec![1, 4, 16]);
        assert_eq!(spec.batch_for(1), 1);
        assert_eq!(spec.batch_for(3), 4);
        assert_eq!(spec.batch_for(5), 16);
        assert_eq!(spec.batch_for(99), 16); // caller must split
    }

    #[test]
    fn unknown_model_is_error() {
        let m = load_sample();
        assert!(m.model("nope").is_err());
        assert!(m.hlo_path("toy", 999).is_err());
    }

    #[test]
    fn paths_are_resolved() {
        let m = load_sample();
        assert!(m.hlo_path("toy", 4).unwrap().ends_with("toy_b4.hlo.txt"));
        assert!(m.weights_path("toy").unwrap().ends_with("toy.weights.npz"));
    }

    #[test]
    fn real_manifest_loads_if_present() {
        // Integration sanity: when `make artifacts` has run, the real
        // manifest must parse and contain the paper's three models.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            for name in ["hermit", "mir", "mir_noln"] {
                assert!(m.models.contains_key(name), "missing {name}");
            }
            let hermit = m.model("hermit").unwrap();
            assert_eq!(hermit.input_shape, vec![42]);
            assert!(hermit.param_count > 2_700_000);
        }
    }
}
