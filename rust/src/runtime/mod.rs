//! Layer-3 runtime: loads AOT artifacts and executes them via PJRT.
//!
//! The compile path (`make artifacts`) leaves three things in
//! `artifacts/`: per-(model, batch) HLO text, a `.weights.npz` per
//! model, and `manifest.json` describing shapes and calling
//! conventions.  This module turns those into live PJRT executables:
//!
//! * [`manifest`] — typed view of `manifest.json` (parsed with the
//!   in-tree JSON parser).
//! * [`engine`]   — the [`Engine`]: one PJRT client, per-model weight
//!   buffers uploaded **once** (`PjRtBuffer::read_npz_by_name`), one
//!   compiled executable per (model, mini-batch) reused for every
//!   request via `execute_b` — the request path never re-uploads
//!   weights and never touches Python.

pub mod engine;
pub mod manifest;

pub use engine::{Engine, ExecTiming};
pub use manifest::{BatchArtifact, Manifest, ModelSpec, ParamSpec};
