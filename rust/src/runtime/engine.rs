//! The execution engine behind the coordinator: compiled-executable
//! cache + resident weight buffers on the PJRT path, or a
//! deterministic pure-Rust reference executor on the simulated path.
//!
//! Two backends share one `Engine` API (the hot path of the serving
//! system — one execution per mini-batch, zero Python, zero weight
//! re-uploads):
//!
//! * **PJRT** ([`Engine::load`]) — executes the AOT artifacts
//!   (`artifacts/manifest.json` + HLO text + npz weights) on a PJRT
//!   device.  In the offline build the vendored `xla` crate is an API
//!   stub, so this path compiles but reports at runtime that the real
//!   bridge is required.
//! * **Simulated** ([`Engine::simulated`] / [`Engine::sim_reference`])
//!   — a seeded, shape-faithful reference executor: every output row
//!   is a deterministic function of its own input row only, so
//!   batching, padding and routing can be validated end-to-end (rows
//!   must be identical no matter which mini-batch or replica carried
//!   them).  Square (autoencoder-shaped) models squash outputs into
//!   (0, 1), matching the real MIR sigmoid head; like the
//!   coefficients, the decision derives from the shape alone so
//!   identically-shaped replicas behave identically.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};
use xla::{
    FromRawBytes, HloModuleProto, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation,
};

use crate::util::rng::Rng;

use super::manifest::{Manifest, ModelSpec};

/// Wall-clock breakdown of one execution (feeds the §Perf analysis:
/// the paper's GPU measurements exclude host<->device movement, the
/// DataScale measurements include it — we report both pieces).
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecTiming {
    /// Host -> device input upload.
    pub upload: Duration,
    /// Device execution (incl. PJRT dispatch).
    pub execute: Duration,
    /// Device -> host result fetch.
    pub fetch: Duration,
}

impl ExecTiming {
    pub fn total(&self) -> Duration {
        self.upload + self.execute + self.fetch
    }

    /// "Node-local GPU" accounting: the paper's GPU numbers exclude
    /// data movement (simulation and model share the device).
    pub fn compute_only(&self) -> Duration {
        self.execute
    }
}

/// One PJRT-loaded model: resident weights + per-batch executables.
struct LoadedModel {
    spec: ModelSpec,
    /// Weight buffers in calling-convention order, uploaded once.
    weights: Vec<PjRtBuffer>,
    /// batch size -> compiled executable.
    exes: BTreeMap<usize, PjRtLoadedExecutable>,
}

/// One simulated model: the spec plus the seeded reference transform.
struct SimModel {
    spec: ModelSpec,
    /// Per-output-element affine coefficients; seeded from the
    /// manifest seed and the model's *shape* (not its name), so
    /// identically-shaped replicas of one logical model produce
    /// identical rows — the semantics replica routing relies on.
    coeff_bias: Vec<f32>,
    coeff_mean: Vec<f32>,
    coeff_gather: Vec<f32>,
    /// Squash outputs into (0, 1) (MIR's sigmoid head).  Derived from
    /// the shape alone (square, autoencoder-like models squash) so
    /// the replica-transparency guarantee above covers it too.
    squash01: bool,
}

impl SimModel {
    fn new(spec: ModelSpec, manifest_seed: u64) -> SimModel {
        let in_el = spec.input_elems();
        let out_el = spec.output_elems();
        let seed = manifest_seed
            ^ (in_el as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ (out_el as u64).rotate_left(23);
        let mut rng = Rng::new(seed);
        let mut coeff = |_| (0..out_el).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        let squash01 = in_el == out_el;
        SimModel {
            coeff_bias: coeff(0),
            coeff_mean: coeff(1),
            coeff_gather: coeff(2),
            spec,
            squash01,
        }
    }

    /// Reference forward for one row; per-sample by construction so
    /// padding in the same mini-batch cannot leak between rows.
    fn forward_row(&self, x: &[f32], out: &mut Vec<f32>) {
        let in_el = x.len();
        let mean = x.iter().sum::<f32>() / in_el as f32;
        for j in 0..self.spec.output_elems() {
            let t = self.coeff_bias[j]
                + self.coeff_mean[j] * mean
                + self.coeff_gather[j] * x[j % in_el];
            out.push(if self.squash01 { 1.0 / (1.0 + (-t).exp()) } else { t });
        }
    }
}

enum Exec {
    Pjrt { client: PjRtClient, models: BTreeMap<String, LoadedModel> },
    Sim { models: BTreeMap<String, SimModel> },
}

/// The engine owns one execution backend and every loaded model.
///
/// ## Thread-safety
/// The real `xla` crate's wrappers hold raw pointers and are `!Send`,
/// but the underlying PJRT CPU client is thread-safe (its C++ API is
/// documented thread-compatible and the CPU plugin serialises
/// appropriately).  The coordinator keeps the engine behind worker
/// threads that serialise executions, matching how a single physical
/// accelerator serialises work in the paper's setup.  The simulated
/// backend is plain data.
pub struct Engine {
    exec: Exec,
    manifest: Manifest,
}

// SAFETY: PJRT CPU client/executable/buffer handles are usable from
// any thread; the real crate's Rust wrappers are !Send only because
// they contain raw pointers.  All mutation goes through &mut self or
// is internally synchronised by PJRT.  See the struct docs for the
// usage contract.  (With the vendored stub these impls are redundant
// but harmless.)
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Create a CPU-PJRT engine and load `models` (all models in the
    /// manifest when `None`).
    pub fn load(artifacts_dir: impl AsRef<Path>, models: Option<&[&str]>) -> Result<Self> {
        let manifest = Manifest::load(&artifacts_dir)?;
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        let mut engine = Engine {
            exec: Exec::Pjrt { client, models: BTreeMap::new() },
            manifest,
        };
        for name in engine.select_names(models) {
            engine.load_model(&name)?;
        }
        Ok(engine)
    }

    /// Create a simulated engine over `manifest` (no artifacts, no
    /// PJRT): deterministic reference numerics with real shapes,
    /// ladders and padding behaviour.
    pub fn simulated(manifest: Manifest, models: Option<&[&str]>) -> Result<Self> {
        let mut engine = Engine { exec: Exec::Sim { models: BTreeMap::new() }, manifest };
        for name in engine.select_names(models) {
            let spec = engine.manifest.model(&name)?.clone();
            let seed = engine.manifest.seed;
            let Exec::Sim { models } = &mut engine.exec else { unreachable!() };
            models.insert(name.clone(), SimModel::new(spec, seed));
        }
        Ok(engine)
    }

    /// The default simulated engine: the paper's three models on the
    /// synthetic manifest.  Never fails.
    pub fn sim_reference() -> Engine {
        Engine::simulated(Manifest::synthetic(), None).expect("synthetic manifest is valid")
    }

    /// Whether this engine runs the simulated reference executor.
    pub fn is_simulated(&self) -> bool {
        matches!(self.exec, Exec::Sim { .. })
    }

    fn select_names(&self, models: Option<&[&str]>) -> Vec<String> {
        match models {
            Some(list) => list.iter().map(|s| s.to_string()).collect(),
            None => self.manifest.models.keys().cloned().collect(),
        }
    }

    fn load_model(&mut self, name: &str) -> Result<()> {
        let spec = self.manifest.model(name)?.clone();

        // --- weights: one upload, resident for the process lifetime ---
        // NOTE: we read npz entries as Literals and upload via
        // buffer_from_host_literal.  The direct
        // PjRtBuffer::read_npz_by_name path mis-declares the element
        // type (xla 0.1.6 passes ElementType where PJRT expects
        // PrimitiveType, turning F32 arrays into F16 buffers).
        let weights_path = self.manifest.weights_path(name)?;
        let param_names: Vec<&str> = spec.params.iter().map(|p| p.name.as_str()).collect();
        let literals = xla::Literal::read_npz_by_name(&weights_path, &(), &param_names)
            .map_err(|e| anyhow!("loading {weights_path:?}: {e}"))?;
        let Exec::Pjrt { client, models } = &mut self.exec else {
            bail!("load_model on a simulated engine");
        };
        let weights: Vec<PjRtBuffer> = literals
            .iter()
            .map(|lit| {
                client
                    .buffer_from_host_literal(None, lit)
                    .map_err(|e| anyhow!("uploading weights: {e}"))
            })
            .collect::<Result<_>>()?;
        if weights.len() != spec.params.len() {
            bail!(
                "{name}: loaded {} weight buffers, expected {}",
                weights.len(),
                spec.params.len()
            );
        }

        // --- executables: compile once per mini-batch size ---
        let mut exes = BTreeMap::new();
        for artifact in &spec.batches {
            let path = self.manifest.hlo_path(name, artifact.batch)?;
            let proto = HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
            let comp = XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {path:?}: {e}"))?;
            exes.insert(artifact.batch, exe);
        }

        models.insert(name.to_string(), LoadedModel { spec, weights, exes });
        Ok(())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn model_names(&self) -> Vec<String> {
        match &self.exec {
            Exec::Pjrt { models, .. } => models.keys().cloned().collect(),
            Exec::Sim { models } => models.keys().cloned().collect(),
        }
    }

    pub fn spec(&self, model: &str) -> Result<&ModelSpec> {
        let spec = match &self.exec {
            Exec::Pjrt { models, .. } => models.get(model).map(|m| &m.spec),
            Exec::Sim { models } => models.get(model).map(|m| &m.spec),
        };
        spec.ok_or_else(|| {
            anyhow!("model {model:?} not loaded (have {:?})", self.model_names())
        })
    }

    /// Execute one mini-batch at an exact compiled batch size.
    ///
    /// `input` must hold `batch * input_elems` f32s.  Returns
    /// `batch * output_elems` f32s plus the timing breakdown.
    pub fn execute(
        &self,
        model: &str,
        batch: usize,
        input: &[f32],
    ) -> Result<(Vec<f32>, ExecTiming)> {
        let spec = self.spec(model)?;
        let expected = batch * spec.input_elems();
        if input.len() != expected {
            bail!(
                "{model}: input has {} elements, batch {batch} needs {expected}",
                input.len()
            );
        }
        if !spec.batch_ladder().contains(&batch) {
            bail!("{model}: no batch-{batch} executable (ladder {:?})", spec.batch_ladder());
        }
        match &self.exec {
            Exec::Pjrt { client, models } => {
                let loaded = models.get(model).expect("spec() checked presence");
                execute_pjrt(client, loaded, model, batch, input)
            }
            Exec::Sim { models } => {
                let sim = models.get(model).expect("spec() checked presence");
                execute_sim(sim, batch, input)
            }
        }
    }

    /// Execute `n` samples by padding up to the smallest compiled
    /// batch (or chunking through the largest).  This is what the
    /// dynamic batcher calls; padding waste is the price of a fixed
    /// executable ladder and is reported by [`Engine::padding_waste`].
    pub fn execute_padded(&self, model: &str, input: &[f32]) -> Result<(Vec<f32>, ExecTiming)> {
        let spec = self.spec(model)?;
        let in_el = spec.input_elems();
        let out_el = spec.output_elems();
        if input.len() % in_el != 0 {
            bail!("{model}: input not a whole number of samples");
        }
        let n = input.len() / in_el;
        if n == 0 {
            return Ok((Vec::new(), ExecTiming::default()));
        }
        let ladder_max = *spec.batch_ladder().last().unwrap();

        let mut out = Vec::with_capacity(n * out_el);
        let mut timing = ExecTiming::default();
        let mut done = 0usize;
        while done < n {
            let remaining = n - done;
            let chunk = remaining.min(ladder_max);
            let exe_batch = spec.batch_for(chunk);
            let mut padded = vec![0f32; exe_batch * in_el];
            padded[..chunk * in_el]
                .copy_from_slice(&input[done * in_el..(done + chunk) * in_el]);
            let (chunk_out, t) = self.execute(model, exe_batch, &padded)?;
            out.extend_from_slice(&chunk_out[..chunk * out_el]);
            timing.upload += t.upload;
            timing.execute += t.execute;
            timing.fetch += t.fetch;
            done += chunk;
        }
        Ok((out, timing))
    }

    /// Fraction of executed samples that were padding for a request of
    /// `n` samples (0.0 = perfect fit).
    pub fn padding_waste(&self, model: &str, n: usize) -> Result<f64> {
        let spec = self.spec(model)?;
        let ladder_max = *spec.batch_ladder().last().unwrap();
        let mut executed = 0usize;
        let mut done = 0usize;
        while done < n {
            let chunk = (n - done).min(ladder_max);
            executed += spec.batch_for(chunk);
            done += chunk;
        }
        if executed == 0 {
            return Ok(0.0);
        }
        Ok(1.0 - n as f64 / executed as f64)
    }
}

fn execute_pjrt(
    client: &PjRtClient,
    loaded: &LoadedModel,
    model: &str,
    batch: usize,
    input: &[f32],
) -> Result<(Vec<f32>, ExecTiming)> {
    let spec = &loaded.spec;
    let exe = loaded.exes.get(&batch).ok_or_else(|| {
        anyhow!("{model}: no batch-{batch} executable (ladder {:?})", spec.batch_ladder())
    })?;

    let mut timing = ExecTiming::default();

    // host -> device
    let t0 = Instant::now();
    let mut dims = vec![batch];
    dims.extend_from_slice(&spec.input_shape);
    let x_buf = client
        .buffer_from_host_buffer::<f32>(input, &dims, None)
        .map_err(|e| anyhow!("upload: {e}"))?;
    timing.upload = t0.elapsed();

    // execute with resident weights (no weight copies!)
    let t1 = Instant::now();
    let mut args: Vec<&PjRtBuffer> = Vec::with_capacity(1 + loaded.weights.len());
    args.push(&x_buf);
    args.extend(loaded.weights.iter());
    let result = exe.execute_b(&args).map_err(|e| anyhow!("execute: {e}"))?;
    timing.execute = t1.elapsed();

    // device -> host
    let t2 = Instant::now();
    let literal = result[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("fetch: {e}"))?;
    // aot.py lowers with return_tuple=True -> 1-tuple.
    let out = literal
        .to_tuple1()
        .map_err(|e| anyhow!("untuple: {e}"))?
        .to_vec::<f32>()
        .map_err(|e| anyhow!("to_vec: {e}"))?;
    timing.fetch = t2.elapsed();

    let expected_out = batch * spec.output_elems();
    if out.len() != expected_out {
        bail!("{model}: output has {} elements, expected {expected_out}", out.len());
    }
    Ok((out, timing))
}

fn execute_sim(sim: &SimModel, batch: usize, input: &[f32]) -> Result<(Vec<f32>, ExecTiming)> {
    let in_el = sim.spec.input_elems();
    let out_el = sim.spec.output_elems();
    let t0 = Instant::now();
    let mut out = Vec::with_capacity(batch * out_el);
    for row in input.chunks_exact(in_el) {
        sim.forward_row(row, &mut out);
    }
    let timing = ExecTiming {
        upload: Duration::ZERO,
        execute: t0.elapsed().max(Duration::from_nanos(1)),
        fetch: Duration::ZERO,
    };
    Ok((out, timing))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_reference_loads_paper_models() {
        let e = Engine::sim_reference();
        assert!(e.is_simulated());
        assert_eq!(e.model_names(), vec!["hermit", "mir", "mir_noln"]);
        assert_eq!(e.spec("hermit").unwrap().input_elems(), 42);
        assert_eq!(e.spec("hermit").unwrap().output_elems(), 30);
        assert!(e.spec("nope").is_err());
    }

    #[test]
    fn sim_execute_is_deterministic_and_shaped() {
        let e = Engine::sim_reference();
        let x: Vec<f32> = (0..42).map(|i| (i as f32) * 0.01 - 0.2).collect();
        let (out, t) = e.execute("hermit", 1, &x).unwrap();
        assert_eq!(out.len(), 30);
        assert!(out.iter().all(|v| v.is_finite()));
        assert!(t.execute.as_nanos() > 0);
        let (out2, _) = e.execute("hermit", 1, &x).unwrap();
        assert_eq!(out, out2);
    }

    #[test]
    fn sim_batch_consistency_padding_does_not_leak() {
        let e = Engine::sim_reference();
        let x: Vec<f32> = (0..42).map(|i| (i as f32) * 0.03 - 0.5).collect();
        let (solo, _) = e.execute("hermit", 1, &x).unwrap();
        let mut x4 = vec![0f32; 4 * 42];
        x4[..42].copy_from_slice(&x);
        let (padded, _) = e.execute("hermit", 4, &x4).unwrap();
        assert_eq!(&padded[..30], &solo[..]);
    }

    #[test]
    fn sim_execute_padded_roundtrip() {
        let e = Engine::sim_reference();
        let x: Vec<f32> = (0..5 * 42).map(|i| (i % 13) as f32 * 0.05).collect();
        let (out, _) = e.execute_padded("hermit", &x).unwrap();
        assert_eq!(out.len(), 5 * 30);
        for s in 0..5 {
            let (row, _) = e.execute("hermit", 1, &x[s * 42..(s + 1) * 42]).unwrap();
            assert_eq!(&out[s * 30..(s + 1) * 30], &row[..]);
        }
    }

    #[test]
    fn sim_mir_outputs_are_volume_fractions() {
        let e = Engine::sim_reference();
        let x = vec![0.25f32; 48 * 48];
        let (out, _) = e.execute("mir", 1, &x).unwrap();
        assert_eq!(out.len(), 48 * 48);
        assert!(out.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn sim_rejects_bad_inputs_like_pjrt_would() {
        let e = Engine::sim_reference();
        assert!(e.execute("hermit", 1, &[0.0; 10]).is_err());
        assert!(e.execute("hermit", 3, &[0.0; 3 * 42]).is_err()); // 3 not in ladder
        assert!(e.execute("nope", 1, &[0.0; 42]).is_err());
    }

    #[test]
    fn sim_identically_shaped_replicas_agree() {
        // Replica routing depends on this: two engine models with the
        // same shape (stand-ins for two copies of one weight set)
        // produce identical rows.
        let m = Manifest::synthetic_named(&[("hermit_a", 42, 30), ("hermit_b", 42, 30)]);
        let e = Engine::simulated(m, None).unwrap();
        let x: Vec<f32> = (0..42).map(|i| (i as f32).sin()).collect();
        let (a, _) = e.execute("hermit_a", 1, &x).unwrap();
        let (b, _) = e.execute("hermit_b", 1, &x).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn sim_padding_waste_matches_ladder() {
        let e = Engine::sim_reference();
        assert_eq!(e.padding_waste("hermit", 1).unwrap(), 0.0);
        assert_eq!(e.padding_waste("hermit", 4).unwrap(), 0.0);
        let w3 = e.padding_waste("hermit", 3).unwrap();
        assert!((w3 - 0.25).abs() < 1e-12, "3 of 4 -> 25% waste, got {w3}");
    }

    #[test]
    fn pjrt_path_reports_stub_clearly() {
        // Engine::load without artifacts fails on the manifest; with a
        // manifest it would fail on the stubbed PJRT client.  Either
        // way the error is actionable.
        let err = Engine::load("/nonexistent-artifacts", None).unwrap_err();
        let rendered = format!("{err:#}");
        assert!(
            rendered.contains("manifest.json") || rendered.contains("artifacts"),
            "{rendered}"
        );
    }
}
