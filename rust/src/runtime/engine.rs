//! The PJRT execution engine: compiled-executable cache + resident
//! weight buffers.  This is the hot path of the serving system — one
//! `execute_b` per mini-batch, zero Python, zero weight re-uploads.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};
use xla::{FromRawBytes, HloModuleProto, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::manifest::{Manifest, ModelSpec};

/// Wall-clock breakdown of one execution (feeds the §Perf analysis:
/// the paper's GPU measurements exclude host<->device movement, the
/// DataScale measurements include it — we report both pieces).
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecTiming {
    /// Host -> device input upload.
    pub upload: Duration,
    /// Device execution (incl. PJRT dispatch).
    pub execute: Duration,
    /// Device -> host result fetch.
    pub fetch: Duration,
}

impl ExecTiming {
    pub fn total(&self) -> Duration {
        self.upload + self.execute + self.fetch
    }

    /// "Node-local GPU" accounting: the paper's GPU numbers exclude
    /// data movement (simulation and model share the device).
    pub fn compute_only(&self) -> Duration {
        self.execute
    }
}

/// One loaded model: resident weights + per-batch executables.
struct LoadedModel {
    spec: ModelSpec,
    /// Weight buffers in calling-convention order, uploaded once.
    weights: Vec<PjRtBuffer>,
    /// batch size -> compiled executable.
    exes: BTreeMap<usize, PjRtLoadedExecutable>,
}

/// The engine owns one PJRT client and every loaded model.
///
/// ## Thread-safety
/// The `xla` crate's wrappers hold raw pointers and are `!Send`, but
/// the underlying PJRT CPU client is thread-safe (its C++ API is
/// documented thread-compatible and the CPU plugin serialises
/// appropriately).  The coordinator keeps the engine behind a mutex
/// (`coordinator::executor`) and only ever calls it from its executor
/// threads, matching how a single physical accelerator serialises
/// work in the paper's setup.
pub struct Engine {
    client: PjRtClient,
    models: BTreeMap<String, LoadedModel>,
    manifest: Manifest,
}

// SAFETY: PJRT CPU client/executable/buffer handles are usable from
// any thread; the Rust wrappers are !Send only because they contain
// raw pointers.  All mutation goes through &mut self or is internally
// synchronised by PJRT.  See the struct docs for the usage contract.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Create a CPU-PJRT engine and load `models` (all models in the
    /// manifest when `None`).
    pub fn load(artifacts_dir: impl AsRef<Path>, models: Option<&[&str]>) -> Result<Self> {
        let manifest = Manifest::load(&artifacts_dir)?;
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        let mut engine = Engine { client, models: BTreeMap::new(), manifest };
        let names: Vec<String> = match models {
            Some(list) => list.iter().map(|s| s.to_string()).collect(),
            None => engine.manifest.models.keys().cloned().collect(),
        };
        for name in names {
            engine.load_model(&name)?;
        }
        Ok(engine)
    }

    fn load_model(&mut self, name: &str) -> Result<()> {
        let spec = self.manifest.model(name)?.clone();

        // --- weights: one upload, resident for the process lifetime ---
        // NOTE: we read npz entries as Literals and upload via
        // buffer_from_host_literal.  The direct
        // PjRtBuffer::read_npz_by_name path mis-declares the element
        // type (xla 0.1.6 passes ElementType where PJRT expects
        // PrimitiveType, turning F32 arrays into F16 buffers).
        let weights_path = self.manifest.weights_path(name)?;
        let param_names: Vec<&str> = spec.params.iter().map(|p| p.name.as_str()).collect();
        let literals =
            xla::Literal::read_npz_by_name(&weights_path, &(), &param_names)
                .map_err(|e| anyhow!("loading {weights_path:?}: {e}"))?;
        let weights: Vec<PjRtBuffer> = literals
            .iter()
            .map(|lit| {
                self.client
                    .buffer_from_host_literal(None, lit)
                    .map_err(|e| anyhow!("uploading weights: {e}"))
            })
            .collect::<Result<_>>()?;
        if weights.len() != spec.params.len() {
            bail!("{name}: loaded {} weight buffers, expected {}", weights.len(), spec.params.len());
        }

        // --- executables: compile once per mini-batch size ---
        let mut exes = BTreeMap::new();
        for artifact in &spec.batches {
            let path = self.manifest.hlo_path(name, artifact.batch)?;
            let proto = HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
            let comp = XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {path:?}: {e}"))?;
            exes.insert(artifact.batch, exe);
        }

        self.models.insert(name.to_string(), LoadedModel { spec, weights, exes });
        Ok(())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn model_names(&self) -> Vec<String> {
        self.models.keys().cloned().collect()
    }

    pub fn spec(&self, model: &str) -> Result<&ModelSpec> {
        Ok(&self.model(model)?.spec)
    }

    fn model(&self, name: &str) -> Result<&LoadedModel> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("model {name:?} not loaded (have {:?})", self.model_names()))
    }

    /// Execute one mini-batch at an exact compiled batch size.
    ///
    /// `input` must hold `batch * input_elems` f32s.  Returns
    /// `batch * output_elems` f32s plus the timing breakdown.
    pub fn execute(
        &self,
        model: &str,
        batch: usize,
        input: &[f32],
    ) -> Result<(Vec<f32>, ExecTiming)> {
        let loaded = self.model(model)?;
        let spec = &loaded.spec;
        let expected = batch * spec.input_elems();
        if input.len() != expected {
            bail!(
                "{model}: input has {} elements, batch {batch} needs {expected}",
                input.len()
            );
        }
        let exe = loaded.exes.get(&batch).ok_or_else(|| {
            anyhow!("{model}: no batch-{batch} executable (ladder {:?})", spec.batch_ladder())
        })?;

        let mut timing = ExecTiming::default();

        // host -> device
        let t0 = Instant::now();
        let mut dims = vec![batch];
        dims.extend_from_slice(&spec.input_shape);
        let x_buf = self
            .client
            .buffer_from_host_buffer::<f32>(input, &dims, None)
            .map_err(|e| anyhow!("upload: {e}"))?;
        timing.upload = t0.elapsed();

        // execute with resident weights (no weight copies!)
        let t1 = Instant::now();
        let mut args: Vec<&PjRtBuffer> = Vec::with_capacity(1 + loaded.weights.len());
        args.push(&x_buf);
        args.extend(loaded.weights.iter());
        let result = exe.execute_b(&args).map_err(|e| anyhow!("execute: {e}"))?;
        timing.execute = t1.elapsed();

        // device -> host
        let t2 = Instant::now();
        let literal = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch: {e}"))?;
        // aot.py lowers with return_tuple=True -> 1-tuple.
        let out = literal
            .to_tuple1()
            .map_err(|e| anyhow!("untuple: {e}"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("to_vec: {e}"))?;
        timing.fetch = t2.elapsed();

        let expected_out = batch * spec.output_elems();
        if out.len() != expected_out {
            bail!("{model}: output has {} elements, expected {expected_out}", out.len());
        }
        Ok((out, timing))
    }

    /// Execute `n` samples by padding up to the smallest compiled
    /// batch (or chunking through the largest).  This is what the
    /// dynamic batcher calls; padding waste is the price of a fixed
    /// executable ladder and is reported by [`padding_waste`].
    pub fn execute_padded(&self, model: &str, input: &[f32]) -> Result<(Vec<f32>, ExecTiming)> {
        let spec = &self.model(model)?.spec;
        let in_el = spec.input_elems();
        let out_el = spec.output_elems();
        if input.len() % in_el != 0 {
            bail!("{model}: input not a whole number of samples");
        }
        let n = input.len() / in_el;
        if n == 0 {
            return Ok((Vec::new(), ExecTiming::default()));
        }
        let ladder_max = *spec.batch_ladder().last().unwrap();

        let mut out = Vec::with_capacity(n * out_el);
        let mut timing = ExecTiming::default();
        let mut done = 0usize;
        while done < n {
            let remaining = n - done;
            let chunk = remaining.min(ladder_max);
            let exe_batch = spec.batch_for(chunk);
            let mut padded = vec![0f32; exe_batch * in_el];
            padded[..chunk * in_el]
                .copy_from_slice(&input[done * in_el..(done + chunk) * in_el]);
            let (chunk_out, t) = self.execute(model, exe_batch, &padded)?;
            out.extend_from_slice(&chunk_out[..chunk * out_el]);
            timing.upload += t.upload;
            timing.execute += t.execute;
            timing.fetch += t.fetch;
            done += chunk;
        }
        Ok((out, timing))
    }

    /// Fraction of executed samples that were padding for a request of
    /// `n` samples (0.0 = perfect fit).
    pub fn padding_waste(&self, model: &str, n: usize) -> Result<f64> {
        let spec = &self.model(model)?.spec;
        let ladder_max = *spec.batch_ladder().last().unwrap();
        let mut executed = 0usize;
        let mut done = 0usize;
        while done < n {
            let chunk = (n - done).min(ladder_max);
            executed += spec.batch_for(chunk);
            done += chunk;
        }
        if executed == 0 {
            return Ok(0.0);
        }
        Ok(1.0 - n as f64 / executed as f64)
    }
}
