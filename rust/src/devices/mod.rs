//! Calibrated analytic performance models for every accelerator/API
//! configuration in the paper's evaluation (§V).
//!
//! We have none of the paper's hardware (P100/V100/A100, MI50/MI100,
//! SambaNova SN10-8), so each device is modelled as
//!
//! ```text
//! latency(batch) = host_overhead(api, model)
//!                + max(compute_time(batch), memory_time(batch))
//! ```
//!
//! with per-device constants (peak half-precision FLOPs, memory
//! bandwidth, per-kernel-launch host cost, utilisation ramp) tuned so
//! the paper's *anchor numbers* come out within tolerance — e.g. the
//! A100's 0.65 ms naive single-sample latency and 3.92 ms at 32K
//! (Fig. 4), or 0.12 ms / 1.52 ms under TensorRT+CUDA-Graphs (Fig. 8).
//! `rust/tests/paper_shapes.rs` asserts both the anchors and the
//! figure-level shape invariants (who wins, where the crossovers sit).
//!
//! The analytic form is what gives the model its predictive shape:
//! small mini-batches are *host-bound* (launch count × launch cost —
//! why naive PyTorch on a Power9 V100 node is slower than on an x86
//! P100 node, Fig. 4 left), large mini-batches are *device-bound*
//! (roofline: compute vs. memory), and the API configurations differ
//! only in how many host launches they need and how well they fuse.
//!
//! Submodules:
//! * [`profiles`] — per-model compute profiles (FLOPs/sample, bytes
//!   moved, layer/kernel counts) derived from the actual Hermit/MIR
//!   architectures in `python/compile/models/`.
//! * [`gpu`]      — the GPU latency/throughput model + the five API
//!   configurations of Figs. 8–10.
//!
//! The RDU dataflow model lives in [`crate::rdu`] (it has different
//! physics: spatial pipeline + micro-batches, not kernel launches).

pub mod gpu;
pub mod profiles;

pub use gpu::{Api, Gpu, GpuModel};
pub use profiles::ModelProfile;

/// Paper batch ladder (§V-A): 1, 4, 16, 64, 256, 1K, 2K, 4K, 8K, 16K, 32K.
pub const PAPER_BATCHES: [usize; 11] =
    [1, 4, 16, 64, 256, 1024, 2048, 4096, 8192, 16384, 32768];
