//! The GPU latency/throughput model and the five API configurations
//! from the paper's Figs. 4–10.
//!
//! Two regimes compose the model (see module docs in [`super`]):
//!
//! * **Host-bound** (small mini-batch): the CPU issues one or more
//!   kernel launches per layer.  Naive eager PyTorch issues
//!   `kernels_per_layer_naive × n_layers` of them; TensorRT fuses to
//!   roughly one per layer; CUDA Graphs replays the whole graph from a
//!   single host operation.  The per-launch cost is a property of the
//!   *host* (x86 vs Power9) — which is exactly why the paper's V100
//!   (Power9 host) shows higher small-batch latency than the older
//!   P100 (x86 host) in Fig. 4.  A per-kernel device-time floor
//!   (`kernel_min_us`) keeps tiny GEMMs from being free.
//! * **Device-bound** (large mini-batch): a roofline of compute
//!   (`flops / (peak × utilisation(batch))`) against memory traffic
//!   (weights once per pass + unfused activation round-trips).
//!
//! Utilisation follows a power-law ramp
//! `eff(b) = eff_sat · (min(b, 32768)/32768)^q` — narrow-GEMM models
//! like Hermit need enormous batches to fill a modern GPU, while
//! MIR's 48×48 convolutions expose per-sample parallelism and
//! saturate almost immediately (per-model `util_factor` /
//! `sat_exp_scale` in [`ModelProfile`]... see `profiles.rs`).
//!
//! Every constant is calibrated against the paper's published
//! anchors; `calibration_anchor_*` tests below and
//! `rust/tests/paper_shapes.rs` pin them.

use super::profiles::ModelProfile;

/// The paper's largest tested mini-batch; utilisation is defined
/// relative to it.
const BATCH_SAT: f64 = 32768.0;

/// Host/API configuration (Figs. 8–10).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Api {
    /// Eager PyTorch from Python — one host launch per elementary op.
    NaivePyTorch,
    /// PyTorch + TensorRT via torch2trt: layer fusion, fewer launches.
    TensorRt,
    /// PyTorch + CUDA Graphs: the whole forward replays from one host
    /// op, but the kernels remain unfused eager kernels.
    CudaGraphs,
    /// TensorRT engine captured inside a CUDA Graph (the paper's best
    /// Hermit configuration).
    TrtCudaGraphs,
    /// The TensorRT C++ API: fused engine, no Python interpreter.
    CppTensorRt,
}

impl Api {
    pub const ALL: [Api; 5] = [
        Api::NaivePyTorch,
        Api::TensorRt,
        Api::CudaGraphs,
        Api::TrtCudaGraphs,
        Api::CppTensorRt,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            Api::NaivePyTorch => "PyTorch (naive)",
            Api::TensorRt => "PyTorch+TensorRT",
            Api::CudaGraphs => "PyTorch+CUDA Graphs",
            Api::TrtCudaGraphs => "PyTorch+TRT+CUDA Graphs",
            Api::CppTensorRt => "C++ TensorRT",
        }
    }

    /// Host-side launch operations for one forward pass.
    fn host_launches(&self, p: &ModelProfile) -> f64 {
        let layers = p.n_layers as f64;
        match self {
            Api::NaivePyTorch => layers * p.kernels_per_layer_naive,
            Api::TensorRt | Api::CppTensorRt => layers,
            // One graph replay + I/O binding.
            Api::CudaGraphs | Api::TrtCudaGraphs => 2.0,
        }
    }

    /// Device kernels actually executed (floor on device time; CUDA
    /// Graphs elides *launches*, not kernels).
    fn device_kernels(&self, p: &ModelProfile) -> f64 {
        let layers = p.n_layers as f64;
        match self {
            Api::NaivePyTorch | Api::CudaGraphs => layers * p.kernels_per_layer_naive,
            Api::TensorRt | Api::TrtCudaGraphs | Api::CppTensorRt => layers,
        }
    }

    /// Fixed per-request host overhead, µs (interpreter dispatch,
    /// binding setup, stream sync, graph-replay bookkeeping).
    fn base_overhead_us(&self) -> f64 {
        match self {
            Api::NaivePyTorch => 30.0,
            Api::TensorRt => 40.0,
            Api::CudaGraphs => 45.0,
            Api::TrtCudaGraphs => 70.0,
            Api::CppTensorRt => 10.0,
        }
    }

    /// Fused engines keep activations on-chip between layers and pick
    /// autotuned kernels (~2.2× effective utilisation — calibrated so
    /// TRT+Graphs lands at the paper's 1.52 ms/21.6 M s⁻¹ at 32K).
    fn fused(&self) -> bool {
        matches!(self, Api::TensorRt | Api::TrtCudaGraphs | Api::CppTensorRt)
    }

    const FUSED_EFF_BONUS: f64 = 2.22;

    /// torch2trt's unoptimised layernorm/unary kernels (Fig. 10): a
    /// per-sample compute penalty on torch2trt paths when the model
    /// contains layernorm.  The C++ TensorRT path in the paper still
    /// goes through the same converted network, so it is penalised too.
    fn layernorm_penalty(&self, p: &ModelProfile) -> f64 {
        if p.has_layernorm
            && matches!(self, Api::TensorRt | Api::TrtCudaGraphs | Api::CppTensorRt)
        {
            2.2
        } else {
            1.0
        }
    }
}

/// Hardware constants for one GPU (+host) pairing.
#[derive(Debug, Clone, PartialEq)]
pub struct Gpu {
    pub name: &'static str,
    /// Peak half-precision TFLOP/s.
    pub peak_half_tflops: f64,
    /// HBM bandwidth, GB/s.
    pub mem_bw_gbps: f64,
    /// Host per-launch cost, µs (x86 ≈ 8–12, Power9 ≈ 16).
    pub launch_us: f64,
    /// Minimum device time per kernel, µs (pipeline drain, tiny-GEMM
    /// floor).
    pub kernel_min_us: f64,
    /// Achieved fraction of peak at the 32K saturation batch under
    /// the *naive* API on narrow-GEMM (Hermit-like) models.
    pub eff_sat: f64,
    /// Power-law exponent of the utilisation ramp.
    pub sat_exponent: f64,
    /// Board power (W) — Fig. 7's TDP normalisation.
    pub tdp_w: f64,
    /// Transistor count (billions) — Fig. 19's normalisation.
    pub transistors_b: f64,
    /// Multiplicative efficiency penalty applied at or above a batch
    /// threshold (models the MI100's beta-ROCm plateau, Fig. 6/7).
    pub plateau: Option<(usize, f64)>,
}

impl Gpu {
    /// Nvidia P100 (Pascal, x86 host; fp16 via CUDA cores).  Early
    /// saturation: "latency increases more rapidly for the P100" and
    /// it ends up ">8x" the A100 at 32K (Fig. 4).
    pub fn p100() -> Gpu {
        Gpu {
            name: "P100",
            peak_half_tflops: 21.2,
            mem_bw_gbps: 732.0,
            launch_us: 10.5,
            kernel_min_us: 3.0,
            eff_sat: 0.285,
            sat_exponent: 0.12,
            tdp_w: 300.0,
            transistors_b: 15.3,
            plateau: None,
        }
    }

    /// Nvidia V100 on an IBM Power9 host (Sierra-class node).  The
    /// Power9's slower single-thread dispatch raises per-launch cost —
    /// the paper's explanation for V100 > P100 small-batch latency
    /// (§V-B, Fig. 4).
    pub fn v100() -> Gpu {
        Gpu {
            name: "V100",
            peak_half_tflops: 112.0,
            mem_bw_gbps: 900.0,
            launch_us: 16.0,
            kernel_min_us: 2.5,
            eff_sat: 0.305,
            sat_exponent: 0.20,
            tdp_w: 300.0,
            transistors_b: 21.1,
            plateau: None,
        }
    }

    /// Nvidia A100 (Ampere, x86 host).  Calibration anchors (naive
    /// PyTorch, Hermit): 0.65 ms @1, 3.92 ms @32K, 1 534 samples/s @1,
    /// 8.35 M samples/s @32K (Figs. 4–5).
    pub fn a100() -> Gpu {
        Gpu {
            name: "A100",
            peak_half_tflops: 312.0,
            mem_bw_gbps: 1555.0,
            launch_us: 8.0,
            kernel_min_us: 1.5,
            eff_sat: 0.183,
            sat_exponent: 0.30,
            tdp_w: 250.0, // paper: "the A100 has a lower TDP at 250W"
            transistors_b: 54.2,
            plateau: None,
        }
    }

    /// AMD MI50 (Vega 20, ROCm) — P100-like early saturation (Fig. 6).
    pub fn mi50() -> Gpu {
        Gpu {
            name: "MI50",
            peak_half_tflops: 26.5,
            mem_bw_gbps: 1024.0,
            launch_us: 11.0,
            kernel_min_us: 3.0,
            eff_sat: 0.285,
            sat_exponent: 0.12,
            tdp_w: 300.0,
            transistors_b: 13.2,
            plateau: None,
        }
    }

    /// AMD MI100 (CDNA1).  Anchors: 0.96 ms @1, 5.59 ms @32K,
    /// 5.85 M samples/s max (Fig. 6).  PyTorch 1.9's ROCm support was
    /// beta; the paper's unexplained 1K–4K plateau is modelled as a
    /// dispatch-path penalty from 2K up ("may be explained by the beta
    /// support for AMD GPUs of PyTorch 1.9.0", §V-B).
    pub fn mi100() -> Gpu {
        Gpu {
            name: "MI100",
            peak_half_tflops: 184.6,
            mem_bw_gbps: 1228.8,
            launch_us: 12.0,
            kernel_min_us: 2.5,
            eff_sat: 0.272,
            sat_exponent: 0.153,
            tdp_w: 290.0, // paper: "the MI100 at 290W"
            transistors_b: 25.6,
            plateau: Some((2048, 0.78)),
        }
    }

    pub fn by_name(name: &str) -> Option<Gpu> {
        match name.to_ascii_lowercase().as_str() {
            "p100" => Some(Gpu::p100()),
            "v100" => Some(Gpu::v100()),
            "a100" => Some(Gpu::a100()),
            "mi50" => Some(Gpu::mi50()),
            "mi100" => Some(Gpu::mi100()),
            _ => None,
        }
    }

    pub const ALL_NVIDIA: [&'static str; 3] = ["P100", "V100", "A100"];
    pub const ALL_AMD: [&'static str; 2] = ["MI50", "MI100"];
}

/// A (GPU, API, model) triple that predicts latency/throughput.
#[derive(Debug, Clone)]
pub struct GpuModel {
    pub gpu: Gpu,
    pub api: Api,
    pub profile: ModelProfile,
}

impl GpuModel {
    pub fn new(gpu: Gpu, api: Api, profile: ModelProfile) -> Self {
        GpuModel { gpu, api, profile }
    }

    /// Host-side overhead per forward pass, seconds.
    pub fn host_overhead_s(&self) -> f64 {
        (self.api.host_launches(&self.profile) * self.gpu.launch_us
            + self.api.base_overhead_us())
            * 1e-6
    }

    /// Achieved fraction of peak at a mini-batch size.
    fn utilisation(&self, batch: usize) -> f64 {
        let b = (batch as f64).min(BATCH_SAT);
        let ramp = (b / BATCH_SAT).powf(
            self.gpu.sat_exponent * self.profile.sat_exp_scale,
        );
        let mut eff = self.gpu.eff_sat * self.profile.util_factor * ramp;
        // TRT's autotuned fused kernels raise effective utilisation —
        // but not when torch2trt's unoptimised layernorm sits in the
        // middle of the engine (Fig. 10): those graphs lose the
        // fusion benefit *and* pay the layernorm compute penalty.
        if self.api.fused() && !self.profile.has_layernorm {
            eff *= Api::FUSED_EFF_BONUS;
        }
        if let Some((threshold, penalty)) = self.gpu.plateau {
            if batch >= threshold {
                eff *= penalty;
            }
        }
        eff
    }

    /// Device time for one mini-batch, seconds: roofline of compute
    /// vs memory vs the per-kernel floor.
    pub fn device_time_s(&self, batch: usize) -> f64 {
        let b = batch as f64;
        let flops =
            self.profile.flops_per_sample * b * self.api.layernorm_penalty(&self.profile);
        let compute = flops / (self.gpu.peak_half_tflops * 1e12 * self.utilisation(batch));

        // Memory: weights stream once per pass; unfused APIs also
        // round-trip activations between layers (fused keeps ~85 %
        // on-chip).
        let act = self.profile.activation_bytes_per_sample * b;
        let bytes = self.profile.weight_bytes
            + if self.api.fused() { 0.15 * act } else { act };
        let memory = bytes / (self.gpu.mem_bw_gbps * 1e9);

        let floor =
            self.api.device_kernels(&self.profile) * self.gpu.kernel_min_us * 1e-6;
        compute.max(memory).max(floor)
    }

    /// End-to-end mini-batch latency, seconds.  Matches the paper's
    /// GPU measurement convention: **no host<->device data movement**
    /// (simulation and surrogate share the GPU, §V-A).
    pub fn latency_s(&self, batch: usize) -> f64 {
        self.host_overhead_s() + self.device_time_s(batch)
    }

    /// Throughput in samples/s (synchronous submission, as the paper
    /// measures: total samples / wall-clock).
    pub fn throughput(&self, batch: usize) -> f64 {
        batch as f64 / self.latency_s(batch)
    }

    /// Fig. 7's TDP-normalised throughput.
    pub fn throughput_tdp_normalised(&self, batch: usize, reference_tdp_w: f64) -> f64 {
        self.throughput(batch) * reference_tdp_w / self.gpu.tdp_w
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::profiles;

    fn model(gpu: Gpu, api: Api) -> GpuModel {
        GpuModel::new(gpu, api, profiles::hermit())
    }

    fn ms(s: f64) -> f64 {
        s * 1e3
    }

    /// |actual/target - 1| <= tol
    fn within(actual: f64, target: f64, tol: f64) -> bool {
        (actual / target - 1.0).abs() <= tol
    }

    // ------------------------- anchor calibration (paper numbers)

    #[test]
    fn calibration_anchor_a100_naive() {
        let m = model(Gpu::a100(), Api::NaivePyTorch);
        // Fig. 4: "The A100 has the lowest single sample latency of
        // 0.65ms" ... "latency of 3.92ms at this mini-batch size [32K]".
        assert!(within(ms(m.latency_s(1)), 0.65, 0.10), "{}", ms(m.latency_s(1)));
        assert!(within(ms(m.latency_s(32768)), 3.92, 0.10), "{}", ms(m.latency_s(32768)));
        // Fig. 5: 1,534 samples/s at 1; 8.35M samples/s at 32K.
        assert!(within(m.throughput(1), 1534.0, 0.10), "{}", m.throughput(1));
        assert!(within(m.throughput(32768), 8.35e6, 0.10), "{}", m.throughput(32768));
    }

    #[test]
    fn calibration_anchor_a100_trt_graphs() {
        let m = model(Gpu::a100(), Api::TrtCudaGraphs);
        // Fig. 8: "single sample latency of 0.12ms and a 32k samples
        // latency of 1.52ms"; Fig. 9: 8,240 samples/s and 21.6M/s.
        assert!(within(ms(m.latency_s(1)), 0.12, 0.15), "{}", ms(m.latency_s(1)));
        assert!(within(ms(m.latency_s(32768)), 1.52, 0.10), "{}", ms(m.latency_s(32768)));
        assert!(within(m.throughput(1), 8240.0, 0.15), "{}", m.throughput(1));
        assert!(within(m.throughput(32768), 21.6e6, 0.10), "{}", m.throughput(32768));
    }

    #[test]
    fn calibration_anchor_mi100() {
        let m = model(Gpu::mi100(), Api::NaivePyTorch);
        // Fig. 6: 0.96 ms single-sample; 5.59 ms / 5.85 M s⁻¹ at 32K.
        assert!(within(ms(m.latency_s(1)), 0.96, 0.10), "{}", ms(m.latency_s(1)));
        assert!(within(ms(m.latency_s(32768)), 5.59, 0.10), "{}", ms(m.latency_s(32768)));
        assert!(within(m.throughput(32768), 5.85e6, 0.10), "{}", m.throughput(32768));
    }

    #[test]
    fn calibration_anchor_p100_8x_slower_at_32k() {
        // Fig. 4: "The P100 latency is more than 8x that of the A100
        // at the largest mini-batch size".
        let p = model(Gpu::p100(), Api::NaivePyTorch).latency_s(32768);
        let a = model(Gpu::a100(), Api::NaivePyTorch).latency_s(32768);
        assert!(p / a > 8.0, "ratio {}", p / a);
    }

    #[test]
    fn calibration_anchor_v100_over_5m() {
        // Fig. 5: V100 and A100 "achieve inference throughputs in
        // excess of 5 Million samples/s".
        assert!(model(Gpu::v100(), Api::NaivePyTorch).throughput(32768) > 5e6);
    }

    // ------------------------------- figure-shape invariants

    #[test]
    fn a100_lowest_nvidia_latency_everywhere() {
        // Fig. 4: "lowest latency across all mini-batch sizes with
        // the A100".
        for b in crate::devices::PAPER_BATCHES {
            let a = model(Gpu::a100(), Api::NaivePyTorch).latency_s(b);
            assert!(a <= model(Gpu::p100(), Api::NaivePyTorch).latency_s(b), "{b}");
            assert!(a <= model(Gpu::v100(), Api::NaivePyTorch).latency_s(b), "{b}");
        }
    }

    #[test]
    fn v100_slower_than_p100_at_small_batch_only() {
        // Fig. 4: Power9 host dispatch at small batches...
        for b in [1usize, 4, 16, 64] {
            assert!(
                model(Gpu::v100(), Api::NaivePyTorch).latency_s(b)
                    > model(Gpu::p100(), Api::NaivePyTorch).latency_s(b),
                "{b}"
            );
        }
        // ...but V100 wins once the P100 saturates.
        assert!(
            model(Gpu::v100(), Api::NaivePyTorch).latency_s(32768)
                < model(Gpu::p100(), Api::NaivePyTorch).latency_s(32768)
        );
    }

    #[test]
    fn a100_beats_mi100_at_every_batch() {
        // Fig. 7: "the measured throughput of the A100 is larger than
        // the MI100 at all tested mini-batch sizes".
        for b in crate::devices::PAPER_BATCHES {
            assert!(
                model(Gpu::a100(), Api::NaivePyTorch).throughput(b)
                    > model(Gpu::mi100(), Api::NaivePyTorch).throughput(b),
                "batch {b}"
            );
        }
    }

    #[test]
    fn mi100_flat_latency_below_1k() {
        // Fig. 6: "near constant latency with the MI100 for mini-batch
        // sizes at and below 1K".
        let m = model(Gpu::mi100(), Api::NaivePyTorch);
        assert!(m.latency_s(1024) / m.latency_s(1) < 1.5);
    }

    #[test]
    fn mi100_plateau_between_1k_and_4k() {
        // Fig. 7: throughput growth stalls between 1K and 4K relative
        // to the surrounding intervals.
        let m = model(Gpu::mi100(), Api::NaivePyTorch);
        let g_256_1k = m.throughput(1024) / m.throughput(256);
        let g_1k_4k = m.throughput(4096) / m.throughput(1024);
        assert!(g_1k_4k < g_256_1k, "{g_1k_4k} vs {g_256_1k}");
    }

    #[test]
    fn all_optimized_apis_beat_naive_2x_at_batch_1() {
        // Fig. 8: "all configurations are more than twice as fast as
        // the initial naive PyTorch implementation for single sample".
        let naive = model(Gpu::a100(), Api::NaivePyTorch).latency_s(1);
        for api in [Api::TensorRt, Api::CudaGraphs, Api::TrtCudaGraphs, Api::CppTensorRt] {
            let l = model(Gpu::a100(), api).latency_s(1);
            assert!(naive / l > 2.0, "{api:?}: {}", naive / l);
        }
    }

    #[test]
    fn trt_graphs_best_hermit_config_everywhere() {
        // Fig. 8/9: TRT+CUDA-Graphs lowest latency and highest
        // bandwidth at all mini-batch sizes.
        for b in crate::devices::PAPER_BATCHES {
            let best = model(Gpu::a100(), Api::TrtCudaGraphs).latency_s(b);
            for api in [Api::NaivePyTorch, Api::TensorRt, Api::CudaGraphs] {
                assert!(best <= model(Gpu::a100(), api).latency_s(b) * 1.001, "{api:?}@{b}");
            }
        }
    }

    #[test]
    fn trt_configs_converge_at_large_batch() {
        // Fig. 9: "all the configurations using TensorRT provide very
        // similar bandwidth performance" at large batch.
        let b = 32768;
        let t1 = model(Gpu::a100(), Api::TensorRt).throughput(b);
        let t2 = model(Gpu::a100(), Api::TrtCudaGraphs).throughput(b);
        let t3 = model(Gpu::a100(), Api::CppTensorRt).throughput(b);
        let hi = t1.max(t2).max(t3);
        let lo = t1.min(t2).min(t3);
        assert!(hi / lo < 1.10, "{hi} vs {lo}");
    }

    #[test]
    fn mir_trt_penalty_and_convergence() {
        // Fig. 10: CUDA Graphs best for MIR; TRT configs worse than
        // naive beyond batch 64 (torch2trt layernorm); all converge at
        // the largest batch.
        let naive = GpuModel::new(Gpu::a100(), Api::NaivePyTorch, profiles::mir());
        let graphs = GpuModel::new(Gpu::a100(), Api::CudaGraphs, profiles::mir());
        let trt = GpuModel::new(Gpu::a100(), Api::TensorRt, profiles::mir());
        for b in [256usize, 1024, 4096] {
            assert!(graphs.throughput(b) >= naive.throughput(b), "{b}");
            assert!(trt.throughput(b) < naive.throughput(b), "{b}");
        }
        // convergence of naive and graphs at 32K (both eager kernels)
        let r = graphs.throughput(32768) / naive.throughput(32768);
        assert!(r < 1.05, "{r}");
    }

    #[test]
    fn latency_monotone_in_batch() {
        for api in Api::ALL {
            let m = model(Gpu::a100(), api);
            let mut prev = 0.0;
            for b in crate::devices::PAPER_BATCHES {
                let l = m.latency_s(b);
                assert!(l >= prev, "{api:?} batch {b}");
                prev = l;
            }
        }
    }

    #[test]
    fn tdp_normalisation_scales_correctly() {
        let m = model(Gpu::mi100(), Api::NaivePyTorch);
        let raw = m.throughput(1024);
        assert!((m.throughput_tdp_normalised(1024, 250.0) - raw * 250.0 / 290.0).abs() < 1e-9);
    }

    #[test]
    fn by_name_lookup() {
        for n in ["p100", "V100", "a100", "MI50", "mi100"] {
            assert!(Gpu::by_name(n).is_some());
        }
        assert!(Gpu::by_name("h100").is_none());
    }
}
