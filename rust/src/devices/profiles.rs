//! Per-model compute profiles: the numbers the device models need
//! about Hermit and MIR.  Derived from the *actual* architectures in
//! `python/compile/models/` (layer widths, conv geometry); the tests
//! cross-check the parameter counts against the AOT manifest.

/// Static compute profile of one surrogate model.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelProfile {
    pub name: &'static str,
    /// Trainable parameters.
    pub param_count: usize,
    /// Multiply-accumulate FLOPs per sample (2 × MACs).
    pub flops_per_sample: f64,
    /// Parameter bytes at half precision (the paper runs FP16/BF16).
    pub weight_bytes: f64,
    /// Activation bytes written+read per sample per *unfused* layer
    /// boundary at half precision (naive-API memory traffic).
    pub activation_bytes_per_sample: f64,
    /// Weight-carrying layers (FC or conv).
    pub n_layers: usize,
    /// Extra non-GEMM ops per layer under the naive eager API
    /// (bias add, activation, reshape ... each its own kernel).
    pub kernels_per_layer_naive: f64,
    /// Whether the model contains layernorm — torch2trt's unoptimised
    /// layernorm is the Fig. 10 TensorRT penalty.
    pub has_layernorm: bool,
    /// Input / output elements per sample (network payload sizing).
    pub input_elems: usize,
    pub output_elems: usize,
    /// Fraction of a GPU's Hermit-calibrated saturated efficiency this
    /// model reaches (MIR's small-channel 48×48 convs + layernorm are
    /// far less MXU-friendly than dense GEMMs: ~0.065, calibrated to
    /// the A100's ~100K samples/s ceiling in Fig. 20).
    pub util_factor: f64,
    /// Scale on the GPU's utilisation-ramp exponent.  MIR exposes
    /// per-*sample* parallelism (2 304 pixels), so it saturates at a
    /// tiny fraction of the batch Hermit needs.
    pub sat_exp_scale: f64,
}

/// Hermit layer widths (mirrors `python/compile/models/hermit.py`).
pub const HERMIT_WIDTHS: [usize; 22] = [
    42, 19, 17, 13, 10, // encoder
    12, 16, 24, 32, 48, 64, 128, 256, 512, 1024, 2050, // DJINN
    27, 27, 27, 27, 27, 30, // decoder
];

/// Build the Hermit profile from its widths.
pub fn hermit() -> ModelProfile {
    let mut params = 0usize;
    let mut flops = 0f64;
    let mut act_bytes = 0f64;
    for w in HERMIT_WIDTHS.windows(2) {
        let (d_in, d_out) = (w[0], w[1]);
        params += d_in * d_out + d_out;
        flops += 2.0 * (d_in * d_out) as f64;
        // each unfused layer writes + re-reads its activations (fp16)
        act_bytes += 2.0 * 2.0 * d_out as f64;
    }
    ModelProfile {
        name: "hermit",
        param_count: params,
        flops_per_sample: flops,
        weight_bytes: 2.0 * params as f64,
        activation_bytes_per_sample: act_bytes,
        n_layers: HERMIT_WIDTHS.len() - 1,
        kernels_per_layer_naive: 3.0, // gemm + bias + relu
        has_layernorm: false,
        input_elems: 42,
        output_elems: 30,
        util_factor: 1.0,
        sat_exp_scale: 1.0,
    }
}

/// MIR conv geometry (mirrors `python/compile/models/mir.py`):
/// 48×48 input, channels 1→16→32→64→128 with pooling after the first
/// three convs, FC 4608→64→64→4608, tied transposed-conv decoder.
pub fn mir() -> ModelProfile {
    let channels = [1usize, 16, 32, 64, 128];
    let sizes = [48usize, 24, 12, 6]; // feature-map side before each conv
    let mut params = 0usize;
    let mut flops = 0f64;
    let mut act_bytes = 0f64;
    // encoder convs (3x3)
    for i in 0..4 {
        let (cin, cout) = (channels[i], channels[i + 1]);
        let hw = sizes[i] * sizes[i];
        params += 9 * cin * cout + cout;
        flops += 2.0 * (hw * 9 * cin * cout) as f64;
        act_bytes += 2.0 * 2.0 * (hw * cout) as f64;
        // layernorm params
        params += 2 * cout;
    }
    // FC stack
    for (d_in, d_out) in [(4608usize, 64usize), (64, 64), (64, 4608)] {
        params += d_in * d_out + d_out;
        flops += 2.0 * (d_in * d_out) as f64;
        act_bytes += 2.0 * 2.0 * d_out as f64;
    }
    // decoder: tied weights (no new kernel params, only biases), but
    // the same conv FLOPs mirrored at decoder resolutions.
    let dec_sizes = [6usize, 6, 12, 24]; // input side per decoder stage
    for (i, layer) in (0..4).rev().enumerate() {
        let (cin, cout) = (channels[layer + 1], channels[layer]);
        let stride: usize = if layer == 3 { 1 } else { 2 };
        let out_side = dec_sizes[i] * stride;
        let hw = out_side * out_side;
        params += cout; // decoder bias only (kernels tied)
        flops += 2.0 * (hw * 9 * cin * cout) as f64;
        act_bytes += 2.0 * 2.0 * (hw * cout) as f64;
    }
    ModelProfile {
        name: "mir",
        param_count: params,
        flops_per_sample: flops,
        weight_bytes: 2.0 * params as f64,
        activation_bytes_per_sample: act_bytes,
        n_layers: 15, // 4 conv + 4 ln + 3 fc + 4 convT
        kernels_per_layer_naive: 4.0, // conv/gemm + bias + act + pool/norm
        has_layernorm: true,
        input_elems: 48 * 48,
        output_elems: 48 * 48,
        util_factor: 0.065,
        sat_exp_scale: 0.065,
    }
}

/// The Fig-20 variant: layernorm removed for cross-architecture
/// compile compatibility.
pub fn mir_noln() -> ModelProfile {
    let mut p = mir();
    p.name = "mir_noln";
    p.has_layernorm = false;
    // 4 layernorms' (gamma, beta) pairs removed
    let ln_params: usize = [16usize, 32, 64, 128].iter().map(|c| 2 * c).sum();
    p.param_count -= ln_params;
    p.weight_bytes = 2.0 * p.param_count as f64;
    p.n_layers = 11;
    p
}

pub fn by_name(name: &str) -> Option<ModelProfile> {
    match name {
        "hermit" => Some(hermit()),
        "mir" => Some(mir()),
        "mir_noln" => Some(mir_noln()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hermit_matches_python_param_count() {
        // python/compile/models/hermit.py reports 2,866,530.
        assert_eq!(hermit().param_count, 2_866_530);
    }

    #[test]
    fn mir_matches_python_param_count() {
        // python/compile/models/mir.py reports 696,401.
        assert_eq!(mir().param_count, 696_401);
    }

    #[test]
    fn mir_noln_matches_python_param_count() {
        // 696,401 - 480 layernorm params = 695,921.
        assert_eq!(mir_noln().param_count, 695_921);
    }

    #[test]
    fn hermit_flops_scale() {
        // ~2 FLOPs per parameter (dense layers): 5.7 MFLOP/sample.
        let p = hermit();
        assert!(p.flops_per_sample > 5.5e6 && p.flops_per_sample < 6.0e6);
    }

    #[test]
    fn mir_flops_dominated_by_convs() {
        // conv autoencoder: tens of MFLOPs despite only 700K params.
        let p = mir();
        assert!(p.flops_per_sample > 2.0e7, "{}", p.flops_per_sample);
        assert!(p.flops_per_sample < 6.0e7, "{}", p.flops_per_sample);
    }

    #[test]
    fn layer_counts_match_paper() {
        assert_eq!(hermit().n_layers, 21); // "21 fully connected layers"
        assert!(mir().has_layernorm);
        assert!(!mir_noln().has_layernorm);
    }

    #[test]
    fn by_name_roundtrip() {
        for n in ["hermit", "mir", "mir_noln"] {
            assert_eq!(by_name(n).unwrap().name, n);
        }
        assert!(by_name("nope").is_none());
    }
}
