//! The shared request lifecycle: routing → batching → (residency) →
//! dispatch → service → completion, over either the legacy
//! fixed-charge link or the multi-phase contention-aware fabric.
//!
//! See the [module docs](super) for the effects protocol.  The rule
//! that makes the extraction behaviour-preserving: every effect is
//! appended in **exactly** the order the pre-refactor engines pushed
//! the corresponding event or record, because event-queue insertion
//! order defines heap sequence numbers and record order defines the
//! golden JSON.
//!
//! Per-event cost: model names are interned to dense ids at submit
//! (`models`), so the hot path — routing, the residency touch, the
//! weights-ready gate — indexes flat `Vec` tables instead of hashing
//! strings, and the id buffers inside [`Effects`] cycle through a
//! free list ([`Pipeline::recycle_effects`]) instead of being
//! reallocated per batch.  Both are invisible to the effects
//! protocol: same decisions, same order, same bytes.

use crate::cluster::{policy, Backend, Policy};
use crate::devices::{profiles, ModelProfile};
use crate::fabric::FabricSpec;
use crate::netsim::dir_payload_bytes;
use crate::trace::Recorder;

use crate::eventsim::equeue::{CLASS_COMPLETION, CLASS_DEADLINE};

use super::{BatchStage, Batching, FabricLayer, FlowCont, Residency};

/// Pipeline-owned events: the engine wraps them in its own event enum
/// and hands them back to [`Pipeline::handle`] when they pop.
#[derive(Debug, Clone)]
pub enum PipeEvent {
    /// Re-check the batcher's deadline-ready queues.
    BatchDeadline,
    /// A direct-path batch finished; `token` indexes the live direct
    /// batch table (stale — a no-op — when the batch was orphaned by
    /// a backend leaving mid-flight).
    Completion { token: usize },
    /// The fabric engine's earliest flow completion (stale when
    /// `version` is no longer current — see [`FabricLayer`]).
    FabricWake { version: u64 },
    /// A batch's request payload finished its fixed-latency tail and
    /// is at the accelerator; begin queue + execution.
    XferInDone { token: usize },
    /// A batch's device execution finished; start the result flow.
    ServiceDone { token: usize },
    /// The result payload is back at the host; complete the batch.
    XferOutDone { token: usize },
}

/// How a dispatched batch will complete.
#[derive(Debug, Clone, Copy)]
pub enum Outcome {
    /// Legacy fixed-charge path: the completion instant (and every
    /// phase share) is known at dispatch.
    Direct { wait_s: f64, swap_s: f64, link_s: f64, exec_s: f64, complete_s: f64 },
    /// Fabric path: transit `token` opened; the measured timings land
    /// with the matching [`Completed`] effect.
    InFlight { token: usize },
}

/// One batch the pipeline dispatched: the engine opens its records
/// (in effect order — record order is part of the golden contract).
#[derive(Debug, Clone)]
pub struct Dispatched {
    pub ids: Vec<usize>,
    pub backend: usize,
    pub batch_samples: usize,
    pub outcome: Outcome,
    /// True when this is a control-plane *re*-dispatch of work
    /// orphaned by a backend failure: the engine updates the ids'
    /// existing records in place instead of opening new ones.
    pub retry: bool,
}

/// Measured phase timings of a fabric batch, filled when the result
/// lands: `swap_s` is the *excess* residency wait not hidden behind
/// the payload transfer.
#[derive(Debug, Clone, Copy)]
pub struct TransitTiming {
    pub wait_s: f64,
    pub swap_s: f64,
    pub link_s: f64,
    pub contention_s: f64,
    pub exec_s: f64,
}

/// One batch whose completion fired: `timing` is `None` on the direct
/// path (the engine already knows the completion fields from
/// [`Outcome::Direct`]); on the fabric path `token` identifies the
/// transit whose record block the engine opened at dispatch.
#[derive(Debug, Clone)]
pub struct Completed {
    pub ids: Vec<usize>,
    pub token: Option<usize>,
    pub timing: Option<TransitTiming>,
}

/// Everything a pipeline call produced, in exact legacy push order.
#[derive(Debug, Default)]
pub struct Effects {
    /// `(time, event-queue class, event)` to insert, in order.
    pub scheduled: Vec<(f64, u8, PipeEvent)>,
    pub dispatched: Vec<Dispatched>,
    pub completed: Vec<Completed>,
    /// Request ids whose in-flight batch died with its backend this
    /// call (control plane only — always empty on a static run).  The
    /// engine must void these records **before** applying
    /// `dispatched`: every orphan is re-dispatched exactly once and
    /// reappears there with `retry = true`.
    pub orphaned: Vec<usize>,
}

/// The residency stage's knobs (engaged only when configured).
#[derive(Debug, Clone, Copy)]
pub struct ResidencySpec {
    /// Models resident per backend (LRU eviction).
    pub slots: usize,
    /// Seconds charged when a backend serves a model it doesn't hold.
    pub swap_s: f64,
}

/// Per-request metadata, dense: `model` indexes the intern table.
#[derive(Debug, Clone, Copy)]
struct ReqMeta {
    rank: u32,
    model: u32,
    samples: u32,
}

/// One timed control-plane action: what happens to the fleet, and
/// when.  Engines schedule these as ordinary events (arrival class)
/// and forward the action to the pipeline's control hooks.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FleetEvent {
    pub at_s: f64,
    pub action: FleetAction,
}

/// The control-plane vocabulary.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FleetAction {
    /// Backend leaves the fleet (failure or scale-down): queue
    /// drained, residency and weights-ready gates invalidated, flows
    /// cancelled, in-flight batches orphaned and re-dispatched once.
    BackendLeave(usize),
    /// Backend (re)joins cold; parked batches flush.
    BackendJoin(usize),
    /// Every fabric link degrades to `factor` × as-built capacity.
    LinkDegrade(f64),
    /// Capacities return to as-built (factor 1, drift-free).
    LinkRestore,
    /// Rank fails and restarts from checkpoint, replaying its
    /// in-flight timestep (coupled engine; no-op for open/closed-loop
    /// streams, which have no rank-owned state to lose).
    RankFail(usize),
}

/// Reactive queue-depth autoscaler knobs: the engine samples the mean
/// routing backlog over the *active* hermit-tier backends between
/// steps and grows/shrinks the pool one backend at a time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AutoscalerCfg {
    /// Backends active at t=0 (the rest start parked).
    pub initial: usize,
    /// Never shrink below this many active backends.
    pub min_active: usize,
    /// Never grow past this many active backends.
    pub max_active: usize,
    /// Shrink when mean backlog per active backend falls below this.
    pub low_s: f64,
    /// Grow when mean backlog per active backend exceeds this.
    pub high_s: f64,
}

impl AutoscalerCfg {
    /// Check the config against a hermit tier of `tier` backends.
    /// Returns the human-readable constraint violated, if any — a
    /// user-supplied `auto:` spec must surface as a named CLI error,
    /// not an abort.  (Pass `usize::MAX` as `tier` to check only the
    /// tier-independent constraints, e.g. at parse time.)
    pub fn validate(&self, tier: usize) -> Result<(), String> {
        if self.min_active < 1 {
            return Err("autoscaler must keep one backend".to_string());
        }
        if !(self.min_active <= self.initial && self.initial <= self.max_active) {
            return Err("autoscaler bounds must satisfy min <= initial <= max".to_string());
        }
        if self.max_active > tier {
            return Err(format!("autoscaler max exceeds the tier size ({tier})"));
        }
        if !(self.low_s >= 0.0 && self.high_s > self.low_s && self.high_s.is_finite()) {
            return Err("autoscaler thresholds must satisfy 0 <= low < high < inf".to_string());
        }
        Ok(())
    }

    /// Panicking [`Self::validate`] for programmatic construction
    /// (tests, hand-built configs): misuse in code is a bug, not a
    /// user error.
    pub fn assert_valid(&self, tier: usize) {
        if let Err(why) = self.validate(tier) {
            panic!("{why}");
        }
    }
}

/// A direct-path batch whose completion event is still in flight.
/// `dead` marks batches orphaned by a backend leave: the already
/// scheduled [`PipeEvent::Completion`] becomes a no-op and the ids
/// travel on through the orphan/retry path instead.
#[derive(Debug)]
struct DirectBatch {
    ids: Vec<usize>,
    backend: usize,
    dead: bool,
}

/// One batch in flight through the fabric.  The weights-ready fields
/// are inert for engines without a residency stage (`swap_done` is
/// true from creation and the gate never parks).
#[derive(Debug, Clone)]
struct Transit {
    ids: Vec<usize>,
    backend: usize,
    accel: usize,
    host: usize,
    /// Model id the batch serves (the weights-ready gate's key).
    model: usize,
    bytes_out: f64,
    dispatch_s: f64,
    net_in_s: f64,
    /// When the payload's fixed tail landed (valid once `in_done`).
    in_done_s: f64,
    in_done: bool,
    swap_done: bool,
    /// Service already scheduled (guards double-starts when a parked
    /// batch is re-tried by the weights-ready drain).
    started: bool,
    /// Orphaned by a backend leave: every later phase event for this
    /// token is stale and must be dropped.
    dead: bool,
    /// Swap time *not* hidden behind the payload transfer: the serial
    /// residency charge on the batch's critical chain.
    swap_excess_s: f64,
    wait_s: f64,
    exec_s: f64,
    out_start_s: f64,
    ideal_rtt_s: f64,
}

/// The engine-agnostic pipeline: backends + policy + batching +
/// residency + fabric, driven through submit/handle/take_effects.
pub struct Pipeline {
    backends: Vec<Box<dyn Backend>>,
    policy: Policy,
    hermit_tier: Vec<usize>,
    mir_tier: Vec<usize>,
    hermit_profile: ModelProfile,
    mir_profile: ModelProfile,
    rr_cursor: usize,
    /// Interned model names: submit resolves each name to its id once
    /// (linear scan — the model population is small and stable), and
    /// every per-event structure below indexes by that id.
    models: Vec<String>,
    /// Per-model: does the name select the MIR tier/profile?
    model_is_mir: Vec<bool>,
    /// Per-model sticky-affinity slot ([`Policy::ModelAffinity`]).
    affinity: Vec<Option<usize>>,
    clock_s: f64,
    batcher: Option<BatchStage>,
    fabric: Option<FabricLayer>,
    residency: Option<Vec<Residency>>,
    swap_cfg_s: f64,
    transits: Vec<Transit>,
    /// `[model][backend]` — when that backend's copy of the model's
    /// weights lands: `INFINITY` while the swap flow is still on the
    /// wire (followers must not execute before the weights arrive —
    /// the residency `touch` marks the model resident at dispatch,
    /// this gate makes that honest), `NEG_INFINITY` = never swapped
    /// (absent).  The in-transit test is `== INFINITY` *exactly*.
    swap_ready_s: Vec<Vec<f64>>,
    /// `[model][backend]` — batches parked on an in-transit swap.
    swap_waiters: Vec<Vec<Vec<usize>>>,
    req_meta: Vec<ReqMeta>,
    /// Free list of id buffers cycling through [`Effects`].
    id_pool: Vec<Vec<usize>>,
    /// Drained [`Effects`] shell awaiting reuse by `take_effects`.
    spare: Option<Effects>,
    // -------- control plane (inert on a static run) --------
    /// Per-backend membership: control events flip these; routing
    /// only ever considers the live tiers below.
    active: Vec<bool>,
    /// `hermit_tier` / `mir_tier` filtered to active backends,
    /// order-preserving; rebuilt on every membership change.
    live_hermit: Vec<usize>,
    live_mir: Vec<usize>,
    /// Direct-path batches in flight, indexed by completion token.
    direct_live: Vec<DirectBatch>,
    /// Free direct tokens (a token recycles only when its scheduled
    /// completion event has popped, so stale events cannot alias).
    direct_free: Vec<usize>,
    /// Batches with no live backend in their tier, awaiting a join.
    parked: Vec<(Vec<usize>, bool)>,
    /// Batches in flight per backend (direct + fabric): the
    /// autoscaler's is-it-idle check.
    live_batches: Vec<u32>,
    /// Requests re-dispatched after their backend died.
    retries: u64,
    /// Requests orphaned by backend leaves (each re-dispatched once).
    orphaned: u64,
    submitted: u64,
    dispatched: u64,
    completed: u64,
    batches: u64,
    swaps: u64,
    swap_time_s: f64,
    effects: Effects,
    /// The flight recorder ([`crate::trace`]).  `None` on every
    /// default-constructed pipeline: each hook below is a single
    /// `Option` check when tracing is off, and the differential tests
    /// pin that the disarmed path is output-unobservable.
    rec: Option<Box<Recorder>>,
    /// Always-on per-backend service-seconds counter (one add per
    /// batch): the ground truth the recorder's per-device busy
    /// integrals must reconcile against to 1e-9.
    device_busy_s: Vec<f64>,
}

impl Pipeline {
    pub fn new(
        backends: Vec<Box<dyn Backend>>,
        policy: Policy,
        hermit_tier: Vec<usize>,
        mir_tier: Vec<usize>,
        batching: Batching,
        residency: Option<ResidencySpec>,
    ) -> Pipeline {
        assert!(!backends.is_empty(), "pipeline needs at least one backend");
        assert!(!hermit_tier.is_empty(), "hermit tier must not be empty");
        assert!(hermit_tier.iter().chain(&mir_tier).all(|&i| i < backends.len()));
        if let Some(spec) = residency {
            assert!(spec.slots >= 1);
            assert!(spec.swap_s >= 0.0 && spec.swap_s.is_finite());
        }
        let batcher = BatchStage::from_config(batching);
        let residency_state =
            residency.map(|spec| backends.iter().map(|_| Residency::new(spec.slots)).collect());
        let n = backends.len();
        Pipeline {
            active: vec![true; n],
            live_hermit: hermit_tier.clone(),
            live_mir: mir_tier.clone(),
            direct_live: Vec::new(),
            direct_free: Vec::new(),
            parked: Vec::new(),
            live_batches: vec![0; n],
            retries: 0,
            orphaned: 0,
            backends,
            policy,
            hermit_tier,
            mir_tier,
            hermit_profile: profiles::hermit(),
            mir_profile: profiles::mir_noln(),
            rr_cursor: 0,
            models: Vec::new(),
            model_is_mir: Vec::new(),
            affinity: Vec::new(),
            clock_s: 0.0,
            batcher,
            fabric: None,
            residency: residency_state,
            swap_cfg_s: residency.map_or(0.0, |spec| spec.swap_s),
            transits: Vec::new(),
            swap_ready_s: Vec::new(),
            swap_waiters: Vec::new(),
            req_meta: Vec::new(),
            id_pool: Vec::new(),
            spare: None,
            submitted: 0,
            dispatched: 0,
            completed: 0,
            batches: 0,
            swaps: 0,
            swap_time_s: 0.0,
            effects: Effects::default(),
            rec: None,
            device_busy_s: vec![0.0; n],
        }
    }

    /// Attach the contention-aware fabric: remote dispatches become
    /// flow events instead of the fixed link charge.
    pub fn attach_fabric(&mut self, spec: FabricSpec) {
        self.fabric = Some(FabricLayer::new(spec, self.backends.len()));
    }

    // ----------------------------------------------- flight recorder

    /// Arm the flight recorder: device tracks register from the
    /// backend names, link tracks (when a fabric is attached) from
    /// the topology's as-built capacities.  Call before the run
    /// starts; every timestamp recorded from here on is virtual time.
    pub fn arm_trace(&mut self) {
        let mut rec = Box::new(Recorder::new());
        rec.register_devices(self.backends.iter().map(|b| b.name().to_string()));
        if let Some(fab) = self.fabric.as_ref() {
            let topo = &fab.spec.topology;
            let labels = (0..topo.n_links()).map(|l| topo.link_label(l)).collect();
            rec.register_links(labels, topo.capacities().to_vec());
        }
        self.rec = Some(rec);
        // seed the series with the idle t=0 state
        self.trace_fabric_sample();
    }

    /// Carry a recorder that records nothing — the bench's probe for
    /// the disarmed hooks' hot-path cost.
    pub fn attach_disarmed_recorder(&mut self) {
        self.rec = Some(Box::new(Recorder::disarmed()));
    }

    /// Detach the recorder, closing its books at the current clock.
    pub fn take_recorder(&mut self) -> Option<Box<Recorder>> {
        let clock = self.clock_s;
        let mut rec = self.rec.take()?;
        if rec.armed() {
            rec.finalize(clock);
        }
        Some(rec)
    }

    /// Is an armed recorder attached?
    pub fn trace_armed(&self) -> bool {
        self.rec.as_deref().is_some_and(Recorder::armed)
    }

    /// Record a control-plane marker at the current virtual clock
    /// (no-op unless armed — guard any costly `detail` formatting
    /// with [`Self::trace_armed`]).
    pub fn trace_marker(&mut self, name: &'static str, detail: &str) {
        let t = self.clock_s;
        if let Some(rec) = self.rec.as_deref_mut() {
            if rec.armed() {
                rec.marker(name, detail.to_string(), t);
            }
        }
    }

    /// Per-backend service seconds accumulated so far (always on).
    pub fn device_busy_s(&self) -> &[f64] {
        &self.device_busy_s
    }

    /// Sample the fabric's per-link rates into the recorder; called
    /// at every flow mutation site (start/finish/cancel/degrade).
    fn trace_fabric_sample(&mut self) {
        let clock = self.clock_s;
        if let (Some(rec), Some(fab)) = (self.rec.as_deref_mut(), self.fabric.as_mut()) {
            if rec.armed() {
                rec.fabric_sample(clock, &mut fab.engine);
            }
        }
    }

    // ----------------------------------------------------- effects

    /// Drain everything accumulated since the last call, in exact
    /// dispatch/push order.
    pub fn take_effects(&mut self) -> Effects {
        let fresh = self.spare.take().unwrap_or_default();
        std::mem::replace(&mut self.effects, fresh)
    }

    /// Hand a consumed [`Effects`] back for reuse: its id buffers and
    /// the three vectors return to the pipeline's free lists.  Purely
    /// an allocation-recycling hook — skipping it only costs fresh
    /// allocations, never correctness.
    pub fn recycle_effects(&mut self, mut effects: Effects) {
        for d in effects.dispatched.drain(..) {
            self.recycle_ids(d.ids);
        }
        for c in effects.completed.drain(..) {
            self.recycle_ids(c.ids);
        }
        effects.scheduled.clear();
        effects.orphaned.clear();
        self.spare = Some(effects);
    }

    fn recycle_ids(&mut self, mut ids: Vec<usize>) {
        ids.clear();
        self.id_pool.push(ids);
    }

    fn pooled_ids(&mut self) -> Vec<usize> {
        self.id_pool.pop().unwrap_or_default()
    }

    // --------------------------------------------------- accessors

    pub fn clock_s(&self) -> f64 {
        self.clock_s
    }

    pub fn policy(&self) -> Policy {
        self.policy
    }

    /// Requests that have entered the router.
    pub fn submitted(&self) -> u64 {
        self.submitted
    }

    /// Requests dispatched to a backend (inside some batch).
    pub fn dispatched(&self) -> u64 {
        self.dispatched
    }

    /// Requests whose completion fired.
    pub fn completed(&self) -> u64 {
        self.completed
    }

    /// Batches dispatched so far.
    pub fn batches(&self) -> u64 {
        self.batches
    }

    /// Residency misses so far.
    pub fn swaps(&self) -> u64 {
        self.swaps
    }

    /// Seconds of swap time charged (legacy path) or measured on the
    /// wire (fabric path).
    pub fn swap_time_s(&self) -> f64 {
        self.swap_time_s
    }

    /// Requests waiting in the batching window.
    pub fn batcher_pending(&self) -> u64 {
        self.batcher.as_ref().map_or(0, BatchStage::pending)
    }

    /// Requests re-dispatched after a backend leave orphaned them.
    pub fn retries(&self) -> u64 {
        self.retries
    }

    /// Requests orphaned by backend leaves so far.
    pub fn orphaned(&self) -> u64 {
        self.orphaned
    }

    /// Requests parked with no live backend in their tier.
    pub fn parked_requests(&self) -> u64 {
        self.parked.iter().map(|(ids, _)| ids.len() as u64).sum()
    }

    /// Is backend `idx` currently in the pool?
    pub fn is_active(&self, idx: usize) -> bool {
        self.active[idx]
    }

    /// Active backends across both tiers.
    pub fn active_count(&self) -> usize {
        self.active.iter().filter(|&&a| a).count()
    }

    /// The hermit (default) tier's backend indices, as configured.
    pub fn hermit_tier(&self) -> &[usize] {
        &self.hermit_tier
    }

    /// Routing backlog of backend `idx` in seconds.
    pub fn backlog_s(&self, idx: usize) -> f64 {
        self.backends[idx].queue_s()
    }

    /// Batches currently in flight on backend `idx`.
    pub fn live_batches(&self, idx: usize) -> u32 {
        self.live_batches[idx]
    }

    /// Metadata of request `id` as submitted: `(rank, model,
    /// samples)`.  The pipeline is the single metadata store; engines
    /// keep only what the pipeline cannot know (emission times, step
    /// membership, record indices), id-aligned by submit order.
    pub fn request(&self, id: usize) -> (usize, &str, usize) {
        let m = &self.req_meta[id];
        (m.rank as usize, &self.models[m.model as usize], m.samples as usize)
    }

    /// Resolve a model name to its dense id, interning on first
    /// sighting (and growing every per-model table in lockstep).
    fn intern_model(&mut self, model: &str) -> usize {
        if let Some(mid) = self.models.iter().position(|m| m == model) {
            return mid;
        }
        let mid = self.models.len();
        self.models.push(model.to_string());
        self.model_is_mir.push(model.starts_with("mir"));
        self.affinity.push(None);
        self.swap_ready_s.push(vec![f64::NEG_INFINITY; self.backends.len()]);
        self.swap_waiters.push(vec![Vec::new(); self.backends.len()]);
        mid
    }

    // ----------------------------------------------------- run loop

    /// Advance virtual time: every backend's routing queue drains.
    pub fn advance_to(&mut self, t_s: f64) {
        let dt = t_s - self.clock_s;
        if dt <= 0.0 {
            return;
        }
        for b in &mut self.backends {
            b.drain_queue_s(dt);
        }
        self.clock_s = t_s;
    }

    /// One request enters the router at the current clock; returns
    /// the request id (engines keep a parallel metadata store —
    /// ids are assigned in submit order, so the stores align).
    pub fn submit(&mut self, rank: usize, model: &str, samples: usize) -> usize {
        self.submitted += 1;
        let id = self.req_meta.len();
        let mid = self.intern_model(model);
        self.req_meta.push(ReqMeta {
            rank: rank as u32,
            model: mid as u32,
            samples: samples as u32,
        });
        if let Some(rec) = self.rec.as_deref_mut() {
            if rec.armed() {
                rec.on_submit(id, rank as u32, mid as u32, &self.models[mid], self.clock_s);
            }
        }
        if self.batcher.is_some() {
            let stage = self.batcher.as_mut().unwrap();
            stage.enqueue(model, id as u64, samples, self.clock_s);
            // Arrival path: dispatch only queues the *size* trigger
            // filled; deadline-expired queues close via their
            // wake-up, after every same-instant arrival (see
            // [`BatchStage`]).
            let ready = stage.drain_size_ready();
            for ids in ready {
                self.dispatch(ids);
            }
            self.arm_batch_wakeup();
        } else {
            let mut ids = self.pooled_ids();
            ids.push(id);
            self.dispatch(ids);
        }
        id
    }

    /// A pipeline event popped off the engine's queue.
    pub fn handle(&mut self, event: PipeEvent) {
        match event {
            PipeEvent::BatchDeadline => self.pump_batcher(),
            PipeEvent::Completion { token } => self.on_direct_completion(token),
            PipeEvent::FabricWake { version } => self.on_fabric_wake(version),
            PipeEvent::XferInDone { token } => self.on_xfer_in_done(token),
            PipeEvent::ServiceDone { token } => self.on_service_done(token),
            PipeEvent::XferOutDone { token } => self.on_xfer_out_done(token),
        }
    }

    // ---------------------------------------------------- batching

    /// Schedule the next batch-close wake-up [`BatchStage`] asks for.
    fn arm_batch_wakeup(&mut self) {
        if let Some(t) = self.batcher.as_ref().unwrap().wakeup_at(self.clock_s) {
            self.effects.scheduled.push((t, CLASS_DEADLINE, PipeEvent::BatchDeadline));
        }
    }

    /// Deadline wake-up: drain every ready batcher queue at the
    /// current virtual time, then arm the next future deadline.
    fn pump_batcher(&mut self) {
        let ready = self.batcher.as_mut().unwrap().drain_ready(self.clock_s);
        for ids in ready {
            self.dispatch(ids);
        }
        self.arm_batch_wakeup();
    }

    // ----------------------------------------------------- routing

    /// Route one batch (same-instance request ids) exactly as the
    /// analytic cluster would: policy selection over the candidate
    /// tier, the residency touch (when configured), then either the
    /// legacy fixed-charge path or the multi-phase fabric path.
    fn dispatch(&mut self, ids: Vec<usize>) {
        self.dispatch_inner(ids, false);
    }

    fn dispatch_inner(&mut self, ids: Vec<usize>, retry: bool) {
        debug_assert!(!ids.is_empty());
        let meta0 = self.req_meta[ids[0]];
        let rank0 = meta0.rank as usize;
        let mid = meta0.model as usize;
        let total: usize = ids.iter().map(|&i| self.req_meta[i].samples as usize).sum();
        let is_mir = self.model_is_mir[mid];
        let candidates: &[usize] = if is_mir { &self.live_mir } else { &self.live_hermit };
        if candidates.is_empty() {
            // every backend in the tier has left: park until a join
            self.parked.push((ids, retry));
            return;
        }
        if retry {
            self.retries += ids.len() as u64;
        }
        let idx = policy::select_slot(
            self.policy,
            &self.backends,
            &mut self.rr_cursor,
            &mut self.affinity[mid],
            candidates,
            if is_mir { &self.mir_profile } else { &self.hermit_profile },
            total,
        );
        let miss = match self.residency.as_mut() {
            Some(residency) => residency[idx].touch(mid),
            None => false,
        };
        if miss {
            self.swaps += 1;
        }
        if self.fabric.as_ref().is_some_and(|f| f.is_remote(idx)) {
            self.dispatch_remote(ids, idx, total, miss, rank0, mid, retry);
            return;
        }
        let swap_s = if miss { self.swap_cfg_s } else { 0.0 };
        if miss {
            self.swap_time_s += swap_s;
        }
        let profile = if is_mir { &self.mir_profile } else { &self.hermit_profile };
        let backend = &mut self.backends[idx];
        let wait_s = backend.queue_s();
        let link_s = backend.link_overhead_s(profile, total);
        let exec_s = backend.execute_s(profile, total);
        let latency_s = wait_s + swap_s + (link_s + exec_s);
        let occupancy = backend.occupancy_s(profile, total) + swap_s;
        backend.add_queue_s(occupancy);
        let complete_s = self.clock_s + latency_s;
        self.device_busy_s[idx] += exec_s;
        if let Some(rec) = self.rec.as_deref_mut() {
            if rec.armed() {
                rec.on_direct(
                    &ids, idx, self.clock_s, wait_s, swap_s, link_s, exec_s, complete_s, miss,
                );
            }
        }
        let mut rec_ids = self.pooled_ids();
        rec_ids.extend_from_slice(&ids);
        self.effects.dispatched.push(Dispatched {
            ids: rec_ids,
            backend: idx,
            batch_samples: total,
            outcome: Outcome::Direct { wait_s, swap_s, link_s, exec_s, complete_s },
            retry,
        });
        self.dispatched += ids.len() as u64;
        self.batches += 1;
        self.live_batches[idx] += 1;
        let token = match self.direct_free.pop() {
            Some(t) => {
                let slot = &mut self.direct_live[t];
                slot.ids = ids;
                slot.backend = idx;
                slot.dead = false;
                t
            }
            None => {
                self.direct_live.push(DirectBatch { ids, backend: idx, dead: false });
                self.direct_live.len() - 1
            }
        };
        self.effects.scheduled.push((
            complete_s,
            CLASS_COMPLETION,
            PipeEvent::Completion { token },
        ));
    }

    /// A direct-path completion event fired.  Stale for batches the
    /// control plane orphaned (the ids were re-dispatched already);
    /// either way the token is spent and returns to the free list.
    fn on_direct_completion(&mut self, token: usize) {
        let batch = &mut self.direct_live[token];
        if batch.dead {
            batch.dead = false;
            self.direct_free.push(token);
            return;
        }
        let ids = std::mem::take(&mut batch.ids);
        let idx = batch.backend;
        self.direct_free.push(token);
        self.live_batches[idx] -= 1;
        self.complete(ids, None, None);
    }

    // ----------------------------------------------- fabric phases

    /// Remote dispatch over the fabric: the request payload starts
    /// its flow immediately; on a residency miss the model's weights
    /// start *their* flow at the same instant (prefetch), riding the
    /// same accel-leaf downlink and rx NIC — swap traffic congests
    /// inference.  Execution begins once both have landed; the result
    /// rides its own flow home.  A router-coalesced batch travels as
    /// one flow attributed to the leading request's host (batching
    /// happens at the host leaf).  The FIFO slot is reserved **at
    /// dispatch** (`queue_s` reflects committed work immediately), so
    /// the routing policies see exactly the feedback the legacy path
    /// gives them.
    #[allow(clippy::too_many_arguments)]
    fn dispatch_remote(
        &mut self,
        ids: Vec<usize>,
        idx: usize,
        total: usize,
        miss: bool,
        rank0: usize,
        mid: usize,
        retry: bool,
    ) {
        let is_mir = self.model_is_mir[mid];
        let profile = if is_mir { &self.mir_profile } else { &self.hermit_profile };
        let (bytes_in, bytes_out) =
            dir_payload_bytes(profile.input_elems, profile.output_elems, total);
        let fab = self.fabric.as_ref().expect("remote dispatch without a fabric");
        let accel = fab.accel(idx);
        let host = fab.spec.host_of_rank(rank0);
        let ideal_rtt_s = fab.ideal_rtt_s(bytes_in + bytes_out);
        // Sized so an uncontended swap takes exactly `swap_s` at the
        // endpoint's single-stream bandwidth — the degenerate charge.
        let swap_bytes = self.swap_cfg_s * fab.spec.topology.link().eff_bandwidth;

        // reserve the backend's routing queue now: transfers are
        // explicit, so the batch occupies the device for its
        // execution time only, and policies see committed work
        // immediately (the physical one-batch-at-a-time constraint
        // is [`FabricLayer::occupy`]'s device clock)
        let backend = &mut self.backends[idx];
        let exec_s = backend.execute_s(profile, total);
        backend.add_queue_s(exec_s);

        let token = self.transits.len();
        let mut rec_ids = self.pooled_ids();
        rec_ids.extend_from_slice(&ids);
        self.effects.dispatched.push(Dispatched {
            ids: rec_ids,
            backend: idx,
            batch_samples: total,
            outcome: Outcome::InFlight { token },
            retry,
        });
        self.dispatched += ids.len() as u64;
        self.batches += 1;
        self.live_batches[idx] += 1;
        if let Some(rec) = self.rec.as_deref_mut() {
            if rec.armed() {
                rec.on_remote_dispatch(&ids, idx, self.clock_s, miss);
            }
        }

        let needs_swap_flow = miss && swap_bytes > 0.0;
        if needs_swap_flow {
            // weights are on the wire: same-model followers routed
            // here park until they land (the residency touch already
            // counts the model resident, this keeps it honest)
            self.swap_ready_s[mid][idx] = f64::INFINITY;
        }
        self.transits.push(Transit {
            ids,
            backend: idx,
            accel,
            host,
            model: mid,
            bytes_out,
            dispatch_s: self.clock_s,
            net_in_s: 0.0,
            in_done_s: 0.0,
            in_done: false,
            swap_done: !needs_swap_flow,
            started: false,
            dead: false,
            swap_excess_s: 0.0,
            wait_s: 0.0,
            exec_s,
            out_start_s: 0.0,
            ideal_rtt_s,
        });

        let clock = self.clock_s;
        let fab = self.fabric.as_mut().expect("checked above");
        let path = fab.spec.topology.request_path(host, accel);
        let flow = fab.engine.start(clock, path, bytes_in);
        fab.cont.insert(flow, FlowCont::In { token });
        if needs_swap_flow {
            let path = fab.spec.topology.swap_path(accel);
            let flow = fab.engine.start(clock, path, swap_bytes);
            fab.cont.insert(flow, FlowCont::Swap { token });
        }
        self.trace_fabric_sample();
        self.arm_fabric();
    }

    /// Re-arm the fabric wake-up at the engine's (new) earliest flow
    /// completion; called after every flow start/finish.  Earlier
    /// armed wake-ups become stale through the version bump.
    fn arm_fabric(&mut self) {
        let clock = self.clock_s;
        let armed = self.fabric.as_mut().expect("arm_fabric without a fabric").next_wake(clock);
        if let Some((t, version)) = armed {
            self.effects.scheduled.push((
                t,
                CLASS_COMPLETION,
                PipeEvent::FabricWake { version },
            ));
        }
    }

    /// A fabric wake-up fired: drain finished flows.  Payload and
    /// result flows get their direction's fixed-latency tail as a
    /// scheduled event; swap completions take effect immediately (a
    /// bulk weight stream has no per-message rendezvous).
    fn on_fabric_wake(&mut self, version: u64) {
        let clock = self.clock_s;
        let conts = {
            let Some(fab) = self.fabric.as_mut() else { return };
            let Some(conts) = fab.drain_wake(version, clock) else {
                return; // stale: a newer wake-up is armed
            };
            conts
        };
        for cont in conts {
            match cont {
                FlowCont::In { token } => {
                    let fixed = self.dir_fixed_of(token);
                    self.effects.scheduled.push((
                        self.clock_s + fixed,
                        CLASS_COMPLETION,
                        PipeEvent::XferInDone { token },
                    ));
                }
                FlowCont::Swap { token } => {
                    let measured = self.clock_s - self.transits[token].dispatch_s;
                    self.swap_time_s += measured;
                    self.transits[token].swap_done = true;
                    // the weights landed: unblock this batch, then
                    // every same-model follower parked behind it
                    let (mid, idx) =
                        (self.transits[token].model, self.transits[token].backend);
                    self.swap_ready_s[mid][idx] = self.clock_s;
                    self.try_begin_service(token);
                    let mut waiters = std::mem::take(&mut self.swap_waiters[mid][idx]);
                    for &waiter in &waiters {
                        self.try_begin_service(waiter);
                    }
                    // nothing re-parks once the weights are resident:
                    // hand the drained buffer back to its slot
                    waiters.clear();
                    self.swap_waiters[mid][idx] = waiters;
                }
                FlowCont::Out { token } => {
                    let fixed = self.dir_fixed_of(token);
                    self.effects.scheduled.push((
                        self.clock_s + fixed,
                        CLASS_COMPLETION,
                        PipeEvent::XferOutDone { token },
                    ));
                }
            }
        }
        if self.fabric.is_some() {
            // the drained completions changed the active flow set
            self.trace_fabric_sample();
            self.arm_fabric();
        }
    }

    fn dir_fixed_of(&self, token: usize) -> f64 {
        let fab = self.fabric.as_ref().expect("fabric phase without a fabric");
        fab.spec.topology.dir_fixed_s(self.transits[token].accel)
    }

    /// The request payload is at the accelerator.
    fn on_xfer_in_done(&mut self, token: usize) {
        let tr = &mut self.transits[token];
        if tr.dead {
            return;
        }
        tr.net_in_s = self.clock_s - tr.dispatch_s;
        tr.in_done_s = self.clock_s;
        tr.in_done = true;
        self.try_begin_service(token);
    }

    /// Begin execution once the payload has landed, the batch's own
    /// swap (on a miss) has landed, **and** the model's weights are
    /// actually on the backend — a follower routed to a backend whose
    /// weights are still on the wire parks until they arrive (the
    /// wait lands in its `swap_s` component).  The batch then
    /// executes as soon as the device frees up ([`FabricLayer::occupy`]
    /// — strictly one batch at a time per device, work-conserving
    /// order).
    fn try_begin_service(&mut self, token: usize) {
        let clock = self.clock_s;
        let (ready, idx, exec_s, in_done_s, mid) = {
            let tr = &self.transits[token];
            (!tr.dead && !tr.started && tr.in_done && tr.swap_done, tr.backend, tr.exec_s,
             tr.in_done_s, tr.model)
        };
        if !ready {
            return;
        }
        // `== INFINITY` exactly: `NEG_INFINITY` means "never swapped
        // here", which must not park the batch.
        if self.swap_ready_s[mid][idx] == f64::INFINITY {
            self.swap_waiters[mid][idx].push(token);
            return;
        }
        let fab = self.fabric.as_mut().expect("fabric phase without a fabric");
        let (wait_s, done_s) = fab.occupy(idx, clock, exec_s);
        // Re-sync the routing signal with the device horizon: long
        // transfers/swaps can outlive the dispatch-time reservation's
        // wall-time drain, and the policies must keep seeing the
        // serialized backlog `occupy` is accumulating.
        let backend = &mut self.backends[idx];
        let deficit = (done_s - clock) - backend.queue_s();
        if deficit > 0.0 {
            backend.add_queue_s(deficit);
        }
        let requests = {
            let tr = &mut self.transits[token];
            tr.started = true;
            tr.swap_excess_s = clock - in_done_s;
            tr.wait_s = wait_s;
            tr.ids.len()
        };
        self.device_busy_s[idx] += exec_s;
        if let Some(rec) = self.rec.as_deref_mut() {
            if rec.armed() {
                rec.on_occupy(idx, done_s - exec_s, done_s, requests);
            }
        }
        self.effects.scheduled.push((
            done_s,
            CLASS_COMPLETION,
            PipeEvent::ServiceDone { token },
        ));
    }

    /// Execution finished: send the result payload home.
    fn on_service_done(&mut self, token: usize) {
        let (host, accel, bytes_out) = {
            let tr = &self.transits[token];
            if tr.dead {
                return;
            }
            (tr.host, tr.accel, tr.bytes_out)
        };
        self.transits[token].out_start_s = self.clock_s;
        let clock = self.clock_s;
        let fab = self.fabric.as_mut().expect("fabric phase without a fabric");
        let path = fab.spec.topology.response_path(host, accel);
        let flow = fab.engine.start(clock, path, bytes_out);
        fab.cont.insert(flow, FlowCont::Out { token });
        self.trace_fabric_sample();
        self.arm_fabric();
    }

    /// The result landed: hand the engine the measured phase timings
    /// and run the shared completion accounting.
    fn on_xfer_out_done(&mut self, token: usize) {
        let timing = {
            let tr = &self.transits[token];
            if tr.dead {
                return;
            }
            let net_out_s = self.clock_s - tr.out_start_s;
            let link_s = tr.net_in_s + net_out_s;
            TransitTiming {
                wait_s: tr.wait_s,
                swap_s: tr.swap_excess_s,
                link_s,
                contention_s: (link_s - tr.ideal_rtt_s).max(0.0),
                exec_s: tr.exec_s,
            }
        };
        // The transit is finished: move its id buffer out instead of
        // cloning it (the token keeps indexing the timing fields).
        let ids = std::mem::take(&mut self.transits[token].ids);
        self.live_batches[self.transits[token].backend] -= 1;
        if let Some(rec) = self.rec.as_deref_mut() {
            if rec.armed() {
                let tr = &self.transits[token];
                let req_meta = &self.req_meta;
                rec.on_transit_done(
                    &ids,
                    |id| {
                        let m = &req_meta[id];
                        (m.rank, m.model)
                    },
                    tr.backend,
                    tr.dispatch_s,
                    tr.in_done_s,
                    tr.swap_excess_s,
                    tr.wait_s,
                    tr.exec_s,
                    tr.out_start_s,
                    self.clock_s,
                );
            }
        }
        self.complete(ids, Some(token), Some(timing));
    }

    fn complete(&mut self, ids: Vec<usize>, token: Option<usize>, timing: Option<TransitTiming>) {
        self.completed += ids.len() as u64;
        self.effects.completed.push(Completed { ids, token, timing });
    }

    // ----------------------------------------------- control plane

    /// Rebuild the live routing tiers from the configured tiers and
    /// the membership flags (order-preserving, so routing decisions
    /// over an unchanged membership are bit-identical).
    fn rebuild_live_tiers(&mut self) {
        let active = &self.active;
        let hermit: Vec<usize> =
            self.hermit_tier.iter().copied().filter(|&i| active[i]).collect();
        let mir: Vec<usize> = self.mir_tier.iter().copied().filter(|&i| active[i]).collect();
        self.live_hermit = hermit;
        self.live_mir = mir;
    }

    /// Control plane: backend `idx` leaves the fleet (failure or
    /// scale-down).  Its routing queue drains, its residency and
    /// weights-ready gates invalidate, its in-flight flows cancel
    /// (survivors re-solve the fair shares immediately), and every
    /// batch it held is orphaned and re-dispatched exactly once onto
    /// the surviving tier (or parked when the tier emptied).  No-op
    /// when already inactive.
    pub fn control_backend_leave(&mut self, idx: usize) {
        assert!(idx < self.backends.len(), "unknown backend {idx}");
        if !self.active[idx] {
            return;
        }
        if self.trace_armed() {
            let detail = format!("{} leaves", self.backends[idx].name());
            self.trace_marker("backend_leave", &detail);
        }
        self.active[idx] = false;
        self.rebuild_live_tiers();
        // sticky affinity must not keep pointing at the dead slot
        for slot in self.affinity.iter_mut() {
            if *slot == Some(idx) {
                *slot = None;
            }
        }
        // drain the dead backend's routing queue: its committed work
        // is exactly the in-flight set being orphaned below
        let q = self.backends[idx].queue_s();
        if q > 0.0 {
            self.backends[idx].drain_queue_s(q);
        }
        // residency + weights-ready gates: device memory is gone
        if let Some(residency) = self.residency.as_mut() {
            residency[idx].clear();
        }
        for mid in 0..self.models.len() {
            self.swap_ready_s[mid][idx] = f64::NEG_INFINITY;
            self.swap_waiters[mid][idx].clear();
        }
        // orphan every batch the backend held, direct then fabric,
        // ascending token order (deterministic re-dispatch order)
        let mut orphans: Vec<Vec<usize>> = Vec::new();
        for batch in self.direct_live.iter_mut() {
            if batch.backend == idx && !batch.dead && !batch.ids.is_empty() {
                batch.dead = true;
                orphans.push(std::mem::take(&mut batch.ids));
            }
        }
        for tr in self.transits.iter_mut() {
            if tr.backend == idx && !tr.dead && !tr.ids.is_empty() {
                tr.dead = true;
                orphans.push(std::mem::take(&mut tr.ids));
            }
        }
        let clock = self.clock_s;
        if let Some(fab) = self.fabric.as_mut() {
            let transits = &self.transits;
            fab.cancel_flows_of(clock, |token| transits[token].dead);
            fab.reset_busy(idx);
        }
        if self.fabric.is_some() {
            // cancelled flows returned their shares to the survivors
            self.trace_fabric_sample();
            self.arm_fabric();
        }
        self.live_batches[idx] = 0;
        for ids in orphans {
            self.orphaned += ids.len() as u64;
            self.effects.orphaned.extend_from_slice(&ids);
            self.dispatch_inner(ids, true);
        }
    }

    /// Control plane: backend `idx` (re)joins the fleet — scale-up or
    /// checkpoint/restart.  It returns cold (empty residency, no
    /// resident weights) and any parked batches flush through the
    /// router in arrival order.  No-op when already active.
    pub fn control_backend_join(&mut self, idx: usize) {
        assert!(idx < self.backends.len(), "unknown backend {idx}");
        if self.active[idx] {
            return;
        }
        if self.trace_armed() {
            let detail = format!("{} joins", self.backends[idx].name());
            self.trace_marker("backend_join", &detail);
        }
        self.active[idx] = true;
        self.rebuild_live_tiers();
        let parked = std::mem::take(&mut self.parked);
        for (ids, retry) in parked {
            self.dispatch_inner(ids, retry);
        }
    }

    /// Control plane: scale every fabric link to `factor` × its
    /// as-built capacity (degrade < 1, restore = 1) and re-solve the
    /// fair shares.  No-op on the fixed-charge (fabric-less) path.
    pub fn control_link_scale(&mut self, factor: f64) {
        if self.trace_armed() {
            if factor == 1.0 {
                self.trace_marker("link_restore", "capacity restored");
            } else {
                let detail = format!("capacity x{factor}");
                self.trace_marker("link_degrade", &detail);
            }
        }
        let clock = self.clock_s;
        if let Some(fab) = self.fabric.as_mut() {
            fab.set_capacity_scale(clock, factor);
            self.trace_fabric_sample();
            self.arm_fabric();
        }
    }
}
