//! The shared request lifecycle: routing → batching → (residency) →
//! dispatch → service → completion, over either the legacy
//! fixed-charge link or the multi-phase contention-aware fabric.
//!
//! See the [module docs](super) for the effects protocol.  The rule
//! that makes the extraction behaviour-preserving: every effect is
//! appended in **exactly** the order the pre-refactor engines pushed
//! the corresponding event or record, because event-queue insertion
//! order defines heap sequence numbers and record order defines the
//! golden JSON.
//!
//! Per-event cost: model names are interned to dense ids at submit
//! (`models`), so the hot path — routing, the residency touch, the
//! weights-ready gate — indexes flat `Vec` tables instead of hashing
//! strings, and the id buffers inside [`Effects`] cycle through a
//! free list ([`Pipeline::recycle_effects`]) instead of being
//! reallocated per batch.  Both are invisible to the effects
//! protocol: same decisions, same order, same bytes.

use crate::cluster::{policy, Backend, Policy};
use crate::devices::{profiles, ModelProfile};
use crate::fabric::FabricSpec;
use crate::netsim::dir_payload_bytes;

use crate::eventsim::equeue::{CLASS_COMPLETION, CLASS_DEADLINE};

use super::{BatchStage, Batching, FabricLayer, FlowCont, Residency};

/// Pipeline-owned events: the engine wraps them in its own event enum
/// and hands them back to [`Pipeline::handle`] when they pop.
#[derive(Debug, Clone)]
pub enum PipeEvent {
    /// Re-check the batcher's deadline-ready queues.
    BatchDeadline,
    /// A direct-path batch finished; ids index the request metadata.
    Completion { ids: Vec<usize> },
    /// The fabric engine's earliest flow completion (stale when
    /// `version` is no longer current — see [`FabricLayer`]).
    FabricWake { version: u64 },
    /// A batch's request payload finished its fixed-latency tail and
    /// is at the accelerator; begin queue + execution.
    XferInDone { token: usize },
    /// A batch's device execution finished; start the result flow.
    ServiceDone { token: usize },
    /// The result payload is back at the host; complete the batch.
    XferOutDone { token: usize },
}

/// How a dispatched batch will complete.
#[derive(Debug, Clone, Copy)]
pub enum Outcome {
    /// Legacy fixed-charge path: the completion instant (and every
    /// phase share) is known at dispatch.
    Direct { wait_s: f64, swap_s: f64, link_s: f64, exec_s: f64, complete_s: f64 },
    /// Fabric path: transit `token` opened; the measured timings land
    /// with the matching [`Completed`] effect.
    InFlight { token: usize },
}

/// One batch the pipeline dispatched: the engine opens its records
/// (in effect order — record order is part of the golden contract).
#[derive(Debug, Clone)]
pub struct Dispatched {
    pub ids: Vec<usize>,
    pub backend: usize,
    pub batch_samples: usize,
    pub outcome: Outcome,
}

/// Measured phase timings of a fabric batch, filled when the result
/// lands: `swap_s` is the *excess* residency wait not hidden behind
/// the payload transfer.
#[derive(Debug, Clone, Copy)]
pub struct TransitTiming {
    pub wait_s: f64,
    pub swap_s: f64,
    pub link_s: f64,
    pub contention_s: f64,
    pub exec_s: f64,
}

/// One batch whose completion fired: `timing` is `None` on the direct
/// path (the engine already knows the completion fields from
/// [`Outcome::Direct`]); on the fabric path `token` identifies the
/// transit whose record block the engine opened at dispatch.
#[derive(Debug, Clone)]
pub struct Completed {
    pub ids: Vec<usize>,
    pub token: Option<usize>,
    pub timing: Option<TransitTiming>,
}

/// Everything a pipeline call produced, in exact legacy push order.
#[derive(Debug, Default)]
pub struct Effects {
    /// `(time, event-queue class, event)` to insert, in order.
    pub scheduled: Vec<(f64, u8, PipeEvent)>,
    pub dispatched: Vec<Dispatched>,
    pub completed: Vec<Completed>,
}

/// The residency stage's knobs (engaged only when configured).
#[derive(Debug, Clone, Copy)]
pub struct ResidencySpec {
    /// Models resident per backend (LRU eviction).
    pub slots: usize,
    /// Seconds charged when a backend serves a model it doesn't hold.
    pub swap_s: f64,
}

/// Per-request metadata, dense: `model` indexes the intern table.
#[derive(Debug, Clone, Copy)]
struct ReqMeta {
    rank: u32,
    model: u32,
    samples: u32,
}

/// One batch in flight through the fabric.  The weights-ready fields
/// are inert for engines without a residency stage (`swap_done` is
/// true from creation and the gate never parks).
#[derive(Debug, Clone)]
struct Transit {
    ids: Vec<usize>,
    backend: usize,
    accel: usize,
    host: usize,
    /// Model id the batch serves (the weights-ready gate's key).
    model: usize,
    bytes_out: f64,
    dispatch_s: f64,
    net_in_s: f64,
    /// When the payload's fixed tail landed (valid once `in_done`).
    in_done_s: f64,
    in_done: bool,
    swap_done: bool,
    /// Service already scheduled (guards double-starts when a parked
    /// batch is re-tried by the weights-ready drain).
    started: bool,
    /// Swap time *not* hidden behind the payload transfer: the serial
    /// residency charge on the batch's critical chain.
    swap_excess_s: f64,
    wait_s: f64,
    exec_s: f64,
    out_start_s: f64,
    ideal_rtt_s: f64,
}

/// The engine-agnostic pipeline: backends + policy + batching +
/// residency + fabric, driven through submit/handle/take_effects.
pub struct Pipeline {
    backends: Vec<Box<dyn Backend>>,
    policy: Policy,
    hermit_tier: Vec<usize>,
    mir_tier: Vec<usize>,
    hermit_profile: ModelProfile,
    mir_profile: ModelProfile,
    rr_cursor: usize,
    /// Interned model names: submit resolves each name to its id once
    /// (linear scan — the model population is small and stable), and
    /// every per-event structure below indexes by that id.
    models: Vec<String>,
    /// Per-model: does the name select the MIR tier/profile?
    model_is_mir: Vec<bool>,
    /// Per-model sticky-affinity slot ([`Policy::ModelAffinity`]).
    affinity: Vec<Option<usize>>,
    clock_s: f64,
    batcher: Option<BatchStage>,
    fabric: Option<FabricLayer>,
    residency: Option<Vec<Residency>>,
    swap_cfg_s: f64,
    transits: Vec<Transit>,
    /// `[model][backend]` — when that backend's copy of the model's
    /// weights lands: `INFINITY` while the swap flow is still on the
    /// wire (followers must not execute before the weights arrive —
    /// the residency `touch` marks the model resident at dispatch,
    /// this gate makes that honest), `NEG_INFINITY` = never swapped
    /// (absent).  The in-transit test is `== INFINITY` *exactly*.
    swap_ready_s: Vec<Vec<f64>>,
    /// `[model][backend]` — batches parked on an in-transit swap.
    swap_waiters: Vec<Vec<Vec<usize>>>,
    req_meta: Vec<ReqMeta>,
    /// Free list of id buffers cycling through [`Effects`].
    id_pool: Vec<Vec<usize>>,
    /// Drained [`Effects`] shell awaiting reuse by `take_effects`.
    spare: Option<Effects>,
    submitted: u64,
    dispatched: u64,
    completed: u64,
    batches: u64,
    swaps: u64,
    swap_time_s: f64,
    effects: Effects,
}

impl Pipeline {
    pub fn new(
        backends: Vec<Box<dyn Backend>>,
        policy: Policy,
        hermit_tier: Vec<usize>,
        mir_tier: Vec<usize>,
        batching: Batching,
        residency: Option<ResidencySpec>,
    ) -> Pipeline {
        assert!(!backends.is_empty(), "pipeline needs at least one backend");
        assert!(!hermit_tier.is_empty(), "hermit tier must not be empty");
        assert!(hermit_tier.iter().chain(&mir_tier).all(|&i| i < backends.len()));
        if let Some(spec) = residency {
            assert!(spec.slots >= 1);
            assert!(spec.swap_s >= 0.0 && spec.swap_s.is_finite());
        }
        let batcher = BatchStage::from_config(batching);
        let residency_state =
            residency.map(|spec| backends.iter().map(|_| Residency::new(spec.slots)).collect());
        Pipeline {
            backends,
            policy,
            hermit_tier,
            mir_tier,
            hermit_profile: profiles::hermit(),
            mir_profile: profiles::mir_noln(),
            rr_cursor: 0,
            models: Vec::new(),
            model_is_mir: Vec::new(),
            affinity: Vec::new(),
            clock_s: 0.0,
            batcher,
            fabric: None,
            residency: residency_state,
            swap_cfg_s: residency.map_or(0.0, |spec| spec.swap_s),
            transits: Vec::new(),
            swap_ready_s: Vec::new(),
            swap_waiters: Vec::new(),
            req_meta: Vec::new(),
            id_pool: Vec::new(),
            spare: None,
            submitted: 0,
            dispatched: 0,
            completed: 0,
            batches: 0,
            swaps: 0,
            swap_time_s: 0.0,
            effects: Effects::default(),
        }
    }

    /// Attach the contention-aware fabric: remote dispatches become
    /// flow events instead of the fixed link charge.
    pub fn attach_fabric(&mut self, spec: FabricSpec) {
        self.fabric = Some(FabricLayer::new(spec, self.backends.len()));
    }

    // ----------------------------------------------------- effects

    /// Drain everything accumulated since the last call, in exact
    /// dispatch/push order.
    pub fn take_effects(&mut self) -> Effects {
        let fresh = self.spare.take().unwrap_or_default();
        std::mem::replace(&mut self.effects, fresh)
    }

    /// Hand a consumed [`Effects`] back for reuse: its id buffers and
    /// the three vectors return to the pipeline's free lists.  Purely
    /// an allocation-recycling hook — skipping it only costs fresh
    /// allocations, never correctness.
    pub fn recycle_effects(&mut self, mut effects: Effects) {
        for d in effects.dispatched.drain(..) {
            self.recycle_ids(d.ids);
        }
        for c in effects.completed.drain(..) {
            self.recycle_ids(c.ids);
        }
        effects.scheduled.clear();
        self.spare = Some(effects);
    }

    fn recycle_ids(&mut self, mut ids: Vec<usize>) {
        ids.clear();
        self.id_pool.push(ids);
    }

    fn pooled_ids(&mut self) -> Vec<usize> {
        self.id_pool.pop().unwrap_or_default()
    }

    // --------------------------------------------------- accessors

    pub fn clock_s(&self) -> f64 {
        self.clock_s
    }

    pub fn policy(&self) -> Policy {
        self.policy
    }

    /// Requests that have entered the router.
    pub fn submitted(&self) -> u64 {
        self.submitted
    }

    /// Requests dispatched to a backend (inside some batch).
    pub fn dispatched(&self) -> u64 {
        self.dispatched
    }

    /// Requests whose completion fired.
    pub fn completed(&self) -> u64 {
        self.completed
    }

    /// Batches dispatched so far.
    pub fn batches(&self) -> u64 {
        self.batches
    }

    /// Residency misses so far.
    pub fn swaps(&self) -> u64 {
        self.swaps
    }

    /// Seconds of swap time charged (legacy path) or measured on the
    /// wire (fabric path).
    pub fn swap_time_s(&self) -> f64 {
        self.swap_time_s
    }

    /// Requests waiting in the batching window.
    pub fn batcher_pending(&self) -> u64 {
        self.batcher.as_ref().map_or(0, BatchStage::pending)
    }

    /// Metadata of request `id` as submitted: `(rank, model,
    /// samples)`.  The pipeline is the single metadata store; engines
    /// keep only what the pipeline cannot know (emission times, step
    /// membership, record indices), id-aligned by submit order.
    pub fn request(&self, id: usize) -> (usize, &str, usize) {
        let m = &self.req_meta[id];
        (m.rank as usize, &self.models[m.model as usize], m.samples as usize)
    }

    /// Resolve a model name to its dense id, interning on first
    /// sighting (and growing every per-model table in lockstep).
    fn intern_model(&mut self, model: &str) -> usize {
        if let Some(mid) = self.models.iter().position(|m| m == model) {
            return mid;
        }
        let mid = self.models.len();
        self.models.push(model.to_string());
        self.model_is_mir.push(model.starts_with("mir"));
        self.affinity.push(None);
        self.swap_ready_s.push(vec![f64::NEG_INFINITY; self.backends.len()]);
        self.swap_waiters.push(vec![Vec::new(); self.backends.len()]);
        mid
    }

    // ----------------------------------------------------- run loop

    /// Advance virtual time: every backend's routing queue drains.
    pub fn advance_to(&mut self, t_s: f64) {
        let dt = t_s - self.clock_s;
        if dt <= 0.0 {
            return;
        }
        for b in &mut self.backends {
            b.drain_queue_s(dt);
        }
        self.clock_s = t_s;
    }

    /// One request enters the router at the current clock; returns
    /// the request id (engines keep a parallel metadata store —
    /// ids are assigned in submit order, so the stores align).
    pub fn submit(&mut self, rank: usize, model: &str, samples: usize) -> usize {
        self.submitted += 1;
        let id = self.req_meta.len();
        let mid = self.intern_model(model);
        self.req_meta.push(ReqMeta {
            rank: rank as u32,
            model: mid as u32,
            samples: samples as u32,
        });
        if self.batcher.is_some() {
            let stage = self.batcher.as_mut().unwrap();
            stage.enqueue(model, id as u64, samples, self.clock_s);
            // Arrival path: dispatch only queues the *size* trigger
            // filled; deadline-expired queues close via their
            // wake-up, after every same-instant arrival (see
            // [`BatchStage`]).
            let ready = stage.drain_size_ready();
            for ids in ready {
                self.dispatch(ids);
            }
            self.arm_batch_wakeup();
        } else {
            let mut ids = self.pooled_ids();
            ids.push(id);
            self.dispatch(ids);
        }
        id
    }

    /// A pipeline event popped off the engine's queue.
    pub fn handle(&mut self, event: PipeEvent) {
        match event {
            PipeEvent::BatchDeadline => self.pump_batcher(),
            PipeEvent::Completion { ids } => self.complete(ids, None, None),
            PipeEvent::FabricWake { version } => self.on_fabric_wake(version),
            PipeEvent::XferInDone { token } => self.on_xfer_in_done(token),
            PipeEvent::ServiceDone { token } => self.on_service_done(token),
            PipeEvent::XferOutDone { token } => self.on_xfer_out_done(token),
        }
    }

    // ---------------------------------------------------- batching

    /// Schedule the next batch-close wake-up [`BatchStage`] asks for.
    fn arm_batch_wakeup(&mut self) {
        if let Some(t) = self.batcher.as_ref().unwrap().wakeup_at(self.clock_s) {
            self.effects.scheduled.push((t, CLASS_DEADLINE, PipeEvent::BatchDeadline));
        }
    }

    /// Deadline wake-up: drain every ready batcher queue at the
    /// current virtual time, then arm the next future deadline.
    fn pump_batcher(&mut self) {
        let ready = self.batcher.as_mut().unwrap().drain_ready(self.clock_s);
        for ids in ready {
            self.dispatch(ids);
        }
        self.arm_batch_wakeup();
    }

    // ----------------------------------------------------- routing

    /// Route one batch (same-instance request ids) exactly as the
    /// analytic cluster would: policy selection over the candidate
    /// tier, the residency touch (when configured), then either the
    /// legacy fixed-charge path or the multi-phase fabric path.
    fn dispatch(&mut self, ids: Vec<usize>) {
        debug_assert!(!ids.is_empty());
        let meta0 = self.req_meta[ids[0]];
        let rank0 = meta0.rank as usize;
        let mid = meta0.model as usize;
        let total: usize = ids.iter().map(|&i| self.req_meta[i].samples as usize).sum();
        let is_mir = self.model_is_mir[mid];
        let candidates: &[usize] = if is_mir { &self.mir_tier } else { &self.hermit_tier };
        let idx = policy::select_slot(
            self.policy,
            &self.backends,
            &mut self.rr_cursor,
            &mut self.affinity[mid],
            candidates,
            if is_mir { &self.mir_profile } else { &self.hermit_profile },
            total,
        );
        let miss = match self.residency.as_mut() {
            Some(residency) => residency[idx].touch(mid),
            None => false,
        };
        if miss {
            self.swaps += 1;
        }
        if self.fabric.as_ref().is_some_and(|f| f.is_remote(idx)) {
            self.dispatch_remote(ids, idx, total, miss, rank0, mid);
            return;
        }
        let swap_s = if miss { self.swap_cfg_s } else { 0.0 };
        if miss {
            self.swap_time_s += swap_s;
        }
        let profile = if is_mir { &self.mir_profile } else { &self.hermit_profile };
        let backend = &mut self.backends[idx];
        let wait_s = backend.queue_s();
        let link_s = backend.link_overhead_s(profile, total);
        let exec_s = backend.execute_s(profile, total);
        let latency_s = wait_s + swap_s + (link_s + exec_s);
        let occupancy = backend.occupancy_s(profile, total) + swap_s;
        backend.add_queue_s(occupancy);
        let complete_s = self.clock_s + latency_s;
        let mut rec_ids = self.pooled_ids();
        rec_ids.extend_from_slice(&ids);
        self.effects.dispatched.push(Dispatched {
            ids: rec_ids,
            backend: idx,
            batch_samples: total,
            outcome: Outcome::Direct { wait_s, swap_s, link_s, exec_s, complete_s },
        });
        self.dispatched += ids.len() as u64;
        self.batches += 1;
        self.effects.scheduled.push((
            complete_s,
            CLASS_COMPLETION,
            PipeEvent::Completion { ids },
        ));
    }

    // ----------------------------------------------- fabric phases

    /// Remote dispatch over the fabric: the request payload starts
    /// its flow immediately; on a residency miss the model's weights
    /// start *their* flow at the same instant (prefetch), riding the
    /// same accel-leaf downlink and rx NIC — swap traffic congests
    /// inference.  Execution begins once both have landed; the result
    /// rides its own flow home.  A router-coalesced batch travels as
    /// one flow attributed to the leading request's host (batching
    /// happens at the host leaf).  The FIFO slot is reserved **at
    /// dispatch** (`queue_s` reflects committed work immediately), so
    /// the routing policies see exactly the feedback the legacy path
    /// gives them.
    fn dispatch_remote(
        &mut self,
        ids: Vec<usize>,
        idx: usize,
        total: usize,
        miss: bool,
        rank0: usize,
        mid: usize,
    ) {
        let is_mir = self.model_is_mir[mid];
        let profile = if is_mir { &self.mir_profile } else { &self.hermit_profile };
        let (bytes_in, bytes_out) =
            dir_payload_bytes(profile.input_elems, profile.output_elems, total);
        let fab = self.fabric.as_ref().expect("remote dispatch without a fabric");
        let accel = fab.accel(idx);
        let host = fab.spec.host_of_rank(rank0);
        let ideal_rtt_s = fab.ideal_rtt_s(bytes_in + bytes_out);
        // Sized so an uncontended swap takes exactly `swap_s` at the
        // endpoint's single-stream bandwidth — the degenerate charge.
        let swap_bytes = self.swap_cfg_s * fab.spec.topology.link().eff_bandwidth;

        // reserve the backend's routing queue now: transfers are
        // explicit, so the batch occupies the device for its
        // execution time only, and policies see committed work
        // immediately (the physical one-batch-at-a-time constraint
        // is [`FabricLayer::occupy`]'s device clock)
        let backend = &mut self.backends[idx];
        let exec_s = backend.execute_s(profile, total);
        backend.add_queue_s(exec_s);

        let token = self.transits.len();
        let mut rec_ids = self.pooled_ids();
        rec_ids.extend_from_slice(&ids);
        self.effects.dispatched.push(Dispatched {
            ids: rec_ids,
            backend: idx,
            batch_samples: total,
            outcome: Outcome::InFlight { token },
        });
        self.dispatched += ids.len() as u64;
        self.batches += 1;

        let needs_swap_flow = miss && swap_bytes > 0.0;
        if needs_swap_flow {
            // weights are on the wire: same-model followers routed
            // here park until they land (the residency touch already
            // counts the model resident, this keeps it honest)
            self.swap_ready_s[mid][idx] = f64::INFINITY;
        }
        self.transits.push(Transit {
            ids,
            backend: idx,
            accel,
            host,
            model: mid,
            bytes_out,
            dispatch_s: self.clock_s,
            net_in_s: 0.0,
            in_done_s: 0.0,
            in_done: false,
            swap_done: !needs_swap_flow,
            started: false,
            swap_excess_s: 0.0,
            wait_s: 0.0,
            exec_s,
            out_start_s: 0.0,
            ideal_rtt_s,
        });

        let clock = self.clock_s;
        let fab = self.fabric.as_mut().expect("checked above");
        let path = fab.spec.topology.request_path(host, accel);
        let flow = fab.engine.start(clock, path, bytes_in);
        fab.cont.insert(flow, FlowCont::In { token });
        if needs_swap_flow {
            let path = fab.spec.topology.swap_path(accel);
            let flow = fab.engine.start(clock, path, swap_bytes);
            fab.cont.insert(flow, FlowCont::Swap { token });
        }
        self.arm_fabric();
    }

    /// Re-arm the fabric wake-up at the engine's (new) earliest flow
    /// completion; called after every flow start/finish.  Earlier
    /// armed wake-ups become stale through the version bump.
    fn arm_fabric(&mut self) {
        let clock = self.clock_s;
        let armed = self.fabric.as_mut().expect("arm_fabric without a fabric").next_wake(clock);
        if let Some((t, version)) = armed {
            self.effects.scheduled.push((
                t,
                CLASS_COMPLETION,
                PipeEvent::FabricWake { version },
            ));
        }
    }

    /// A fabric wake-up fired: drain finished flows.  Payload and
    /// result flows get their direction's fixed-latency tail as a
    /// scheduled event; swap completions take effect immediately (a
    /// bulk weight stream has no per-message rendezvous).
    fn on_fabric_wake(&mut self, version: u64) {
        let clock = self.clock_s;
        let conts = {
            let Some(fab) = self.fabric.as_mut() else { return };
            let Some(conts) = fab.drain_wake(version, clock) else {
                return; // stale: a newer wake-up is armed
            };
            conts
        };
        for cont in conts {
            match cont {
                FlowCont::In { token } => {
                    let fixed = self.dir_fixed_of(token);
                    self.effects.scheduled.push((
                        self.clock_s + fixed,
                        CLASS_COMPLETION,
                        PipeEvent::XferInDone { token },
                    ));
                }
                FlowCont::Swap { token } => {
                    let measured = self.clock_s - self.transits[token].dispatch_s;
                    self.swap_time_s += measured;
                    self.transits[token].swap_done = true;
                    // the weights landed: unblock this batch, then
                    // every same-model follower parked behind it
                    let (mid, idx) =
                        (self.transits[token].model, self.transits[token].backend);
                    self.swap_ready_s[mid][idx] = self.clock_s;
                    self.try_begin_service(token);
                    let mut waiters = std::mem::take(&mut self.swap_waiters[mid][idx]);
                    for &waiter in &waiters {
                        self.try_begin_service(waiter);
                    }
                    // nothing re-parks once the weights are resident:
                    // hand the drained buffer back to its slot
                    waiters.clear();
                    self.swap_waiters[mid][idx] = waiters;
                }
                FlowCont::Out { token } => {
                    let fixed = self.dir_fixed_of(token);
                    self.effects.scheduled.push((
                        self.clock_s + fixed,
                        CLASS_COMPLETION,
                        PipeEvent::XferOutDone { token },
                    ));
                }
            }
        }
        if self.fabric.is_some() {
            self.arm_fabric();
        }
    }

    fn dir_fixed_of(&self, token: usize) -> f64 {
        let fab = self.fabric.as_ref().expect("fabric phase without a fabric");
        fab.spec.topology.dir_fixed_s(self.transits[token].accel)
    }

    /// The request payload is at the accelerator.
    fn on_xfer_in_done(&mut self, token: usize) {
        let tr = &mut self.transits[token];
        tr.net_in_s = self.clock_s - tr.dispatch_s;
        tr.in_done_s = self.clock_s;
        tr.in_done = true;
        self.try_begin_service(token);
    }

    /// Begin execution once the payload has landed, the batch's own
    /// swap (on a miss) has landed, **and** the model's weights are
    /// actually on the backend — a follower routed to a backend whose
    /// weights are still on the wire parks until they arrive (the
    /// wait lands in its `swap_s` component).  The batch then
    /// executes as soon as the device frees up ([`FabricLayer::occupy`]
    /// — strictly one batch at a time per device, work-conserving
    /// order).
    fn try_begin_service(&mut self, token: usize) {
        let clock = self.clock_s;
        let (ready, idx, exec_s, in_done_s, mid) = {
            let tr = &self.transits[token];
            (!tr.started && tr.in_done && tr.swap_done, tr.backend, tr.exec_s, tr.in_done_s,
             tr.model)
        };
        if !ready {
            return;
        }
        // `== INFINITY` exactly: `NEG_INFINITY` means "never swapped
        // here", which must not park the batch.
        if self.swap_ready_s[mid][idx] == f64::INFINITY {
            self.swap_waiters[mid][idx].push(token);
            return;
        }
        let fab = self.fabric.as_mut().expect("fabric phase without a fabric");
        let (wait_s, done_s) = fab.occupy(idx, clock, exec_s);
        // Re-sync the routing signal with the device horizon: long
        // transfers/swaps can outlive the dispatch-time reservation's
        // wall-time drain, and the policies must keep seeing the
        // serialized backlog `occupy` is accumulating.
        let backend = &mut self.backends[idx];
        let deficit = (done_s - clock) - backend.queue_s();
        if deficit > 0.0 {
            backend.add_queue_s(deficit);
        }
        let tr = &mut self.transits[token];
        tr.started = true;
        tr.swap_excess_s = clock - in_done_s;
        tr.wait_s = wait_s;
        self.effects.scheduled.push((
            done_s,
            CLASS_COMPLETION,
            PipeEvent::ServiceDone { token },
        ));
    }

    /// Execution finished: send the result payload home.
    fn on_service_done(&mut self, token: usize) {
        let (host, accel, bytes_out) = {
            let tr = &self.transits[token];
            (tr.host, tr.accel, tr.bytes_out)
        };
        self.transits[token].out_start_s = self.clock_s;
        let clock = self.clock_s;
        let fab = self.fabric.as_mut().expect("fabric phase without a fabric");
        let path = fab.spec.topology.response_path(host, accel);
        let flow = fab.engine.start(clock, path, bytes_out);
        fab.cont.insert(flow, FlowCont::Out { token });
        self.arm_fabric();
    }

    /// The result landed: hand the engine the measured phase timings
    /// and run the shared completion accounting.
    fn on_xfer_out_done(&mut self, token: usize) {
        let timing = {
            let tr = &self.transits[token];
            let net_out_s = self.clock_s - tr.out_start_s;
            let link_s = tr.net_in_s + net_out_s;
            TransitTiming {
                wait_s: tr.wait_s,
                swap_s: tr.swap_excess_s,
                link_s,
                contention_s: (link_s - tr.ideal_rtt_s).max(0.0),
                exec_s: tr.exec_s,
            }
        };
        // The transit is finished: move its id buffer out instead of
        // cloning it (the token keeps indexing the timing fields).
        let ids = std::mem::take(&mut self.transits[token].ids);
        self.complete(ids, Some(token), Some(timing));
    }

    fn complete(&mut self, ids: Vec<usize>, token: Option<usize>, timing: Option<TransitTiming>) {
        self.completed += ids.len() as u64;
        self.effects.completed.push(Completed { ids, token, timing });
    }
}
