//! SimCore: the engine-agnostic request pipeline shared by every
//! discrete-event engine in the crate.
//!
//! [`crate::eventsim::EventSim`] (open-/closed-loop request streams)
//! and [`crate::eventsim::cogsim::CogSim`] (the coupled timestep
//! model) used to each carry their own copy of the dispatch → batch →
//! fabric-transfer → service → completion pipeline; every new stage
//! (PR 4's fabric layer, the residency gate) had to be wired twice.
//! This module holds the single copy:
//!
//! * [`BatchStage`] — the router-level dynamic-batching stage (the
//!   serving stack's [`crate::coordinator::batcher::DynamicBatcher`]
//!   mapped onto virtual time, with the same-instant tie-breaking
//!   contract both engines rely on);
//! * [`FabricLayer`] — the contention-aware network stage: a
//!   [`crate::fabric::FabricSpec`] driving an incremental
//!   [`crate::fabric::FabricEngine`], the flow→continuation table,
//!   versioned wake-ups, and the per-device busy clock
//!   ([`FabricLayer::occupy`] — strictly one batch at a time);
//! * [`Residency`] — per-backend LRU model residency (the swap stage,
//!   engaged only when a [`pipeline::ResidencySpec`] is configured);
//! * [`pipeline::Pipeline`] — the request lifecycle itself: policy
//!   routing via [`crate::cluster::policy`], batching, the legacy
//!   fixed-charge dispatch, and the multi-phase fabric path (payload
//!   flow in, weights-ready gate, device occupancy, result flow out).
//!
//! Engines drive the pipeline through a narrow, effect-based surface
//! ([`pipeline::Pipeline::submit`] / [`pipeline::Pipeline::handle`] /
//! [`pipeline::Pipeline::take_effects`]): the pipeline never touches
//! an engine's event queue or record store; it returns, in exact
//! dispatch order, the events to schedule and the batches opened or
//! completed, and the engine interprets them.  Event-queue insertion
//! order defines heap sequence numbers, so the effects' order is part
//! of the byte-stability contract the campaign goldens pin.
//!
//! `python/sim/simcore.py` is the line-faithful transliteration that
//! regenerates the committed goldens byte-exactly.

pub mod pipeline;

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use crate::coordinator::batcher::{BatcherConfig, DynamicBatcher, PendingRequest, Priority};
use crate::fabric::{FabricEngine, FabricSpec};

pub use pipeline::{
    AutoscalerCfg, Completed, Dispatched, Effects, FleetAction, FleetEvent, Outcome, PipeEvent,
    Pipeline, ResidencySpec, TransitTiming,
};

/// Router-level dynamic batching configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Batching {
    /// Every request dispatches alone, immediately (the analytic
    /// cluster's behaviour).
    Off,
    /// Coalesce same-instance requests arriving within `window_s`,
    /// capped at `max_batch` samples per dispatched batch.
    Window { window_s: f64, max_batch: usize },
}

/// The router-level batching stage shared by the engines: the serving
/// stack's [`DynamicBatcher`] mapped onto virtual time via a fixed
/// epoch, plus the same-instant tie-breaking contract both engines
/// rely on:
///
/// * the **arrival path** drains only *size*-ready queues
///   ([`Self::drain_size_ready`]) — a queue whose deadline expires at
///   the very instant new requests arrive is closed by its deadline
///   wake-up instead, which the event queue orders *after* every
///   same-instant arrival, so simultaneous requests ride the closing
///   batch deterministically;
/// * **wake-ups** ([`Self::wakeup_at`]) land on the exact
///   ns-quantised deadline — a ns-resolution `Duration` round-trips
///   `as_secs_f64`/`from_secs_f64` exactly at simulation time scales,
///   and the batcher counts `now == deadline` as expired, so a
///   wake-up never lands early and respins.
pub struct BatchStage {
    batcher: DynamicBatcher,
    /// Virtual-time anchor for the batcher's `Instant` API.
    epoch: Instant,
    /// Requests enqueued but not yet drained into a batch.
    pending: u64,
}

impl BatchStage {
    /// `None` for [`Batching::Off`] (every request dispatches alone).
    pub(crate) fn from_config(batching: Batching) -> Option<BatchStage> {
        match batching {
            Batching::Off => None,
            Batching::Window { window_s, max_batch } => {
                assert!(window_s >= 0.0 && window_s.is_finite());
                assert!(max_batch >= 1);
                let window = Duration::from_secs_f64(window_s);
                Some(BatchStage {
                    batcher: DynamicBatcher::new(BatcherConfig {
                        // size trigger = the cap: a window's queue
                        // fires early only once it can fill a whole
                        // batch
                        target_batch: max_batch,
                        max_wait: window,
                        deferred_max_wait: window,
                        max_batch,
                    }),
                    epoch: Instant::now(),
                    pending: 0,
                })
            }
        }
    }

    fn inst(&self, t_s: f64) -> Instant {
        self.epoch + Duration::from_secs_f64(t_s)
    }

    pub(crate) fn pending(&self) -> u64 {
        self.pending
    }

    fn enqueue(&mut self, instance: &str, id: u64, samples: usize, clock_s: f64) {
        let arrived = self.inst(clock_s);
        self.batcher.enqueue(
            instance,
            PendingRequest {
                id,
                input: Vec::new(),
                samples,
                arrived,
                priority: Priority::Critical,
            },
        );
        self.pending += 1;
    }

    /// Drain everything the size trigger alone makes ready, as lists
    /// of request ids per batch (deadline-expired queues stay put for
    /// their wake-up).
    fn drain_size_ready(&mut self) -> Vec<Vec<usize>> {
        let mut out = Vec::new();
        while self.batcher.has_size_ready() {
            for batch in self.batcher.drain_size_ready() {
                self.pending -= batch.requests.len() as u64;
                out.push(batch.requests.iter().map(|r| r.id as usize).collect());
            }
        }
        out
    }

    /// Drain everything ready at `clock_s`, size- or deadline-wise.
    fn drain_ready(&mut self, clock_s: f64) -> Vec<Vec<usize>> {
        let now = self.inst(clock_s);
        let mut out = Vec::new();
        while self.batcher.has_ready(now) {
            for batch in self.batcher.drain_ready(now) {
                self.pending -= batch.requests.len() as u64;
                out.push(batch.requests.iter().map(|r| r.id as usize).collect());
            }
        }
        out
    }

    /// When the engine must schedule its next batch-close wake-up:
    /// `Some(clock_s)` when some queue is already expired at this
    /// exact instant (close it after all same-instant arrivals), the
    /// earliest future deadline otherwise, `None` when idle.
    fn wakeup_at(&self, clock_s: f64) -> Option<f64> {
        let now = self.inst(clock_s);
        if self.batcher.has_ready(now) {
            return Some(clock_s);
        }
        self.batcher
            .next_deadline(now)
            .map(|d| d.duration_since(self.epoch).as_secs_f64().max(clock_s))
    }
}

/// The contention-aware network stage: a [`FabricSpec`] (topology +
/// backend→accel endpoint map) driving an incremental
/// [`FabricEngine`], plus the flow→continuation table, the wake-up
/// versioning, and the per-device busy clock.
///
/// Flow completion times change whenever the active flow set changes,
/// so a previously armed wake-up event can go stale; every mutation
/// bumps `wake_version` and arms a fresh wake-up at the engine's new
/// earliest completion, and handlers drop wake-ups whose version is
/// not current.
pub struct FabricLayer {
    pub(crate) spec: FabricSpec,
    pub(crate) engine: FabricEngine,
    pub(crate) cont: BTreeMap<u64, FlowCont>,
    pub(crate) wake_version: u64,
    /// Per-backend device-busy horizon: fabric batches execute
    /// strictly one at a time per device ([`Self::occupy`]).
    pub(crate) busy_until_s: Vec<f64>,
}

/// What happens when a fabric flow finishes: `token` indexes the
/// pipeline's in-transit batch table.
#[derive(Debug, Clone, Copy)]
pub(crate) enum FlowCont {
    /// Request payload arrived at the accelerator.
    In { token: usize },
    /// Model weights arrived at the accelerator (residency stage).
    Swap { token: usize },
    /// Result payload arrived back at the host.
    Out { token: usize },
}

impl FlowCont {
    /// The in-transit batch this flow belongs to.
    pub(crate) fn token(&self) -> usize {
        match *self {
            FlowCont::In { token } | FlowCont::Swap { token } | FlowCont::Out { token } => token,
        }
    }
}

impl FabricLayer {
    pub(crate) fn new(spec: FabricSpec, n_backends: usize) -> FabricLayer {
        spec.validate(n_backends);
        let engine = FabricEngine::new(spec.topology.clone());
        FabricLayer {
            spec,
            engine,
            cont: BTreeMap::new(),
            wake_version: 0,
            busy_until_s: vec![0.0; n_backends],
        }
    }

    /// Serialize one batch onto a backend's device: execution starts
    /// at `max(ready, device free)` (work-conserving — a batch whose
    /// payload lands first runs first), never overlapping the
    /// previous batch.  Returns `(device wait, completion time)` and
    /// advances the device clock.  The dispatch-time `queue_s`
    /// reservation remains the *routing* signal; this clock is the
    /// physical exclusivity constraint.
    pub(crate) fn occupy(&mut self, backend: usize, ready_s: f64, exec_s: f64) -> (f64, f64) {
        let start_s = ready_s.max(self.busy_until_s[backend]);
        let done_s = start_s + exec_s;
        self.busy_until_s[backend] = done_s;
        (start_s - ready_s, done_s)
    }

    /// Stale-check a wake-up; when current, drain every finished
    /// flow and hand back its continuation (`None` = stale, drop it).
    pub(crate) fn drain_wake(&mut self, version: u64, clock_s: f64) -> Option<Vec<FlowCont>> {
        if version != self.wake_version {
            return None;
        }
        let done = self.engine.take_completed(clock_s);
        Some(
            done.iter()
                .map(|flow| self.cont.remove(flow).expect("completed flow has a continuation"))
                .collect(),
        )
    }

    /// Bump the wake version and return the `(time, version)` to arm
    /// at the engine's earliest completion; `None` when idle.
    pub(crate) fn next_wake(&mut self, clock_s: f64) -> Option<(f64, u64)> {
        let t = self.engine.next_completion_s()?;
        self.wake_version += 1;
        Some((t.max(clock_s), self.wake_version))
    }

    /// Control plane: degrade (or restore) every fabric link to
    /// `factor` × its as-built capacity and re-solve the fair shares
    /// over the surviving bandwidth.  The caller re-arms the wake-up
    /// (completion times just moved).
    pub(crate) fn set_capacity_scale(&mut self, clock_s: f64, factor: f64) {
        self.engine.set_capacity_scale(clock_s, factor);
    }

    /// Control plane: cancel every in-flight flow whose transit token
    /// satisfies `token_dead` (its destination backend left the
    /// fleet).  Survivors immediately reclaim the cancelled shares;
    /// the caller re-arms the wake-up.  Returns the cancelled count.
    pub(crate) fn cancel_flows_of(
        &mut self,
        clock_s: f64,
        token_dead: impl Fn(usize) -> bool,
    ) -> usize {
        let doomed: Vec<u64> = self
            .cont
            .iter()
            .filter(|(_, c)| token_dead(c.token()))
            .map(|(&id, _)| id)
            .collect();
        for id in &doomed {
            self.cont.remove(id);
            self.engine.cancel(clock_s, *id);
        }
        doomed.len()
    }

    /// Control plane: a backend left the fleet — forget its device
    /// horizon so a later rejoin starts from an idle device.
    pub(crate) fn reset_busy(&mut self, backend: usize) {
        self.busy_until_s[backend] = 0.0;
    }

    /// Does `backend` sit behind the shared fabric (vs in its node)?
    pub(crate) fn is_remote(&self, backend: usize) -> bool {
        self.spec.topology.is_pooled(self.spec.accel_of_backend[backend])
    }

    pub(crate) fn accel(&self, backend: usize) -> usize {
        self.spec.accel_of_backend[backend]
    }

    /// Uncontended round trip for a payload — the degenerate
    /// [`crate::netsim::Link`] charge the fabric collapses to with
    /// one flow on a 1:1 topology; measured transfer time beyond it
    /// is the *contention* share.
    pub(crate) fn ideal_rtt_s(&self, bytes_total: f64) -> f64 {
        self.spec.topology.link().rtt_overhead_s(bytes_total)
    }
}

/// Per-backend LRU model residency (most recently used last), keyed
/// by the pipeline's dense model ids.
#[derive(Debug, Clone, Default)]
pub struct Residency {
    slots: usize,
    held: Vec<usize>,
}

impl Residency {
    pub(crate) fn new(slots: usize) -> Residency {
        Residency { slots, held: Vec::new() }
    }

    /// Control plane: the backend's device memory is gone — forget
    /// every resident model (the slot count is configuration and
    /// survives).
    pub(crate) fn clear(&mut self) {
        self.held.clear();
    }

    /// Record a dispatch of `model`; returns true on a residency
    /// miss (the swap is charged), false on a hit.
    pub(crate) fn touch(&mut self, model: usize) -> bool {
        if let Some(pos) = self.held.iter().position(|&m| m == model) {
            let m = self.held.remove(pos);
            self.held.push(m);
            return false;
        }
        self.held.push(model);
        if self.held.len() > self.slots {
            self.held.remove(0);
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lru_residency_touch_semantics() {
        let (a, b, c) = (0, 1, 2);
        let mut r = Residency::new(2);
        assert!(r.touch(a)); // miss: first sighting
        assert!(r.touch(b));
        assert!(!r.touch(a)); // hit, refreshes a
        assert!(r.touch(c)); // evicts b (LRU)
        assert!(r.touch(b)); // b gone: miss again
        assert!(!r.touch(c)); // c survived (a was evicted by b)
    }
}
