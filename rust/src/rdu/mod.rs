//! Dataflow-accelerator (RDU) simulator.
//!
//! The SambaNova SN10 RDU is a spatial dataflow chip: the model is
//! *placed* onto a fabric of compute/memory tiles and samples stream
//! through a hardware pipeline — there are no per-kernel host
//! launches.  Each RDU has 4 tiles; a model can be deployed on 1..4
//! tiles (§V-A).  Two parameters the GPUs don't have:
//!
//! * **micro-batch**: the unit of data accumulated and sent across
//!   the tiles during inference.  Must be ≤ the mini-batch.  Small
//!   micro-batches under-fill the pipeline (per-micro overhead
//!   dominates); big micro-batches overflow tile SRAM and spill
//!   (Fig. 11/12's 10× spread at 32K).
//! * **placement**: hand-optimised placement shortens the pipeline's
//!   critical path (the paper's "optimized" configuration, Fig. 13).
//!
//! The model is a fill-drain pipeline:
//!
//! ```text
//! latency(mini, micro) = host(api)
//!                      + (depth - 1 + ceil(mini/micro)) · stage(micro)
//! stage(micro) = t_stage_min + micro · t_sample(tiles) · spill(micro)
//! ```
//!
//! calibrated to the paper's anchors: 0.04 ms minimum local latency
//! (C++ API, Fig. 13), 8.14 M samples/s at 16K (Fig. 14), a 10×
//! best-to-worst micro-batch spread at 32K on one RDU (Fig. 12), and
//! the "preferred multiple-of-6" bonus (§V-C).

pub mod allocator;

use crate::devices::profiles::ModelProfile;

/// Software stack used to drive the RDU (Fig. 13/14's three
/// configurations plus the preferred-MB variant).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RduApi {
    /// SambaFlow Python API, compiler-default placement ("naive").
    Python,
    /// Python API with hand-optimised model placement ("optimized").
    PythonOptimized,
    /// C++ API with hand-optimised placement (best; used for remote).
    CppOptimized,
}

impl RduApi {
    pub const ALL: [RduApi; 3] = [RduApi::Python, RduApi::PythonOptimized, RduApi::CppOptimized];

    pub fn label(&self) -> &'static str {
        match self {
            RduApi::Python => "Python (naive)",
            RduApi::PythonOptimized => "Python (optimized placement)",
            RduApi::CppOptimized => "C++ (optimized placement)",
        }
    }

    /// Fixed host-side overhead per inference request, µs.  The C++
    /// API "more than halve[s]" small-batch latency vs Python
    /// (Fig. 13).
    fn host_us(&self) -> f64 {
        match self {
            RduApi::Python => 75.0,
            RduApi::PythonOptimized => 70.0,
            RduApi::CppOptimized => 18.0,
        }
    }

    /// Hand-optimised placement shortens the pipeline stages.
    fn placement_speedup(&self) -> f64 {
        match self {
            RduApi::Python => 1.0,
            RduApi::PythonOptimized | RduApi::CppOptimized => 1.55,
        }
    }

    /// Per-micro-batch software cost.  The Python runtime's async
    /// prefetcher amortises micro-batch handoffs better than the
    /// prototype C++ API's synchronous enqueue — which is why the
    /// paper sees Python edge out C++ at the two largest mini-batches
    /// (Fig. 13) even though C++ wins everywhere else.
    fn per_micro_us(&self) -> f64 {
        match self {
            RduApi::Python | RduApi::PythonOptimized => 0.55,
            RduApi::CppOptimized => 1.2,
        }
    }
}

/// One deployed model on a tile allocation.
#[derive(Debug, Clone)]
pub struct RduModel {
    pub profile: ModelProfile,
    /// Tiles the model is placed on (1..=4; ¼ RDU to 1 RDU).
    pub tiles: usize,
    pub api: RduApi,
    /// Round micro/mini batches to the hardware's preferred
    /// multiple-of-6 sizes (§V-C "preferred MB").
    pub preferred_mb: bool,
}

/// Per-tile SRAM available for streaming activations, bytes.
const TILE_SRAM_BYTES: f64 = 8.0 * 1024.0 * 1024.0;

/// Preferred multiple-of-6 sizes "exploit hardware properties of the
/// DataScale" (§V-C): the fabric's vector lanes are 6-wide.
const PREFERRED_MB_SPEEDUP: f64 = 0.88;

impl RduModel {
    pub fn new(profile: ModelProfile, tiles: usize, api: RduApi) -> Self {
        assert!((1..=4).contains(&tiles), "an SN10 RDU has 4 tiles");
        RduModel { profile, tiles, api, preferred_mb: false }
    }

    pub fn with_preferred_mb(mut self) -> Self {
        self.preferred_mb = true;
        self
    }

    /// Pipeline depth: how many spatial stages the placement cuts the
    /// model into.  More tiles -> more fabric -> deeper pipeline but
    /// proportionally faster stages.
    pub fn depth(&self) -> usize {
        // Hermit's 21 layers place onto ~2 stages per tile; MIR's
        // conv pipeline is deeper per tile.
        let per_tile = if self.profile.name.starts_with("mir") { 3 } else { 2 };
        per_tile * self.tiles
    }

    /// Streaming throughput of the placed pipeline, seconds per
    /// sample, once full (no spill).
    fn t_sample_s(&self) -> f64 {
        // Calibration: Hermit on 1 RDU (4 tiles), optimised placement,
        // saturates around 8.14M samples/s incl. per-micro overheads
        // (Fig. 14) => ~0.1 µs/sample streaming rate.  The fabric
        // scales near-linearly with tiles for these small models
        // (they fit even a single tile).
        let full_rdu_rate = match self.profile.name {
            "hermit" => 9.9e6,
            // MIR's conv pipeline: >140K samples/s at 8K (Fig. 20).
            _ => 0.148e6,
        };
        let rate = full_rdu_rate * self.tiles as f64 / 4.0 * self.api.placement_speedup() / 1.55;
        1.0 / rate
    }

    /// Activation bytes a sample occupies while streaming tile-to-tile
    /// (widest edge of the model at bf16).
    fn stream_bytes_per_sample(&self) -> f64 {
        if self.profile.name.starts_with("mir") {
            // widest feature map: 48*48*16 at bf16
            2.0 * 48.0 * 48.0 * 16.0
        } else {
            // widest FC edge: 2050 at bf16
            2.0 * 2050.0
        }
    }

    /// SRAM spill factor for a micro-batch: once the accumulated
    /// micro-batch no longer fits tile SRAM, stages stall on fabric
    /// DRAM (the right edge of Figs. 11/12).
    fn spill_factor(&self, micro: usize) -> f64 {
        let bytes = micro as f64 * self.stream_bytes_per_sample();
        let sram = TILE_SRAM_BYTES * self.tiles as f64;
        if bytes <= sram {
            1.0
        } else {
            1.0 + 1.05 * (bytes / sram - 1.0).min(6.0)
        }
    }

    /// Whether a (mini, micro) pair is valid on hardware: micro must
    /// divide the work and fit the fabric queues (Figs. 11/12 mask
    /// invalid/failed configs as white squares).
    pub fn config_valid(&self, mini: usize, micro: usize) -> bool {
        micro >= 1 && micro <= mini
    }

    /// Fixed per-micro-batch handoff cost, seconds.
    fn t_min_s(&self) -> f64 {
        0.45e-6 + self.api.per_micro_us() * 1e-6
    }

    /// Steady-state time between micro-batches once streaming
    /// (includes the SRAM-spill penalty).
    fn stage_s(&self, micro: usize) -> f64 {
        self.t_min_s() + micro as f64 * self.t_sample_s() * self.spill_factor(micro)
    }

    /// Pipeline-fill time per stage for the *first* micro-batch
    /// (spill does not apply while the fabric queues are still empty).
    fn fill_stage_s(&self, micro: usize) -> f64 {
        self.t_min_s() + micro as f64 * self.t_sample_s()
    }

    /// Node-local inference latency for (mini, micro), seconds:
    /// `host + (depth-1)·fill + n_micro·stage`.
    pub fn latency_s(&self, mini: usize, micro: usize) -> f64 {
        assert!(self.config_valid(mini, micro), "invalid (mini={mini}, micro={micro})");
        let n_micro = mini.div_ceil(micro) as f64;
        let mut lat = self.api.host_us() * 1e-6
            + (self.depth() - 1) as f64 * self.fill_stage_s(micro)
            + n_micro * self.stage_s(micro);
        if self.preferred_mb && micro % 6 == 0 && mini % micro == 0 {
            lat *= PREFERRED_MB_SPEEDUP;
        }
        lat
    }

    /// The best micro-batch for a mini-batch (the paper "performed
    /// parameter sweeps of the (mini-batch, micro-batch) landscape …
    /// and report the maximum throughput and minimum latency", §V-C).
    pub fn best_micro(&self, mini: usize) -> usize {
        let mut best = (1usize, f64::INFINITY);
        for micro in Self::micro_candidates(mini, self.preferred_mb) {
            let l = self.latency_s(mini, micro);
            if l < best.1 {
                best = (micro, l);
            }
        }
        best.0
    }

    /// Candidate micro-batch sizes for a sweep: powers of two up to
    /// the mini-batch (the paper's Figs. 11/12 grid), plus
    /// multiples-of-6 when preferred-MB is enabled.
    pub fn micro_candidates(mini: usize, preferred: bool) -> Vec<usize> {
        let mut v: Vec<usize> = std::iter::successors(Some(1usize), |&m| Some(m * 2))
            .take_while(|&m| m <= mini)
            .collect();
        if preferred {
            let mut m = 6;
            while m <= mini {
                if mini % m == 0 {
                    v.push(m);
                }
                m += 6;
            }
            v.sort_unstable();
            v.dedup();
        }
        v
    }

    /// Latency at the swept-optimal micro-batch.
    pub fn latency_best_s(&self, mini: usize) -> f64 {
        self.latency_s(mini, self.best_micro(mini))
    }

    /// Node-local throughput at the swept-optimal micro-batch
    /// (synchronous request loop, like the paper's local tests).
    pub fn throughput_best(&self, mini: usize) -> f64 {
        mini as f64 / self.latency_best_s(mini)
    }

    /// SN10 RDU transistor count, billions.  The paper: "The A100 has
    /// 1.3x the transistor count of the DataScale RDU" — 54.2/1.3.
    pub const TRANSISTORS_B: f64 = 41.7;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::profiles;

    fn rdu(api: RduApi) -> RduModel {
        RduModel::new(profiles::hermit(), 4, api)
    }

    fn ms(s: f64) -> f64 {
        s * 1e3
    }

    #[test]
    fn calibration_anchor_min_latency() {
        // Fig. 13: "At the smallest mini-batch sizes we observe a
        // minimum latency of 0.04ms" (C++ optimised).
        let m = rdu(RduApi::CppOptimized);
        let l = ms(m.latency_best_s(1));
        assert!((0.03..=0.055).contains(&l), "{l} ms");
    }

    #[test]
    fn calibration_anchor_16k_throughput() {
        // Fig. 14: "maximum throughput bandwidth of 8.14M samples/s at
        // a mini-batch size of 16K" (C++ optimised).
        let m = rdu(RduApi::CppOptimized);
        let t = m.throughput_best(16384);
        assert!((t / 8.14e6 - 1.0).abs() < 0.15, "{t}");
    }

    #[test]
    fn cpp_more_than_halves_python_small_batch_latency() {
        // Fig. 13: "inference latency is more than halved compared to
        // the Python API" at the smallest mini-batches.
        for mini in [1usize, 4] {
            let py = rdu(RduApi::PythonOptimized).latency_best_s(mini);
            let cpp = rdu(RduApi::CppOptimized).latency_best_s(mini);
            assert!(py / cpp > 2.0, "mini={mini}: {}", py / cpp);
        }
        // still close to 2x at 16
        let py = rdu(RduApi::PythonOptimized).latency_best_s(16);
        let cpp = rdu(RduApi::CppOptimized).latency_best_s(16);
        assert!(py / cpp > 1.8, "mini=16: {}", py / cpp);
    }

    #[test]
    fn python_edges_out_cpp_at_largest_minibatches() {
        // Fig. 13: "with the exception of the 2 largest mini-batch
        // sizes, where the Python API provides slightly lower latency".
        for mini in [16384usize, 32768] {
            let py = rdu(RduApi::PythonOptimized).latency_best_s(mini);
            let cpp = rdu(RduApi::CppOptimized).latency_best_s(mini);
            assert!(py < cpp, "mini={mini}: {py} vs {cpp}");
        }
        // but not at mid-size batches
        let py = rdu(RduApi::PythonOptimized).latency_best_s(256);
        let cpp = rdu(RduApi::CppOptimized).latency_best_s(256);
        assert!(cpp < py);
    }

    #[test]
    fn optimized_placement_helps_especially_large_batches() {
        // Fig. 13: "Hand-optimized model placement … provides benefits
        // to the latency, especially at larger mini-batch sizes".
        let naive = rdu(RduApi::Python);
        let opt = rdu(RduApi::PythonOptimized);
        let small_gain = naive.latency_best_s(4) / opt.latency_best_s(4);
        let large_gain = naive.latency_best_s(32768) / opt.latency_best_s(32768);
        assert!(large_gain > small_gain, "{small_gain} vs {large_gain}");
        assert!(large_gain > 1.3);
    }

    #[test]
    fn micro_batch_spread_is_10x_at_32k() {
        // Fig. 12: "at a mini-batch size of 32K, the difference
        // between the slowest and fastest micro-batch size is 10-fold".
        let m = rdu(RduApi::PythonOptimized);
        let lats: Vec<f64> = RduModel::micro_candidates(32768, false)
            .into_iter()
            .map(|micro| m.latency_s(32768, micro))
            .collect();
        let spread = lats.iter().cloned().fold(0.0f64, f64::max)
            / lats.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!((6.0..=16.0).contains(&spread), "spread {spread}");
    }

    #[test]
    fn micro_batch_benign_at_small_mini() {
        // Figs. 11/12: "at low mini-batch sizes, the micro-batch size
        // has benign effects on performance".
        let m = rdu(RduApi::PythonOptimized);
        let lats: Vec<f64> = RduModel::micro_candidates(16, false)
            .into_iter()
            .map(|micro| m.latency_s(16, micro))
            .collect();
        let spread = lats.iter().cloned().fold(0.0f64, f64::max)
            / lats.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(spread < 2.0, "spread {spread}");
    }

    #[test]
    fn optimal_micro_is_interior_at_large_mini() {
        // Figs. 11/12 highlight per-mini optimal micro sizes that are
        // neither 1 nor the mini-batch itself at large mini.
        let m = rdu(RduApi::PythonOptimized);
        let best = m.best_micro(32768);
        assert!(best > 1 && best < 32768, "best micro {best}");
    }

    #[test]
    fn more_tiles_shift_the_optimum() {
        // Fig. 12 vs Fig. 11: "providing more RDU tiles for model
        // inference changes which mini-batch and micro-batch size
        // combinations give optimal performance".
        let one_tile = RduModel::new(profiles::hermit(), 1, RduApi::Python);
        let four_tiles = RduModel::new(profiles::hermit(), 4, RduApi::Python);
        assert_ne!(one_tile.best_micro(32768), four_tiles.best_micro(32768));
    }

    #[test]
    fn more_tiles_is_faster() {
        for mini in [256usize, 4096, 32768] {
            let l1 = RduModel::new(profiles::hermit(), 1, RduApi::Python).latency_best_s(mini);
            let l4 = RduModel::new(profiles::hermit(), 4, RduApi::Python).latency_best_s(mini);
            assert!(l4 < l1, "mini {mini}");
        }
    }

    #[test]
    fn preferred_mb_improves_latency() {
        // Fig. 13: "The 'preferred MB' optimization provides
        // additional reduction in latency."
        let base = rdu(RduApi::CppOptimized);
        let pref = rdu(RduApi::CppOptimized).with_preferred_mb();
        // 24 = 4·6 is both a power-of-2-adjacent size and a multiple
        // of 6 that divides 96.
        assert!(pref.latency_best_s(96) < base.latency_best_s(96));
    }

    #[test]
    fn invalid_configs_rejected() {
        let m = rdu(RduApi::Python);
        assert!(!m.config_valid(4, 8)); // micro > mini
        assert!(m.config_valid(8, 8));
    }

    #[test]
    #[should_panic(expected = "4 tiles")]
    fn tile_count_bounds() {
        RduModel::new(profiles::hermit(), 5, RduApi::Python);
    }

    #[test]
    fn mir_hits_paper_throughput_targets() {
        // Fig. 20: the DataScale reaches the 100K samples/s target at
        // mini-batch 128 and exceeds 140K at 8K.
        let m = RduModel::new(profiles::mir_noln(), 4, RduApi::CppOptimized);
        assert!(m.throughput_best(128) >= 100_000.0, "{}", m.throughput_best(128));
        assert!(m.throughput_best(8192) > 140_000.0, "{}", m.throughput_best(8192));
    }

    #[test]
    fn transistor_ratio_matches_paper() {
        // "The A100 has 1.3x the transistor count of the DataScale RDU."
        let ratio = 54.2 / RduModel::TRANSISTORS_B;
        assert!((ratio - 1.3).abs() < 0.01, "{ratio}");
    }
}
