//! Tile allocation across a DataScale node.
//!
//! The paper's system has **8 SN10 RDUs × 4 tiles** (§II-A) and the
//! in-the-loop use case needs **multiple independent models resident
//! concurrently** (5–10 per-material Hermit instances per rank, plus
//! MIR — §II-B "should support concurrent execution", §IV).  Their
//! §VI names the multi-model serving application as ongoing work;
//! this module is the resource-management half of it:
//!
//! * a model deployment occupies 1..=4 tiles of a *single* RDU (the
//!   hardware's deployment granularity, §V-A);
//! * a model may be **replicated** across RDUs for load;
//! * the allocator distributes tiles greedily by marginal utility:
//!   at each step the model whose load-to-capacity ratio is worst
//!   gets its cheapest upgrade (grow a deployment within its RDU, or
//!   add a replica on a free RDU).
//!
//! The result feeds the scaling analysis (`harness::scaling`): how
//! many MPI ranks can one DataScale node absorb before latency SLOs
//! or the Infiniband link give out.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::devices::profiles::ModelProfile;

use super::{RduApi, RduModel};

/// The DataScale node geometry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NodeGeometry {
    pub rdus: usize,
    pub tiles_per_rdu: usize,
}

impl NodeGeometry {
    /// The paper's system: "The DataScale system houses 8 SambaNova
    /// Reconfigurable Dataflow Units", each with 4 tiles.
    pub fn sn10_8() -> NodeGeometry {
        NodeGeometry { rdus: 8, tiles_per_rdu: 4 }
    }

    pub fn total_tiles(&self) -> usize {
        self.rdus * self.tiles_per_rdu
    }
}

/// A model's demand declaration.
#[derive(Debug, Clone)]
pub struct Demand {
    pub profile: ModelProfile,
    /// Expected offered load, samples/s.
    pub load: f64,
    /// Typical request mini-batch (sets the operating point).
    pub mini_batch: usize,
}

/// One deployment: a model replica on `tiles` tiles of one RDU.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Deployment {
    pub model: String,
    pub rdu: usize,
    pub tiles: usize,
}

/// The allocation result.
#[derive(Debug, Clone)]
pub struct Allocation {
    pub geometry: NodeGeometry,
    pub deployments: Vec<Deployment>,
}

impl Allocation {
    /// Deployments of one model.
    pub fn of_model(&self, model: &str) -> Vec<&Deployment> {
        self.deployments.iter().filter(|d| d.model == model).collect()
    }

    /// Tiles used on one RDU.
    pub fn tiles_used(&self, rdu: usize) -> usize {
        self.deployments.iter().filter(|d| d.rdu == rdu).map(|d| d.tiles).sum()
    }

    /// Total tiles in use.
    pub fn total_tiles_used(&self) -> usize {
        self.deployments.iter().map(|d| d.tiles).sum()
    }

    /// Aggregate serving capacity of a model at its operating
    /// mini-batch, samples/s (replicas sum; load is balanced).
    pub fn capacity(&self, model: &str, demand: &Demand, api: RduApi) -> f64 {
        self.of_model(model)
            .iter()
            .map(|d| {
                RduModel::new(demand.profile.clone(), d.tiles, api)
                    .throughput_best(demand.mini_batch)
            })
            .sum()
    }

    /// Load-to-capacity ratio (>1 ⇒ overload) for a model.
    pub fn utilisation(&self, model: &str, demand: &Demand, api: RduApi) -> f64 {
        let cap = self.capacity(model, demand, api);
        if cap == 0.0 {
            f64::INFINITY
        } else {
            demand.load / cap
        }
    }
}

/// Greedy marginal-utility allocator.  Every demanded model gets at
/// least one tile; remaining tiles go to whichever model currently
/// has the worst load/capacity ratio, preferring to grow an existing
/// deployment (cheaper: no extra weight copy) over replicating.
pub fn allocate(
    geometry: NodeGeometry,
    demands: &BTreeMap<String, Demand>,
    api: RduApi,
) -> Result<Allocation> {
    if demands.is_empty() {
        bail!("no demands");
    }
    if demands.len() > geometry.total_tiles() {
        bail!(
            "{} models exceed {} tiles (one tile minimum each)",
            demands.len(),
            geometry.total_tiles()
        );
    }

    let mut alloc = Allocation { geometry, deployments: Vec::new() };
    let mut rdu_free: Vec<usize> = vec![geometry.tiles_per_rdu; geometry.rdus];

    // 1. seed: one tile per model, round-robin across RDUs so models
    //    start spread out (independent queues, §II-B).
    let mut rdu_cursor = 0usize;
    for model in demands.keys() {
        // find the next RDU with a free tile
        let mut tries = 0;
        while rdu_free[rdu_cursor] == 0 {
            rdu_cursor = (rdu_cursor + 1) % geometry.rdus;
            tries += 1;
            if tries > geometry.rdus {
                bail!("no free tiles during seeding");
            }
        }
        alloc.deployments.push(Deployment {
            model: model.clone(),
            rdu: rdu_cursor,
            tiles: 1,
        });
        rdu_free[rdu_cursor] -= 1;
        rdu_cursor = (rdu_cursor + 1) % geometry.rdus;
    }

    // 2. greedy: hand out remaining tiles one at a time.
    while rdu_free.iter().sum::<usize>() > 0 {
        // most-overloaded model first
        let (model, _) = match demands
            .iter()
            .map(|(m, d)| (m, alloc.utilisation(m, d, api)))
            .filter(|(_, u)| *u > 0.0)
            .max_by(|a, b| a.1.total_cmp(&b.1))
        {
            Some(x) => x,
            None => break,
        };
        let demand = &demands[model];

        // stop when everything is comfortably provisioned
        if alloc.utilisation(model, demand, api) < 0.5 {
            break;
        }

        // (a) grow an existing deployment in place if its RDU has room
        let mut grown = false;
        let mut grow_idx: Option<usize> = None;
        for (i, d) in alloc.deployments.iter().enumerate() {
            if d.model == *model && d.tiles < geometry.tiles_per_rdu && rdu_free[d.rdu] > 0 {
                grow_idx = Some(i);
                break;
            }
        }
        if let Some(i) = grow_idx {
            let rdu = alloc.deployments[i].rdu;
            alloc.deployments[i].tiles += 1;
            rdu_free[rdu] -= 1;
            grown = true;
        }
        // (b) otherwise replicate onto the emptiest RDU with space
        if !grown {
            let best_rdu = (0..geometry.rdus)
                .filter(|&r| rdu_free[r] > 0)
                .max_by_key(|&r| rdu_free[r]);
            match best_rdu {
                Some(r) => {
                    alloc.deployments.push(Deployment {
                        model: model.clone(),
                        rdu: r,
                        tiles: 1,
                    });
                    rdu_free[r] -= 1;
                }
                None => break,
            }
        }
    }

    Ok(alloc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::profiles;

    fn demand(load: f64, mini: usize) -> Demand {
        Demand { profile: profiles::hermit(), load, mini_batch: mini }
    }

    fn hermit_materials(n: usize, load: f64) -> BTreeMap<String, Demand> {
        (0..n)
            .map(|m| (format!("hermit/mat{m}"), demand(load, 64)))
            .collect()
    }

    #[test]
    fn every_model_gets_a_tile() {
        let demands = hermit_materials(8, 100_000.0);
        let alloc = allocate(NodeGeometry::sn10_8(), &demands, RduApi::CppOptimized).unwrap();
        for m in demands.keys() {
            assert!(!alloc.of_model(m).is_empty(), "{m}");
        }
    }

    #[test]
    fn deployments_respect_rdu_boundaries() {
        let demands = hermit_materials(4, 5_000_000.0);
        let geo = NodeGeometry::sn10_8();
        let alloc = allocate(geo, &demands, RduApi::CppOptimized).unwrap();
        for d in &alloc.deployments {
            assert!(d.tiles >= 1 && d.tiles <= geo.tiles_per_rdu);
            assert!(d.rdu < geo.rdus);
        }
        for r in 0..geo.rdus {
            assert!(alloc.tiles_used(r) <= geo.tiles_per_rdu, "rdu {r}");
        }
    }

    #[test]
    fn hot_model_gets_more_tiles() {
        let mut demands = hermit_materials(2, 50_000.0);
        demands.insert("hermit/hot".into(), demand(6_000_000.0, 1024));
        let alloc = allocate(NodeGeometry::sn10_8(), &demands, RduApi::CppOptimized).unwrap();
        let hot: usize = alloc.of_model("hermit/hot").iter().map(|d| d.tiles).sum();
        let cold: usize = alloc.of_model("hermit/mat0").iter().map(|d| d.tiles).sum();
        assert!(hot > cold, "hot {hot} vs cold {cold}");
    }

    #[test]
    fn replication_across_rdus_when_one_is_full() {
        // demand that exceeds a single 4-tile RDU's capacity forces
        // replicas on other RDUs
        let mut demands = BTreeMap::new();
        demands.insert("hermit/huge".into(), demand(40_000_000.0, 4096));
        let alloc = allocate(NodeGeometry::sn10_8(), &demands, RduApi::CppOptimized).unwrap();
        let deps = alloc.of_model("hermit/huge");
        assert!(deps.len() > 1, "expected replicas, got {deps:?}");
        let rdus: std::collections::BTreeSet<_> = deps.iter().map(|d| d.rdu).collect();
        assert!(rdus.len() > 1);
    }

    #[test]
    fn capacity_and_utilisation_accounting() {
        let demands = hermit_materials(1, 1_000_000.0);
        let alloc = allocate(NodeGeometry::sn10_8(), &demands, RduApi::CppOptimized).unwrap();
        let d = &demands["hermit/mat0"];
        let cap = alloc.capacity("hermit/mat0", d, RduApi::CppOptimized);
        assert!(cap > 0.0);
        let util = alloc.utilisation("hermit/mat0", d, RduApi::CppOptimized);
        assert!((util - 1_000_000.0 / cap).abs() < 1e-9);
    }

    #[test]
    fn overload_is_visible_not_hidden() {
        // one tiny geometry, big demand: utilisation must exceed 1
        let geo = NodeGeometry { rdus: 1, tiles_per_rdu: 1 };
        let demands = hermit_materials(1, 50_000_000.0);
        let alloc = allocate(geo, &demands, RduApi::CppOptimized).unwrap();
        let util = alloc.utilisation(
            "hermit/mat0",
            &demands["hermit/mat0"],
            RduApi::CppOptimized,
        );
        assert!(util > 1.0, "{util}");
    }

    #[test]
    fn too_many_models_rejected() {
        let geo = NodeGeometry { rdus: 1, tiles_per_rdu: 4 };
        let demands = hermit_materials(5, 1000.0);
        assert!(allocate(geo, &demands, RduApi::CppOptimized).is_err());
    }

    #[test]
    fn paper_deployment_shape_fits() {
        // 8 per-material Hermit models + MIR on one SN10-8: fits with
        // room to spare, nothing overloaded at paper-scale loads
        // (20-30K inferences/timestep/rank * O(10) ranks).
        let mut demands = hermit_materials(8, 300_000.0);
        demands.insert(
            "mir".into(),
            Demand { profile: profiles::mir_noln(), load: 100_000.0, mini_batch: 256 },
        );
        let geo = NodeGeometry::sn10_8();
        let alloc = allocate(geo, &demands, RduApi::CppOptimized).unwrap();
        assert!(alloc.total_tiles_used() <= geo.total_tiles());
        for (m, d) in &demands {
            let u = alloc.utilisation(m, d, RduApi::CppOptimized);
            assert!(u <= 1.0, "{m}: {u}");
        }
    }
}
