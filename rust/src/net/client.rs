//! The remote-inference client library (the MPI-rank side of the
//! paper's prototype API).
//!
//! Two usage patterns, matching the paper's two measurements (§V-A):
//!
//! * **latency**: [`Client::infer`] — synchronous request/response
//!   round trip, what an in-the-loop Hydra zone calculation does.
//! * **throughput**: [`Client::submit`] + [`Client::recv`] — the
//!   pipelined mode: "Throughput was maximized in these tests by
//!   allowing asynchronous communication … The client sends
//!   mini-batch n+1 to the server before inference results for
//!   mini-batch n are returned."

use std::collections::HashMap;
use std::io::BufReader;
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use super::protocol::{self, Request, Response};

/// A connection to the disaggregated inference server.
pub struct Client {
    write: Mutex<TcpStream>,
    next_id: AtomicU64,
    /// Completions parked by the reader thread, keyed by request id.
    pending: Arc<Mutex<HashMap<u64, std::sync::mpsc::Sender<Response>>>>,
    reader_thread: Option<std::thread::JoinHandle<()>>,
}

impl Client {
    /// Connect to the server.
    pub fn connect<A: ToSocketAddrs + std::fmt::Debug>(addr: A) -> Result<Client> {
        let stream = TcpStream::connect(&addr).with_context(|| format!("connecting {addr:?}"))?;
        stream.set_nodelay(true)?;
        let read_stream = stream.try_clone()?;

        let pending: Arc<Mutex<HashMap<u64, std::sync::mpsc::Sender<Response>>>> =
            Arc::new(Mutex::new(HashMap::new()));
        let reader_pending = Arc::clone(&pending);
        let reader_thread = std::thread::Builder::new()
            .name("cogsim-client-reader".into())
            .spawn(move || {
                let mut r = BufReader::new(read_stream);
                loop {
                    match protocol::read_response(&mut r) {
                        Ok(Some(resp)) => {
                            let tx = reader_pending.lock().unwrap().remove(&resp.id);
                            if let Some(tx) = tx {
                                let _ = tx.send(resp);
                            }
                        }
                        Ok(None) | Err(_) => return, // server closed
                    }
                }
            })?;

        Ok(Client {
            write: Mutex::new(stream),
            next_id: AtomicU64::new(1),
            pending,
            reader_thread: Some(reader_thread),
        })
    }

    /// Submit a mini-batch without waiting (pipelined mode).  Returns
    /// a receiver for this request's response.
    pub fn submit(
        &self,
        model: &str,
        n_samples: usize,
        payload: &[f32],
    ) -> Result<Receiver<Response>> {
        self.submit_with_priority(model, n_samples, payload, 0)
    }

    /// Submit at deferred (on-the-loop) priority: the server may hold
    /// the request much longer for co-batching and never lets it
    /// pre-empt critical in-the-loop traffic (paper SII-B).
    pub fn submit_deferred(
        &self,
        model: &str,
        n_samples: usize,
        payload: &[f32],
    ) -> Result<Receiver<Response>> {
        self.submit_with_priority(model, n_samples, payload, 1)
    }

    fn submit_with_priority(
        &self,
        model: &str,
        n_samples: usize,
        payload: &[f32],
        priority: u8,
    ) -> Result<Receiver<Response>> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = channel();
        self.pending.lock().unwrap().insert(id, tx);

        let req = Request {
            id,
            model: model.to_string(),
            priority,
            n_samples: n_samples as u32,
            payload: payload.to_vec(),
        };
        let mut w = self.write.lock().unwrap();
        if let Err(e) = protocol::write_request(&mut *w, &req) {
            self.pending.lock().unwrap().remove(&id);
            return Err(e);
        }
        Ok(rx)
    }

    /// Wait for a submitted request's rows.
    pub fn recv(&self, rx: Receiver<Response>) -> Result<Vec<f32>> {
        let resp = rx
            .recv()
            .map_err(|_| anyhow!("connection closed before response"))?;
        resp.rows()
    }

    /// Synchronous round trip: the latency-measurement path.
    pub fn infer(&self, model: &str, n_samples: usize, payload: &[f32]) -> Result<Vec<f32>> {
        if n_samples == 0 {
            bail!("n_samples must be positive");
        }
        let rx = self.submit(model, n_samples, payload)?;
        self.recv(rx)
    }

    /// In-flight request count (diagnostics).
    pub fn in_flight(&self) -> usize {
        self.pending.lock().unwrap().len()
    }
}

impl Drop for Client {
    fn drop(&mut self) {
        // closing the write half unblocks the reader thread
        if let Ok(w) = self.write.lock() {
            let _ = w.shutdown(std::net::Shutdown::Both);
        }
        if let Some(t) = self.reader_thread.take() {
            let _ = t.join();
        }
    }
}
