//! Wire protocol: length-prefixed binary frames, little endian.
//!
//! ```text
//! request  := MAGIC(4) op(1=Infer) id(8) model_len(2) model(...)
//!             priority(1) n_samples(4) payload_len(4) payload(f32 LE ...)
//! response := MAGIC(4) op(2=Result) id(8) status(1)
//!             payload_len(4) payload(f32 LE ... | utf-8 error)
//! ```
//!
//! The payload is `n_samples × input_elems` f32s on the way in and
//! `n_samples × output_elems` f32s on the way out; the server knows
//! the shapes from the model manifest, and validates both.

use std::io::{self, Read, Write};

use anyhow::{anyhow, bail, Result};

/// Frame magic: "CgSm".
pub const MAGIC: [u8; 4] = *b"CgSm";

/// Maximum accepted payload (64K samples of MIR ≈ 600 MB would be
/// absurd; cap at 256 MiB).
pub const MAX_PAYLOAD_BYTES: u32 = 256 * 1024 * 1024;

const OP_INFER: u8 = 1;
const OP_RESULT: u8 = 2;

/// Response status byte.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Status {
    Ok,
    Error,
}

impl Status {
    fn to_byte(self) -> u8 {
        match self {
            Status::Ok => 0,
            Status::Error => 1,
        }
    }

    fn from_byte(b: u8) -> Result<Status> {
        match b {
            0 => Ok(Status::Ok),
            1 => Ok(Status::Error),
            other => bail!("invalid status byte {other}"),
        }
    }
}

/// An inference request frame.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    pub model: String,
    /// 0 = critical (in-the-loop), 1 = deferred (on-the-loop).
    pub priority: u8,
    pub n_samples: u32,
    pub payload: Vec<f32>,
}

/// An inference response frame.
#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    pub id: u64,
    pub status: Status,
    /// f32 rows when Ok; UTF-8 error message bytes (as f32-packed? no
    /// — raw bytes) when Error.
    pub payload: Vec<u8>,
}

impl Response {
    pub fn ok(id: u64, rows: &[f32]) -> Response {
        Response { id, status: Status::Ok, payload: f32s_to_bytes(rows) }
    }

    pub fn error(id: u64, message: &str) -> Response {
        Response { id, status: Status::Error, payload: message.as_bytes().to_vec() }
    }

    pub fn rows(&self) -> Result<Vec<f32>> {
        match self.status {
            Status::Ok => bytes_to_f32s(&self.payload),
            Status::Error => bail!(
                "server error: {}",
                String::from_utf8_lossy(&self.payload)
            ),
        }
    }
}

pub fn f32s_to_bytes(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

pub fn bytes_to_f32s(bytes: &[u8]) -> Result<Vec<f32>> {
    if bytes.len() % 4 != 0 {
        bail!("payload length {} not a multiple of 4", bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

// ------------------------------------------------------------ write

/// Serialise a request into one contiguous buffer (a single write
/// syscall keeps small-request latency down — see §Perf).
pub fn encode_request(req: &Request) -> Vec<u8> {
    let model = req.model.as_bytes();
    let payload_bytes = req.payload.len() * 4;
    let mut buf = Vec::with_capacity(4 + 1 + 8 + 2 + model.len() + 4 + 4 + payload_bytes);
    buf.extend_from_slice(&MAGIC);
    buf.push(OP_INFER);
    buf.extend_from_slice(&req.id.to_le_bytes());
    buf.extend_from_slice(&(model.len() as u16).to_le_bytes());
    buf.extend_from_slice(model);
    buf.push(req.priority);
    buf.extend_from_slice(&req.n_samples.to_le_bytes());
    buf.extend_from_slice(&(payload_bytes as u32).to_le_bytes());
    for x in &req.payload {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    buf
}

pub fn write_request<W: Write>(w: &mut W, req: &Request) -> Result<()> {
    w.write_all(&encode_request(req))?;
    w.flush()?;
    Ok(())
}

pub fn encode_response(resp: &Response) -> Vec<u8> {
    let mut buf = Vec::with_capacity(4 + 1 + 8 + 1 + 4 + resp.payload.len());
    buf.extend_from_slice(&MAGIC);
    buf.push(OP_RESULT);
    buf.extend_from_slice(&resp.id.to_le_bytes());
    buf.push(resp.status.to_byte());
    buf.extend_from_slice(&(resp.payload.len() as u32).to_le_bytes());
    buf.extend_from_slice(&resp.payload);
    buf
}

pub fn write_response<W: Write>(w: &mut W, resp: &Response) -> Result<()> {
    w.write_all(&encode_response(resp))?;
    w.flush()?;
    Ok(())
}

// ------------------------------------------------------------- read

fn read_exact_or_eof<R: Read>(r: &mut R, buf: &mut [u8]) -> Result<bool> {
    // distinguish clean EOF (no frame) from a truncated frame
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                if filled == 0 {
                    return Ok(false);
                }
                bail!("connection closed mid-frame ({filled} bytes in)");
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        }
    }
    Ok(true)
}

fn read_header<R: Read>(r: &mut R, expected_op: u8) -> Result<Option<u64>> {
    let mut head = [0u8; 13];
    if !read_exact_or_eof(r, &mut head)? {
        return Ok(None);
    }
    if head[0..4] != MAGIC {
        bail!("bad frame magic {:02x?}", &head[0..4]);
    }
    if head[4] != expected_op {
        bail!("unexpected opcode {} (wanted {expected_op})", head[4]);
    }
    let id = u64::from_le_bytes(head[5..13].try_into().unwrap());
    Ok(Some(id))
}

fn checked_len(len: u32) -> Result<usize> {
    if len > MAX_PAYLOAD_BYTES {
        bail!("payload {len} exceeds cap {MAX_PAYLOAD_BYTES}");
    }
    Ok(len as usize)
}

/// Read one request frame; `Ok(None)` on clean EOF.
pub fn read_request<R: Read>(r: &mut R) -> Result<Option<Request>> {
    let Some(id) = read_header(r, OP_INFER)? else {
        return Ok(None);
    };
    let mut len2 = [0u8; 2];
    read_exact(r, &mut len2)?;
    let model_len = u16::from_le_bytes(len2) as usize;
    let mut model = vec![0u8; model_len];
    read_exact(r, &mut model)?;
    let mut prio = [0u8; 1];
    read_exact(r, &mut prio)?;
    if prio[0] > 1 {
        bail!("invalid priority byte {}", prio[0]);
    }
    let mut word = [0u8; 4];
    read_exact(r, &mut word)?;
    let n_samples = u32::from_le_bytes(word);
    read_exact(r, &mut word)?;
    let payload_len = checked_len(u32::from_le_bytes(word))?;
    let mut payload = vec![0u8; payload_len];
    read_exact(r, &mut payload)?;
    Ok(Some(Request {
        id,
        model: String::from_utf8(model).map_err(|e| anyhow!("model name: {e}"))?,
        priority: prio[0],
        n_samples,
        payload: bytes_to_f32s(&payload)?,
    }))
}

/// Read one response frame; `Ok(None)` on clean EOF.
pub fn read_response<R: Read>(r: &mut R) -> Result<Option<Response>> {
    let Some(id) = read_header(r, OP_RESULT)? else {
        return Ok(None);
    };
    let mut status = [0u8; 1];
    read_exact(r, &mut status)?;
    let mut word = [0u8; 4];
    read_exact(r, &mut word)?;
    let payload_len = checked_len(u32::from_le_bytes(word))?;
    let mut payload = vec![0u8; payload_len];
    read_exact(r, &mut payload)?;
    Ok(Some(Response { id, status: Status::from_byte(status[0])?, payload }))
}

fn read_exact<R: Read>(r: &mut R, buf: &mut [u8]) -> Result<()> {
    if !read_exact_or_eof(r, buf)? {
        bail!("unexpected EOF");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let req = Request {
            id: 42,
            model: "hermit/mat3".into(),
            priority: 0,
            n_samples: 2,
            payload: vec![1.0, -2.5, 3.25, 0.0],
        };
        let bytes = encode_request(&req);
        let got = read_request(&mut &bytes[..]).unwrap().unwrap();
        assert_eq!(got, req);
    }

    #[test]
    fn response_roundtrip_ok() {
        let resp = Response::ok(7, &[0.5, 1.5]);
        let bytes = encode_response(&resp);
        let got = read_response(&mut &bytes[..]).unwrap().unwrap();
        assert_eq!(got.id, 7);
        assert_eq!(got.rows().unwrap(), vec![0.5, 1.5]);
    }

    #[test]
    fn response_roundtrip_error() {
        let resp = Response::error(9, "no such model");
        let bytes = encode_response(&resp);
        let got = read_response(&mut &bytes[..]).unwrap().unwrap();
        assert_eq!(got.status, Status::Error);
        let err = got.rows().unwrap_err().to_string();
        assert!(err.contains("no such model"), "{err}");
    }

    #[test]
    fn clean_eof_is_none() {
        let empty: &[u8] = &[];
        assert!(read_request(&mut &empty[..]).unwrap().is_none());
        assert!(read_response(&mut &empty[..]).unwrap().is_none());
    }

    #[test]
    fn truncated_frame_is_error() {
        let req = Request { id: 1, model: "m".into(), priority: 0, n_samples: 1, payload: vec![1.0] };
        let bytes = encode_request(&req);
        let cut = &bytes[..bytes.len() - 2];
        assert!(read_request(&mut &cut[..]).is_err());
    }

    #[test]
    fn bad_magic_rejected() {
        let mut bytes = encode_request(&Request {
            id: 1,
            model: "m".into(),
            priority: 0,
            n_samples: 1,
            payload: vec![1.0],
        });
        bytes[0] = b'X';
        assert!(read_request(&mut &bytes[..]).is_err());
    }

    #[test]
    fn wrong_opcode_rejected() {
        let bytes = encode_response(&Response::ok(1, &[1.0]));
        assert!(read_request(&mut &bytes[..]).is_err());
    }

    #[test]
    fn oversized_payload_rejected() {
        // hand-build a request header claiming a huge payload
        let mut buf = Vec::new();
        buf.extend_from_slice(&MAGIC);
        buf.push(1);
        buf.extend_from_slice(&1u64.to_le_bytes());
        buf.extend_from_slice(&1u16.to_le_bytes());
        buf.push(b'm');
        buf.push(0); // priority
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.extend_from_slice(&(MAX_PAYLOAD_BYTES + 1).to_le_bytes());
        assert!(read_request(&mut &buf[..]).is_err());
    }

    #[test]
    fn f32_bytes_roundtrip() {
        let xs = vec![f32::MIN, -0.0, 0.0, 1.5e-30, f32::MAX];
        assert_eq!(bytes_to_f32s(&f32s_to_bytes(&xs)).unwrap(), xs);
        assert!(bytes_to_f32s(&[1, 2, 3]).is_err());
    }
}
