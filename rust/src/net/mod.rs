//! The remote-inference transport — the reproduction of the paper's
//! "prototype C++ API and library" (§V-A) that carried inference
//! between Corona compute nodes and the DataScale over Infiniband.
//!
//! * [`protocol`] — a length-prefixed binary wire format (little
//!   endian, f32 payloads at the precision boundary of the runtime).
//! * [`server`]   — a threaded TCP server: one reader thread per
//!   connection feeding the [`crate::coordinator::Coordinator`],
//!   responses written back as they complete (out-of-order safe:
//!   responses carry the request id).
//! * [`client`]   — the client library: synchronous `infer`, plus the
//!   pipelined `submit`/`recv` pair used for throughput runs ("The
//!   client sends mini-batch n+1 to the server before inference
//!   results for mini-batch n are returned", §V-A).
//!
//! No tokio in the offline build environment — plain `std::net` with
//! a thread per connection, which for the paper's rank counts
//! (tens of clients) is the honest equivalent of the prototype.

pub mod client;
pub mod protocol;
pub mod server;

pub use client::Client;
pub use protocol::{Request, Response, Status};
pub use server::Server;
