//! The disaggregated inference server.
//!
//! One accept loop; per connection, a reader thread that parses
//! request frames, submits them to the coordinator, and a small
//! per-request completion thread-free path: the coordinator's
//! response receiver is handed to a per-connection writer thread
//! through a channel, so responses stream back as they complete
//! (requests from one client may complete out of order across
//! instances; frames carry ids).

use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::batcher::Priority;
use crate::coordinator::Coordinator;

use super::protocol::{self, Response};

/// Server handle: accepts connections until shut down.
pub struct Server {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    connections: Arc<AtomicU64>,
}

impl Server {
    /// Bind `addr` (e.g. "127.0.0.1:0") and serve `coordinator`.
    pub fn serve(coordinator: Arc<Coordinator>, addr: &str) -> Result<Server> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let addr = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let connections = Arc::new(AtomicU64::new(0));

        let accept_shutdown = Arc::clone(&shutdown);
        let accept_connections = Arc::clone(&connections);
        let accept_thread = std::thread::Builder::new()
            .name("cogsim-accept".into())
            .spawn(move || {
                // Non-blocking accept so shutdown is prompt.
                listener.set_nonblocking(true).expect("nonblocking listener");
                loop {
                    if accept_shutdown.load(Ordering::SeqCst) {
                        return;
                    }
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            accept_connections.fetch_add(1, Ordering::Relaxed);
                            let coordinator = Arc::clone(&coordinator);
                            let shutdown = Arc::clone(&accept_shutdown);
                            std::thread::Builder::new()
                                .name("cogsim-conn".into())
                                .spawn(move || {
                                    let _ = handle_connection(stream, coordinator, shutdown);
                                })
                                .expect("spawn connection handler");
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_micros(200));
                        }
                        Err(_) => return,
                    }
                }
            })?;

        Ok(Server {
            addr,
            shutdown,
            accept_thread: Some(accept_thread),
            connections,
        })
    }

    /// The bound address (use with "127.0.0.1:0" for tests).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn connections_accepted(&self) -> u64 {
        self.connections.load(Ordering::Relaxed)
    }

    /// Stop accepting; existing connections drain on client close.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

fn handle_connection(
    stream: TcpStream,
    coordinator: Arc<Coordinator>,
    shutdown: Arc<AtomicBool>,
) -> Result<()> {
    stream.set_nodelay(true)?; // latency-bound small frames
    let write_stream = stream.try_clone()?;

    // Writer thread: serialises responses back to the client in
    // completion order.
    let (resp_tx, resp_rx): (Sender<Response>, Receiver<Response>) = channel();
    let writer = std::thread::Builder::new()
        .name("cogsim-writer".into())
        .spawn(move || {
            let mut w = write_stream;
            while let Ok(resp) = resp_rx.recv() {
                if protocol::write_response(&mut w, &resp).is_err() {
                    return;
                }
            }
        })?;

    let mut reader = BufReader::new(stream);
    loop {
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
        let Some(req) = protocol::read_request(&mut reader)? else {
            break; // clean client close
        };
        let id = req.id;

        // validate sample count against payload
        let submit = (|| -> Result<std::sync::mpsc::Receiver<_>> {
            let model = coordinator.registry().resolve(&req.model)?;
            let in_el = coordinator.engine().spec(model)?.input_elems();
            if req.payload.len() != req.n_samples as usize * in_el {
                anyhow::bail!(
                    "payload {} != {} samples x {in_el}",
                    req.payload.len(),
                    req.n_samples
                );
            }
            let priority = if req.priority == 1 { Priority::Deferred } else { Priority::Critical };
            coordinator.submit_with_priority(&req.model, req.payload, priority)
        })();

        match submit {
            Ok(rx) => {
                // completion forwarder: tiny thread per in-flight
                // request keeps responses out-of-order capable without
                // an async runtime.  In-flight depth is bounded by the
                // client's pipelining window.
                let resp_tx = resp_tx.clone();
                std::thread::Builder::new()
                    .name("cogsim-complete".into())
                    .spawn(move || {
                        let resp = match rx.recv() {
                            Ok(Ok(rows)) => Response::ok(id, &rows),
                            Ok(Err(e)) => Response::error(id, &e),
                            Err(_) => Response::error(id, "coordinator dropped request"),
                        };
                        let _ = resp_tx.send(resp);
                    })?;
            }
            Err(e) => {
                resp_tx.send(Response::error(id, &format!("{e:#}")))?;
            }
        }
    }

    drop(resp_tx);
    let _ = writer.join();
    Ok(())
}
