//! Deterministic discrete-event simulation of a multi-rank CogSim
//! inference cluster — the queueing-level companion to the closed-form
//! virtual-time [`crate::cluster::Cluster`].
//!
//! The analytic cluster answers "what does one request cost given the
//! queue it finds"; it cannot express *when* requests find those
//! queues.  The paper's hard regime is exactly a timing phenomenon:
//! every MPI rank hits the inference point of its timestep at once
//! and emits a burst of tiny per-material requests whose latency sits
//! on the simulation's critical path (§IV-A).  This module replays
//! that workload event by event:
//!
//! * **events** — a binary-heap [`equeue::EventQueue`] ordered by
//!   `(virtual time, class, insertion seq)` (same-instant semantics:
//!   completions, then arrivals, then batch-close deadlines):
//!   arrivals, batching-window deadlines, completions, and the
//!   generator events that produce the arrival stream;
//! * **arrivals** — three [`arrival::ArrivalProcess`]es: synchronised
//!   per-timestep bursts, open-loop Poisson, closed-loop think time;
//! * **batching** — an optional router-level stage that coalesces
//!   same-instance requests within a window/max-batch, *reusing* the
//!   serving stack's [`crate::coordinator::batcher::DynamicBatcher`]
//!   (virtual time is mapped onto its `Instant` API via a fixed
//!   epoch);
//! * **service** — each batch is routed through the *same*
//!   [`crate::cluster::Policy`] selection the analytic cluster uses,
//!   waits behind the chosen backend's FIFO queue, pays the
//!   [`crate::netsim::Link`] round trip, and occupies the backend for
//!   the paper's double-buffered period;
//! * **metrics** — full latency distributions
//!   (p50/p90/p99/p99.9, histogram, per-rank slowdown) instead of
//!   means only ([`metrics::LatencyDist`]);
//! * **fabric** — optionally ([`EventSim::with_fabric`]), remote
//!   dispatches ride the contention-aware [`crate::fabric`] layer:
//!   the fixed link charge becomes two time-varying transfer events
//!   (request in, result out) competing for shared leaf/spine
//!   bandwidth under max-min fair share, so a 64-rank burst pays for
//!   the wire it actually shares;
//! * **cogsim** — the *application-level* coupling ([`cogsim::CogSim`]):
//!   N ranks run T bulk-synchronous timesteps, each stalling on its
//!   in-the-loop inference burst, with per-backend model residency and
//!   swap costs — the paper's actual figure of merit, time-to-solution.
//!
//! Everything is seeded from [`crate::util::rng::Rng`] and ordered
//! deterministically, so identical configs produce byte-identical
//! summaries — `rust/tests/eventsim_props.rs` pins that, and
//! `rust/tests/eventsim_vs_analytic.rs` proves the engine degrades to
//! the analytic model in the contention-free limit.

pub mod arrival;
pub mod cogsim;
pub mod equeue;
pub mod metrics;

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use crate::cluster::{policy, Backend, Policy};
use crate::coordinator::batcher::{BatcherConfig, DynamicBatcher, PendingRequest, Priority};
use crate::devices::{profiles, ModelProfile};
use crate::fabric::{FabricEngine, FabricSpec};
use crate::netsim::dir_payload_bytes;
use crate::util::rng::Rng;
use crate::workload::HydraWorkload;

use equeue::{CLASS_COMPLETION, CLASS_DEADLINE};

pub use arrival::ArrivalProcess;
pub use cogsim::{CogRecord, CogSim, CogSimConfig};
pub use equeue::EventQueue;
pub use metrics::{CogSummary, EventSummary, LatencyDist, StepBreakdown};

/// Router-level dynamic batching configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Batching {
    /// Every request dispatches alone, immediately (the analytic
    /// cluster's behaviour).
    Off,
    /// Coalesce same-instance requests arriving within `window_s`,
    /// capped at `max_batch` samples per dispatched batch.
    Window { window_s: f64, max_batch: usize },
}

/// The router-level batching stage shared by [`EventSim`] and
/// [`cogsim::CogSim`]: the serving stack's [`DynamicBatcher`] mapped
/// onto virtual time via a fixed epoch, plus the same-instant
/// tie-breaking contract both engines rely on:
///
/// * the **arrival path** drains only *size*-ready queues
///   ([`Self::drain_size_ready`]) — a queue whose deadline expires at
///   the very instant new requests arrive is closed by its deadline
///   wake-up instead, which the event queue orders *after* every
///   same-instant arrival, so simultaneous requests ride the closing
///   batch deterministically;
/// * **wake-ups** ([`Self::wakeup_at`]) land on the exact
///   ns-quantised deadline — a ns-resolution `Duration` round-trips
///   `as_secs_f64`/`from_secs_f64` exactly at simulation time scales,
///   and the batcher counts `now == deadline` as expired, so a
///   wake-up never lands early and respins.
pub(crate) struct BatchStage {
    batcher: DynamicBatcher,
    /// Virtual-time anchor for the batcher's `Instant` API.
    epoch: Instant,
    /// Requests enqueued but not yet drained into a batch.
    pending: u64,
}

impl BatchStage {
    /// `None` for [`Batching::Off`] (every request dispatches alone).
    fn from_config(batching: Batching) -> Option<BatchStage> {
        match batching {
            Batching::Off => None,
            Batching::Window { window_s, max_batch } => {
                assert!(window_s >= 0.0 && window_s.is_finite());
                assert!(max_batch >= 1);
                let window = Duration::from_secs_f64(window_s);
                Some(BatchStage {
                    batcher: DynamicBatcher::new(BatcherConfig {
                        // size trigger = the cap: a window's queue
                        // fires early only once it can fill a whole
                        // batch
                        target_batch: max_batch,
                        max_wait: window,
                        deferred_max_wait: window,
                        max_batch,
                    }),
                    epoch: Instant::now(),
                    pending: 0,
                })
            }
        }
    }

    fn inst(&self, t_s: f64) -> Instant {
        self.epoch + Duration::from_secs_f64(t_s)
    }

    fn pending(&self) -> u64 {
        self.pending
    }

    fn enqueue(&mut self, instance: &str, id: u64, samples: usize, clock_s: f64) {
        let arrived = self.inst(clock_s);
        self.batcher.enqueue(
            instance,
            PendingRequest {
                id,
                input: Vec::new(),
                samples,
                arrived,
                priority: Priority::Critical,
            },
        );
        self.pending += 1;
    }

    /// Drain everything the size trigger alone makes ready, as lists
    /// of request ids per batch (deadline-expired queues stay put for
    /// their wake-up).
    fn drain_size_ready(&mut self) -> Vec<Vec<usize>> {
        let mut out = Vec::new();
        while self.batcher.has_size_ready() {
            for batch in self.batcher.drain_size_ready() {
                self.pending -= batch.requests.len() as u64;
                out.push(batch.requests.iter().map(|r| r.id as usize).collect());
            }
        }
        out
    }

    /// Drain everything ready at `clock_s`, size- or deadline-wise.
    fn drain_ready(&mut self, clock_s: f64) -> Vec<Vec<usize>> {
        let now = self.inst(clock_s);
        let mut out = Vec::new();
        while self.batcher.has_ready(now) {
            for batch in self.batcher.drain_ready(now) {
                self.pending -= batch.requests.len() as u64;
                out.push(batch.requests.iter().map(|r| r.id as usize).collect());
            }
        }
        out
    }

    /// When the engine must schedule its next batch-close wake-up:
    /// `Some(clock_s)` when some queue is already expired at this
    /// exact instant (close it after all same-instant arrivals), the
    /// earliest future deadline otherwise, `None` when idle.
    fn wakeup_at(&self, clock_s: f64) -> Option<f64> {
        let now = self.inst(clock_s);
        if self.batcher.has_ready(now) {
            return Some(clock_s);
        }
        self.batcher
            .next_deadline(now)
            .map(|d| d.duration_since(self.epoch).as_secs_f64().max(clock_s))
    }
}

/// The contention-aware network stage shared by [`EventSim`] and
/// [`cogsim::CogSim`]: a [`FabricSpec`] (topology + backend→accel
/// endpoint map) driving an incremental [`FabricEngine`], plus the
/// flow→continuation table and the wake-up versioning both engines
/// use.
///
/// Flow completion times change whenever the active flow set changes,
/// so a previously armed wake-up event can go stale; every mutation
/// bumps `wake_version` and arms a fresh wake-up at the engine's new
/// earliest completion, and handlers drop wake-ups whose version is
/// not current.
pub(crate) struct FabricLayer {
    pub(crate) spec: FabricSpec,
    pub(crate) engine: FabricEngine,
    pub(crate) cont: BTreeMap<u64, FlowCont>,
    pub(crate) wake_version: u64,
    /// Per-backend device-busy horizon: fabric batches execute
    /// strictly one at a time per device ([`Self::occupy`]).
    pub(crate) busy_until_s: Vec<f64>,
}

/// What happens when a fabric flow finishes: `token` indexes the
/// engine's in-transit batch table.
#[derive(Debug, Clone, Copy)]
pub(crate) enum FlowCont {
    /// Request payload arrived at the accelerator.
    In { token: usize },
    /// Model weights arrived at the accelerator (cogsim residency).
    Swap { token: usize },
    /// Result payload arrived back at the host.
    Out { token: usize },
}

impl FabricLayer {
    pub(crate) fn new(spec: FabricSpec, n_backends: usize) -> FabricLayer {
        spec.validate(n_backends);
        let engine = FabricEngine::new(spec.topology.clone());
        FabricLayer {
            spec,
            engine,
            cont: BTreeMap::new(),
            wake_version: 0,
            busy_until_s: vec![0.0; n_backends],
        }
    }

    /// Serialize one batch onto a backend's device: execution starts
    /// at `max(ready, device free)` (work-conserving — a batch whose
    /// payload lands first runs first), never overlapping the
    /// previous batch.  Returns `(device wait, completion time)` and
    /// advances the device clock.  The dispatch-time `queue_s`
    /// reservation remains the *routing* signal; this clock is the
    /// physical exclusivity constraint.
    pub(crate) fn occupy(&mut self, backend: usize, ready_s: f64, exec_s: f64) -> (f64, f64) {
        let start_s = ready_s.max(self.busy_until_s[backend]);
        let done_s = start_s + exec_s;
        self.busy_until_s[backend] = done_s;
        (start_s - ready_s, done_s)
    }

    /// Stale-check a wake-up; when current, drain every finished
    /// flow and hand back its continuation (`None` = stale, drop it).
    pub(crate) fn drain_wake(&mut self, version: u64, clock_s: f64) -> Option<Vec<FlowCont>> {
        if version != self.wake_version {
            return None;
        }
        let done = self.engine.take_completed(clock_s);
        Some(
            done.iter()
                .map(|flow| self.cont.remove(flow).expect("completed flow has a continuation"))
                .collect(),
        )
    }

    /// Bump the wake version and return the `(time, version)` to arm
    /// at the engine's earliest completion; `None` when idle.
    pub(crate) fn next_wake(&mut self, clock_s: f64) -> Option<(f64, u64)> {
        let t = self.engine.next_completion_s()?;
        self.wake_version += 1;
        Some((t.max(clock_s), self.wake_version))
    }

    /// Does `backend` sit behind the shared fabric (vs in its node)?
    pub(crate) fn is_remote(&self, backend: usize) -> bool {
        self.spec.topology.is_pooled(self.spec.accel_of_backend[backend])
    }

    pub(crate) fn accel(&self, backend: usize) -> usize {
        self.spec.accel_of_backend[backend]
    }

    /// Uncontended round trip for a payload — the degenerate
    /// [`crate::netsim::Link`] charge the fabric collapses to with
    /// one flow on a 1:1 topology; measured transfer time beyond it
    /// is the *contention* share.
    pub(crate) fn ideal_rtt_s(&self, bytes_total: f64) -> f64 {
        self.spec.topology.link().rtt_overhead_s(bytes_total)
    }
}

/// One event-sim run's knobs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EventSimConfig {
    /// MPI ranks issuing requests.
    pub ranks: usize,
    /// Per-material Hermit instances the ranks spread requests over.
    pub materials: usize,
    /// Samples per request, uniform inclusive (paper: 2–3 per zone).
    pub samples_per_request: (usize, usize),
    /// Synchronized mode: requests per rank per timestep burst.
    pub requests_per_burst: usize,
    /// Synchronized mode: every `mir_every`-th burst each rank also
    /// emits one MIR mixed-zone request (0 = never).
    pub mir_every: usize,
    /// Samples in each MIR request.
    pub mir_samples: usize,
    pub arrival: ArrivalProcess,
    pub batching: Batching,
    /// Arrival generators stop at the horizon; in-flight work drains.
    pub horizon_s: f64,
    pub seed: u64,
}

impl Default for EventSimConfig {
    fn default() -> Self {
        EventSimConfig {
            ranks: 4,
            materials: 8,
            samples_per_request: (2, 3),
            requests_per_burst: 6,
            mir_every: 0,
            mir_samples: 512,
            arrival: ArrivalProcess::Synchronized { period_s: 0.02, jitter_s: 0.0 },
            batching: Batching::Off,
            horizon_s: 0.2,
            seed: 42,
        }
    }
}

/// The full story of one completed request.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestRecord {
    pub id: u64,
    pub rank: usize,
    pub model: String,
    pub samples: usize,
    /// When the rank emitted the request.
    pub arrival_s: f64,
    /// When the router dispatched the (possibly coalesced) batch.
    pub dispatch_s: f64,
    /// When the result returned to the rank.
    pub complete_s: f64,
    /// Backend index the batch was routed to.
    pub backend: usize,
    /// Total samples in the dispatched batch this request rode in.
    pub batch_samples: usize,
    /// Link round-trip share of the service time, seconds.  With the
    /// fabric layer this is the *measured* transfer time (both
    /// directions, fixed latency included).
    pub link_overhead_s: f64,
    /// Fabric-contention share of `link_overhead_s`: measured minus
    /// the uncontended round trip.  Zero without the fabric layer.
    pub contention_s: f64,
}

impl RequestRecord {
    /// End-to-end latency as the rank observes it.
    pub fn latency_s(&self) -> f64 {
        self.complete_s - self.arrival_s
    }

    /// Time spent coalescing in the batching window.
    pub fn batch_wait_s(&self) -> f64 {
        self.dispatch_s - self.arrival_s
    }
}

#[derive(Debug, Clone)]
struct PendingMeta {
    rank: usize,
    model: String,
    samples: usize,
    arrival_s: f64,
}

#[derive(Debug, Clone)]
enum Event {
    /// Synchronized-mode generator: emit burst `step`, schedule the next.
    Burst { step: usize },
    /// One request entering the router.
    Arrival { rank: usize, model: String, samples: usize },
    /// Poisson generator tick for one rank.
    PoissonArrival { rank: usize },
    /// Closed-loop rank ready to submit again.
    ClosedArrival { rank: usize },
    /// Re-check the batcher's deadline-ready queues.
    BatchDeadline,
    /// A dispatched batch finished; ids index the request metadata.
    Completion { ids: Vec<usize> },
    /// The fabric engine's earliest flow completion (stale when
    /// `version` is no longer current — see [`FabricLayer`]).
    FabricWake { version: u64 },
    /// A batch's request payload finished its fixed-latency tail and
    /// is at the accelerator; begin queue + execution.
    XferInDone { token: usize },
    /// A batch's device execution finished; start the result flow.
    ServiceDone { token: usize },
    /// The result payload is back at the host; complete the batch.
    XferOutDone { token: usize },
}

/// One batch in flight through the fabric: which phase timings have
/// been measured so far (token-indexed; records are filled when the
/// result lands).
#[derive(Debug, Clone)]
struct BatchTransit {
    ids: Vec<usize>,
    backend: usize,
    accel: usize,
    host: usize,
    bytes_out: f64,
    dispatch_s: f64,
    net_in_s: f64,
    exec_s: f64,
    out_start_s: f64,
    ideal_rtt_s: f64,
    /// First record index of this batch (`ids.len()` consecutive).
    rec0: usize,
}

/// The engine: backends + policy + event queue + optional batcher +
/// optional contention-aware fabric.
pub struct EventSim {
    cfg: EventSimConfig,
    backends: Vec<Box<dyn Backend>>,
    policy: Policy,
    hermit_tier: Vec<usize>,
    mir_tier: Vec<usize>,
    hermit_profile: ModelProfile,
    mir_profile: ModelProfile,
    rr_cursor: usize,
    affinity: BTreeMap<String, usize>,
    clock_s: f64,
    events: EventQueue<Event>,
    batcher: Option<BatchStage>,
    fabric: Option<FabricLayer>,
    transits: Vec<BatchTransit>,
    rngs: Vec<Rng>,
    pending: Vec<PendingMeta>,
    records: Vec<RequestRecord>,
    submitted: u64,
    dispatched: u64,
    completed: u64,
    batches: u64,
    events_processed: u64,
}

impl EventSim {
    /// All backends serve all model classes.
    pub fn new(backends: Vec<Box<dyn Backend>>, policy: Policy, cfg: EventSimConfig) -> EventSim {
        let all: Vec<usize> = (0..backends.len()).collect();
        Self::with_tiers(backends, policy, cfg, all.clone(), all)
    }

    /// Tiered fleet: `hermit_tier`/`mir_tier` are candidate backend
    /// indices per model class (the campaign's hybrid topology pins
    /// MIR to local GPUs and Hermit to the remote pool).
    pub fn with_tiers(
        backends: Vec<Box<dyn Backend>>,
        policy: Policy,
        cfg: EventSimConfig,
        hermit_tier: Vec<usize>,
        mir_tier: Vec<usize>,
    ) -> EventSim {
        assert!(!backends.is_empty(), "event sim needs at least one backend");
        assert!(cfg.ranks >= 1 && cfg.materials >= 1);
        assert!(cfg.samples_per_request.0 >= 1);
        assert!(cfg.samples_per_request.0 <= cfg.samples_per_request.1);
        assert!(cfg.horizon_s > 0.0 && cfg.horizon_s.is_finite());
        assert!(!hermit_tier.is_empty(), "hermit tier must not be empty");
        assert!(
            cfg.mir_every == 0 || !mir_tier.is_empty(),
            "mir_every > 0 needs a non-empty mir tier"
        );
        assert!(hermit_tier.iter().chain(&mir_tier).all(|&i| i < backends.len()));

        let batcher = BatchStage::from_config(cfg.batching);
        let rngs = (0..cfg.ranks)
            .map(|r| Rng::new(cfg.seed ^ (r as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15)))
            .collect();

        let mut sim = EventSim {
            cfg,
            backends,
            policy,
            hermit_tier,
            mir_tier,
            hermit_profile: profiles::hermit(),
            mir_profile: profiles::mir_noln(),
            rr_cursor: 0,
            affinity: BTreeMap::new(),
            clock_s: 0.0,
            events: EventQueue::new(),
            batcher,
            fabric: None,
            transits: Vec::new(),
            rngs,
            pending: Vec::new(),
            records: Vec::new(),
            submitted: 0,
            dispatched: 0,
            completed: 0,
            batches: 0,
            events_processed: 0,
        };
        sim.seed_generators();
        sim
    }

    /// As [`Self::with_tiers`], with remote dispatches carried by the
    /// contention-aware fabric: the fixed `Link::rtt_overhead_s`
    /// charge is replaced by time-varying transfer events (request
    /// payload in, result payload out) competing for shared-link
    /// bandwidth under max-min fair share.  Backends whose accel
    /// endpoint is node-local in the topology keep the legacy path.
    pub fn with_fabric(
        backends: Vec<Box<dyn Backend>>,
        policy: Policy,
        cfg: EventSimConfig,
        hermit_tier: Vec<usize>,
        mir_tier: Vec<usize>,
        spec: FabricSpec,
    ) -> EventSim {
        let mut sim = Self::with_tiers(backends, policy, cfg, hermit_tier, mir_tier);
        sim.fabric = Some(FabricLayer::new(spec, sim.backends.len()));
        sim
    }

    fn seed_generators(&mut self) {
        match self.cfg.arrival {
            ArrivalProcess::Synchronized { .. } => {
                self.events.push(0.0, Event::Burst { step: 0 });
            }
            ArrivalProcess::Poisson { rate_per_rank } => {
                assert!(rate_per_rank > 0.0);
                for rank in 0..self.cfg.ranks {
                    let t = self.rngs[rank].exponential(rate_per_rank);
                    if t <= self.cfg.horizon_s {
                        self.events.push(t, Event::PoissonArrival { rank });
                    }
                }
            }
            ArrivalProcess::ClosedLoop { think_s } => {
                assert!(think_s >= 0.0);
                for rank in 0..self.cfg.ranks {
                    // small deterministic stagger so ranks do not all
                    // submit at t=0 in lockstep
                    let t = self.rngs[rank].uniform(0.0, think_s.max(1e-6));
                    if t <= self.cfg.horizon_s {
                        self.events.push(t, Event::ClosedArrival { rank });
                    }
                }
            }
        }
    }

    // ------------------------------------------------------ run loop

    /// Process one event; false when the queue is empty.
    fn step(&mut self) -> bool {
        let Some((t, event)) = self.events.pop() else {
            return false;
        };
        self.events_processed += 1;
        self.advance_clock(t);
        self.handle(event);
        true
    }

    /// Process every event with time <= `t_s` (for mid-run
    /// conservation checks); later events stay queued.
    pub fn run_until(&mut self, t_s: f64) {
        while self.events.peek_time().is_some_and(|t| t <= t_s) {
            self.step();
        }
    }

    /// Drain the event queue completely.  Arrival generators stop at
    /// the horizon, so this terminates with every request completed.
    pub fn run_to_completion(&mut self) {
        while self.step() {}
    }

    fn advance_clock(&mut self, t_s: f64) {
        let dt = t_s - self.clock_s;
        if dt <= 0.0 {
            return;
        }
        for b in &mut self.backends {
            b.drain_queue_s(dt);
        }
        self.clock_s = t_s;
    }

    fn handle(&mut self, event: Event) {
        match event {
            Event::Burst { step } => self.on_burst(step),
            Event::Arrival { rank, model, samples } => self.on_request(rank, model, samples),
            Event::PoissonArrival { rank } => self.on_poisson(rank),
            Event::ClosedArrival { rank } => self.on_closed(rank),
            Event::BatchDeadline => self.pump_batcher(),
            Event::Completion { ids } => self.on_completion(ids),
            Event::FabricWake { version } => self.on_fabric_wake(version),
            Event::XferInDone { token } => self.on_xfer_in_done(token),
            Event::ServiceDone { token } => self.on_service_done(token),
            Event::XferOutDone { token } => self.on_xfer_out_done(token),
        }
    }

    // ---------------------------------------------------- generators

    fn gen_hermit(&mut self, rank: usize) -> (String, usize) {
        let materials = self.cfg.materials;
        let (lo, hi) = self.cfg.samples_per_request;
        let rng = &mut self.rngs[rank];
        let model = HydraWorkload::material_model(rng.below(materials));
        let samples = rng.range(lo, hi);
        (model, samples)
    }

    fn on_burst(&mut self, step: usize) {
        let ArrivalProcess::Synchronized { period_s, jitter_s } = self.cfg.arrival else {
            unreachable!("burst event outside synchronized mode");
        };
        let t0 = step as f64 * period_s;
        for rank in 0..self.cfg.ranks {
            for _ in 0..self.cfg.requests_per_burst {
                let (model, samples) = self.gen_hermit(rank);
                let jitter =
                    if jitter_s > 0.0 { self.rngs[rank].uniform(0.0, jitter_s) } else { 0.0 };
                let t = t0 + jitter;
                if t <= self.cfg.horizon_s {
                    self.events.push(t, Event::Arrival { rank, model, samples });
                }
            }
            if self.cfg.mir_every > 0 && step % self.cfg.mir_every == 0 {
                let samples = self.cfg.mir_samples;
                self.events.push(t0, Event::Arrival { rank, model: "mir".to_string(), samples });
            }
        }
        let next = (step + 1) as f64 * period_s;
        if next <= self.cfg.horizon_s {
            self.events.push(next, Event::Burst { step: step + 1 });
        }
    }

    fn on_poisson(&mut self, rank: usize) {
        let ArrivalProcess::Poisson { rate_per_rank } = self.cfg.arrival else {
            unreachable!("poisson event outside poisson mode");
        };
        let (model, samples) = self.gen_hermit(rank);
        let next = self.clock_s + self.rngs[rank].exponential(rate_per_rank);
        if next <= self.cfg.horizon_s {
            self.events.push(next, Event::PoissonArrival { rank });
        }
        self.on_request(rank, model, samples);
    }

    fn on_closed(&mut self, rank: usize) {
        let (model, samples) = self.gen_hermit(rank);
        self.on_request(rank, model, samples);
    }

    // ------------------------------------------------------- routing

    fn on_request(&mut self, rank: usize, model: String, samples: usize) {
        self.submitted += 1;
        let id = self.pending.len();
        self.pending.push(PendingMeta {
            rank,
            model: model.clone(),
            samples,
            arrival_s: self.clock_s,
        });
        if self.batcher.is_some() {
            let stage = self.batcher.as_mut().unwrap();
            stage.enqueue(&model, id as u64, samples, self.clock_s);
            // Arrival path: dispatch only queues the *size* trigger
            // filled; deadline-expired queues close via their wake-up,
            // after every same-instant arrival (see [`BatchStage`]).
            let ready = stage.drain_size_ready();
            self.dispatch_batches(ready);
            self.arm_batch_wakeup();
        } else {
            self.dispatch(vec![id]);
        }
    }

    fn dispatch_batches(&mut self, batches: Vec<Vec<usize>>) {
        for ids in batches {
            self.dispatch(ids);
        }
    }

    /// Schedule the next batch-close wake-up [`BatchStage`] asks for.
    fn arm_batch_wakeup(&mut self) {
        if let Some(t) = self.batcher.as_ref().unwrap().wakeup_at(self.clock_s) {
            self.events.push_class(t, CLASS_DEADLINE, Event::BatchDeadline);
        }
    }

    /// Deadline wake-up: drain every ready batcher queue at the
    /// current virtual time, then arm the next future deadline.
    fn pump_batcher(&mut self) {
        let ready = self.batcher.as_mut().unwrap().drain_ready(self.clock_s);
        self.dispatch_batches(ready);
        self.arm_batch_wakeup();
    }

    /// Route one batch (same-instance request ids) exactly as the
    /// analytic cluster would: policy selection over the candidate
    /// tier, wait behind the backend's queued seconds, pay link +
    /// execute, occupy the backend for the double-buffered period.
    ///
    /// With a [`FabricLayer`] attached, remote backends instead enter
    /// the multi-phase path ([`Self::dispatch_remote`]): the network
    /// cost becomes two fabric flows whose durations depend on what
    /// else is on the wire.
    fn dispatch(&mut self, ids: Vec<usize>) {
        debug_assert!(!ids.is_empty());
        let model = self.pending[ids[0]].model.clone();
        let total: usize = ids.iter().map(|&i| self.pending[i].samples).sum();
        let is_mir = model.starts_with("mir");
        let profile =
            if is_mir { self.mir_profile.clone() } else { self.hermit_profile.clone() };
        let candidates: &[usize] = if is_mir { &self.mir_tier } else { &self.hermit_tier };
        let idx = policy::select(
            self.policy,
            &self.backends,
            &mut self.rr_cursor,
            &mut self.affinity,
            candidates,
            &model,
            &profile,
            total,
        );
        if self.fabric.as_ref().is_some_and(|f| f.is_remote(idx)) {
            self.dispatch_remote(ids, idx, total, &profile);
            return;
        }
        let backend = &mut self.backends[idx];
        let wait_s = backend.queue_s();
        let link_overhead_s = backend.link_overhead_s(&profile, total);
        let latency_s = wait_s + backend.latency_s(&profile, total);
        let occupancy = backend.occupancy_s(&profile, total);
        backend.add_queue_s(occupancy);

        let complete_s = self.clock_s + latency_s;
        for &id in &ids {
            let meta = &self.pending[id];
            self.records.push(RequestRecord {
                id: id as u64,
                rank: meta.rank,
                model: meta.model.clone(),
                samples: meta.samples,
                arrival_s: meta.arrival_s,
                dispatch_s: self.clock_s,
                complete_s,
                backend: idx,
                batch_samples: total,
                link_overhead_s,
                contention_s: 0.0,
            });
        }
        self.dispatched += ids.len() as u64;
        self.batches += 1;
        self.events.push_class(complete_s, CLASS_COMPLETION, Event::Completion { ids });
    }

    // ------------------------------------------------- fabric phases

    /// Remote dispatch over the fabric: the batch's request payload
    /// becomes a flow toward the accelerator; execution begins once
    /// the payload lands ([`Event::XferInDone`]) *and* the backlog
    /// the batch reserved behind has drained, and the result rides
    /// its own flow back.  The FIFO slot is reserved **at dispatch**
    /// (`queue_s` reflects committed work immediately), so the
    /// routing policies see exactly the feedback the legacy path
    /// gives them.  Records are created now (dispatch order) and
    /// their completion fields filled when the result lands.
    ///
    /// Simplification: a router-coalesced batch travels as **one**
    /// flow attributed to the leading request's host (and its result
    /// returns there) — the router batches at the host leaf, so the
    /// merged payload crosses the leaf uplink and the accelerator
    /// side (where the shared-pool contention lives) exactly once;
    /// the per-member host-NIC hops of the tiny pre-merge requests
    /// are not modeled.
    fn dispatch_remote(
        &mut self,
        ids: Vec<usize>,
        idx: usize,
        total: usize,
        profile: &ModelProfile,
    ) {
        let (bytes_in, bytes_out) =
            dir_payload_bytes(profile.input_elems, profile.output_elems, total);
        let fab = self.fabric.as_ref().expect("remote dispatch without a fabric");
        let accel = fab.accel(idx);
        let host = fab.spec.host_of_rank(self.pending[ids[0]].rank);
        let ideal_rtt_s = fab.ideal_rtt_s(bytes_in + bytes_out);

        // reserve the backend's routing queue now: transfers are
        // explicit, so the batch occupies the device for its
        // execution time only, and policies see committed work
        // immediately (the physical one-batch-at-a-time constraint
        // is [`FabricLayer::occupy`]'s device clock)
        let backend = &mut self.backends[idx];
        let exec_s = backend.execute_s(profile, total);
        backend.add_queue_s(exec_s);

        let rec0 = self.records.len();
        for &id in &ids {
            let meta = &self.pending[id];
            self.records.push(RequestRecord {
                id: id as u64,
                rank: meta.rank,
                model: meta.model.clone(),
                samples: meta.samples,
                arrival_s: meta.arrival_s,
                dispatch_s: self.clock_s,
                complete_s: f64::NAN,
                backend: idx,
                batch_samples: total,
                link_overhead_s: 0.0,
                contention_s: 0.0,
            });
        }
        self.dispatched += ids.len() as u64;
        self.batches += 1;

        let token = self.transits.len();
        self.transits.push(BatchTransit {
            ids,
            backend: idx,
            accel,
            host,
            bytes_out,
            dispatch_s: self.clock_s,
            net_in_s: 0.0,
            exec_s,
            out_start_s: 0.0,
            ideal_rtt_s,
            rec0,
        });

        let clock = self.clock_s;
        let fab = self.fabric.as_mut().expect("checked above");
        let path = fab.spec.topology.request_path(host, accel);
        let flow = fab.engine.start(clock, path, bytes_in);
        fab.cont.insert(flow, FlowCont::In { token });
        self.arm_fabric();
    }

    /// Re-arm the fabric wake-up at the engine's (new) earliest flow
    /// completion; called after every flow start/finish.  Earlier
    /// armed wake-ups become stale through the version bump.
    fn arm_fabric(&mut self) {
        let clock = self.clock_s;
        let armed = self.fabric.as_mut().expect("arm_fabric without a fabric").next_wake(clock);
        if let Some((t, version)) = armed {
            self.events.push_class(t, CLASS_COMPLETION, Event::FabricWake { version });
        }
    }

    /// A fabric wake-up fired: drain every finished flow and schedule
    /// its continuation after the direction's fixed-latency tail
    /// (wire + half the per-message software cost — the bytes share
    /// the fabric, the fixed share does not).
    fn on_fabric_wake(&mut self, version: u64) {
        let clock = self.clock_s;
        let conts = {
            let Some(fab) = self.fabric.as_mut() else { return };
            let Some(conts) = fab.drain_wake(version, clock) else {
                return; // stale: a newer wake-up is armed
            };
            conts
        };
        for cont in conts {
            match cont {
                FlowCont::In { token } => {
                    let fixed = self.dir_fixed_of(token);
                    self.events.push_class(
                        self.clock_s + fixed,
                        CLASS_COMPLETION,
                        Event::XferInDone { token },
                    );
                }
                FlowCont::Out { token } => {
                    let fixed = self.dir_fixed_of(token);
                    self.events.push_class(
                        self.clock_s + fixed,
                        CLASS_COMPLETION,
                        Event::XferOutDone { token },
                    );
                }
                FlowCont::Swap { .. } => {
                    unreachable!("EventSim starts no swap flows (see cogsim)")
                }
            }
        }
        if self.fabric.is_some() {
            self.arm_fabric();
        }
    }

    fn dir_fixed_of(&self, token: usize) -> f64 {
        let fab = self.fabric.as_ref().expect("fabric phase without a fabric");
        fab.spec.topology.dir_fixed_s(self.transits[token].accel)
    }

    /// The request payload is at the accelerator: execute as soon as
    /// the device frees up ([`FabricLayer::occupy`] — strictly one
    /// batch at a time per device, work-conserving order; the device
    /// wait is part of the record's end-to-end latency).
    fn on_xfer_in_done(&mut self, token: usize) {
        let clock = self.clock_s;
        let (idx, exec_s) = {
            let tr = &self.transits[token];
            (tr.backend, tr.exec_s)
        };
        let fab = self.fabric.as_mut().expect("fabric phase without a fabric");
        let (_wait_s, done_s) = fab.occupy(idx, clock, exec_s);
        // Re-sync the routing signal with the device horizon: long
        // transfers can outlive the dispatch-time reservation's
        // wall-time drain, and the policies must keep seeing the
        // serialized backlog `occupy` is accumulating.
        let backend = &mut self.backends[idx];
        let deficit = (done_s - clock) - backend.queue_s();
        if deficit > 0.0 {
            backend.add_queue_s(deficit);
        }
        self.transits[token].net_in_s = clock - self.transits[token].dispatch_s;
        self.events.push_class(done_s, CLASS_COMPLETION, Event::ServiceDone { token });
    }

    /// Execution finished: send the result payload home.
    fn on_service_done(&mut self, token: usize) {
        let (host, accel, bytes_out) = {
            let tr = &self.transits[token];
            (tr.host, tr.accel, tr.bytes_out)
        };
        self.transits[token].out_start_s = self.clock_s;
        let clock = self.clock_s;
        let fab = self.fabric.as_mut().expect("fabric phase without a fabric");
        let path = fab.spec.topology.response_path(host, accel);
        let flow = fab.engine.start(clock, path, bytes_out);
        fab.cont.insert(flow, FlowCont::Out { token });
        self.arm_fabric();
    }

    /// The result landed: fill the batch's records with the measured
    /// transfer timings and run the shared completion logic.
    fn on_xfer_out_done(&mut self, token: usize) {
        let (ids, rec0, link_s, contention_s) = {
            let tr = &self.transits[token];
            let net_out_s = self.clock_s - tr.out_start_s;
            let link_s = tr.net_in_s + net_out_s;
            (tr.ids.clone(), tr.rec0, link_s, (link_s - tr.ideal_rtt_s).max(0.0))
        };
        for k in 0..ids.len() {
            let r = &mut self.records[rec0 + k];
            r.complete_s = self.clock_s;
            r.link_overhead_s = link_s;
            r.contention_s = contention_s;
        }
        self.on_completion(ids);
    }

    fn on_completion(&mut self, ids: Vec<usize>) {
        self.completed += ids.len() as u64;
        if let ArrivalProcess::ClosedLoop { think_s } = self.cfg.arrival {
            for &id in &ids {
                let rank = self.pending[id].rank;
                let t = self.clock_s + think_s;
                if t <= self.cfg.horizon_s {
                    self.events.push(t, Event::ClosedArrival { rank });
                }
            }
        }
    }

    // ----------------------------------------------------- accessors

    pub fn clock_s(&self) -> f64 {
        self.clock_s
    }

    pub fn policy(&self) -> Policy {
        self.policy
    }

    /// Requests that have entered the router.
    pub fn submitted(&self) -> u64 {
        self.submitted
    }

    /// Requests dispatched to a backend (inside some batch).
    pub fn dispatched(&self) -> u64 {
        self.dispatched
    }

    /// Requests whose completion event has fired.
    pub fn completed(&self) -> u64 {
        self.completed
    }

    /// Dispatched but not yet completed.
    pub fn in_flight(&self) -> u64 {
        self.dispatched - self.completed
    }

    /// Requests waiting in the batching window.
    pub fn batcher_pending(&self) -> u64 {
        self.batcher.as_ref().map_or(0, BatchStage::pending)
    }

    /// Batches dispatched so far.
    pub fn batches(&self) -> u64 {
        self.batches
    }

    /// Events popped off the queue so far (the micro-benchmark's
    /// denominator: events/sec = this over wall time).
    pub fn events_processed(&self) -> u64 {
        self.events_processed
    }

    /// Per-request records, in dispatch order.  A record exists from
    /// the moment its batch is dispatched; without the fabric layer
    /// its completion time is already determined then, with it the
    /// completion fields are filled when the result lands.
    pub fn records(&self) -> &[RequestRecord] {
        &self.records
    }

    /// Summarise the run (intended after [`Self::run_to_completion`]).
    /// Fabric-mode records whose result is still in transit
    /// (`complete_s` not yet filled) are excluded, so a mid-run
    /// summary is well-defined rather than NaN-poisoned; after a
    /// full run the filter is a no-op.
    pub fn summary(&self) -> EventSummary {
        let records: Vec<&RequestRecord> =
            self.records.iter().filter(|r| r.complete_s.is_finite()).collect();
        let latencies: Vec<f64> = records.iter().map(|r| r.latency_s()).collect();
        let samples: u64 = records.iter().map(|r| r.samples as u64).sum();
        let makespan_s = records.iter().map(|r| r.complete_s).fold(0.0, f64::max);

        let mut rank_sum = vec![0.0f64; self.cfg.ranks];
        let mut rank_n = vec![0u64; self.cfg.ranks];
        let mut link_sum = 0.0;
        let mut contention_sum = 0.0;
        for r in &records {
            rank_sum[r.rank] += r.latency_s();
            rank_n[r.rank] += 1;
            link_sum += r.link_overhead_s;
            contention_sum += r.contention_s;
        }
        let per_rank_mean_s: Vec<f64> = rank_sum
            .iter()
            .zip(&rank_n)
            .map(|(&s, &n)| if n > 0 { s / n as f64 } else { 0.0 })
            .collect();
        let active: Vec<f64> = per_rank_mean_s
            .iter()
            .zip(&rank_n)
            .filter(|(_, &n)| n > 0)
            .map(|(&m, _)| m)
            .collect();
        let slowdown_max = match (
            active.iter().copied().fold(f64::INFINITY, f64::min),
            active.iter().copied().fold(0.0f64, f64::max),
        ) {
            (min, max) if min > 0.0 && min.is_finite() => max / min,
            _ => 1.0,
        };

        EventSummary {
            requests: records.len() as u64,
            samples,
            batches: self.batches,
            mean_batch_samples: if self.batches > 0 {
                samples as f64 / self.batches as f64
            } else {
                0.0
            },
            latency: LatencyDist::from_latencies(&latencies),
            mean_link_overhead_s: if records.is_empty() {
                0.0
            } else {
                link_sum / records.len() as f64
            },
            mean_contention_s: if records.is_empty() {
                0.0
            } else {
                contention_sum / records.len() as f64
            },
            per_rank_mean_s,
            slowdown_max,
            makespan_s,
            samples_per_s: if makespan_s > 0.0 { samples as f64 / makespan_s } else { 0.0 },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{GpuBackend, RduBackend};
    use crate::devices::{Api, Gpu};
    use crate::rdu::RduApi;

    fn gpu_fleet(n: usize) -> Vec<Box<dyn Backend>> {
        (0..n)
            .map(|i| {
                Box::new(GpuBackend::node_local(
                    format!("gpu/rank{i}"),
                    Gpu::a100(),
                    Api::TrtCudaGraphs,
                )) as Box<dyn Backend>
            })
            .collect()
    }

    fn pool() -> Vec<Box<dyn Backend>> {
        vec![
            Box::new(RduBackend::disaggregated("rdu/pool0", 4, RduApi::CppOptimized)),
            Box::new(RduBackend::disaggregated("rdu/pool1", 2, RduApi::Python)),
        ]
    }

    #[test]
    fn synchronized_run_completes_everything() {
        // horizon strictly between the 4th and 5th burst so float
        // rounding of k * period cannot flip the burst count
        let cfg = EventSimConfig { ranks: 8, horizon_s: 0.065, ..Default::default() };
        let mut sim = EventSim::new(gpu_fleet(4), Policy::LeastOutstanding, cfg);
        sim.run_to_completion();
        // 4 bursts (t = 0, 0.02, 0.04, 0.06) x 8 ranks x 6 requests
        assert_eq!(sim.submitted(), 4 * 8 * 6);
        assert_eq!(sim.completed(), sim.submitted());
        assert_eq!(sim.in_flight(), 0);
        assert_eq!(sim.batcher_pending(), 0);
        assert_eq!(sim.records().len() as u64, sim.submitted());
    }

    #[test]
    fn batching_off_is_one_request_per_batch() {
        let cfg = EventSimConfig { horizon_s: 0.04, ..Default::default() };
        let mut sim = EventSim::new(pool(), Policy::LatencyAware, cfg);
        sim.run_to_completion();
        assert_eq!(sim.batches(), sim.submitted());
        assert!(sim.records().iter().all(|r| r.batch_samples == r.samples));
    }

    #[test]
    fn batching_window_coalesces_bursts() {
        let cfg = EventSimConfig {
            ranks: 16,
            horizon_s: 0.04,
            batching: Batching::Window { window_s: 200e-6, max_batch: 256 },
            ..Default::default()
        };
        let mut sim = EventSim::new(pool(), Policy::LatencyAware, cfg);
        sim.run_to_completion();
        assert_eq!(sim.completed(), sim.submitted());
        // 16 ranks x 6 requests per burst over 8 materials must
        // coalesce well below one-batch-per-request
        assert!(
            sim.batches() * 4 <= sim.submitted(),
            "{} batches for {} requests",
            sim.batches(),
            sim.submitted()
        );
        // batch membership recorded
        assert!(sim.records().iter().any(|r| r.batch_samples > r.samples));
    }

    #[test]
    fn mir_requests_ride_their_tier() {
        let cfg = EventSimConfig {
            ranks: 2,
            mir_every: 1,
            mir_samples: 128,
            horizon_s: 0.04,
            ..Default::default()
        };
        let mut fleet = gpu_fleet(2);
        fleet.extend(pool());
        // MIR pinned to the GPUs (0, 1), Hermit to the pool (2, 3)
        let mut sim =
            EventSim::with_tiers(fleet, Policy::LatencyAware, cfg, vec![2, 3], vec![0, 1]);
        sim.run_to_completion();
        for r in sim.records() {
            if r.model.starts_with("mir") {
                assert!(r.backend < 2, "mir routed to {}", r.backend);
            } else {
                assert!(r.backend >= 2, "hermit routed to {}", r.backend);
            }
        }
        assert!(sim.records().iter().any(|r| r.model == "mir"));
    }

    #[test]
    fn closed_loop_keeps_one_in_flight_per_rank() {
        let cfg = EventSimConfig {
            ranks: 3,
            arrival: ArrivalProcess::ClosedLoop { think_s: 1e-3 },
            horizon_s: 0.05,
            ..Default::default()
        };
        let mut sim = EventSim::new(gpu_fleet(1), Policy::RoundRobin, cfg);
        sim.run_to_completion();
        assert!(sim.submitted() > 0);
        assert_eq!(sim.completed(), sim.submitted());
        // a rank never has two requests overlapping in flight
        for rank in 0..3 {
            let mut recs: Vec<&RequestRecord> =
                sim.records().iter().filter(|r| r.rank == rank).collect();
            recs.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
            for pair in recs.windows(2) {
                assert!(
                    pair[1].arrival_s >= pair[0].complete_s - 1e-12,
                    "rank {rank} overlapped"
                );
            }
        }
    }

    #[test]
    fn poisson_generates_within_horizon() {
        let cfg = EventSimConfig {
            ranks: 4,
            arrival: ArrivalProcess::Poisson { rate_per_rank: 2000.0 },
            horizon_s: 0.05,
            ..Default::default()
        };
        let mut sim = EventSim::new(gpu_fleet(2), Policy::LeastOutstanding, cfg);
        sim.run_to_completion();
        // ~ 4 ranks x 2000/s x 0.05s = 400 expected
        assert!(sim.submitted() > 200, "{}", sim.submitted());
        assert!(sim.records().iter().all(|r| r.arrival_s <= 0.05));
        assert_eq!(sim.completed(), sim.submitted());
    }

    #[test]
    fn summary_accounts_everything() {
        let cfg = EventSimConfig { ranks: 4, horizon_s: 0.04, ..Default::default() };
        let mut sim = EventSim::new(pool(), Policy::LatencyAware, cfg);
        sim.run_to_completion();
        let s = sim.summary();
        assert_eq!(s.requests, sim.submitted());
        assert_eq!(s.batches, sim.batches());
        assert!(s.latency.p50_s > 0.0);
        assert!(s.latency.p999_s >= s.latency.p99_s);
        assert!(s.latency.p99_s >= s.latency.p50_s);
        assert!(s.makespan_s > 0.0);
        assert!(s.slowdown_max >= 1.0);
        assert_eq!(s.per_rank_mean_s.len(), 4);
        let hist_total: u64 =
            s.latency.histogram.iter().map(|(_, c)| c).sum::<u64>() + s.latency.overflow;
        assert_eq!(hist_total, s.requests);
        assert!(sim.events_processed() > s.requests, "every request costs >= 1 event");
    }

    // ------------------------------------------------- fabric layer

    fn pool_fabric(ranks: usize, oversub: f64) -> crate::fabric::FabricSpec {
        crate::fabric::FabricSpec {
            topology: crate::fabric::Topology::pooled(ranks, 2, oversub),
            accel_of_backend: vec![0, 1],
        }
    }

    #[test]
    fn fabric_run_completes_everything_and_measures_contention() {
        let cfg = EventSimConfig { ranks: 16, horizon_s: 0.045, ..Default::default() };
        let mut sim = EventSim::with_fabric(
            pool(),
            Policy::LeastOutstanding,
            cfg,
            vec![0, 1],
            vec![0, 1],
            pool_fabric(16, 4.0),
        );
        sim.run_to_completion();
        assert_eq!(sim.completed(), sim.submitted());
        assert_eq!(sim.in_flight(), 0);
        assert_eq!(sim.records().len() as u64, sim.submitted());
        // every record's completion was filled and transfers were paid
        for r in sim.records() {
            assert!(r.complete_s.is_finite() && r.complete_s >= r.dispatch_s);
            assert!(r.link_overhead_s > 0.0, "remote batch must ride the fabric");
            assert!(r.contention_s >= 0.0);
            assert!(r.contention_s <= r.link_overhead_s + 1e-15);
        }
        // a synchronized 16-rank burst on a 4:1 fabric must contend
        let s = sim.summary();
        assert!(s.mean_contention_s > 0.0, "bursts on 4:1 must queue on the wire");
        assert!(s.mean_link_overhead_s > s.mean_contention_s);
    }

    #[test]
    fn fabric_oversubscription_slows_the_tail() {
        let run = |oversub: f64| {
            let cfg = EventSimConfig { ranks: 32, horizon_s: 0.045, ..Default::default() };
            let mut sim = EventSim::with_fabric(
                pool(),
                Policy::LeastOutstanding,
                cfg,
                vec![0, 1],
                vec![0, 1],
                pool_fabric(32, oversub),
            );
            sim.run_to_completion();
            sim.summary()
        };
        let mut last = 0.0;
        for oversub in [1.0, 2.0, 4.0, 8.0] {
            let s = run(oversub);
            assert!(
                s.mean_link_overhead_s >= last - 1e-12,
                "oversub {oversub}: mean link {} < previous {last}",
                s.mean_link_overhead_s
            );
            last = s.mean_link_overhead_s;
        }
    }
}
