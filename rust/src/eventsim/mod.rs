//! Deterministic discrete-event simulation of a multi-rank CogSim
//! inference cluster — the queueing-level companion to the closed-form
//! virtual-time [`crate::cluster::Cluster`].
//!
//! The analytic cluster answers "what does one request cost given the
//! queue it finds"; it cannot express *when* requests find those
//! queues.  The paper's hard regime is exactly a timing phenomenon:
//! every MPI rank hits the inference point of its timestep at once
//! and emits a burst of tiny per-material requests whose latency sits
//! on the simulation's critical path (§IV-A).  This module replays
//! that workload event by event:
//!
//! * **events** — a ladder-backed [`equeue::EventQueue`] ordered by
//!   `(virtual time, class, insertion seq)` (same-instant semantics:
//!   completions, then arrivals, then batch-close deadlines; the
//!   reference `BinaryHeap` backing survives behind
//!   [`EventQueue::binary_heap`] for differential testing);
//! * **arrivals** — three [`arrival::ArrivalProcess`]es: synchronised
//!   per-timestep bursts, open-loop Poisson, closed-loop think time.
//!   Jitter-free synchronized bursts submit *lazily in bulk*: the
//!   burst event itself routes every same-instant request, so the
//!   queue never materializes the O(ranks·K) per-request arrivals
//!   (see DESIGN.md "Event-engine scale-out" for why this is
//!   pop-order-identical to eager materialization);
//! * **pipeline** — everything between arrival and completion
//!   (routing through [`crate::cluster::Policy`] selection, the
//!   dynamic-batching window, FIFO service with
//!   [`crate::netsim::Link`] overhead and double-buffered occupancy,
//!   and the optional contention-aware fabric path) lives in the
//!   shared [`crate::simcore::Pipeline`] — one copy for this engine
//!   and the coupled [`cogsim::CogSim`];
//! * **records** — per-request results live in a struct-of-arrays
//!   store keyed by the dense request id (no per-request allocation;
//!   model names stay interned in the pipeline), with a dispatch-order
//!   index so summaries accumulate floats in the same order as the
//!   original row store — golden bytes included;
//! * **metrics** — full latency distributions
//!   (p50/p90/p99/p99.9, histogram, per-rank slowdown) instead of
//!   means only ([`metrics::LatencyDist`]);
//! * **cogsim** — the *application-level* coupling ([`cogsim::CogSim`]):
//!   N ranks run T bulk-synchronous timesteps, each stalling on its
//!   in-the-loop inference burst, with per-backend model residency and
//!   swap costs — the paper's actual figure of merit, time-to-solution.
//!
//! Everything is seeded from [`crate::util::rng::Rng`] and ordered
//! deterministically, so identical configs produce byte-identical
//! summaries — `rust/tests/eventsim_props.rs` pins that, and
//! `rust/tests/eventsim_vs_analytic.rs` proves the engine degrades to
//! the analytic model in the contention-free limit.

pub mod arrival;
pub mod cogsim;
pub mod equeue;
pub mod metrics;

use crate::cluster::{Backend, Policy};
use crate::fabric::FabricSpec;
use crate::simcore::{Completed, Dispatched, Outcome, PipeEvent, Pipeline};
use crate::util::rng::Rng;
use crate::workload::HydraWorkload;

pub use crate::simcore::{AutoscalerCfg, Batching, FleetAction, FleetEvent};
pub use arrival::ArrivalProcess;
pub use cogsim::{CogRecord, CogSim, CogSimConfig};
pub use equeue::EventQueue;
pub use metrics::{CogSummary, EventSummary, LatencyDist, StepBreakdown};

/// Per-rank RNG streams: a rank's draw sequence is independent of the
/// total rank count (shared by both engines).
pub(crate) fn rank_rngs(seed: u64, ranks: usize) -> Vec<Rng> {
    (0..ranks)
        .map(|r| Rng::new(seed ^ (r as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15)))
        .collect()
}

/// One event-sim run's knobs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EventSimConfig {
    /// MPI ranks issuing requests.
    pub ranks: usize,
    /// Per-material Hermit instances the ranks spread requests over.
    pub materials: usize,
    /// Samples per request, uniform inclusive (paper: 2–3 per zone).
    pub samples_per_request: (usize, usize),
    /// Synchronized mode: requests per rank per timestep burst.
    pub requests_per_burst: usize,
    /// Synchronized mode: every `mir_every`-th burst each rank also
    /// emits one MIR mixed-zone request (0 = never).
    pub mir_every: usize,
    /// Samples in each MIR request.
    pub mir_samples: usize,
    pub arrival: ArrivalProcess,
    pub batching: Batching,
    /// Arrival generators stop at the horizon; in-flight work drains.
    pub horizon_s: f64,
    pub seed: u64,
}

impl Default for EventSimConfig {
    fn default() -> Self {
        EventSimConfig {
            ranks: 4,
            materials: 8,
            samples_per_request: (2, 3),
            requests_per_burst: 6,
            mir_every: 0,
            mir_samples: 512,
            arrival: ArrivalProcess::Synchronized { period_s: 0.02, jitter_s: 0.0 },
            batching: Batching::Off,
            horizon_s: 0.2,
            seed: 42,
        }
    }
}

/// The full story of one completed request — a materialized *view*
/// row assembled on demand from the engine's columnar store plus the
/// pipeline's interned request metadata (see [`EventSim::records`]).
#[derive(Debug, Clone, PartialEq)]
pub struct RequestRecord {
    pub id: u64,
    pub rank: usize,
    pub model: String,
    pub samples: usize,
    /// When the rank emitted the request.
    pub arrival_s: f64,
    /// When the router dispatched the (possibly coalesced) batch.
    pub dispatch_s: f64,
    /// When the result returned to the rank.
    pub complete_s: f64,
    /// Backend index the batch was routed to.
    pub backend: usize,
    /// Total samples in the dispatched batch this request rode in.
    pub batch_samples: usize,
    /// Link round-trip share of the service time, seconds.  With the
    /// fabric layer this is the *measured* transfer time (both
    /// directions, fixed latency included).
    pub link_overhead_s: f64,
    /// Fabric-contention share of `link_overhead_s`: measured minus
    /// the uncontended round trip.  Zero without the fabric layer.
    pub contention_s: f64,
    /// The request's first batch died with its backend and it was
    /// re-dispatched by the control plane; the completion fields
    /// describe the *successful* attempt.
    pub retried: bool,
}

impl RequestRecord {
    /// End-to-end latency as the rank observes it.
    pub fn latency_s(&self) -> f64 {
        self.complete_s - self.arrival_s
    }

    /// Time spent coalescing in the batching window.
    pub fn batch_wait_s(&self) -> f64 {
        self.dispatch_s - self.arrival_s
    }
}

/// Struct-of-arrays request store, keyed by the dense request id (ids
/// are sequential in this engine — pinned by a debug assert at
/// submit).  Nothing here allocates per request beyond amortized
/// column growth; rank/model/samples live in the pipeline's interned
/// metadata and are only materialized into [`RequestRecord`] rows for
/// tests.  `order` lists ids in *dispatch* order: summaries iterate
/// through it so float accumulation order — and therefore golden
/// bytes — is identical to the old row store's push order.
#[derive(Default)]
struct EventRecords {
    /// Id-keyed, set at submit.
    arrival_s: Vec<f64>,
    /// Id-keyed, NaN/zero until the id's batch is dispatched.
    dispatch_s: Vec<f64>,
    complete_s: Vec<f64>,
    backend: Vec<u32>,
    batch_samples: Vec<u32>,
    link_s: Vec<f64>,
    contention_s: Vec<f64>,
    retried: Vec<bool>,
    /// Ids in dispatch order (one entry per dispatched id, ever).
    order: Vec<u32>,
}

impl EventRecords {
    /// Register a submitted request; returns the id the pipeline must
    /// agree on.
    fn on_submit(&mut self, arrival_s: f64) -> usize {
        let id = self.arrival_s.len();
        self.arrival_s.push(arrival_s);
        self.dispatch_s.push(f64::NAN);
        self.complete_s.push(f64::NAN);
        self.backend.push(0);
        self.batch_samples.push(0);
        self.link_s.push(0.0);
        self.contention_s.push(0.0);
        self.retried.push(false);
        id
    }
}

#[derive(Debug, Clone)]
enum Event {
    /// Synchronized-mode generator: emit burst `step`, schedule the next.
    Burst { step: usize },
    /// One request entering the router (jittered bursts only — the
    /// jitter-free path submits in bulk from the burst event).
    Arrival { rank: usize, model: String, samples: usize },
    /// Poisson generator tick for one rank.
    PoissonArrival { rank: usize },
    /// Closed-loop rank ready to submit again.
    ClosedArrival { rank: usize },
    /// A timed control-plane action from the scenario's trace.
    Fleet { action: FleetAction },
    /// Everything past the router lives in [`crate::simcore`].
    Pipe(PipeEvent),
}

/// The engine: arrival generators + record store around the shared
/// [`Pipeline`] (backends, policy routing, batching, fabric).
pub struct EventSim {
    cfg: EventSimConfig,
    core: Pipeline,
    events: EventQueue<Event>,
    rngs: Vec<Rng>,
    /// Material model names, interned once: draw `i`, submit
    /// `&material_names[i]` — no per-draw formatting.
    material_names: Vec<String>,
    rec: EventRecords,
    events_processed: u64,
}

impl EventSim {
    /// All backends serve all model classes.
    pub fn new(backends: Vec<Box<dyn Backend>>, policy: Policy, cfg: EventSimConfig) -> EventSim {
        let all: Vec<usize> = (0..backends.len()).collect();
        Self::with_tiers(backends, policy, cfg, all.clone(), all)
    }

    /// Tiered fleet: `hermit_tier`/`mir_tier` are candidate backend
    /// indices per model class (the campaign's hybrid topology pins
    /// MIR to local GPUs and Hermit to the remote pool).
    pub fn with_tiers(
        backends: Vec<Box<dyn Backend>>,
        policy: Policy,
        cfg: EventSimConfig,
        hermit_tier: Vec<usize>,
        mir_tier: Vec<usize>,
    ) -> EventSim {
        assert!(!backends.is_empty(), "event sim needs at least one backend");
        assert!(cfg.ranks >= 1 && cfg.materials >= 1);
        assert!(cfg.samples_per_request.0 >= 1);
        assert!(cfg.samples_per_request.0 <= cfg.samples_per_request.1);
        assert!(cfg.horizon_s > 0.0 && cfg.horizon_s.is_finite());
        assert!(
            cfg.mir_every == 0 || !mir_tier.is_empty(),
            "mir_every > 0 needs a non-empty mir tier"
        );

        let core = Pipeline::new(backends, policy, hermit_tier, mir_tier, cfg.batching, None);
        let rngs = rank_rngs(cfg.seed, cfg.ranks);
        let material_names: Vec<String> =
            (0..cfg.materials).map(HydraWorkload::material_model).collect();

        let mut sim = EventSim {
            cfg,
            core,
            events: EventQueue::new(),
            rngs,
            material_names,
            rec: EventRecords::default(),
            events_processed: 0,
        };
        sim.events.reserve(sim.cfg.ranks * 2 + 16);
        sim.seed_generators();
        sim
    }

    /// Swap the event queue onto the reference `BinaryHeap` backing —
    /// pop order (and therefore every output) is unchanged; only the
    /// queue's complexity profile differs.  For differential tests
    /// and A/B benchmarks.
    pub fn use_binary_heap_queue(&mut self) {
        self.events.convert_to_binary_heap();
    }

    /// Arm a control-plane trace: each [`FleetEvent`] fires at its
    /// time as an ordinary arrival-class event.  An empty trace adds
    /// nothing — the run is bit-identical to a static one (the
    /// differential suite pins this).  Rank failures are a
    /// coupled-engine concept and are ignored by the open/closed-loop
    /// streams.
    pub fn with_control(&mut self, trace: &[FleetEvent]) {
        for ev in trace {
            assert!(
                ev.at_s >= 0.0 && ev.at_s.is_finite(),
                "fleet event time must be finite and non-negative ({})",
                ev.at_s
            );
            self.events.push(ev.at_s, Event::Fleet { action: ev.action });
        }
    }

    /// As [`Self::with_tiers`], with remote dispatches carried by the
    /// contention-aware fabric: the fixed `Link::rtt_overhead_s`
    /// charge is replaced by time-varying transfer events (request
    /// payload in, result payload out) competing for shared-link
    /// bandwidth under max-min fair share.  Backends whose accel
    /// endpoint is node-local in the topology keep the legacy path.
    pub fn with_fabric(
        backends: Vec<Box<dyn Backend>>,
        policy: Policy,
        cfg: EventSimConfig,
        hermit_tier: Vec<usize>,
        mir_tier: Vec<usize>,
        spec: FabricSpec,
    ) -> EventSim {
        let mut sim = Self::with_tiers(backends, policy, cfg, hermit_tier, mir_tier);
        sim.core.attach_fabric(spec);
        sim
    }

    fn seed_generators(&mut self) {
        match self.cfg.arrival {
            ArrivalProcess::Synchronized { .. } => {
                self.events.push(0.0, Event::Burst { step: 0 });
            }
            ArrivalProcess::Poisson { rate_per_rank } => {
                assert!(rate_per_rank > 0.0);
                for rank in 0..self.cfg.ranks {
                    let t = self.rngs[rank].exponential(rate_per_rank);
                    if t <= self.cfg.horizon_s {
                        self.events.push(t, Event::PoissonArrival { rank });
                    }
                }
            }
            ArrivalProcess::ClosedLoop { think_s } => {
                assert!(think_s >= 0.0);
                for rank in 0..self.cfg.ranks {
                    // small deterministic stagger so ranks do not all
                    // submit at t=0 in lockstep
                    let t = self.rngs[rank].uniform(0.0, think_s.max(1e-6));
                    if t <= self.cfg.horizon_s {
                        self.events.push(t, Event::ClosedArrival { rank });
                    }
                }
            }
        }
    }

    // ------------------------------------------------------ run loop

    /// Process one event; false when the queue is empty.
    fn step(&mut self) -> bool {
        let Some((t, event)) = self.events.pop() else {
            return false;
        };
        self.events_processed += 1;
        self.core.advance_to(t);
        self.handle(event);
        true
    }

    /// Process every event with time <= `t_s` (for mid-run
    /// conservation checks); later events stay queued.
    pub fn run_until(&mut self, t_s: f64) {
        while self.events.peek_time().is_some_and(|t| t <= t_s) {
            self.step();
        }
    }

    /// Drain the event queue completely.  Arrival generators stop at
    /// the horizon, so this terminates with every request completed.
    pub fn run_to_completion(&mut self) {
        while self.step() {}
    }

    fn handle(&mut self, event: Event) {
        match event {
            Event::Burst { step } => self.on_burst(step),
            Event::Arrival { rank, model, samples } => self.on_request(rank, &model, samples),
            Event::PoissonArrival { rank } => self.on_poisson(rank),
            Event::ClosedArrival { rank } => self.on_closed(rank),
            Event::Fleet { action } => self.on_fleet(action),
            Event::Pipe(ev) => {
                self.core.handle(ev);
                self.apply_effects();
            }
        }
    }

    // ---------------------------------------------------- generators

    /// One Hermit draw: `(material index, samples)`.  The rank's RNG
    /// stream consumption is identical whether the request is then
    /// submitted inline (lazy burst) or via a materialized arrival.
    fn draw_hermit(&mut self, rank: usize) -> (usize, usize) {
        let materials = self.cfg.materials;
        let (lo, hi) = self.cfg.samples_per_request;
        let rng = &mut self.rngs[rank];
        let material = rng.below(materials);
        let samples = rng.range(lo, hi);
        (material, samples)
    }

    fn on_burst(&mut self, step: usize) {
        let ArrivalProcess::Synchronized { period_s, jitter_s } = self.cfg.arrival else {
            unreachable!("burst event outside synchronized mode");
        };
        let t0 = step as f64 * period_s;
        if jitter_s > 0.0 {
            // Eager path: jittered arrival times are not monotone
            // within a rank, so each must be materialized to sort
            // against everything else in the queue.
            for rank in 0..self.cfg.ranks {
                for _ in 0..self.cfg.requests_per_burst {
                    let (material, samples) = self.draw_hermit(rank);
                    let jitter = self.rngs[rank].uniform(0.0, jitter_s);
                    let t = t0 + jitter;
                    if t <= self.cfg.horizon_s {
                        let model = self.material_names[material].clone();
                        self.events.push(t, Event::Arrival { rank, model, samples });
                    }
                }
                if self.cfg.mir_every > 0 && step % self.cfg.mir_every == 0 {
                    let samples = self.cfg.mir_samples;
                    self.events
                        .push(t0, Event::Arrival { rank, model: "mir".to_string(), samples });
                }
            }
        } else {
            // Lazy bulk arrivals: every request of this burst shares
            // the burst event's own instant `t0`, and nothing a
            // submission schedules can land at `t0` with a lower
            // class (service and transfer times are strictly
            // positive), so routing the whole burst inline — in the
            // same rank-major draw order the eager path would pop —
            // is pop-order-identical while the queue holds O(1)
            // entries for the burst instead of O(ranks·K).
            debug_assert!(t0 <= self.cfg.horizon_s);
            let emit_mir = self.cfg.mir_every > 0 && step % self.cfg.mir_every == 0;
            for rank in 0..self.cfg.ranks {
                for _ in 0..self.cfg.requests_per_burst {
                    let (material, samples) = self.draw_hermit(rank);
                    self.submit_request(rank, material, samples);
                }
                if emit_mir {
                    self.on_request(rank, "mir", self.cfg.mir_samples);
                }
            }
        }
        let next = (step + 1) as f64 * period_s;
        if next <= self.cfg.horizon_s {
            self.events.push(next, Event::Burst { step: step + 1 });
        }
    }

    fn on_poisson(&mut self, rank: usize) {
        let ArrivalProcess::Poisson { rate_per_rank } = self.cfg.arrival else {
            unreachable!("poisson event outside poisson mode");
        };
        let (material, samples) = self.draw_hermit(rank);
        let next = self.core.clock_s() + self.rngs[rank].exponential(rate_per_rank);
        if next <= self.cfg.horizon_s {
            self.events.push(next, Event::PoissonArrival { rank });
        }
        self.submit_request(rank, material, samples);
    }

    fn on_closed(&mut self, rank: usize) {
        let (material, samples) = self.draw_hermit(rank);
        self.submit_request(rank, material, samples);
    }

    // ------------------------------------------------------- routing

    /// Submit a Hermit request by interned material index.
    fn submit_request(&mut self, rank: usize, material: usize, samples: usize) {
        let id = self.rec.on_submit(self.core.clock_s());
        let submitted = self.core.submit(rank, &self.material_names[material], samples);
        debug_assert_eq!(id, submitted, "engine/pipeline id spaces align");
        self.apply_effects();
    }

    fn on_request(&mut self, rank: usize, model: &str, samples: usize) {
        let id = self.rec.on_submit(self.core.clock_s());
        let submitted = self.core.submit(rank, model, samples);
        debug_assert_eq!(id, submitted, "engine/pipeline id spaces align");
        self.apply_effects();
    }

    // ------------------------------------------------- control plane

    fn on_fleet(&mut self, action: FleetAction) {
        match action {
            FleetAction::BackendLeave(idx) => self.core.control_backend_leave(idx),
            FleetAction::BackendJoin(idx) => self.core.control_backend_join(idx),
            FleetAction::LinkDegrade(factor) => self.core.control_link_scale(factor),
            FleetAction::LinkRestore => self.core.control_link_scale(1.0),
            FleetAction::RankFail(_) => {} // no rank-owned state to replay here
        }
        self.apply_effects();
    }

    /// Interpret the pipeline's effects, in order: open records for
    /// dispatched batches, insert scheduled events (insertion order =
    /// queue seq order), then run completion hooks.  The drained
    /// shell goes back to the pipeline's free lists.
    fn apply_effects(&mut self) {
        let mut effects = self.core.take_effects();
        let clock = self.core.clock_s();
        // a backend left: void the orphans' completion state first —
        // each reappears in `dispatched` below with `retry` set
        for &id in &effects.orphaned {
            self.rec.complete_s[id] = f64::NAN;
            self.rec.retried[id] = true;
        }
        for d in &effects.dispatched {
            self.open_records(d, clock);
        }
        for (t, class, ev) in effects.scheduled.drain(..) {
            self.events.push_class(t, class, Event::Pipe(ev));
        }
        for c in &effects.completed {
            self.on_batch_done(c, clock);
        }
        self.core.recycle_effects(effects);
    }

    fn open_records(&mut self, d: &Dispatched, clock: f64) {
        let (complete_s, link_s) = match d.outcome {
            Outcome::Direct { link_s, complete_s, .. } => (complete_s, link_s),
            Outcome::InFlight { .. } => (f64::NAN, 0.0),
        };
        for &id in &d.ids {
            if !d.retry {
                // first dispatch: the id takes its place in the
                // dispatch-order index
                self.rec.order.push(id as u32);
            }
            // retries keep the id's one row; the routing fields
            // describe the new attempt
            self.rec.dispatch_s[id] = clock;
            self.rec.complete_s[id] = complete_s;
            self.rec.backend[id] = d.backend as u32;
            self.rec.batch_samples[id] = d.batch_samples as u32;
            self.rec.link_s[id] = link_s;
            self.rec.contention_s[id] = 0.0;
        }
    }

    fn on_batch_done(&mut self, c: &Completed, clock: f64) {
        if let (Some(_), Some(timing)) = (c.token, c.timing) {
            // fabric path: fill the batch's records with measured
            // timings (addressed by id — identical to the old
            // contiguous-block fill on a static run, and correct for
            // retried batches whose records are scattered)
            for &id in &c.ids {
                self.rec.complete_s[id] = clock;
                self.rec.link_s[id] = timing.link_s;
                self.rec.contention_s[id] = timing.contention_s;
            }
        }
        if let ArrivalProcess::ClosedLoop { think_s } = self.cfg.arrival {
            for &id in &c.ids {
                let (rank, _, _) = self.core.request(id);
                let t = clock + think_s;
                if t <= self.cfg.horizon_s {
                    self.events.push(t, Event::ClosedArrival { rank });
                }
            }
        }
    }

    // ----------------------------------------------- flight recorder

    /// Arm the flight recorder ([`crate::trace`]): call before the
    /// run; detach the finished trace with [`Self::take_recorder`].
    pub fn arm_trace(&mut self) {
        self.core.arm_trace();
    }

    /// Carry a recorder that records nothing (bench overhead probe).
    pub fn attach_disarmed_recorder(&mut self) {
        self.core.attach_disarmed_recorder();
    }

    /// Detach the recorder, finalized at the current virtual clock.
    pub fn take_recorder(&mut self) -> Option<Box<crate::trace::Recorder>> {
        self.core.take_recorder()
    }

    /// Per-backend service seconds (always on — the recorder's busy
    /// integrals reconcile against this to 1e-9).
    pub fn device_busy_s(&self) -> &[f64] {
        self.core.device_busy_s()
    }

    // ----------------------------------------------------- accessors

    pub fn clock_s(&self) -> f64 {
        self.core.clock_s()
    }

    pub fn policy(&self) -> Policy {
        self.core.policy()
    }

    /// Requests that have entered the router.
    pub fn submitted(&self) -> u64 {
        self.core.submitted()
    }

    /// Requests dispatched to a backend (inside some batch).
    pub fn dispatched(&self) -> u64 {
        self.core.dispatched()
    }

    /// Requests whose completion event has fired.
    pub fn completed(&self) -> u64 {
        self.core.completed()
    }

    /// Dispatched at least once but not yet completed (includes
    /// orphaned work parked with no live backend).
    pub fn in_flight(&self) -> u64 {
        self.core.dispatched() - self.core.retries() - self.core.completed()
    }

    /// Requests waiting in the batching window.
    pub fn batcher_pending(&self) -> u64 {
        self.core.batcher_pending()
    }

    /// Requests re-dispatched after a backend leave orphaned them.
    pub fn retries(&self) -> u64 {
        self.core.retries()
    }

    /// Requests orphaned by backend leaves so far.
    pub fn orphaned(&self) -> u64 {
        self.core.orphaned()
    }

    /// Requests parked with no live backend in their tier.
    pub fn parked(&self) -> u64 {
        self.core.parked_requests()
    }

    /// Is backend `idx` currently in the fleet?
    pub fn backend_active(&self, idx: usize) -> bool {
        self.core.is_active(idx)
    }

    /// Batches dispatched so far.
    pub fn batches(&self) -> u64 {
        self.core.batches()
    }

    /// Events popped off the queue so far (the micro-benchmark's
    /// denominator: events/sec = this over wall time).  Lazy bulk
    /// arrivals route a whole jitter-free burst from one event, so
    /// this undercounts *requests* by design; completions still cost
    /// one event each.
    pub fn events_processed(&self) -> u64 {
        self.events_processed
    }

    /// Materialize one request's record row from the columnar store.
    fn record(&self, id: usize) -> RequestRecord {
        let (rank, model, samples) = self.core.request(id);
        RequestRecord {
            id: id as u64,
            rank,
            model: model.to_string(),
            samples,
            arrival_s: self.rec.arrival_s[id],
            dispatch_s: self.rec.dispatch_s[id],
            complete_s: self.rec.complete_s[id],
            backend: self.rec.backend[id] as usize,
            batch_samples: self.rec.batch_samples[id] as usize,
            link_overhead_s: self.rec.link_s[id],
            contention_s: self.rec.contention_s[id],
            retried: self.rec.retried[id],
        }
    }

    /// Per-request records, in dispatch order, materialized from the
    /// columnar store (test/report convenience — the summary path
    /// reads the columns directly).  A record exists from the moment
    /// its batch is dispatched; without the fabric layer its
    /// completion time is already determined then, with it the
    /// completion fields are filled when the result lands.
    pub fn records(&self) -> Vec<RequestRecord> {
        self.rec.order.iter().map(|&id| self.record(id as usize)).collect()
    }

    /// Summarise the run (intended after [`Self::run_to_completion`]).
    /// Fabric-mode records whose result is still in transit
    /// (`complete_s` not yet filled) are excluded, so a mid-run
    /// summary is well-defined rather than NaN-poisoned; after a
    /// full run the filter is a no-op.  Iterates the columnar store
    /// in dispatch order — the same accumulation order as the old
    /// row store, so every float in the summary is bit-identical.
    pub fn summary(&self) -> EventSummary {
        let rec = &self.rec;
        let done: Vec<usize> = rec
            .order
            .iter()
            .map(|&id| id as usize)
            .filter(|&id| rec.complete_s[id].is_finite())
            .collect();
        // first-attempt latencies only: a retried completion's chain
        // includes the failure gap and is counted via `retries`
        let latencies: Vec<f64> = done
            .iter()
            .filter(|&&id| !rec.retried[id])
            .map(|&id| rec.complete_s[id] - rec.arrival_s[id])
            .collect();
        let mut samples: u64 = 0;
        let mut rank_sum = vec![0.0f64; self.cfg.ranks];
        let mut rank_n = vec![0u64; self.cfg.ranks];
        let mut link_sum = 0.0;
        let mut contention_sum = 0.0;
        let mut makespan_s = 0.0f64;
        for &id in &done {
            let (_, _, n) = self.core.request(id);
            samples += n as u64;
        }
        for &id in &done {
            makespan_s = makespan_s.max(rec.complete_s[id]);
        }
        for &id in &done {
            let (rank, _, _) = self.core.request(id);
            rank_sum[rank] += rec.complete_s[id] - rec.arrival_s[id];
            rank_n[rank] += 1;
            link_sum += rec.link_s[id];
            contention_sum += rec.contention_s[id];
        }
        let per_rank_mean_s: Vec<f64> = rank_sum
            .iter()
            .zip(&rank_n)
            .map(|(&s, &n)| if n > 0 { s / n as f64 } else { 0.0 })
            .collect();
        let active: Vec<f64> = per_rank_mean_s
            .iter()
            .zip(&rank_n)
            .filter(|(_, &n)| n > 0)
            .map(|(&m, _)| m)
            .collect();
        let slowdown_max = match (
            active.iter().copied().fold(f64::INFINITY, f64::min),
            active.iter().copied().fold(0.0f64, f64::max),
        ) {
            (min, max) if min > 0.0 && min.is_finite() => max / min,
            _ => 1.0,
        };

        EventSummary {
            requests: done.len() as u64,
            samples,
            batches: self.core.batches(),
            mean_batch_samples: if self.core.batches() > 0 {
                samples as f64 / self.core.batches() as f64
            } else {
                0.0
            },
            latency: LatencyDist::from_latencies(&latencies),
            mean_link_overhead_s: if done.is_empty() { 0.0 } else { link_sum / done.len() as f64 },
            mean_contention_s: if done.is_empty() {
                0.0
            } else {
                contention_sum / done.len() as f64
            },
            per_rank_mean_s,
            slowdown_max,
            makespan_s,
            samples_per_s: if makespan_s > 0.0 { samples as f64 / makespan_s } else { 0.0 },
            submitted: self.core.submitted(),
            retries: self.core.retries(),
            failed: self.core.submitted() - done.len() as u64 - self.core.batcher_pending(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{GpuBackend, RduBackend};
    use crate::devices::{Api, Gpu};
    use crate::rdu::RduApi;

    fn gpu_fleet(n: usize) -> Vec<Box<dyn Backend>> {
        (0..n)
            .map(|i| {
                Box::new(GpuBackend::node_local(
                    format!("gpu/rank{i}"),
                    Gpu::a100(),
                    Api::TrtCudaGraphs,
                )) as Box<dyn Backend>
            })
            .collect()
    }

    fn pool() -> Vec<Box<dyn Backend>> {
        vec![
            Box::new(RduBackend::disaggregated("rdu/pool0", 4, RduApi::CppOptimized)),
            Box::new(RduBackend::disaggregated("rdu/pool1", 2, RduApi::Python)),
        ]
    }

    #[test]
    fn synchronized_run_completes_everything() {
        // horizon strictly between the 4th and 5th burst so float
        // rounding of k * period cannot flip the burst count
        let cfg = EventSimConfig { ranks: 8, horizon_s: 0.065, ..Default::default() };
        let mut sim = EventSim::new(gpu_fleet(4), Policy::LeastOutstanding, cfg);
        sim.run_to_completion();
        // 4 bursts (t = 0, 0.02, 0.04, 0.06) x 8 ranks x 6 requests
        assert_eq!(sim.submitted(), 4 * 8 * 6);
        assert_eq!(sim.completed(), sim.submitted());
        assert_eq!(sim.in_flight(), 0);
        assert_eq!(sim.batcher_pending(), 0);
        assert_eq!(sim.records().len() as u64, sim.submitted());
    }

    #[test]
    fn batching_off_is_one_request_per_batch() {
        let cfg = EventSimConfig { horizon_s: 0.04, ..Default::default() };
        let mut sim = EventSim::new(pool(), Policy::LatencyAware, cfg);
        sim.run_to_completion();
        assert_eq!(sim.batches(), sim.submitted());
        assert!(sim.records().iter().all(|r| r.batch_samples == r.samples));
    }

    #[test]
    fn batching_window_coalesces_bursts() {
        let cfg = EventSimConfig {
            ranks: 16,
            horizon_s: 0.04,
            batching: Batching::Window { window_s: 200e-6, max_batch: 256 },
            ..Default::default()
        };
        let mut sim = EventSim::new(pool(), Policy::LatencyAware, cfg);
        sim.run_to_completion();
        assert_eq!(sim.completed(), sim.submitted());
        // 16 ranks x 6 requests per burst over 8 materials must
        // coalesce well below one-batch-per-request
        assert!(
            sim.batches() * 4 <= sim.submitted(),
            "{} batches for {} requests",
            sim.batches(),
            sim.submitted()
        );
        // batch membership recorded
        assert!(sim.records().iter().any(|r| r.batch_samples > r.samples));
    }

    #[test]
    fn mir_requests_ride_their_tier() {
        let cfg = EventSimConfig {
            ranks: 2,
            mir_every: 1,
            mir_samples: 128,
            horizon_s: 0.04,
            ..Default::default()
        };
        let mut fleet = gpu_fleet(2);
        fleet.extend(pool());
        // MIR pinned to the GPUs (0, 1), Hermit to the pool (2, 3)
        let mut sim =
            EventSim::with_tiers(fleet, Policy::LatencyAware, cfg, vec![2, 3], vec![0, 1]);
        sim.run_to_completion();
        for r in sim.records() {
            if r.model.starts_with("mir") {
                assert!(r.backend < 2, "mir routed to {}", r.backend);
            } else {
                assert!(r.backend >= 2, "hermit routed to {}", r.backend);
            }
        }
        assert!(sim.records().iter().any(|r| r.model == "mir"));
    }

    #[test]
    fn closed_loop_keeps_one_in_flight_per_rank() {
        let cfg = EventSimConfig {
            ranks: 3,
            arrival: ArrivalProcess::ClosedLoop { think_s: 1e-3 },
            horizon_s: 0.05,
            ..Default::default()
        };
        let mut sim = EventSim::new(gpu_fleet(1), Policy::RoundRobin, cfg);
        sim.run_to_completion();
        assert!(sim.submitted() > 0);
        assert_eq!(sim.completed(), sim.submitted());
        // a rank never has two requests overlapping in flight
        let records = sim.records();
        for rank in 0..3 {
            let mut recs: Vec<&RequestRecord> =
                records.iter().filter(|r| r.rank == rank).collect();
            recs.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
            for pair in recs.windows(2) {
                assert!(
                    pair[1].arrival_s >= pair[0].complete_s - 1e-12,
                    "rank {rank} overlapped"
                );
            }
        }
    }

    #[test]
    fn poisson_generates_within_horizon() {
        let cfg = EventSimConfig {
            ranks: 4,
            arrival: ArrivalProcess::Poisson { rate_per_rank: 2000.0 },
            horizon_s: 0.05,
            ..Default::default()
        };
        let mut sim = EventSim::new(gpu_fleet(2), Policy::LeastOutstanding, cfg);
        sim.run_to_completion();
        // ~ 4 ranks x 2000/s x 0.05s = 400 expected
        assert!(sim.submitted() > 200, "{}", sim.submitted());
        assert!(sim.records().iter().all(|r| r.arrival_s <= 0.05));
        assert_eq!(sim.completed(), sim.submitted());
    }

    #[test]
    fn summary_accounts_everything() {
        let cfg = EventSimConfig { ranks: 4, horizon_s: 0.04, ..Default::default() };
        let mut sim = EventSim::new(pool(), Policy::LatencyAware, cfg);
        sim.run_to_completion();
        let s = sim.summary();
        assert_eq!(s.requests, sim.submitted());
        assert_eq!(s.batches, sim.batches());
        assert!(s.latency.p50_s > 0.0);
        assert!(s.latency.p999_s >= s.latency.p99_s);
        assert!(s.latency.p99_s >= s.latency.p50_s);
        assert!(s.makespan_s > 0.0);
        assert!(s.slowdown_max >= 1.0);
        assert_eq!(s.per_rank_mean_s.len(), 4);
        let hist_total: u64 =
            s.latency.histogram.iter().map(|(_, c)| c).sum::<u64>() + s.latency.overflow;
        assert_eq!(hist_total, s.requests);
        // lazy bulk arrivals: a jitter-free burst is one event, but
        // every completion still costs one — so events track
        // completions, not submissions
        assert!(sim.events_processed() > 0);
        assert!(sim.events_processed() >= sim.batches(), "every batch completes via an event");
    }

    #[test]
    fn heap_and_ladder_queues_produce_identical_runs() {
        // The queue backing is a pure complexity trade: same pushes,
        // same pop order, byte-identical records and summaries.
        let cfg = EventSimConfig {
            ranks: 8,
            mir_every: 2,
            horizon_s: 0.065,
            batching: Batching::Window { window_s: 200e-6, max_batch: 256 },
            ..Default::default()
        };
        let mut lad = EventSim::new(pool(), Policy::LeastOutstanding, cfg);
        let mut heap = EventSim::new(pool(), Policy::LeastOutstanding, cfg);
        heap.use_binary_heap_queue();
        lad.run_to_completion();
        heap.run_to_completion();
        assert_eq!(lad.records(), heap.records());
        assert_eq!(lad.summary(), heap.summary());
        assert_eq!(lad.events_processed(), heap.events_processed());
    }

    // ------------------------------------------------- fabric layer

    fn pool_fabric(ranks: usize, oversub: f64) -> crate::fabric::FabricSpec {
        crate::fabric::FabricSpec {
            topology: crate::fabric::Topology::pooled(ranks, 2, oversub),
            accel_of_backend: vec![0, 1],
        }
    }

    #[test]
    fn fabric_run_completes_everything_and_measures_contention() {
        let cfg = EventSimConfig { ranks: 16, horizon_s: 0.045, ..Default::default() };
        let mut sim = EventSim::with_fabric(
            pool(),
            Policy::LeastOutstanding,
            cfg,
            vec![0, 1],
            vec![0, 1],
            pool_fabric(16, 4.0),
        );
        sim.run_to_completion();
        assert_eq!(sim.completed(), sim.submitted());
        assert_eq!(sim.in_flight(), 0);
        assert_eq!(sim.records().len() as u64, sim.submitted());
        // every record's completion was filled and transfers were paid
        for r in sim.records() {
            assert!(r.complete_s.is_finite() && r.complete_s >= r.dispatch_s);
            assert!(r.link_overhead_s > 0.0, "remote batch must ride the fabric");
            assert!(r.contention_s >= 0.0);
            assert!(r.contention_s <= r.link_overhead_s + 1e-15);
        }
        // a synchronized 16-rank burst on a 4:1 fabric must contend
        let s = sim.summary();
        assert!(s.mean_contention_s > 0.0, "bursts on 4:1 must queue on the wire");
        assert!(s.mean_link_overhead_s > s.mean_contention_s);
    }

    #[test]
    fn fabric_oversubscription_slows_the_tail() {
        let run = |oversub: f64| {
            let cfg = EventSimConfig { ranks: 32, horizon_s: 0.045, ..Default::default() };
            let mut sim = EventSim::with_fabric(
                pool(),
                Policy::LeastOutstanding,
                cfg,
                vec![0, 1],
                vec![0, 1],
                pool_fabric(32, oversub),
            );
            sim.run_to_completion();
            sim.summary()
        };
        let mut last = 0.0;
        for oversub in [1.0, 2.0, 4.0, 8.0] {
            let s = run(oversub);
            assert!(
                s.mean_link_overhead_s >= last - 1e-12,
                "oversub {oversub}: mean link {} < previous {last}",
                s.mean_link_overhead_s
            );
            last = s.mean_link_overhead_s;
        }
    }
}
