//! The deterministic event queue, keyed by `(time, class, seq)`.
//! Virtual time is `f64` seconds ordered by `total_cmp`; the event
//! *class* defines the semantics of simultaneity (at one instant:
//! completions land, then arrivals enter, then batching windows
//! close); the insertion sequence number breaks the remaining ties,
//! so two runs that push the same events in the same order always pop
//! them in the same order — the foundation of the engine's
//! byte-stable summaries.
//!
//! The class tier exists for one reason: a batch-close deadline and a
//! request arrival can legitimately share a timestamp (a timestep
//! period that is a multiple of the batching window lines them up
//! exactly).  Ordering them by insertion accident would make the
//! dispatched batch membership depend on *when* the wake-up happened
//! to be scheduled; ordering arrivals before deadlines pins the
//! semantics — a request arriving the instant a window expires rides
//! the closing batch (`rust/tests/eventsim_props.rs`).
//!
//! # Backing stores
//!
//! Two interchangeable backings produce the *identical* pop order:
//!
//! * **Ladder** (the default): a two-tier structure — an unsorted
//!   spill (`top`) plus a sorted run (`bottom`) served from its back.
//!   Pushes to the future are an O(1) append; pops are an O(1)
//!   `Vec::pop`; sorting happens band-by-band only when the run
//!   drains, so the amortized cost per event is O(1) for the
//!   time-advancing streams a simulation produces, instead of the
//!   heap's O(log n) sift per operation with n = every event queued
//!   at a barrier.
//! * **BinaryHeap** (via [`EventQueue::binary_heap`]): the reference
//!   implementation, kept for differential testing
//!   (`rust/tests/equeue_props.rs`) and A/B benchmarking.
//!
//! Because [`EventKey`]s are *strictly* totally ordered (`seq` is
//! unique), "same pop order" is not a tie-break convention but an
//! exact property: any backing that returns keys in ascending key
//! order is byte-equivalent.  The ladder guarantees it through one
//! invariant — every key in `bottom` orders before every key in
//! `top` — maintained by routing on time alone with the boundary
//! *inclusive* on the bottom side (`time <= bottom_max_t`): two keys
//! can only disagree with their time ordering (via class/seq) when
//! their times are equal, and equal times always land in the same
//! tier, where full-key sorting settles them.

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::mem;

/// Same-instant tier: completions first (capacity frees before new
/// work observes it).
pub const CLASS_COMPLETION: u8 = 0;
/// Same-instant tier: arrivals and generator ticks (the default).
pub const CLASS_ARRIVAL: u8 = 1;
/// Same-instant tier: batch-close deadlines fire only after every
/// same-instant arrival has had the chance to join the batch.
pub const CLASS_DEADLINE: u8 = 2;

/// Queue key: event time, then same-instant class, then insertion
/// order.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EventKey {
    pub time_s: f64,
    pub class: u8,
    pub seq: u64,
}

impl Eq for EventKey {}

impl Ord for EventKey {
    fn cmp(&self, other: &Self) -> Ordering {
        self.time_s
            .total_cmp(&other.time_s)
            .then_with(|| self.class.cmp(&other.class))
            .then_with(|| self.seq.cmp(&other.seq))
    }
}

impl PartialOrd for EventKey {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// One queued event; ordered by key only (the payload need not be
/// comparable).
struct Entry<E> {
    key: EventKey,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key
    }
}

impl<E> Eq for Entry<E> {}

impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert so the earliest key pops
        // first.
        other.key.cmp(&self.key)
    }
}

impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Target size of one sorted bottom band.  Refill carves the earliest
/// time band of roughly this many entries out of the spill; bands
/// that cannot be narrowed by time (a same-instant barrier burst) are
/// sorted wholesale — correctness never depends on the estimate.
const SORT_CHUNK: usize = 32;

/// How many drained scratch buffers to keep for reuse: refill
/// alternates between at most two live partitions, so a small pool
/// makes steady-state refills allocation-free.
const SPARE_BUFFERS: usize = 4;

/// The default backing: a two-tier ladder.
///
/// Invariant (checked in debug refills): every key in `bottom` orders
/// strictly before every key in `top`, because `bottom` holds only
/// times `<= bottom_max_t` and `top` only times `> bottom_max_t`.
/// `bottom` is sorted *descending* by full key so the next event is a
/// `Vec::pop` from the back, and a same-instant push (the common
/// in-band case: an effect scheduled at the current instant) inserts
/// near the back with a short memmove.
struct Ladder<E> {
    /// Sorted run, descending by key; pop serves from the back.
    bottom: Vec<Entry<E>>,
    /// Unsorted spill of strictly-later events.
    top: Vec<Entry<E>>,
    /// Inclusive upper time bound of the bottom tier.  Only refill
    /// moves it (monotonically forward): it must not shrink while
    /// `bottom` is non-empty, or an equal-time push could land in
    /// `top` and pop after a later-class equal-time entry in
    /// `bottom`.
    bottom_max_t: f64,
    /// Minimum time in `top` (`+inf` when empty); lets `peek_time`
    /// answer without sorting.
    top_min_t: f64,
    /// Entry free-list: drained partition buffers, kept so refills
    /// reuse capacity across timesteps instead of reallocating.
    spare: Vec<Vec<Entry<E>>>,
}

impl<E> Ladder<E> {
    fn new() -> Self {
        Ladder {
            bottom: Vec::new(),
            top: Vec::new(),
            bottom_max_t: f64::NEG_INFINITY,
            top_min_t: f64::INFINITY,
            spare: Vec::new(),
        }
    }

    fn len(&self) -> usize {
        self.bottom.len() + self.top.len()
    }

    fn push(&mut self, key: EventKey, event: E) {
        if key.time_s <= self.bottom_max_t {
            // In-band: keep the sorted run sorted by full key.
            let idx = self.bottom.partition_point(|e| e.key > key);
            self.bottom.insert(idx, Entry { key, event });
        } else {
            self.top_min_t = self.top_min_t.min(key.time_s);
            self.top.push(Entry { key, event });
        }
    }

    fn pop(&mut self) -> Option<(f64, E)> {
        if self.bottom.is_empty() {
            if self.top.is_empty() {
                return None;
            }
            self.refill();
        }
        self.bottom.pop().map(|e| (e.key.time_s, e.event))
    }

    fn peek_time(&self) -> Option<f64> {
        if let Some(e) = self.bottom.last() {
            return Some(e.key.time_s);
        }
        if !self.top.is_empty() {
            return Some(self.top_min_t);
        }
        None
    }

    fn grab(&mut self) -> Vec<Entry<E>> {
        self.spare.pop().unwrap_or_default()
    }

    fn stash(&mut self, v: Vec<Entry<E>>) {
        debug_assert!(v.is_empty());
        if self.spare.len() < SPARE_BUFFERS {
            self.spare.push(v);
        }
    }

    /// Carve the earliest time band out of `top`, sort it by full
    /// key, and serve it from `bottom`.  Splits are by *time only*;
    /// a band that cannot be narrowed (all one instant — a barrier
    /// burst) is sorted wholesale, so class/seq ordering within an
    /// instant is always settled by the sort, never by a split.
    fn refill(&mut self) {
        debug_assert!(self.bottom.is_empty() && !self.top.is_empty());
        let fresh = self.grab();
        let mut chunk = mem::replace(&mut self.top, fresh);
        self.top_min_t = f64::INFINITY;
        while chunk.len() > SORT_CHUNK {
            let mut min_t = f64::INFINITY;
            let mut max_t = f64::NEG_INFINITY;
            for e in &chunk {
                min_t = min_t.min(e.key.time_s);
                max_t = max_t.max(e.key.time_s);
            }
            if min_t == max_t {
                // One instant: time cannot split it; sort it whole.
                break;
            }
            // Aim the band at ~SORT_CHUNK entries assuming a roughly
            // uniform spread.  If the span is so narrow the division
            // rounds back onto min_t, keep the earliest instant only
            // — progress is guaranteed either way because max_t
            // always lands above the split.
            let bands = (chunk.len() / SORT_CHUNK).max(2) as f64;
            let split = min_t + (max_t - min_t) / bands;
            let instant_only = !(split > min_t);
            let mut below = self.grab();
            for e in chunk.drain(..) {
                let t = e.key.time_s;
                let in_band = if instant_only { t == min_t } else { t < split };
                if in_band {
                    below.push(e);
                } else {
                    self.top_min_t = self.top_min_t.min(t);
                    self.top.push(e);
                }
            }
            self.stash(chunk);
            chunk = below;
        }
        chunk.sort_unstable_by(|a, b| b.key.cmp(&a.key));
        if let Some(first) = chunk.first() {
            // Descending order: the first entry carries the band's
            // latest time.  Monotone: every carved time exceeds the
            // previous bound, so the boundary only moves forward.
            self.bottom_max_t = first.key.time_s;
        }
        self.bottom.append(&mut chunk);
        self.stash(chunk);
    }
}

enum Backing<E> {
    Heap(BinaryHeap<Entry<E>>),
    Ladder(Ladder<E>),
}

/// A min-queue of timestamped events with deterministic tie-breaking.
pub struct EventQueue<E> {
    backing: Backing<E>,
    seq: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    /// The default ladder backing (O(1) amortized push/pop).
    pub fn new() -> Self {
        EventQueue { backing: Backing::Ladder(Ladder::new()), seq: 0 }
    }

    /// The reference `BinaryHeap` backing, kept for differential
    /// testing and A/B benchmarking against the ladder.
    pub fn binary_heap() -> Self {
        EventQueue { backing: Backing::Heap(BinaryHeap::new()), seq: 0 }
    }

    /// Whether this queue runs on the reference heap backing.
    pub fn is_binary_heap(&self) -> bool {
        matches!(self.backing, Backing::Heap(_))
    }

    /// Swap a ladder-backed queue onto the reference heap, preserving
    /// every queued entry's key — the pop order (and therefore every
    /// engine output) is unchanged.  No-op on a heap-backed queue.
    pub fn convert_to_binary_heap(&mut self) {
        if self.is_binary_heap() {
            return;
        }
        let old = mem::replace(&mut self.backing, Backing::Heap(BinaryHeap::new()));
        if let Backing::Ladder(mut l) = old {
            let mut heap = BinaryHeap::with_capacity(l.len());
            for e in l.bottom.drain(..) {
                heap.push(e);
            }
            for e in l.top.drain(..) {
                heap.push(e);
            }
            self.backing = Backing::Heap(heap);
        }
    }

    /// Schedule `event` at `time_s` (must be finite and >= 0) in the
    /// default arrival tier.
    pub fn push(&mut self, time_s: f64, event: E) {
        self.push_class(time_s, CLASS_ARRIVAL, event);
    }

    /// Schedule `event` at `time_s` with an explicit same-instant
    /// class ([`CLASS_COMPLETION`] < [`CLASS_ARRIVAL`] <
    /// [`CLASS_DEADLINE`]).
    pub fn push_class(&mut self, time_s: f64, class: u8, event: E) {
        assert!(time_s.is_finite() && time_s >= 0.0, "bad event time {time_s}");
        let key = EventKey { time_s, class, seq: self.seq };
        self.seq += 1;
        match &mut self.backing {
            Backing::Heap(h) => h.push(Entry { key, event }),
            Backing::Ladder(l) => l.push(key, event),
        }
    }

    /// Pop the earliest event (ties by class, then insertion order).
    pub fn pop(&mut self) -> Option<(f64, E)> {
        match &mut self.backing {
            Backing::Heap(h) => h.pop().map(|e| (e.key.time_s, e.event)),
            Backing::Ladder(l) => l.pop(),
        }
    }

    /// Time of the next event without popping it.
    pub fn peek_time(&self) -> Option<f64> {
        match &self.backing {
            Backing::Heap(h) => h.peek().map(|e| e.key.time_s),
            Backing::Ladder(l) => l.peek_time(),
        }
    }

    /// Pre-size the queue for `additional` more events (a timestep's
    /// worth), so barrier-scale pushes never reallocate mid-burst.
    pub fn reserve(&mut self, additional: usize) {
        match &mut self.backing {
            Backing::Heap(h) => h.reserve(additional),
            Backing::Ladder(l) => l.top.reserve(additional),
        }
    }

    /// Total entry capacity across all internal buffers, including
    /// the refill free-list.  Exposed so tests can pin capacity reuse
    /// across drain/refill cycles.
    pub fn capacity(&self) -> usize {
        match &self.backing {
            Backing::Heap(h) => h.capacity(),
            Backing::Ladder(l) => {
                l.bottom.capacity()
                    + l.top.capacity()
                    + l.spare.iter().map(|v| v.capacity()).sum::<usize>()
            }
        }
    }

    pub fn len(&self) -> usize {
        match &self.backing {
            Backing::Heap(h) => h.len(),
            Backing::Ladder(l) => l.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Run `case` against both backings — every ordering property
    /// must hold identically on the ladder and the reference heap.
    fn both(case: impl Fn(EventQueue<usize>)) {
        case(EventQueue::new());
        case(EventQueue::binary_heap());
    }

    #[test]
    fn pops_in_time_order() {
        for mut q in [EventQueue::new(), EventQueue::binary_heap()] {
            q.push(3.0, "c");
            q.push(1.0, "a");
            q.push(2.0, "b");
            assert_eq!(q.peek_time(), Some(1.0));
            assert_eq!(q.pop(), Some((1.0, "a")));
            assert_eq!(q.pop(), Some((2.0, "b")));
            assert_eq!(q.pop(), Some((3.0, "c")));
            assert_eq!(q.pop(), None);
        }
    }

    #[test]
    fn ties_break_in_insertion_order() {
        both(|mut q| {
            for i in 0..16 {
                q.push(0.5, i);
            }
            let popped: Vec<usize> =
                std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
            assert_eq!(popped, (0..16).collect::<Vec<_>>());
        });
    }

    #[test]
    fn interleaved_push_pop_stays_sorted() {
        both(|mut q| {
            q.push(5.0, 5);
            q.push(1.0, 1);
            assert_eq!(q.pop(), Some((1.0, 1)));
            q.push(3.0, 3);
            q.push(2.0, 2);
            assert_eq!(q.pop(), Some((2.0, 2)));
            assert_eq!(q.pop(), Some((3.0, 3)));
            assert_eq!(q.pop(), Some((5.0, 5)));
            assert!(q.is_empty());
        });
    }

    #[test]
    #[should_panic(expected = "bad event time")]
    fn rejects_nan_times() {
        let mut q = EventQueue::new();
        q.push(f64::NAN, ());
    }

    #[test]
    fn classes_order_same_instant_events() {
        // Adversarial insertion order: deadline first, then arrival,
        // then completion, all at t = 1.0 — they must pop by class
        // (completion, arrival, deadline), not by insertion.
        for mut q in [EventQueue::new(), EventQueue::binary_heap()] {
            q.push_class(1.0, CLASS_DEADLINE, "deadline");
            q.push_class(1.0, CLASS_ARRIVAL, "arrival");
            q.push_class(1.0, CLASS_COMPLETION, "completion");
            q.push(0.5, "early");
            let popped: Vec<&str> =
                std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
            assert_eq!(popped, vec!["early", "completion", "arrival", "deadline"]);
        }
    }

    #[test]
    fn classes_tie_break_by_seq_within_a_class() {
        both(|mut q| {
            for i in 0..8 {
                q.push_class(2.0, CLASS_DEADLINE, i);
            }
            for i in 8..16 {
                q.push_class(2.0, CLASS_ARRIVAL, i);
            }
            let popped: Vec<usize> =
                std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
            // arrivals (8..16) before deadlines (0..8), each in
            // insertion order
            assert_eq!(popped, (8..16).chain(0..8).collect::<Vec<_>>());
        });
    }

    #[test]
    fn equal_time_lower_class_push_lands_in_the_sorted_band() {
        // The routing hazard the inclusive boundary exists for: pop
        // once so a sorted band exists, then push a *completion* at a
        // time already present in the band — it must pop before the
        // band's same-instant arrivals despite its larger seq.
        both(|mut q| {
            for i in 0..8 {
                q.push_class(1.0, CLASS_ARRIVAL, i);
            }
            q.push_class(2.0, CLASS_ARRIVAL, 100);
            assert_eq!(q.pop(), Some((1.0, 0)));
            q.push_class(1.0, CLASS_COMPLETION, 99);
            assert_eq!(q.pop(), Some((1.0, 99)));
            let rest: Vec<usize> =
                std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
            assert_eq!(rest, vec![1, 2, 3, 4, 5, 6, 7, 100]);
        });
    }

    #[test]
    fn ladder_matches_heap_on_a_seeded_adversarial_stream() {
        // Same push sequence into both backings; interleave pops so
        // refills happen mid-stream.  Times are ns-quantised to force
        // heavy tie traffic across all three classes.
        let mut lad = EventQueue::new();
        let mut heap = EventQueue::binary_heap();
        let mut state: u64 = 0x9E3779B97F4A7C15;
        let mut next = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            state >> 33
        };
        let mut pushed = 0usize;
        for round in 0..64 {
            for _ in 0..(1 + (next() as usize % 48)) {
                let t = (next() % 1_000) as f64 * 1e-9 + round as f64 * 1e-7;
                let class = (next() % 3) as u8;
                lad.push_class(t, class, pushed);
                heap.push_class(t, class, pushed);
                pushed += 1;
            }
            for _ in 0..(next() as usize % 24) {
                assert_eq!(lad.peek_time(), heap.peek_time());
                assert_eq!(lad.pop(), heap.pop());
            }
        }
        loop {
            let (a, b) = (lad.pop(), heap.pop());
            assert_eq!(a, b);
            if a.is_none() {
                break;
            }
        }
    }

    #[test]
    fn reserve_prevents_growth_and_capacity_is_reused_across_refills() {
        let mut q: EventQueue<usize> = EventQueue::new();
        q.reserve(512);
        let cap0 = q.capacity();
        assert!(cap0 >= 512);
        for i in 0..512 {
            q.push(i as f64 * 1e-6, i);
        }
        assert_eq!(q.capacity(), cap0, "reserved capacity must absorb the fill");
        while q.pop().is_some() {}
        let cap1 = q.capacity();
        // Second cycle: the drained buffers (including the refill
        // free-list) are reused, so an identical fill/drain cycle
        // allocates nothing new.
        for i in 0..512 {
            q.push(i as f64 * 1e-6, i);
        }
        while q.pop().is_some() {}
        assert_eq!(q.capacity(), cap1, "drain-then-refill must reuse capacity");
    }

    #[test]
    fn drain_then_refill_keeps_exact_order() {
        // Drain to empty, then refill with earlier times than the
        // retired band: the ladder must still serve exact order (the
        // in-band sorted insert path).
        both(|mut q| {
            for i in 0..64 {
                q.push(1.0 + i as f64, i);
            }
            let first: Vec<usize> =
                std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
            assert_eq!(first, (0..64).collect::<Vec<_>>());
            for i in 0..64 {
                q.push(64.0 - i as f64, i);
            }
            let second: Vec<usize> =
                std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
            assert_eq!(second, (0..64).rev().collect::<Vec<_>>());
        });
    }

    #[test]
    fn convert_to_binary_heap_preserves_queued_keys() {
        let mut q = EventQueue::new();
        for i in 0..40 {
            q.push_class(((i * 7) % 10) as f64, (i % 3) as u8, i);
        }
        // Pop a few so a sorted band exists, then convert mid-life.
        let mut popped = vec![q.pop().unwrap(), q.pop().unwrap()];
        q.convert_to_binary_heap();
        assert!(q.is_binary_heap());
        while let Some(e) = q.pop() {
            popped.push(e);
        }
        let mut reference = EventQueue::binary_heap();
        for i in 0..40 {
            reference.push_class(((i * 7) % 10) as f64, (i % 3) as u8, i);
        }
        let expect: Vec<(f64, usize)> =
            std::iter::from_fn(|| reference.pop()).collect();
        assert_eq!(popped, expect);
    }
}
