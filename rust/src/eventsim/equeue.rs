//! The deterministic event queue: a binary heap of `(time, seq)`
//! keys.  Virtual time is `f64` seconds ordered by `total_cmp`; the
//! insertion sequence number breaks ties, so two runs that push the
//! same events in the same order always pop them in the same order —
//! the foundation of the engine's byte-stable summaries.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Heap key: event time, then insertion order.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EventKey {
    pub time_s: f64,
    pub seq: u64,
}

impl Eq for EventKey {}

impl Ord for EventKey {
    fn cmp(&self, other: &Self) -> Ordering {
        self.time_s
            .total_cmp(&other.time_s)
            .then_with(|| self.seq.cmp(&other.seq))
    }
}

impl PartialOrd for EventKey {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// One queued event; ordered by key only (the payload need not be
/// comparable).
struct Entry<E> {
    key: EventKey,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key
    }
}

impl<E> Eq for Entry<E> {}

impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert so the earliest key pops
        // first.
        other.key.cmp(&self.key)
    }
}

impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// A min-heap of timestamped events with deterministic tie-breaking.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0 }
    }

    /// Schedule `event` at `time_s` (must be finite and >= 0).
    pub fn push(&mut self, time_s: f64, event: E) {
        assert!(time_s.is_finite() && time_s >= 0.0, "bad event time {time_s}");
        let key = EventKey { time_s, seq: self.seq };
        self.seq += 1;
        self.heap.push(Entry { key, event });
    }

    /// Pop the earliest event (ties in insertion order).
    pub fn pop(&mut self) -> Option<(f64, E)> {
        self.heap.pop().map(|e| (e.key.time_s, e.event))
    }

    /// Time of the next event without popping it.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.key.time_s)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        assert_eq!(q.peek_time(), Some(1.0));
        assert_eq!(q.pop(), Some((1.0, "a")));
        assert_eq!(q.pop(), Some((2.0, "b")));
        assert_eq!(q.pop(), Some((3.0, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn ties_break_in_insertion_order() {
        let mut q = EventQueue::new();
        for i in 0..16 {
            q.push(0.5, i);
        }
        let popped: Vec<usize> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(popped, (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn interleaved_push_pop_stays_sorted() {
        let mut q = EventQueue::new();
        q.push(5.0, 5);
        q.push(1.0, 1);
        assert_eq!(q.pop(), Some((1.0, 1)));
        q.push(3.0, 3);
        q.push(2.0, 2);
        assert_eq!(q.pop(), Some((2.0, 2)));
        assert_eq!(q.pop(), Some((3.0, 3)));
        assert_eq!(q.pop(), Some((5.0, 5)));
        assert!(q.is_empty());
    }

    #[test]
    #[should_panic(expected = "bad event time")]
    fn rejects_nan_times() {
        let mut q = EventQueue::new();
        q.push(f64::NAN, ());
    }
}
