//! The deterministic event queue: a binary heap of `(time, class,
//! seq)` keys.  Virtual time is `f64` seconds ordered by `total_cmp`;
//! the event *class* defines the semantics of simultaneity (at one
//! instant: completions land, then arrivals enter, then batching
//! windows close); the insertion sequence number breaks the remaining
//! ties, so two runs that push the same events in the same order
//! always pop them in the same order — the foundation of the engine's
//! byte-stable summaries.
//!
//! The class tier exists for one reason: a batch-close deadline and a
//! request arrival can legitimately share a timestamp (a timestep
//! period that is a multiple of the batching window lines them up
//! exactly).  Ordering them by insertion accident would make the
//! dispatched batch membership depend on *when* the wake-up happened
//! to be scheduled; ordering arrivals before deadlines pins the
//! semantics — a request arriving the instant a window expires rides
//! the closing batch (`rust/tests/eventsim_props.rs`).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Same-instant tier: completions first (capacity frees before new
/// work observes it).
pub const CLASS_COMPLETION: u8 = 0;
/// Same-instant tier: arrivals and generator ticks (the default).
pub const CLASS_ARRIVAL: u8 = 1;
/// Same-instant tier: batch-close deadlines fire only after every
/// same-instant arrival has had the chance to join the batch.
pub const CLASS_DEADLINE: u8 = 2;

/// Heap key: event time, then same-instant class, then insertion
/// order.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EventKey {
    pub time_s: f64,
    pub class: u8,
    pub seq: u64,
}

impl Eq for EventKey {}

impl Ord for EventKey {
    fn cmp(&self, other: &Self) -> Ordering {
        self.time_s
            .total_cmp(&other.time_s)
            .then_with(|| self.class.cmp(&other.class))
            .then_with(|| self.seq.cmp(&other.seq))
    }
}

impl PartialOrd for EventKey {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// One queued event; ordered by key only (the payload need not be
/// comparable).
struct Entry<E> {
    key: EventKey,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key
    }
}

impl<E> Eq for Entry<E> {}

impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert so the earliest key pops
        // first.
        other.key.cmp(&self.key)
    }
}

impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// A min-heap of timestamped events with deterministic tie-breaking.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0 }
    }

    /// Schedule `event` at `time_s` (must be finite and >= 0) in the
    /// default arrival tier.
    pub fn push(&mut self, time_s: f64, event: E) {
        self.push_class(time_s, CLASS_ARRIVAL, event);
    }

    /// Schedule `event` at `time_s` with an explicit same-instant
    /// class ([`CLASS_COMPLETION`] < [`CLASS_ARRIVAL`] <
    /// [`CLASS_DEADLINE`]).
    pub fn push_class(&mut self, time_s: f64, class: u8, event: E) {
        assert!(time_s.is_finite() && time_s >= 0.0, "bad event time {time_s}");
        let key = EventKey { time_s, class, seq: self.seq };
        self.seq += 1;
        self.heap.push(Entry { key, event });
    }

    /// Pop the earliest event (ties in insertion order).
    pub fn pop(&mut self) -> Option<(f64, E)> {
        self.heap.pop().map(|e| (e.key.time_s, e.event))
    }

    /// Time of the next event without popping it.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.key.time_s)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        assert_eq!(q.peek_time(), Some(1.0));
        assert_eq!(q.pop(), Some((1.0, "a")));
        assert_eq!(q.pop(), Some((2.0, "b")));
        assert_eq!(q.pop(), Some((3.0, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn ties_break_in_insertion_order() {
        let mut q = EventQueue::new();
        for i in 0..16 {
            q.push(0.5, i);
        }
        let popped: Vec<usize> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(popped, (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn interleaved_push_pop_stays_sorted() {
        let mut q = EventQueue::new();
        q.push(5.0, 5);
        q.push(1.0, 1);
        assert_eq!(q.pop(), Some((1.0, 1)));
        q.push(3.0, 3);
        q.push(2.0, 2);
        assert_eq!(q.pop(), Some((2.0, 2)));
        assert_eq!(q.pop(), Some((3.0, 3)));
        assert_eq!(q.pop(), Some((5.0, 5)));
        assert!(q.is_empty());
    }

    #[test]
    #[should_panic(expected = "bad event time")]
    fn rejects_nan_times() {
        let mut q = EventQueue::new();
        q.push(f64::NAN, ());
    }

    #[test]
    fn classes_order_same_instant_events() {
        // Adversarial insertion order: deadline first, then arrival,
        // then completion, all at t = 1.0 — they must pop by class
        // (completion, arrival, deadline), not by insertion.
        let mut q = EventQueue::new();
        q.push_class(1.0, CLASS_DEADLINE, "deadline");
        q.push_class(1.0, CLASS_ARRIVAL, "arrival");
        q.push_class(1.0, CLASS_COMPLETION, "completion");
        q.push(0.5, "early");
        let popped: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(popped, vec!["early", "completion", "arrival", "deadline"]);
    }

    #[test]
    fn classes_tie_break_by_seq_within_a_class() {
        let mut q = EventQueue::new();
        for i in 0..8 {
            q.push_class(2.0, CLASS_DEADLINE, i);
        }
        for i in 8..16 {
            q.push_class(2.0, CLASS_ARRIVAL, i);
        }
        let popped: Vec<usize> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        // arrivals (8..16) before deadlines (0..8), each in insertion
        // order
        assert_eq!(popped, (8..16).chain(0..8).collect::<Vec<_>>());
    }
}
