//! The coupled CogSim application model: inference **inside** the
//! timestep loop.
//!
//! The open-/closed-loop arrival processes of [`super::EventSim`]
//! drive request streams that are decoupled from simulation progress,
//! so they can report latency distributions but not the paper's real
//! figure of merit — **time-to-solution** (§IV: "the time spent
//! performing inference … directly impacts total simulation time").
//! This module closes the loop:
//!
//! * **N ranks** run **T bulk-synchronous timesteps**.  Every step,
//!   each rank performs `compute_s` of physics, emits `K`
//!   per-material inference requests (each tagged with one of `M`
//!   target models drawn from the rank's mix, plus an optional MIR
//!   mixed-zone request every `mir_every`-th step), and may only
//!   advance once **all** of them complete.  A barrier holds the next
//!   step until the slowest rank is done — one straggling rank stalls
//!   the whole machine, the paper's in-the-loop SLO.
//! * **Overlap**: `overlap ∈ [0, 1]` is the fraction of the physics
//!   compute the rank can keep doing *while* its inference requests
//!   are in flight (requests are emitted `(1-overlap)·compute_s` into
//!   the step; the rank finishes at
//!   `max(compute done, last completion)`).  `overlap = 0` is the
//!   fully serial coupling, `overlap = 1` hides inference entirely
//!   behind compute when the fleet keeps up.
//! * **Model residency**: each backend holds at most
//!   `residency_slots` models (LRU).  Dispatching a batch for a model
//!   the backend does not currently hold charges `swap_s` seconds to
//!   both the requester and the backend's queue — the cost of
//!   swapping weights onto a shared accelerator, and the regime where
//!   [`Policy::ModelAffinity`] routing finally earns its keep over
//!   state-blind policies.
//! * **Critical path**: every step records a
//!   [`StepBreakdown`] — compute / queue / swap / network / service
//!   along the straggler rank's longest chain, summing to the step
//!   duration — so `time_to_solution` decomposes into *where the time
//!   went* ([`CogSummary`]).
//!
//! Routing, queueing, link, and batching semantics are **identical**
//! to [`super::EventSim`] (same [`policy::select`], same
//! [`Backend`] occupancy accounting, same shared
//! [`super::BatchStage`]), so in the contention-free limit
//! (1 rank, 1 model, zero swap, zero overlap, batching off) each
//! timestep degrades to `compute_s` plus the analytic
//! [`crate::cluster::Cluster`] latency for the same K requests —
//! `rust/tests/cogsim_vs_analytic.rs` pins that to 1e-9.
//!
//! With [`CogSim::with_fabric`], remote dispatches instead ride the
//! contention-aware [`crate::fabric`] layer: request payloads, result
//! payloads, and residency-swap weight transfers become fabric flows
//! competing for shared leaf/spine bandwidth, and the per-step
//! breakdown gains a *contention* share (measured transfer time
//! beyond the uncontended round trip).  One flow alone on a 1:1
//! topology reproduces the legacy charge to 1e-9
//! (`rust/tests/fabric_props.rs`).

use std::collections::BTreeMap;

use crate::cluster::{policy, Backend, Policy};
use crate::devices::{profiles, ModelProfile};
use crate::fabric::FabricSpec;
use crate::netsim::dir_payload_bytes;
use crate::util::rng::Rng;
use crate::workload::HydraWorkload;

use super::equeue::{EventQueue, CLASS_ARRIVAL, CLASS_COMPLETION, CLASS_DEADLINE};
use super::metrics::{CogSummary, LatencyDist, StepBreakdown};
use super::{BatchStage, Batching, FabricLayer, FlowCont};

/// One coupled run's knobs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CogSimConfig {
    /// MPI ranks advancing in lockstep.
    pub ranks: usize,
    /// Bulk-synchronous timesteps to run.
    pub timesteps: usize,
    /// Physics compute per rank per timestep, seconds.
    pub compute_s: f64,
    /// Per-rank uniform compute jitter in `[0, jitter)` seconds
    /// (load imbalance; 0 = perfectly balanced ranks).
    pub compute_jitter_s: f64,
    /// In-the-loop inference requests per rank per timestep (K).
    pub requests_per_step: usize,
    /// Target models in the mix (M per-material Hermit instances);
    /// each request draws one uniformly.
    pub models: usize,
    /// Samples per request, uniform inclusive (paper: 2–3 per zone).
    pub samples_per_request: (usize, usize),
    /// Every `mir_every`-th step each rank also emits one MIR
    /// mixed-zone request (0 = never).
    pub mir_every: usize,
    /// Samples in each MIR request.
    pub mir_samples: usize,
    /// Fraction of compute overlappable with in-flight inference.
    pub overlap: f64,
    /// Seconds charged when a backend serves a model it doesn't hold.
    pub swap_s: f64,
    /// Models resident per backend (LRU eviction).
    pub residency_slots: usize,
    pub batching: Batching,
    pub seed: u64,
}

impl Default for CogSimConfig {
    fn default() -> Self {
        CogSimConfig {
            ranks: 4,
            timesteps: 8,
            compute_s: 2e-3,
            compute_jitter_s: 0.0,
            requests_per_step: 6,
            models: 8,
            samples_per_request: (2, 3),
            mir_every: 0,
            mir_samples: 512,
            overlap: 0.0,
            swap_s: 0.0,
            residency_slots: 4,
            batching: Batching::Off,
            seed: 42,
        }
    }
}

/// The full story of one completed in-the-loop request.
#[derive(Debug, Clone, PartialEq)]
pub struct CogRecord {
    pub id: u64,
    /// Timestep the request belongs to.
    pub step: usize,
    pub rank: usize,
    pub model: String,
    pub samples: usize,
    /// When the rank emitted the request.
    pub emit_s: f64,
    /// When the router dispatched the (possibly coalesced) batch.
    pub dispatch_s: f64,
    /// When the result returned to the rank.
    pub complete_s: f64,
    /// Backend index the batch was routed to.
    pub backend: usize,
    /// Total samples in the dispatched batch this request rode in.
    pub batch_samples: usize,
    /// Backend queue the batch waited behind, seconds.
    pub wait_s: f64,
    /// Residency-swap charge paid by the batch, seconds.
    pub swap_s: f64,
    /// Link round-trip share of the service, seconds.  With the
    /// fabric layer this is the *measured* transfer time.
    pub link_s: f64,
    /// Fabric-contention share of `link_s` (measured minus the
    /// uncontended round trip); zero without the fabric layer.
    pub contention_s: f64,
    /// Device execution share of the service, seconds.
    pub exec_s: f64,
}

impl CogRecord {
    /// End-to-end latency as the rank observes it.
    pub fn latency_s(&self) -> f64 {
        self.complete_s - self.emit_s
    }

    /// Time spent coalescing in the batching window.
    pub fn batch_wait_s(&self) -> f64 {
        self.dispatch_s - self.emit_s
    }
}

/// Per-backend LRU model residency (most recently used last).
#[derive(Debug, Clone, Default)]
struct Residency {
    slots: usize,
    held: Vec<String>,
}

impl Residency {
    fn new(slots: usize) -> Residency {
        Residency { slots, held: Vec::new() }
    }

    /// Record a dispatch of `model`; returns true on a residency
    /// miss (the swap is charged), false on a hit.
    fn touch(&mut self, model: &str) -> bool {
        if let Some(pos) = self.held.iter().position(|m| m == model) {
            let m = self.held.remove(pos);
            self.held.push(m);
            return false;
        }
        self.held.push(model.to_string());
        if self.held.len() > self.slots {
            self.held.remove(0);
        }
        true
    }
}

#[derive(Debug, Clone)]
struct PendingMeta {
    step: usize,
    rank: usize,
    model: String,
    samples: usize,
    emit_s: f64,
    /// Index into `records` once the batch carrying it dispatched.
    record: Option<usize>,
}

/// Per-rank progress through the current timestep.
#[derive(Debug, Clone)]
struct RankState {
    /// When this rank's physics compute ends.
    compute_end_s: f64,
    /// When this rank emits its inference burst.
    emit_s: f64,
    /// Requests still in flight this step.
    outstanding: usize,
    compute_done: bool,
    finished: bool,
    finish_s: f64,
    /// Record index of the rank's latest completion this step.
    last_record: Option<usize>,
}

impl RankState {
    fn idle() -> RankState {
        RankState {
            compute_end_s: 0.0,
            emit_s: 0.0,
            outstanding: 0,
            compute_done: false,
            finished: false,
            finish_s: 0.0,
            last_record: None,
        }
    }
}

#[derive(Debug, Clone)]
enum Event {
    /// Barrier release: all ranks begin timestep `step`.
    StepStart { step: usize },
    /// One request entering the router.
    Arrival { rank: usize, model: String, samples: usize },
    /// A rank's physics compute for the current step finished.
    ComputeDone { rank: usize },
    /// Re-check the batcher's deadline-ready queues.
    BatchDeadline,
    /// A dispatched batch finished; ids index the request metadata.
    Completion { ids: Vec<usize> },
    /// The fabric engine's earliest flow completion (stale when
    /// `version` is no longer current — see [`super::FabricLayer`]).
    FabricWake { version: u64 },
    /// A batch's request payload finished its fixed-latency tail.
    XferInDone { token: usize },
    /// A batch's device execution finished; start the result flow.
    ServiceDone { token: usize },
    /// The result payload is back at the host; complete the batch.
    XferOutDone { token: usize },
}

/// One batch in flight through the fabric (cogsim variant: the
/// residency swap rides its own flow, prefetched at dispatch, and
/// execution starts once *both* the payload and the weights are on
/// the accelerator).
#[derive(Debug, Clone)]
struct CogTransit {
    ids: Vec<usize>,
    backend: usize,
    accel: usize,
    host: usize,
    /// Model the batch serves (the weights-ready gate's key).
    model: String,
    bytes_out: f64,
    dispatch_s: f64,
    net_in_s: f64,
    /// When the payload's fixed tail landed (valid once `in_done`).
    in_done_s: f64,
    in_done: bool,
    swap_done: bool,
    /// Service already scheduled (guards double-starts when a parked
    /// batch is re-tried by the weights-ready drain).
    started: bool,
    /// Swap time *not* hidden behind the payload transfer: the
    /// serial residency charge on the batch's critical chain.
    swap_excess_s: f64,
    wait_s: f64,
    exec_s: f64,
    out_start_s: f64,
    ideal_rtt_s: f64,
    /// First record index of this batch (`ids.len()` consecutive).
    rec0: usize,
}

/// The coupled engine: backends + policy + residency + barrier.
pub struct CogSim {
    cfg: CogSimConfig,
    backends: Vec<Box<dyn Backend>>,
    policy: Policy,
    hermit_tier: Vec<usize>,
    mir_tier: Vec<usize>,
    hermit_profile: ModelProfile,
    mir_profile: ModelProfile,
    rr_cursor: usize,
    affinity: BTreeMap<String, usize>,
    residency: Vec<Residency>,
    clock_s: f64,
    events: EventQueue<Event>,
    batcher: Option<BatchStage>,
    fabric: Option<FabricLayer>,
    transits: Vec<CogTransit>,
    /// When a (backend, model)'s weights land: `INFINITY` while the
    /// swap flow is still on the wire (followers must not execute
    /// before the weights arrive — the residency `touch` marks the
    /// model resident at dispatch, this gate makes that honest).
    swap_ready_s: BTreeMap<(usize, String), f64>,
    /// Batches parked on an in-transit swap, by its key.
    swap_waiters: BTreeMap<(usize, String), Vec<usize>>,
    rngs: Vec<Rng>,
    ranks: Vec<RankState>,
    step_start_s: f64,
    current_step: usize,
    finished_ranks: usize,
    pending: Vec<PendingMeta>,
    records: Vec<CogRecord>,
    steps: Vec<StepBreakdown>,
    submitted: u64,
    dispatched: u64,
    completed: u64,
    batches: u64,
    swaps: u64,
    swap_time_s: f64,
}

impl CogSim {
    /// All backends serve all model classes.
    pub fn new(backends: Vec<Box<dyn Backend>>, policy: Policy, cfg: CogSimConfig) -> CogSim {
        let all: Vec<usize> = (0..backends.len()).collect();
        Self::with_tiers(backends, policy, cfg, all.clone(), all)
    }

    /// Tiered fleet: `hermit_tier`/`mir_tier` are candidate backend
    /// indices per model class (the hybrid topology pins MIR to local
    /// GPUs and the Hermit ladder to the remote pool).
    pub fn with_tiers(
        backends: Vec<Box<dyn Backend>>,
        policy: Policy,
        cfg: CogSimConfig,
        hermit_tier: Vec<usize>,
        mir_tier: Vec<usize>,
    ) -> CogSim {
        assert!(!backends.is_empty(), "cogsim needs at least one backend");
        assert!(cfg.ranks >= 1 && cfg.timesteps >= 1);
        assert!(cfg.requests_per_step >= 1 && cfg.models >= 1);
        assert!(cfg.compute_s >= 0.0 && cfg.compute_s.is_finite());
        assert!(cfg.compute_jitter_s >= 0.0 && cfg.compute_jitter_s.is_finite());
        assert!(cfg.samples_per_request.0 >= 1);
        assert!(cfg.samples_per_request.0 <= cfg.samples_per_request.1);
        assert!((0.0..=1.0).contains(&cfg.overlap), "overlap must be in [0, 1]");
        assert!(cfg.swap_s >= 0.0 && cfg.swap_s.is_finite());
        assert!(cfg.residency_slots >= 1);
        assert!(!hermit_tier.is_empty(), "hermit tier must not be empty");
        assert!(
            cfg.mir_every == 0 || !mir_tier.is_empty(),
            "mir_every > 0 needs a non-empty mir tier"
        );
        assert!(hermit_tier.iter().chain(&mir_tier).all(|&i| i < backends.len()));

        let batcher = BatchStage::from_config(cfg.batching);
        let rngs = (0..cfg.ranks)
            .map(|r| Rng::new(cfg.seed ^ (r as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15)))
            .collect();
        let residency = backends.iter().map(|_| Residency::new(cfg.residency_slots)).collect();

        let mut sim = CogSim {
            cfg,
            backends,
            policy,
            hermit_tier,
            mir_tier,
            hermit_profile: profiles::hermit(),
            mir_profile: profiles::mir_noln(),
            rr_cursor: 0,
            affinity: BTreeMap::new(),
            residency,
            clock_s: 0.0,
            events: EventQueue::new(),
            batcher,
            fabric: None,
            transits: Vec::new(),
            swap_ready_s: BTreeMap::new(),
            swap_waiters: BTreeMap::new(),
            rngs,
            ranks: (0..cfg.ranks).map(|_| RankState::idle()).collect(),
            step_start_s: 0.0,
            current_step: 0,
            finished_ranks: 0,
            pending: Vec::new(),
            records: Vec::new(),
            steps: Vec::new(),
            submitted: 0,
            dispatched: 0,
            completed: 0,
            batches: 0,
            swaps: 0,
            swap_time_s: 0.0,
        };
        sim.events.push_class(0.0, CLASS_ARRIVAL, Event::StepStart { step: 0 });
        sim
    }

    /// As [`Self::with_tiers`], with remote dispatches carried by the
    /// contention-aware fabric ([`crate::fabric`]): request payload
    /// in, result payload out, and residency swaps as bulk weight
    /// transfers — all competing for the same oversubscribed uplinks
    /// under max-min fair share.  Backends whose accel endpoint is
    /// node-local in the topology keep the legacy fixed-charge path.
    pub fn with_fabric(
        backends: Vec<Box<dyn Backend>>,
        policy: Policy,
        cfg: CogSimConfig,
        hermit_tier: Vec<usize>,
        mir_tier: Vec<usize>,
        spec: FabricSpec,
    ) -> CogSim {
        let mut sim = Self::with_tiers(backends, policy, cfg, hermit_tier, mir_tier);
        sim.fabric = Some(FabricLayer::new(spec, sim.backends.len()));
        sim
    }

    // ------------------------------------------------------ run loop

    fn pump(&mut self) -> bool {
        let Some((t, event)) = self.events.pop() else {
            return false;
        };
        self.advance_clock(t);
        self.handle(event);
        true
    }

    /// Drain the event queue completely: all T timesteps of all N
    /// ranks run to their final barrier.
    pub fn run_to_completion(&mut self) {
        while self.pump() {}
    }

    fn advance_clock(&mut self, t_s: f64) {
        let dt = t_s - self.clock_s;
        if dt <= 0.0 {
            return;
        }
        for b in &mut self.backends {
            b.drain_queue_s(dt);
        }
        self.clock_s = t_s;
    }

    fn handle(&mut self, event: Event) {
        match event {
            Event::StepStart { step } => self.on_step_start(step),
            Event::Arrival { rank, model, samples } => self.on_request(rank, model, samples),
            Event::ComputeDone { rank } => self.on_compute_done(rank),
            Event::BatchDeadline => self.pump_batcher(),
            Event::Completion { ids } => self.on_completion(ids),
            Event::FabricWake { version } => self.on_fabric_wake(version),
            Event::XferInDone { token } => self.on_xfer_in_done(token),
            Event::ServiceDone { token } => self.on_service_done(token),
            Event::XferOutDone { token } => self.on_xfer_out_done(token),
        }
    }

    // ------------------------------------------------- timestep loop

    /// Barrier release: every rank starts its physics compute, and
    /// this step's inference burst is scheduled at each rank's
    /// emission point.  Request draws happen here, in rank order, so
    /// a rank's stream is independent of the total rank count.
    fn on_step_start(&mut self, step: usize) {
        self.step_start_s = self.clock_s;
        self.current_step = step;
        self.finished_ranks = 0;
        let (lo, hi) = self.cfg.samples_per_request;
        for rank in 0..self.cfg.ranks {
            let jitter = if self.cfg.compute_jitter_s > 0.0 {
                self.rngs[rank].uniform(0.0, self.cfg.compute_jitter_s)
            } else {
                0.0
            };
            let compute = self.cfg.compute_s + jitter;
            let emit_s = self.clock_s + (1.0 - self.cfg.overlap) * compute;
            let compute_end_s = self.clock_s + compute;
            let mut outstanding = 0usize;
            for _ in 0..self.cfg.requests_per_step {
                let model = HydraWorkload::material_model(self.rngs[rank].below(self.cfg.models));
                let samples = self.rngs[rank].range(lo, hi);
                self.events.push_class(emit_s, CLASS_ARRIVAL, Event::Arrival {
                    rank,
                    model,
                    samples,
                });
                outstanding += 1;
            }
            if self.cfg.mir_every > 0 && step % self.cfg.mir_every == 0 {
                self.events.push_class(emit_s, CLASS_ARRIVAL, Event::Arrival {
                    rank,
                    model: "mir".to_string(),
                    samples: self.cfg.mir_samples,
                });
                outstanding += 1;
            }
            self.ranks[rank] = RankState {
                compute_end_s,
                emit_s,
                outstanding,
                compute_done: false,
                finished: false,
                finish_s: 0.0,
                last_record: None,
            };
            self.events.push_class(compute_end_s, CLASS_ARRIVAL, Event::ComputeDone { rank });
        }
    }

    fn on_compute_done(&mut self, rank: usize) {
        self.ranks[rank].compute_done = true;
        self.try_finish(rank);
    }

    fn try_finish(&mut self, rank: usize) {
        let st = &mut self.ranks[rank];
        if st.finished || !st.compute_done || st.outstanding > 0 {
            return;
        }
        st.finished = true;
        st.finish_s = self.clock_s;
        self.finished_ranks += 1;
        if self.finished_ranks == self.cfg.ranks {
            self.end_step();
        }
    }

    /// All ranks reached the barrier: record the step's critical-path
    /// breakdown and release the next step (at this very instant —
    /// the barrier itself is free).
    fn end_step(&mut self) {
        let start = self.step_start_s;
        let end = self.clock_s;
        let step = self.current_step;
        let mut straggler = 0usize;
        for r in 1..self.cfg.ranks {
            if self.ranks[r].finish_s > self.ranks[straggler].finish_s {
                straggler = r;
            }
        }
        let min_finish =
            self.ranks.iter().map(|r| r.finish_s).fold(f64::INFINITY, f64::min);
        let st = &self.ranks[straggler];
        // Compute-bound: the straggler's physics outlasted its last
        // completion (or it had nothing in flight), so the whole step
        // is compute.  Otherwise the chain is: non-overlapped compute
        // until emission, then the critical (= last-completing)
        // request's batching wait, backend queue, swap, link, execute.
        let compute_bound = match st.last_record {
            None => true,
            Some(idx) => self.records[idx].complete_s <= st.compute_end_s,
        };
        let breakdown = if compute_bound {
            StepBreakdown {
                step,
                start_s: start,
                end_s: end,
                straggler,
                compute_s: end - start,
                queue_s: 0.0,
                swap_s: 0.0,
                network_s: 0.0,
                contention_s: 0.0,
                service_s: 0.0,
                spread_s: end - min_finish,
            }
        } else {
            let crit = &self.records[st.last_record.expect("inference-bound step has a record")];
            StepBreakdown {
                step,
                start_s: start,
                end_s: end,
                straggler,
                compute_s: crit.emit_s - start,
                queue_s: (crit.dispatch_s - crit.emit_s) + crit.wait_s,
                swap_s: crit.swap_s,
                network_s: crit.link_s,
                contention_s: crit.contention_s,
                service_s: crit.exec_s,
                spread_s: end - min_finish,
            }
        };
        self.steps.push(breakdown);
        let next = step + 1;
        if next < self.cfg.timesteps {
            self.events.push_class(self.clock_s, CLASS_ARRIVAL, Event::StepStart { step: next });
        }
    }

    // ------------------------------------------------------- routing

    fn on_request(&mut self, rank: usize, model: String, samples: usize) {
        self.submitted += 1;
        let id = self.pending.len();
        self.pending.push(PendingMeta {
            step: self.current_step,
            rank,
            model: model.clone(),
            samples,
            emit_s: self.clock_s,
            record: None,
        });
        if self.batcher.is_some() {
            let stage = self.batcher.as_mut().unwrap();
            stage.enqueue(&model, id as u64, samples, self.clock_s);
            // Arrival path: dispatch only queues the *size* trigger
            // filled; deadline-expired queues close via their wake-up,
            // after every same-instant arrival (see
            // [`super::BatchStage`]).
            let ready = stage.drain_size_ready();
            self.dispatch_batches(ready);
            self.arm_batch_wakeup();
        } else {
            self.dispatch(vec![id]);
        }
    }

    fn dispatch_batches(&mut self, batches: Vec<Vec<usize>>) {
        for ids in batches {
            self.dispatch(ids);
        }
    }

    /// Schedule the next batch-close wake-up [`super::BatchStage`]
    /// asks for.
    fn arm_batch_wakeup(&mut self) {
        if let Some(t) = self.batcher.as_ref().unwrap().wakeup_at(self.clock_s) {
            self.events.push_class(t, CLASS_DEADLINE, Event::BatchDeadline);
        }
    }

    /// Deadline wake-up: drain every ready batcher queue at the
    /// current virtual time, then arm the next future deadline.
    fn pump_batcher(&mut self) {
        let ready = self.batcher.as_mut().unwrap().drain_ready(self.clock_s);
        self.dispatch_batches(ready);
        self.arm_batch_wakeup();
    }

    /// Route one batch exactly as the analytic cluster would — policy
    /// selection over the candidate tier, wait behind the backend's
    /// queued seconds, link + execute — plus the residency stage: a
    /// backend serving a model it doesn't hold charges `swap_s` to
    /// the requester *and* occupies the backend for it.
    ///
    /// With a [`super::FabricLayer`] attached, remote backends enter
    /// the multi-phase path ([`Self::dispatch_remote`]) instead: the
    /// payload and the swapped weights become fabric flows whose
    /// durations depend on what else shares the wire.
    fn dispatch(&mut self, ids: Vec<usize>) {
        debug_assert!(!ids.is_empty());
        let model = self.pending[ids[0]].model.clone();
        let total: usize = ids.iter().map(|&i| self.pending[i].samples).sum();
        let is_mir = model.starts_with("mir");
        let profile =
            if is_mir { self.mir_profile.clone() } else { self.hermit_profile.clone() };
        let candidates: &[usize] = if is_mir { &self.mir_tier } else { &self.hermit_tier };
        let idx = policy::select(
            self.policy,
            &self.backends,
            &mut self.rr_cursor,
            &mut self.affinity,
            candidates,
            &model,
            &profile,
            total,
        );
        let miss = self.residency[idx].touch(&model);
        if miss {
            self.swaps += 1;
        }
        if self.fabric.as_ref().is_some_and(|f| f.is_remote(idx)) {
            self.dispatch_remote(ids, idx, total, &profile, miss);
            return;
        }
        let swap_s = if miss { self.cfg.swap_s } else { 0.0 };
        if miss {
            self.swap_time_s += swap_s;
        }
        let backend = &mut self.backends[idx];
        let wait_s = backend.queue_s();
        let link_s = backend.link_overhead_s(&profile, total);
        let exec_s = backend.execute_s(&profile, total);
        let latency_s = wait_s + swap_s + (link_s + exec_s);
        let occupancy = backend.occupancy_s(&profile, total) + swap_s;
        backend.add_queue_s(occupancy);

        let complete_s = self.clock_s + latency_s;
        for &id in &ids {
            let meta = &mut self.pending[id];
            meta.record = Some(self.records.len());
            let record = CogRecord {
                id: id as u64,
                step: meta.step,
                rank: meta.rank,
                model: meta.model.clone(),
                samples: meta.samples,
                emit_s: meta.emit_s,
                dispatch_s: self.clock_s,
                complete_s,
                backend: idx,
                batch_samples: total,
                wait_s,
                swap_s,
                link_s,
                contention_s: 0.0,
                exec_s,
            };
            self.records.push(record);
        }
        self.dispatched += ids.len() as u64;
        self.batches += 1;
        self.events.push_class(complete_s, CLASS_COMPLETION, Event::Completion { ids });
    }

    // ------------------------------------------------- fabric phases

    /// Remote dispatch over the fabric.  The request payload starts
    /// its flow immediately; on a residency miss the model's weights
    /// start *their* flow at the same instant (prefetch), riding the
    /// same accel-leaf downlink and rx NIC — swap traffic congests
    /// inference.  Execution begins once both have landed; the result
    /// rides its own flow home.  As in [`super::EventSim`], a
    /// router-coalesced batch travels as one flow attributed to the
    /// leading request's host (batching happens at the host leaf).
    fn dispatch_remote(
        &mut self,
        ids: Vec<usize>,
        idx: usize,
        total: usize,
        profile: &ModelProfile,
        miss: bool,
    ) {
        let (bytes_in, bytes_out) =
            dir_payload_bytes(profile.input_elems, profile.output_elems, total);
        let fab = self.fabric.as_ref().expect("remote dispatch without a fabric");
        let accel = fab.accel(idx);
        let host = fab.spec.host_of_rank(self.pending[ids[0]].rank);
        let ideal_rtt_s = fab.ideal_rtt_s(bytes_in + bytes_out);
        // Sized so an uncontended swap takes exactly `swap_s` at the
        // endpoint's single-stream bandwidth — the degenerate charge.
        let swap_bytes = self.cfg.swap_s * fab.spec.topology.link().eff_bandwidth;

        // reserve the backend's routing queue now: transfers are
        // explicit, so the batch occupies the device for its
        // execution time only, and policies see committed work
        // immediately (the physical one-batch-at-a-time constraint
        // is [`super::FabricLayer::occupy`]'s device clock)
        let backend = &mut self.backends[idx];
        let exec_s = backend.execute_s(profile, total);
        backend.add_queue_s(exec_s);

        let model = self.pending[ids[0]].model.clone();
        let rec0 = self.records.len();
        for &id in &ids {
            let meta = &mut self.pending[id];
            meta.record = Some(self.records.len());
            let record = CogRecord {
                id: id as u64,
                step: meta.step,
                rank: meta.rank,
                model: meta.model.clone(),
                samples: meta.samples,
                emit_s: meta.emit_s,
                dispatch_s: self.clock_s,
                complete_s: f64::NAN,
                backend: idx,
                batch_samples: total,
                wait_s: 0.0,
                swap_s: 0.0,
                link_s: 0.0,
                contention_s: 0.0,
                exec_s: 0.0,
            };
            self.records.push(record);
        }
        self.dispatched += ids.len() as u64;
        self.batches += 1;

        let token = self.transits.len();
        let needs_swap_flow = miss && swap_bytes > 0.0;
        if needs_swap_flow {
            // weights are on the wire: same-model followers routed
            // here park until they land (the residency touch already
            // counts the model resident, this keeps it honest)
            self.swap_ready_s.insert((idx, model.clone()), f64::INFINITY);
        }
        self.transits.push(CogTransit {
            ids,
            backend: idx,
            accel,
            host,
            model,
            bytes_out,
            dispatch_s: self.clock_s,
            net_in_s: 0.0,
            in_done_s: 0.0,
            in_done: false,
            swap_done: !needs_swap_flow,
            started: false,
            swap_excess_s: 0.0,
            wait_s: 0.0,
            exec_s,
            out_start_s: 0.0,
            ideal_rtt_s,
            rec0,
        });

        let clock = self.clock_s;
        let fab = self.fabric.as_mut().expect("checked above");
        let path = fab.spec.topology.request_path(host, accel);
        let flow = fab.engine.start(clock, path, bytes_in);
        fab.cont.insert(flow, FlowCont::In { token });
        if needs_swap_flow {
            let path = fab.spec.topology.swap_path(accel);
            let flow = fab.engine.start(clock, path, swap_bytes);
            fab.cont.insert(flow, FlowCont::Swap { token });
        }
        self.arm_fabric();
    }

    /// Re-arm the fabric wake-up at the engine's (new) earliest flow
    /// completion; called after every flow start/finish.
    fn arm_fabric(&mut self) {
        let clock = self.clock_s;
        let armed = self.fabric.as_mut().expect("arm_fabric without a fabric").next_wake(clock);
        if let Some((t, version)) = armed {
            self.events.push_class(t, CLASS_COMPLETION, Event::FabricWake { version });
        }
    }

    /// A fabric wake-up fired: drain finished flows.  Payload and
    /// result flows get their direction's fixed-latency tail as a
    /// scheduled event; swap completions take effect immediately (a
    /// bulk weight stream has no per-message rendezvous).
    fn on_fabric_wake(&mut self, version: u64) {
        let clock = self.clock_s;
        let conts = {
            let Some(fab) = self.fabric.as_mut() else { return };
            let Some(conts) = fab.drain_wake(version, clock) else {
                return; // stale: a newer wake-up is armed
            };
            conts
        };
        for cont in conts {
            match cont {
                FlowCont::In { token } => {
                    let fixed = self.dir_fixed_of(token);
                    self.events.push_class(
                        self.clock_s + fixed,
                        CLASS_COMPLETION,
                        Event::XferInDone { token },
                    );
                }
                FlowCont::Swap { token } => {
                    let measured = self.clock_s - self.transits[token].dispatch_s;
                    self.swap_time_s += measured;
                    self.transits[token].swap_done = true;
                    // the weights landed: unblock this batch, then
                    // every same-model follower parked behind it
                    let key =
                        (self.transits[token].backend, self.transits[token].model.clone());
                    self.swap_ready_s.insert(key.clone(), self.clock_s);
                    self.try_begin_service(token);
                    if let Some(waiters) = self.swap_waiters.remove(&key) {
                        for waiter in waiters {
                            self.try_begin_service(waiter);
                        }
                    }
                }
                FlowCont::Out { token } => {
                    let fixed = self.dir_fixed_of(token);
                    self.events.push_class(
                        self.clock_s + fixed,
                        CLASS_COMPLETION,
                        Event::XferOutDone { token },
                    );
                }
            }
        }
        if self.fabric.is_some() {
            self.arm_fabric();
        }
    }

    fn dir_fixed_of(&self, token: usize) -> f64 {
        let fab = self.fabric.as_ref().expect("fabric phase without a fabric");
        fab.spec.topology.dir_fixed_s(self.transits[token].accel)
    }

    /// The request payload is at the accelerator.
    fn on_xfer_in_done(&mut self, token: usize) {
        let tr = &mut self.transits[token];
        tr.net_in_s = self.clock_s - tr.dispatch_s;
        tr.in_done_s = self.clock_s;
        tr.in_done = true;
        self.try_begin_service(token);
    }

    /// Begin execution once the payload has landed, the batch's own
    /// swap (on a miss) has landed, **and** the model's weights are
    /// actually on the backend — a follower routed to a backend whose
    /// weights are still on the wire parks until they arrive (the
    /// wait lands in its `swap_s` component).  The batch then
    /// executes as soon as the device frees up
    /// ([`super::FabricLayer::occupy`] — strictly one batch at a
    /// time per device, work-conserving order).
    fn try_begin_service(&mut self, token: usize) {
        let clock = self.clock_s;
        let (ready, idx, exec_s, in_done_s) = {
            let tr = &self.transits[token];
            (
                !tr.started && tr.in_done && tr.swap_done,
                tr.backend,
                tr.exec_s,
                tr.in_done_s,
            )
        };
        if !ready {
            return;
        }
        let key = (idx, self.transits[token].model.clone());
        if self.swap_ready_s.get(&key).is_some_and(|t| t.is_infinite()) {
            self.swap_waiters.entry(key).or_default().push(token);
            return;
        }
        let fab = self.fabric.as_mut().expect("fabric phase without a fabric");
        let (wait_s, done_s) = fab.occupy(idx, clock, exec_s);
        // Re-sync the routing signal with the device horizon: long
        // transfers/swaps can outlive the dispatch-time reservation's
        // wall-time drain, and the policies must keep seeing the
        // serialized backlog `occupy` is accumulating.
        let backend = &mut self.backends[idx];
        let deficit = (done_s - clock) - backend.queue_s();
        if deficit > 0.0 {
            backend.add_queue_s(deficit);
        }
        let tr = &mut self.transits[token];
        tr.started = true;
        tr.swap_excess_s = clock - in_done_s;
        tr.wait_s = wait_s;
        self.events.push_class(done_s, CLASS_COMPLETION, Event::ServiceDone { token });
    }

    /// Execution finished: send the result payload home.
    fn on_service_done(&mut self, token: usize) {
        let (host, accel, bytes_out) = {
            let tr = &self.transits[token];
            (tr.host, tr.accel, tr.bytes_out)
        };
        self.transits[token].out_start_s = self.clock_s;
        let clock = self.clock_s;
        let fab = self.fabric.as_mut().expect("fabric phase without a fabric");
        let path = fab.spec.topology.response_path(host, accel);
        let flow = fab.engine.start(clock, path, bytes_out);
        fab.cont.insert(flow, FlowCont::Out { token });
        self.arm_fabric();
    }

    /// The result landed: fill the batch's records with the measured
    /// phase timings (so per-step breakdowns still sum exactly) and
    /// run the shared completion logic.
    fn on_xfer_out_done(&mut self, token: usize) {
        let (ids, rec0, wait_s, swap_s, link_s, contention_s, exec_s) = {
            let tr = &self.transits[token];
            let net_out_s = self.clock_s - tr.out_start_s;
            let link_s = tr.net_in_s + net_out_s;
            (
                tr.ids.clone(),
                tr.rec0,
                tr.wait_s,
                tr.swap_excess_s,
                link_s,
                (link_s - tr.ideal_rtt_s).max(0.0),
                tr.exec_s,
            )
        };
        for k in 0..ids.len() {
            let r = &mut self.records[rec0 + k];
            r.complete_s = self.clock_s;
            r.wait_s = wait_s;
            r.swap_s = swap_s;
            r.link_s = link_s;
            r.contention_s = contention_s;
            r.exec_s = exec_s;
        }
        self.on_completion(ids);
    }

    fn on_completion(&mut self, ids: Vec<usize>) {
        self.completed += ids.len() as u64;
        for &id in &ids {
            let rank = self.pending[id].rank;
            let record = self.pending[id].record;
            let st = &mut self.ranks[rank];
            debug_assert!(st.outstanding > 0, "completion for an idle rank");
            st.outstanding -= 1;
            // completions pop in time order, so the last one processed
            // is the rank's latest (ties: latest dispatched wins —
            // deterministic)
            st.last_record = record;
            self.try_finish(rank);
        }
    }

    // ----------------------------------------------------- accessors

    pub fn clock_s(&self) -> f64 {
        self.clock_s
    }

    pub fn policy(&self) -> Policy {
        self.policy
    }

    /// Requests that have entered the router.
    pub fn submitted(&self) -> u64 {
        self.submitted
    }

    /// Requests whose completion event has fired.
    pub fn completed(&self) -> u64 {
        self.completed
    }

    /// Dispatched but not yet completed.
    pub fn in_flight(&self) -> u64 {
        self.dispatched - self.completed
    }

    /// Requests waiting in the batching window.
    pub fn batcher_pending(&self) -> u64 {
        self.batcher.as_ref().map_or(0, BatchStage::pending)
    }

    /// Batches dispatched so far.
    pub fn batches(&self) -> u64 {
        self.batches
    }

    /// Residency misses so far.
    pub fn swaps(&self) -> u64 {
        self.swaps
    }

    /// Per-request records, in dispatch order.
    pub fn records(&self) -> &[CogRecord] {
        &self.records
    }

    /// Completed per-timestep breakdowns, in step order.
    pub fn steps(&self) -> &[StepBreakdown] {
        &self.steps
    }

    /// Virtual time of the last barrier (defined after
    /// [`Self::run_to_completion`]).
    pub fn time_to_solution_s(&self) -> f64 {
        self.steps.last().map_or(0.0, |s| s.end_s)
    }

    /// Summarise the run (intended after [`Self::run_to_completion`]).
    pub fn summary(&self) -> CogSummary {
        let latencies: Vec<f64> = self.records.iter().map(|r| r.latency_s()).collect();
        let samples: u64 = self.records.iter().map(|r| r.samples as u64).sum();
        let mut straggler_counts = vec![0u64; self.cfg.ranks];
        let mut total_compute_s = 0.0;
        let mut total_queue_s = 0.0;
        let mut total_swap_s = 0.0;
        let mut total_network_s = 0.0;
        let mut total_contention_s = 0.0;
        let mut total_service_s = 0.0;
        let mut max_spread_s = 0.0f64;
        for s in &self.steps {
            straggler_counts[s.straggler] += 1;
            total_compute_s += s.compute_s;
            total_queue_s += s.queue_s;
            total_swap_s += s.swap_s;
            total_network_s += s.network_s;
            total_contention_s += s.contention_s;
            total_service_s += s.service_s;
            max_spread_s = max_spread_s.max(s.spread_s);
        }
        let tts = self.time_to_solution_s();
        CogSummary {
            ranks: self.cfg.ranks as u64,
            timesteps: self.steps.len() as u64,
            requests: self.records.len() as u64,
            samples,
            batches: self.batches,
            time_to_solution_s: tts,
            steps: self.steps.clone(),
            total_compute_s,
            total_queue_s,
            total_swap_s,
            total_network_s,
            total_contention_s,
            total_service_s,
            latency: LatencyDist::from_latencies(&latencies),
            swaps: self.swaps,
            swap_time_s: self.swap_time_s,
            straggler_counts,
            max_spread_s,
            mean_step_s: if self.steps.is_empty() {
                0.0
            } else {
                tts / self.steps.len() as f64
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{GpuBackend, RduBackend};
    use crate::devices::{Api, Gpu};
    use crate::rdu::RduApi;

    fn gpu_fleet(n: usize) -> Vec<Box<dyn Backend>> {
        (0..n)
            .map(|i| {
                Box::new(GpuBackend::node_local(
                    format!("gpu/rank{i}"),
                    Gpu::a100(),
                    Api::TrtCudaGraphs,
                )) as Box<dyn Backend>
            })
            .collect()
    }

    fn pool() -> Vec<Box<dyn Backend>> {
        vec![
            Box::new(RduBackend::disaggregated("rdu/pool0", 4, RduApi::CppOptimized)),
            Box::new(RduBackend::disaggregated("rdu/pool1", 2, RduApi::Python)),
        ]
    }

    #[test]
    fn lru_residency_touch_semantics() {
        let mut r = Residency::new(2);
        assert!(r.touch("a")); // miss: first sighting
        assert!(r.touch("b"));
        assert!(!r.touch("a")); // hit, refreshes a
        assert!(r.touch("c")); // evicts b (LRU)
        assert!(r.touch("b")); // b gone: miss again
        assert!(!r.touch("c")); // c survived (a was evicted by b)
    }

    #[test]
    fn coupled_run_completes_every_step_and_request() {
        let cfg = CogSimConfig { ranks: 6, timesteps: 5, ..Default::default() };
        let mut sim = CogSim::new(pool(), Policy::LeastOutstanding, cfg);
        sim.run_to_completion();
        assert_eq!(sim.steps().len(), 5);
        assert_eq!(sim.submitted(), 6 * 5 * 6);
        assert_eq!(sim.completed(), sim.submitted());
        assert_eq!(sim.in_flight(), 0);
        assert_eq!(sim.batcher_pending(), 0);
        assert_eq!(sim.records().len() as u64, sim.submitted());
        assert!(sim.time_to_solution_s() > 0.0);
        // steps tile the run: each starts where the previous ended
        for pair in sim.steps().windows(2) {
            assert_eq!(pair[0].end_s, pair[1].start_s);
        }
    }

    #[test]
    fn per_step_breakdown_sums_to_duration() {
        let cfg = CogSimConfig {
            ranks: 8,
            timesteps: 6,
            swap_s: 100e-6,
            compute_jitter_s: 0.5e-3,
            ..Default::default()
        };
        let mut sim = CogSim::new(pool(), Policy::RoundRobin, cfg);
        sim.run_to_completion();
        for s in sim.steps() {
            assert!(
                (s.components_sum_s() - s.duration_s()).abs() < 1e-9,
                "step {}: components {} vs duration {}",
                s.step,
                s.components_sum_s(),
                s.duration_s()
            );
            assert!(s.spread_s >= 0.0);
            assert!(s.straggler < 8);
        }
    }

    #[test]
    fn compute_bound_steps_are_pure_compute() {
        // Overlap 1.0 with enormous compute: inference hides entirely,
        // every step is compute-bound and exactly compute_s long.
        let cfg = CogSimConfig {
            ranks: 2,
            timesteps: 3,
            compute_s: 1.0,
            overlap: 1.0,
            ..Default::default()
        };
        let mut sim = CogSim::new(gpu_fleet(2), Policy::LatencyAware, cfg);
        sim.run_to_completion();
        for s in sim.steps() {
            assert!((s.duration_s() - 1.0).abs() < 1e-12, "step {}", s.step);
            assert_eq!(s.queue_s, 0.0);
            assert_eq!(s.service_s, 0.0);
        }
    }

    #[test]
    fn swap_cost_slows_time_to_solution() {
        let tts = |swap_s: f64| {
            let cfg = CogSimConfig { swap_s, ..Default::default() };
            let mut sim = CogSim::new(pool(), Policy::RoundRobin, cfg);
            sim.run_to_completion();
            sim.time_to_solution_s()
        };
        let free = tts(0.0);
        let costly = tts(1e-3);
        assert!(costly > free, "swap 1ms {costly} vs free {free}");
    }

    #[test]
    fn residency_hits_need_no_swap() {
        // One model, one backend: exactly one miss ever.
        let cfg = CogSimConfig { models: 1, swap_s: 1e-3, ..Default::default() };
        let mut sim = CogSim::new(gpu_fleet(1), Policy::RoundRobin, cfg);
        sim.run_to_completion();
        assert_eq!(sim.swaps(), 1);
        let with_swap: Vec<&CogRecord> =
            sim.records().iter().filter(|r| r.swap_s > 0.0).collect();
        assert_eq!(with_swap.len(), 1, "only the first dispatch pays");
    }

    #[test]
    fn overlap_hides_inference_behind_compute() {
        let tts = |overlap: f64| {
            let cfg = CogSimConfig { overlap, ..Default::default() };
            let mut sim = CogSim::new(pool(), Policy::LatencyAware, cfg);
            sim.run_to_completion();
            sim.time_to_solution_s()
        };
        assert!(tts(1.0) <= tts(0.0) + 1e-12);
    }

    #[test]
    fn mir_requests_ride_their_tier() {
        let cfg = CogSimConfig {
            ranks: 2,
            timesteps: 4,
            mir_every: 2,
            mir_samples: 128,
            ..Default::default()
        };
        let mut fleet = gpu_fleet(2);
        fleet.extend(pool());
        let mut sim =
            CogSim::with_tiers(fleet, Policy::LatencyAware, cfg, vec![2, 3], vec![0, 1]);
        sim.run_to_completion();
        assert!(sim.records().iter().any(|r| r.model == "mir"));
        for r in sim.records() {
            if r.model.starts_with("mir") {
                assert!(r.backend < 2, "mir routed to {}", r.backend);
            } else {
                assert!(r.backend >= 2, "hermit routed to {}", r.backend);
            }
        }
        // MIR fires on steps 0 and 2: 2 ranks x 2 steps
        assert_eq!(sim.records().iter().filter(|r| r.model == "mir").count(), 4);
    }

    #[test]
    fn batching_window_coalesces_the_step_burst() {
        let cfg = CogSimConfig {
            ranks: 16,
            timesteps: 3,
            models: 4,
            batching: Batching::Window { window_s: 200e-6, max_batch: 256 },
            ..Default::default()
        };
        let mut sim = CogSim::new(pool(), Policy::LatencyAware, cfg);
        sim.run_to_completion();
        assert_eq!(sim.completed(), sim.submitted());
        assert!(
            sim.batches() * 4 <= sim.submitted(),
            "{} batches for {} requests",
            sim.batches(),
            sim.submitted()
        );
        assert!(sim.records().iter().any(|r| r.batch_samples > r.samples));
    }

    #[test]
    fn summary_accounts_everything() {
        let cfg = CogSimConfig { ranks: 4, timesteps: 6, swap_s: 50e-6, ..Default::default() };
        let mut sim = CogSim::new(pool(), Policy::ModelAffinity, cfg);
        sim.run_to_completion();
        let s = sim.summary();
        assert_eq!(s.requests, sim.submitted());
        assert_eq!(s.timesteps, 6);
        assert_eq!(s.steps.len(), 6);
        assert_eq!(s.straggler_counts.iter().sum::<u64>(), 6);
        assert_eq!(s.swaps, sim.swaps());
        assert!(s.time_to_solution_s > 0.0);
        assert!((s.mean_step_s * 6.0 - s.time_to_solution_s).abs() < 1e-9);
        assert!(s.total_compute_s > 0.0);
        assert_eq!(s.total_contention_s, 0.0, "no fabric layer, no contention");
        let hist_total: u64 =
            s.latency.histogram.iter().map(|(_, c)| c).sum::<u64>() + s.latency.overflow;
        assert_eq!(hist_total, s.requests);
    }

    // ------------------------------------------------- fabric layer

    fn pool_fabric(ranks: usize, oversub: f64) -> crate::fabric::FabricSpec {
        crate::fabric::FabricSpec {
            topology: crate::fabric::Topology::pooled(ranks, 2, oversub),
            accel_of_backend: vec![0, 1],
        }
    }

    #[test]
    fn fabric_run_conserves_and_breakdowns_still_sum() {
        let cfg = CogSimConfig {
            ranks: 12,
            timesteps: 5,
            swap_s: 200e-6,
            ..Default::default()
        };
        let mut sim = CogSim::with_fabric(
            pool(),
            Policy::LeastOutstanding,
            cfg,
            vec![0, 1],
            vec![0, 1],
            pool_fabric(12, 4.0),
        );
        sim.run_to_completion();
        assert_eq!(sim.steps().len(), 5);
        assert_eq!(sim.submitted(), 12 * 5 * 6);
        assert_eq!(sim.completed(), sim.submitted());
        assert_eq!(sim.in_flight(), 0);
        // the critical-path decomposition survives the multi-phase
        // pipeline: components still sum to each step's duration
        for s in sim.steps() {
            assert!(
                (s.components_sum_s() - s.duration_s()).abs() < 1e-9,
                "step {}: components {} vs duration {}",
                s.step,
                s.components_sum_s(),
                s.duration_s()
            );
            assert!(s.contention_s >= 0.0);
            assert!(s.contention_s <= s.network_s + 1e-15, "contention is a subset");
        }
        // a 12-rank burst on a 4:1 fabric must show real contention
        let s = sim.summary();
        assert!(s.total_contention_s > 0.0);
        assert!(s.total_network_s >= s.total_contention_s);
    }

    #[test]
    fn fabric_oversubscription_monotonically_slows_tts() {
        let tts = |oversub: f64| {
            let cfg = CogSimConfig { ranks: 16, timesteps: 4, ..Default::default() };
            let mut sim = CogSim::with_fabric(
                pool(),
                Policy::LeastOutstanding,
                cfg,
                vec![0, 1],
                vec![0, 1],
                pool_fabric(16, oversub),
            );
            sim.run_to_completion();
            sim.time_to_solution_s()
        };
        let mut last = 0.0;
        for oversub in [1.0, 2.0, 4.0, 8.0] {
            let t = tts(oversub);
            assert!(t >= last - 1e-12, "oversub {oversub}: TTS {t} < previous {last}");
            last = t;
        }
    }

    #[test]
    fn fabric_swap_flows_congest_inference() {
        // Same run, swaps free vs swaps as 4.2 MB weight transfers
        // (2 ms at line rate) on the shared downlink: the swap
        // traffic must slow time-to-solution, and the engine must
        // measure real swap seconds.
        let run = |swap_s: f64| {
            let cfg = CogSimConfig {
                ranks: 8,
                timesteps: 4,
                swap_s,
                ..Default::default()
            };
            let mut sim = CogSim::with_fabric(
                pool(),
                Policy::RoundRobin,
                cfg,
                vec![0, 1],
                vec![0, 1],
                pool_fabric(8, 2.0),
            );
            sim.run_to_completion();
            (sim.time_to_solution_s(), sim.summary())
        };
        let (tts_free, free) = run(0.0);
        let (tts_swap, swapped) = run(2e-3);
        assert!(tts_swap > tts_free, "{tts_swap} vs {tts_free}");
        assert_eq!(free.swap_time_s, 0.0);
        assert!(swapped.swaps > 0);
        // a contended swap takes at least its uncontended duration
        assert!(swapped.swap_time_s >= 2e-3 * swapped.swaps as f64 - 1e-9);
    }
}
