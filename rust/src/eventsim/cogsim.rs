//! The coupled CogSim application model: inference **inside** the
//! timestep loop.
//!
//! The open-/closed-loop arrival processes of [`super::EventSim`]
//! drive request streams that are decoupled from simulation progress,
//! so they can report latency distributions but not the paper's real
//! figure of merit — **time-to-solution** (§IV: "the time spent
//! performing inference … directly impacts total simulation time").
//! This module closes the loop:
//!
//! * **N ranks** run **T bulk-synchronous timesteps**.  Every step,
//!   each rank performs `compute_s` of physics (optional per-rank
//!   jitter), emits `K` per-material inference requests over `M`
//!   models (+ MIR every `mir_every`-th step) at
//!   `(1-overlap)·compute_s` into the step, and advances only when
//!   **all** of them complete — a barrier holds the next step until
//!   the slowest rank is done, the paper's in-the-loop SLO.
//! * **Model residency**: each backend holds at most
//!   `residency_slots` models (LRU); a miss charges `swap_s` — the
//!   regime where [`Policy::ModelAffinity`] routing earns its keep.
//! * **Critical path**: every step records a [`StepBreakdown`] —
//!   compute / queue / swap / network / service along the straggler
//!   rank's longest chain, summing to the step duration — so
//!   `time_to_solution` decomposes into *where the time went*
//!   ([`CogSummary`]).
//!
//! Routing, queueing, link, batching, residency, and fabric semantics
//! all live in the shared [`crate::simcore::Pipeline`] — the same
//! single copy [`super::EventSim`] drives — so in the contention-free
//! limit (1 rank, 1 model, zero swap, zero overlap, batching off)
//! each timestep degrades to `compute_s` plus the analytic
//! [`crate::cluster::Cluster`] latency for the same K requests —
//! `rust/tests/cogsim_vs_analytic.rs` pins that to 1e-9.
//!
//! With [`CogSim::with_fabric`], remote dispatches ride the
//! contention-aware [`crate::fabric`] layer: request payloads, result
//! payloads, and residency-swap weight transfers become fabric flows
//! competing for shared leaf/spine bandwidth, and the per-step
//! breakdown gains a *contention* share.  One flow alone on a 1:1
//! topology reproduces the legacy charge to 1e-9
//! (`rust/tests/fabric_props.rs`).

use crate::cluster::{Backend, Policy};
use crate::fabric::FabricSpec;
use crate::simcore::{
    AutoscalerCfg, Batching, Completed, Dispatched, FleetAction, FleetEvent, Outcome, PipeEvent,
    Pipeline, ResidencySpec,
};
use crate::util::rng::Rng;
use crate::workload::HydraWorkload;

use super::equeue::{EventQueue, CLASS_ARRIVAL};
use super::metrics::{CogSummary, LatencyDist, StepBreakdown};
use super::rank_rngs;

/// One coupled run's knobs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CogSimConfig {
    /// MPI ranks advancing in lockstep.
    pub ranks: usize,
    /// Bulk-synchronous timesteps to run.
    pub timesteps: usize,
    /// Physics compute per rank per timestep, seconds.
    pub compute_s: f64,
    /// Per-rank uniform compute jitter in `[0, jitter)` seconds
    /// (load imbalance; 0 = perfectly balanced ranks).
    pub compute_jitter_s: f64,
    /// In-the-loop inference requests per rank per timestep (K).
    pub requests_per_step: usize,
    /// Target models in the mix (M per-material Hermit instances);
    /// each request draws one uniformly.
    pub models: usize,
    /// Samples per request, uniform inclusive (paper: 2–3 per zone).
    pub samples_per_request: (usize, usize),
    /// Every `mir_every`-th step each rank also emits one MIR
    /// mixed-zone request (0 = never).
    pub mir_every: usize,
    /// Samples in each MIR request.
    pub mir_samples: usize,
    /// Fraction of compute overlappable with in-flight inference.
    pub overlap: f64,
    /// Seconds charged when a backend serves a model it doesn't hold.
    pub swap_s: f64,
    /// Models resident per backend (LRU eviction).
    pub residency_slots: usize,
    pub batching: Batching,
    pub seed: u64,
}

impl Default for CogSimConfig {
    fn default() -> Self {
        CogSimConfig {
            ranks: 4,
            timesteps: 8,
            compute_s: 2e-3,
            compute_jitter_s: 0.0,
            requests_per_step: 6,
            models: 8,
            samples_per_request: (2, 3),
            mir_every: 0,
            mir_samples: 512,
            overlap: 0.0,
            swap_s: 0.0,
            residency_slots: 4,
            batching: Batching::Off,
            seed: 42,
        }
    }
}

/// The full story of one completed in-the-loop request.
#[derive(Debug, Clone, PartialEq)]
pub struct CogRecord {
    pub id: u64,
    /// Timestep the request belongs to.
    pub step: usize,
    pub rank: usize,
    pub model: String,
    pub samples: usize,
    /// When the rank emitted the request.
    pub emit_s: f64,
    /// When the router dispatched the (possibly coalesced) batch.
    pub dispatch_s: f64,
    /// When the result returned to the rank.
    pub complete_s: f64,
    /// Backend index the batch was routed to.
    pub backend: usize,
    /// Total samples in the dispatched batch this request rode in.
    pub batch_samples: usize,
    /// Backend queue the batch waited behind, seconds.
    pub wait_s: f64,
    /// Residency-swap charge paid by the batch, seconds.
    pub swap_s: f64,
    /// Link round-trip share of the service, seconds.  With the
    /// fabric layer this is the *measured* transfer time.
    pub link_s: f64,
    /// Fabric-contention share of `link_s` (measured minus the
    /// uncontended round trip); zero without the fabric layer.
    pub contention_s: f64,
    /// Device execution share of the service, seconds.
    pub exec_s: f64,
    /// The request's first batch died with its backend and it was
    /// re-dispatched by the control plane; the completion fields
    /// describe the *successful* attempt.
    pub retried: bool,
}

impl CogRecord {
    /// End-to-end latency as the rank observes it.
    pub fn latency_s(&self) -> f64 {
        self.complete_s - self.emit_s
    }

    /// Time spent coalescing in the batching window.
    pub fn batch_wait_s(&self) -> f64 {
        self.dispatch_s - self.emit_s
    }
}

/// Struct-of-arrays request store, keyed by the dense request id (ids
/// are sequential in this engine — pinned by a debug assert at
/// submit).  Rank, model and samples live in the pipeline's interned
/// metadata ([`Pipeline::request`]); nothing here allocates per
/// request beyond amortized column growth.  `order` lists ids in
/// *dispatch* order: summaries iterate through it so float
/// accumulation order — and therefore golden bytes — is identical to
/// the old row store's push order.
#[derive(Default)]
struct CogRecords {
    /// Submit-time columns, id-keyed.
    step: Vec<u32>,
    emit_s: Vec<f64>,
    /// Rank epoch the request was emitted in: completions from a
    /// pre-failure epoch are wasted work and do not advance the
    /// barrier.
    epoch: Vec<u32>,
    /// Dispatch-time columns, id-keyed (NaN/zero until dispatched).
    dispatch_s: Vec<f64>,
    complete_s: Vec<f64>,
    backend: Vec<u32>,
    batch_samples: Vec<u32>,
    wait_s: Vec<f64>,
    swap_s: Vec<f64>,
    link_s: Vec<f64>,
    contention_s: Vec<f64>,
    exec_s: Vec<f64>,
    retried: Vec<bool>,
    /// Ids in dispatch order (one entry per dispatched id, ever).
    order: Vec<u32>,
}

impl CogRecords {
    /// Register a submitted request; returns the id the pipeline must
    /// agree on.
    fn on_submit(&mut self, step: usize, emit_s: f64, epoch: u32) -> usize {
        let id = self.step.len();
        self.step.push(step as u32);
        self.emit_s.push(emit_s);
        self.epoch.push(epoch);
        self.dispatch_s.push(f64::NAN);
        self.complete_s.push(f64::NAN);
        self.backend.push(0);
        self.batch_samples.push(0);
        self.wait_s.push(0.0);
        self.swap_s.push(0.0);
        self.link_s.push(0.0);
        self.contention_s.push(0.0);
        self.exec_s.push(0.0);
        self.retried.push(false);
        id
    }
}

/// Per-rank progress through the current timestep.
#[derive(Debug, Clone)]
struct RankState {
    /// When this rank's physics compute ends.
    compute_end_s: f64,
    /// When this rank emits its inference burst.
    emit_s: f64,
    /// Requests still in flight this step.
    outstanding: usize,
    compute_done: bool,
    finished: bool,
    finish_s: f64,
    /// Request id of the rank's latest completion this step.
    last_record: Option<usize>,
}

impl RankState {
    fn idle() -> RankState {
        RankState {
            compute_end_s: 0.0,
            emit_s: 0.0,
            outstanding: 0,
            compute_done: false,
            finished: false,
            finish_s: 0.0,
            last_record: None,
        }
    }
}

#[derive(Debug, Clone)]
enum Event {
    /// Barrier release: all ranks begin timestep `step`.
    StepStart { step: usize },
    /// One rank's whole inference burst entering the router — every
    /// draw of the rank's step shares this one instant, so the burst
    /// submits lazily in bulk instead of materializing one arrival
    /// event per request (pop-order-identical; see DESIGN.md
    /// "Event-engine scale-out").  Stale when `epoch` is no longer
    /// the rank's current epoch (emitted before a failure) — then the
    /// whole group is dropped, exactly as each eager arrival would be.
    RankBurst { rank: usize, epoch: u32 },
    /// A rank's physics compute for the current step finished (stale
    /// when `epoch` is outdated — the restarted rank re-computes).
    ComputeDone { rank: usize, epoch: u32 },
    /// A timed control-plane action from the scenario's trace.
    Fleet { action: FleetAction },
    /// Everything past the router lives in [`crate::simcore`].
    Pipe(PipeEvent),
}

/// The coupled engine: the bulk-synchronous barrier + per-rank state
/// around the shared [`Pipeline`] (routing, batching, residency,
/// fabric).
pub struct CogSim {
    cfg: CogSimConfig,
    core: Pipeline,
    events: EventQueue<Event>,
    rngs: Vec<Rng>,
    ranks: Vec<RankState>,
    step_start_s: f64,
    current_step: usize,
    finished_ranks: usize,
    rec: CogRecords,
    steps: Vec<StepBreakdown>,
    events_processed: u64,
    /// Per-rank restart epoch: bumped on every checkpoint/restart;
    /// events and completions from older epochs are stale.
    epoch: Vec<u32>,
    /// Model names interned once (`models` material instances plus
    /// "mir" at index `models`): draws carry the index, submits
    /// borrow the name — no per-draw formatting or cloning.
    model_names: Vec<String>,
    /// Per-rank draws of the current step as `(model index, samples)`
    /// — the "checkpoint" a restarted rank replays (same models,
    /// samples, and compute as the lost attempt; the rank's RNG
    /// stream is not re-consumed).
    step_draws: Vec<Vec<(usize, usize)>>,
    /// Per-rank physics duration of the current step (jitter drawn).
    step_compute: Vec<f64>,
    autoscaler: Option<AutoscalerCfg>,
    rank_restarts: u64,
    /// Active backend count sampled at every step start.
    active_samples: Vec<u64>,
}

impl CogSim {
    /// All backends serve all model classes.
    pub fn new(backends: Vec<Box<dyn Backend>>, policy: Policy, cfg: CogSimConfig) -> CogSim {
        let all: Vec<usize> = (0..backends.len()).collect();
        Self::with_tiers(backends, policy, cfg, all.clone(), all)
    }

    /// Tiered fleet: `hermit_tier`/`mir_tier` are candidate backend
    /// indices per model class (the hybrid topology pins MIR to local
    /// GPUs and the Hermit ladder to the remote pool).
    pub fn with_tiers(
        backends: Vec<Box<dyn Backend>>,
        policy: Policy,
        cfg: CogSimConfig,
        hermit_tier: Vec<usize>,
        mir_tier: Vec<usize>,
    ) -> CogSim {
        assert!(!backends.is_empty(), "cogsim needs at least one backend");
        assert!(cfg.ranks >= 1 && cfg.timesteps >= 1);
        assert!(cfg.requests_per_step >= 1 && cfg.models >= 1);
        assert!(cfg.compute_s >= 0.0 && cfg.compute_s.is_finite());
        assert!(cfg.compute_jitter_s >= 0.0 && cfg.compute_jitter_s.is_finite());
        assert!(cfg.samples_per_request.0 >= 1);
        assert!(cfg.samples_per_request.0 <= cfg.samples_per_request.1);
        assert!((0.0..=1.0).contains(&cfg.overlap), "overlap must be in [0, 1]");
        assert!(
            cfg.mir_every == 0 || !mir_tier.is_empty(),
            "mir_every > 0 needs a non-empty mir tier"
        );

        let core = Pipeline::new(
            backends,
            policy,
            hermit_tier,
            mir_tier,
            cfg.batching,
            Some(ResidencySpec { slots: cfg.residency_slots, swap_s: cfg.swap_s }),
        );
        let rngs = rank_rngs(cfg.seed, cfg.ranks);
        let mut model_names: Vec<String> =
            (0..cfg.models).map(HydraWorkload::material_model).collect();
        model_names.push("mir".to_string());

        let mut sim = CogSim {
            cfg,
            core,
            events: EventQueue::new(),
            rngs,
            ranks: (0..cfg.ranks).map(|_| RankState::idle()).collect(),
            step_start_s: 0.0,
            current_step: 0,
            finished_ranks: 0,
            rec: CogRecords::default(),
            steps: Vec::new(),
            events_processed: 0,
            epoch: vec![0; cfg.ranks],
            model_names,
            step_draws: vec![Vec::new(); cfg.ranks],
            step_compute: vec![0.0; cfg.ranks],
            autoscaler: None,
            rank_restarts: 0,
            active_samples: Vec::new(),
        };
        sim.events.reserve(sim.cfg.ranks * 2 + 16);
        sim.events.push_class(0.0, CLASS_ARRIVAL, Event::StepStart { step: 0 });
        sim
    }

    /// Swap the event queue onto the reference `BinaryHeap` backing —
    /// pop order (and therefore every output) is unchanged; only the
    /// queue's complexity profile differs.  For differential tests
    /// and A/B benchmarks.
    pub fn use_binary_heap_queue(&mut self) {
        self.events.convert_to_binary_heap();
    }

    /// Arm a control-plane trace and/or the reactive autoscaler.
    /// Each [`FleetEvent`] fires at its time as an ordinary
    /// arrival-class event; an empty trace with no autoscaler adds
    /// nothing, so the run stays bit-identical to a static one (the
    /// differential suite pins this).  The autoscaler manages the
    /// hermit tier: backends past `initial` start parked, and the
    /// pool grows/shrinks one backend per step from the mean routing
    /// backlog.
    pub fn with_control(&mut self, trace: &[FleetEvent], autoscaler: Option<AutoscalerCfg>) {
        for ev in trace {
            assert!(
                ev.at_s >= 0.0 && ev.at_s.is_finite(),
                "fleet event time must be finite and non-negative ({})",
                ev.at_s
            );
            self.events.push_class(ev.at_s, CLASS_ARRIVAL, Event::Fleet { action: ev.action });
        }
        if let Some(cfg) = autoscaler {
            let tier = self.core.hermit_tier().to_vec();
            // programmatic misuse panics here; user-supplied specs
            // were already validated at the CLI/sweep boundary
            cfg.assert_valid(tier.len());
            for &idx in tier.iter().skip(cfg.initial) {
                self.core.control_backend_leave(idx);
            }
            // nothing is in flight at t = 0: deactivating idle
            // backends produces no observable effects
            let fx = self.core.take_effects();
            self.core.recycle_effects(fx);
            self.autoscaler = Some(cfg);
        }
    }

    /// As [`Self::with_tiers`], with remote dispatches carried by the
    /// contention-aware fabric ([`crate::fabric`]): request payload
    /// in, result payload out, and residency swaps as bulk weight
    /// transfers — all competing for the same oversubscribed uplinks
    /// under max-min fair share.  Backends whose accel endpoint is
    /// node-local in the topology keep the legacy fixed-charge path.
    pub fn with_fabric(
        backends: Vec<Box<dyn Backend>>,
        policy: Policy,
        cfg: CogSimConfig,
        hermit_tier: Vec<usize>,
        mir_tier: Vec<usize>,
        spec: FabricSpec,
    ) -> CogSim {
        let mut sim = Self::with_tiers(backends, policy, cfg, hermit_tier, mir_tier);
        sim.core.attach_fabric(spec);
        sim
    }

    // ------------------------------------------------------ run loop

    fn pump(&mut self) -> bool {
        let Some((t, event)) = self.events.pop() else {
            return false;
        };
        self.events_processed += 1;
        self.core.advance_to(t);
        self.handle(event);
        true
    }

    /// Drain the event queue completely: all T timesteps of all N
    /// ranks run to their final barrier.
    pub fn run_to_completion(&mut self) {
        while self.pump() {}
    }

    fn handle(&mut self, event: Event) {
        match event {
            Event::StepStart { step } => self.on_step_start(step),
            Event::RankBurst { rank, epoch } => self.on_rank_burst(rank, epoch),
            Event::ComputeDone { rank, epoch } => self.on_compute_done(rank, epoch),
            Event::Fleet { action } => self.on_fleet(action),
            Event::Pipe(ev) => {
                self.core.handle(ev);
                self.apply_effects();
            }
        }
    }

    // ------------------------------------------------- timestep loop

    /// Barrier release: every rank starts its physics compute, and
    /// this step's inference burst is scheduled at each rank's
    /// emission point.  Request draws happen here, in rank order, so
    /// a rank's stream is independent of the total rank count.
    fn on_step_start(&mut self, step: usize) {
        self.autoscale();
        self.active_samples.push(self.core.active_count() as u64);
        self.step_start_s = self.core.clock_s();
        self.current_step = step;
        self.finished_ranks = 0;
        let (lo, hi) = self.cfg.samples_per_request;
        for rank in 0..self.cfg.ranks {
            let jitter = if self.cfg.compute_jitter_s > 0.0 {
                self.rngs[rank].uniform(0.0, self.cfg.compute_jitter_s)
            } else {
                0.0
            };
            self.step_compute[rank] = self.cfg.compute_s + jitter;
            let mut draws = std::mem::take(&mut self.step_draws[rank]);
            draws.clear();
            for _ in 0..self.cfg.requests_per_step {
                let model = self.rngs[rank].below(self.cfg.models);
                let samples = self.rngs[rank].range(lo, hi);
                draws.push((model, samples));
            }
            if self.cfg.mir_every > 0 && step % self.cfg.mir_every == 0 {
                // "mir" sits one past the material instances
                draws.push((self.cfg.models, self.cfg.mir_samples));
            }
            self.step_draws[rank] = draws;
            self.emit_step(rank);
        }
    }

    /// (Re)start `rank`'s current step at the current clock: schedule
    /// its physics end and emit the stored draws at the emission
    /// point.  Called once per rank per step, and again on every
    /// checkpoint/restart (same draws — the checkpoint is the step's
    /// input state, not a fresh sample).
    fn emit_step(&mut self, rank: usize) {
        let now = self.core.clock_s();
        let compute = self.step_compute[rank];
        let emit_s = now + (1.0 - self.cfg.overlap) * compute;
        let compute_end_s = now + compute;
        let epoch = self.epoch[rank];
        // Lazy bulk arrivals: the rank's whole burst shares `emit_s`,
        // so one group event replaces the per-draw arrival events.
        // The burst pops before this rank's ComputeDone (earlier
        // time, or same instant with a smaller seq), and everything a
        // submission schedules lands at a strictly later instant, so
        // the pop sequence — and every output byte — matches the
        // eager per-request push exactly.
        let outstanding = self.step_draws[rank].len();
        self.events.push_class(emit_s, CLASS_ARRIVAL, Event::RankBurst { rank, epoch });
        self.ranks[rank] = RankState {
            compute_end_s,
            emit_s,
            outstanding,
            compute_done: false,
            finished: false,
            finish_s: 0.0,
            last_record: None,
        };
        self.events.push_class(compute_end_s, CLASS_ARRIVAL, Event::ComputeDone {
            rank,
            epoch,
        });
    }

    fn on_compute_done(&mut self, rank: usize, epoch: u32) {
        if epoch != self.epoch[rank] {
            return; // pre-failure physics: the restarted rank re-computes
        }
        self.ranks[rank].compute_done = true;
        self.try_finish(rank);
    }

    fn try_finish(&mut self, rank: usize) {
        let st = &mut self.ranks[rank];
        if st.finished || !st.compute_done || st.outstanding > 0 {
            return;
        }
        st.finished = true;
        st.finish_s = self.core.clock_s();
        self.finished_ranks += 1;
        if self.finished_ranks == self.cfg.ranks {
            self.end_step();
        }
    }

    /// All ranks reached the barrier: record the step's critical-path
    /// breakdown and release the next step (at this very instant —
    /// the barrier itself is free).
    fn end_step(&mut self) {
        let start = self.step_start_s;
        let end = self.core.clock_s();
        let step = self.current_step;
        let mut straggler = 0usize;
        for r in 1..self.cfg.ranks {
            if self.ranks[r].finish_s > self.ranks[straggler].finish_s {
                straggler = r;
            }
        }
        let min_finish =
            self.ranks.iter().map(|r| r.finish_s).fold(f64::INFINITY, f64::min);
        let st = &self.ranks[straggler];
        // Compute-bound: the straggler's physics outlasted its last
        // completion (or it had nothing in flight), so the whole step
        // is compute.  Otherwise the chain is: non-overlapped compute
        // until emission, then the critical (= last-completing)
        // request's batching wait, backend queue, swap, link, execute.
        let compute_bound = match st.last_record {
            None => true,
            Some(id) => self.rec.complete_s[id] <= st.compute_end_s,
        };
        let breakdown = if compute_bound {
            StepBreakdown {
                step,
                start_s: start,
                end_s: end,
                straggler,
                compute_s: end - start,
                queue_s: 0.0,
                swap_s: 0.0,
                network_s: 0.0,
                contention_s: 0.0,
                service_s: 0.0,
                spread_s: end - min_finish,
            }
        } else {
            let crit = st.last_record.expect("inference-bound step has a record");
            StepBreakdown {
                step,
                start_s: start,
                end_s: end,
                straggler,
                compute_s: self.rec.emit_s[crit] - start,
                queue_s: (self.rec.dispatch_s[crit] - self.rec.emit_s[crit])
                    + self.rec.wait_s[crit],
                swap_s: self.rec.swap_s[crit],
                network_s: self.rec.link_s[crit],
                contention_s: self.rec.contention_s[crit],
                service_s: self.rec.exec_s[crit],
                spread_s: end - min_finish,
            }
        };
        self.steps.push(breakdown);
        let next = step + 1;
        if next < self.cfg.timesteps {
            self.events.push_class(
                self.core.clock_s(),
                CLASS_ARRIVAL,
                Event::StepStart { step: next },
            );
        }
    }

    // ------------------------------------------------- control plane

    fn on_fleet(&mut self, action: FleetAction) {
        match action {
            FleetAction::BackendLeave(idx) => {
                self.core.control_backend_leave(idx);
                self.apply_effects();
            }
            FleetAction::BackendJoin(idx) => {
                self.core.control_backend_join(idx);
                self.apply_effects();
            }
            FleetAction::LinkDegrade(factor) => {
                self.core.control_link_scale(factor);
                self.apply_effects();
            }
            FleetAction::LinkRestore => {
                self.core.control_link_scale(1.0);
                self.apply_effects();
            }
            FleetAction::RankFail(rank) => self.on_rank_fail(rank),
        }
    }

    /// Rank checkpoint/restart: the rank loses its in-flight
    /// timestep and replays it from the step's input state — same
    /// physics duration, same request draws.  Responses to the lost
    /// attempt's requests still arrive (the pool did the work) but
    /// count as waste: they no longer advance the barrier.  A rank
    /// already checkpointed at this step's barrier loses nothing.
    fn on_rank_fail(&mut self, rank: usize) {
        assert!(rank < self.cfg.ranks, "unknown rank {rank}");
        if self.steps.len() >= self.cfg.timesteps || self.ranks[rank].finished {
            return;
        }
        self.epoch[rank] += 1;
        self.rank_restarts += 1;
        if self.core.trace_armed() {
            let detail = format!("rank {rank} checkpoint restart");
            self.core.trace_marker("rank_fail", &detail);
        }
        self.emit_step(rank);
    }

    /// Reactive queue-depth autoscaling, evaluated at every barrier
    /// release: grow by the lowest-index parked hermit backend when
    /// the mean routing backlog per active backend exceeds `high_s`;
    /// shrink the highest-index *idle* one when it falls below
    /// `low_s`.  One action per step keeps the policy stable.
    fn autoscale(&mut self) {
        let Some(cfg) = self.autoscaler else { return };
        let tier = self.core.hermit_tier().to_vec();
        let active: Vec<usize> =
            tier.iter().copied().filter(|&i| self.core.is_active(i)).collect();
        if active.is_empty() {
            if let Some(&idx) = tier.first() {
                self.core.control_backend_join(idx);
                if self.core.trace_armed() {
                    let detail = format!("backend {idx} joins (pool empty)");
                    self.core.trace_marker("autoscale_up", &detail);
                }
                self.apply_effects();
            }
            return;
        }
        let mean_backlog =
            active.iter().map(|&i| self.core.backlog_s(i)).sum::<f64>() / active.len() as f64;
        if mean_backlog > cfg.high_s && active.len() < cfg.max_active {
            if let Some(&idx) = tier.iter().find(|&&i| !self.core.is_active(i)) {
                self.core.control_backend_join(idx);
                if self.core.trace_armed() {
                    let detail =
                        format!("backend {idx} joins (mean backlog {mean_backlog:.6}s)");
                    self.core.trace_marker("autoscale_up", &detail);
                }
                self.apply_effects();
            }
        } else if mean_backlog < cfg.low_s && active.len() > cfg.min_active {
            let idle = active
                .iter()
                .rev()
                .find(|&&i| self.core.live_batches(i) == 0 && self.core.backlog_s(i) <= 0.0);
            if let Some(&idx) = idle {
                self.core.control_backend_leave(idx);
                if self.core.trace_armed() {
                    let detail =
                        format!("backend {idx} parks (mean backlog {mean_backlog:.6}s)");
                    self.core.trace_marker("autoscale_down", &detail);
                }
                self.apply_effects();
            }
        }
    }

    // ------------------------------------------------------- routing

    /// A rank's burst reached its emission instant: submit every
    /// stored draw of the step, in draw order.  A stale epoch drops
    /// the whole group — the same set each eager arrival's individual
    /// check would have dropped, since all of them carry this epoch.
    fn on_rank_burst(&mut self, rank: usize, epoch: u32) {
        if epoch != self.epoch[rank] {
            return; // emitted before the failure: lost with the checkpoint
        }
        for k in 0..self.step_draws[rank].len() {
            let (model, samples) = self.step_draws[rank][k];
            self.submit_draw(rank, model, samples, epoch);
        }
    }

    fn submit_draw(&mut self, rank: usize, model: usize, samples: usize, epoch: u32) {
        let id = self.rec.on_submit(self.current_step, self.core.clock_s(), epoch);
        let submitted = self.core.submit(rank, &self.model_names[model], samples);
        debug_assert_eq!(id, submitted, "engine/pipeline id spaces align");
        self.apply_effects();
    }

    /// Interpret the pipeline's effects, in order: open records for
    /// dispatched batches, insert scheduled events (insertion order =
    /// heap seq order), then run the barrier accounting for completed
    /// batches.  The drained shell goes back to the pipeline's free
    /// lists.
    fn apply_effects(&mut self) {
        let mut effects = self.core.take_effects();
        let clock = self.core.clock_s();
        // a backend left: void the orphans' completion state first —
        // each reappears in `dispatched` below with `retry` set
        for &id in &effects.orphaned {
            self.rec.complete_s[id] = f64::NAN;
            self.rec.retried[id] = true;
        }
        for d in &effects.dispatched {
            self.open_records(d, clock);
        }
        for (t, class, ev) in effects.scheduled.drain(..) {
            self.events.push_class(t, class, Event::Pipe(ev));
        }
        for c in &effects.completed {
            self.on_batch_done(c, clock);
        }
        self.core.recycle_effects(effects);
    }

    fn open_records(&mut self, d: &Dispatched, clock: f64) {
        let (complete_s, wait_s, swap_s, link_s, exec_s) = match d.outcome {
            Outcome::Direct { wait_s, swap_s, link_s, exec_s, complete_s } => {
                (complete_s, wait_s, swap_s, link_s, exec_s)
            }
            Outcome::InFlight { .. } => (f64::NAN, 0.0, 0.0, 0.0, 0.0),
        };
        for &id in &d.ids {
            if !d.retry {
                // first dispatch: the id takes its place in the
                // dispatch-order index
                self.rec.order.push(id as u32);
            }
            // retries keep the id's one row; the routing fields
            // describe the new attempt
            self.rec.dispatch_s[id] = clock;
            self.rec.complete_s[id] = complete_s;
            self.rec.backend[id] = d.backend as u32;
            self.rec.batch_samples[id] = d.batch_samples as u32;
            self.rec.wait_s[id] = wait_s;
            self.rec.swap_s[id] = swap_s;
            self.rec.link_s[id] = link_s;
            self.rec.contention_s[id] = 0.0;
            self.rec.exec_s[id] = exec_s;
        }
    }

    fn on_batch_done(&mut self, c: &Completed, clock: f64) {
        if let (Some(_), Some(timing)) = (c.token, c.timing) {
            // fabric path: fill the batch's records with the measured
            // phase timings (addressed by id — identical to the old
            // contiguous-block fill on a static run, and correct for
            // retried batches whose records are scattered)
            for &id in &c.ids {
                self.rec.complete_s[id] = clock;
                self.rec.wait_s[id] = timing.wait_s;
                self.rec.swap_s[id] = timing.swap_s;
                self.rec.link_s[id] = timing.link_s;
                self.rec.contention_s[id] = timing.contention_s;
                self.rec.exec_s[id] = timing.exec_s;
            }
        }
        for &id in &c.ids {
            let (rank, _, _) = self.core.request(id);
            if self.rec.epoch[id] != self.epoch[rank] {
                continue; // wasted work from a pre-failure epoch
            }
            let st = &mut self.ranks[rank];
            debug_assert!(st.outstanding > 0, "completion for an idle rank");
            st.outstanding -= 1;
            // completions pop in time order, so the last one processed
            // is the rank's latest (ties: latest dispatched wins —
            // deterministic)
            st.last_record = Some(id);
            self.try_finish(rank);
        }
    }

    // ------------------------------------------- flight recorder

    /// Arm the flight recorder on the shared pipeline (see
    /// [`crate::trace`]).  Call after construction, before any event
    /// is processed.
    pub fn arm_trace(&mut self) {
        self.core.arm_trace();
    }

    /// Attach a recorder but leave it disarmed — compiles the hook
    /// call sites into the hot path without recording anything (the
    /// bench-gate overhead guard).
    pub fn attach_disarmed_recorder(&mut self) {
        self.core.attach_disarmed_recorder();
    }

    /// Detach the recorder, finalizing open tracks at the current
    /// virtual clock.
    pub fn take_recorder(&mut self) -> Option<Box<crate::trace::Recorder>> {
        self.core.take_recorder()
    }

    /// Always-on per-device busy integral (seconds of service), the
    /// recorder's reconciliation ground truth.
    pub fn device_busy_s(&self) -> &[f64] {
        self.core.device_busy_s()
    }

    // ----------------------------------------------------- accessors

    pub fn clock_s(&self) -> f64 {
        self.core.clock_s()
    }

    pub fn policy(&self) -> Policy {
        self.core.policy()
    }

    /// Requests that have entered the router.
    pub fn submitted(&self) -> u64 {
        self.core.submitted()
    }

    /// Requests whose completion event has fired.
    pub fn completed(&self) -> u64 {
        self.core.completed()
    }

    /// Dispatched but not yet completed (a retry is a re-dispatch of
    /// the same request, not a new in-flight unit).
    pub fn in_flight(&self) -> u64 {
        self.core.dispatched() - self.core.retries() - self.core.completed()
    }

    /// Requests re-dispatched after a backend leave orphaned them.
    pub fn retries(&self) -> u64 {
        self.core.retries()
    }

    /// Requests orphaned by backend leaves (each was retried).
    pub fn orphaned(&self) -> u64 {
        self.core.orphaned()
    }

    /// Requests parked because no backend of a usable tier is active.
    pub fn parked(&self) -> u64 {
        self.core.parked_requests()
    }

    /// Whether backend `idx` is currently serving.
    pub fn backend_active(&self, idx: usize) -> bool {
        self.core.is_active(idx)
    }

    /// Currently-active backend count.
    pub fn active_count(&self) -> usize {
        self.core.active_count()
    }

    /// Checkpoint/restart replays across all ranks so far.
    pub fn rank_restarts(&self) -> u64 {
        self.rank_restarts
    }

    /// Requests waiting in the batching window.
    pub fn batcher_pending(&self) -> u64 {
        self.core.batcher_pending()
    }

    /// Batches dispatched so far.
    pub fn batches(&self) -> u64 {
        self.core.batches()
    }

    /// Residency misses so far.
    pub fn swaps(&self) -> u64 {
        self.core.swaps()
    }

    /// Events popped off the queue so far (the micro-benchmark's
    /// denominator: events/sec = this over wall time).
    pub fn events_processed(&self) -> u64 {
        self.events_processed
    }

    /// Materialize one request's record row from the columnar store.
    fn record(&self, id: usize) -> CogRecord {
        let (rank, model, samples) = self.core.request(id);
        CogRecord {
            id: id as u64,
            step: self.rec.step[id] as usize,
            rank,
            model: model.to_string(),
            samples,
            emit_s: self.rec.emit_s[id],
            dispatch_s: self.rec.dispatch_s[id],
            complete_s: self.rec.complete_s[id],
            backend: self.rec.backend[id] as usize,
            batch_samples: self.rec.batch_samples[id] as usize,
            wait_s: self.rec.wait_s[id],
            swap_s: self.rec.swap_s[id],
            link_s: self.rec.link_s[id],
            contention_s: self.rec.contention_s[id],
            exec_s: self.rec.exec_s[id],
            retried: self.rec.retried[id],
        }
    }

    /// Per-request records, in dispatch order, materialized from the
    /// columnar store (test/report convenience — the summary path
    /// reads the columns directly).
    pub fn records(&self) -> Vec<CogRecord> {
        self.rec.order.iter().map(|&id| self.record(id as usize)).collect()
    }

    /// Completed per-timestep breakdowns, in step order.
    pub fn steps(&self) -> &[StepBreakdown] {
        &self.steps
    }

    /// Virtual time of the last barrier (defined after
    /// [`Self::run_to_completion`]).
    pub fn time_to_solution_s(&self) -> f64 {
        self.steps.last().map_or(0.0, |s| s.end_s)
    }

    /// Summarise the run (intended after [`Self::run_to_completion`]).
    pub fn summary(&self) -> CogSummary {
        // completed records only: orphaned-not-yet-recompleted work has
        // complete_s = NaN; retried completions are excluded from the
        // latency distribution (they are not first-attempt samples).
        // Iterates the columnar store in dispatch order — the same
        // accumulation order as the old row store, so every float in
        // the summary is bit-identical.
        let rec = &self.rec;
        let finished: Vec<usize> = rec
            .order
            .iter()
            .map(|&id| id as usize)
            .filter(|&id| rec.complete_s[id].is_finite())
            .collect();
        let latencies: Vec<f64> = finished
            .iter()
            .filter(|&&id| !rec.retried[id])
            .map(|&id| rec.complete_s[id] - rec.emit_s[id])
            .collect();
        let mut samples: u64 = 0;
        for &id in &finished {
            let (_, _, n) = self.core.request(id);
            samples += n as u64;
        }
        let mut straggler_counts = vec![0u64; self.cfg.ranks];
        let mut total_compute_s = 0.0;
        let mut total_queue_s = 0.0;
        let mut total_swap_s = 0.0;
        let mut total_network_s = 0.0;
        let mut total_contention_s = 0.0;
        let mut total_service_s = 0.0;
        let mut max_spread_s = 0.0f64;
        for s in &self.steps {
            straggler_counts[s.straggler] += 1;
            total_compute_s += s.compute_s;
            total_queue_s += s.queue_s;
            total_swap_s += s.swap_s;
            total_network_s += s.network_s;
            total_contention_s += s.contention_s;
            total_service_s += s.service_s;
            max_spread_s = max_spread_s.max(s.spread_s);
        }
        let tts = self.time_to_solution_s();
        let submitted = self.core.submitted();
        let mean_active_backends = if self.active_samples.is_empty() {
            self.core.active_count() as f64
        } else {
            self.active_samples.iter().sum::<u64>() as f64 / self.active_samples.len() as f64
        };
        CogSummary {
            ranks: self.cfg.ranks as u64,
            timesteps: self.steps.len() as u64,
            requests: finished.len() as u64,
            samples,
            batches: self.core.batches(),
            time_to_solution_s: tts,
            steps: self.steps.clone(),
            total_compute_s,
            total_queue_s,
            total_swap_s,
            total_network_s,
            total_contention_s,
            total_service_s,
            latency: LatencyDist::from_latencies(&latencies),
            swaps: self.core.swaps(),
            swap_time_s: self.core.swap_time_s(),
            straggler_counts,
            max_spread_s,
            mean_step_s: if self.steps.is_empty() {
                0.0
            } else {
                tts / self.steps.len() as f64
            },
            submitted,
            retries: self.core.retries(),
            failed: submitted - finished.len() as u64 - self.core.batcher_pending(),
            rank_restarts: self.rank_restarts,
            mean_active_backends,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{GpuBackend, RduBackend};
    use crate::devices::{Api, Gpu};
    use crate::rdu::RduApi;

    fn gpu_fleet(n: usize) -> Vec<Box<dyn Backend>> {
        (0..n)
            .map(|i| {
                Box::new(GpuBackend::node_local(
                    format!("gpu/rank{i}"),
                    Gpu::a100(),
                    Api::TrtCudaGraphs,
                )) as Box<dyn Backend>
            })
            .collect()
    }

    fn pool() -> Vec<Box<dyn Backend>> {
        vec![
            Box::new(RduBackend::disaggregated("rdu/pool0", 4, RduApi::CppOptimized)),
            Box::new(RduBackend::disaggregated("rdu/pool1", 2, RduApi::Python)),
        ]
    }

    #[test]
    fn coupled_run_completes_every_step_and_request() {
        let cfg = CogSimConfig { ranks: 6, timesteps: 5, ..Default::default() };
        let mut sim = CogSim::new(pool(), Policy::LeastOutstanding, cfg);
        sim.run_to_completion();
        assert_eq!(sim.steps().len(), 5);
        assert_eq!(sim.submitted(), 6 * 5 * 6);
        assert_eq!(sim.completed(), sim.submitted());
        assert_eq!(sim.in_flight(), 0);
        assert_eq!(sim.batcher_pending(), 0);
        assert_eq!(sim.records().len() as u64, sim.submitted());
        assert!(sim.time_to_solution_s() > 0.0);
        // steps tile the run: each starts where the previous ended
        for pair in sim.steps().windows(2) {
            assert_eq!(pair[0].end_s, pair[1].start_s);
        }
    }

    #[test]
    fn per_step_breakdown_sums_to_duration() {
        let cfg = CogSimConfig {
            ranks: 8,
            timesteps: 6,
            swap_s: 100e-6,
            compute_jitter_s: 0.5e-3,
            ..Default::default()
        };
        let mut sim = CogSim::new(pool(), Policy::RoundRobin, cfg);
        sim.run_to_completion();
        for s in sim.steps() {
            assert!(
                (s.components_sum_s() - s.duration_s()).abs() < 1e-9,
                "step {}: components {} vs duration {}",
                s.step,
                s.components_sum_s(),
                s.duration_s()
            );
            assert!(s.spread_s >= 0.0);
            assert!(s.straggler < 8);
        }
    }

    #[test]
    fn compute_bound_steps_are_pure_compute() {
        // Overlap 1.0 with enormous compute: inference hides entirely,
        // every step is compute-bound and exactly compute_s long.
        let cfg = CogSimConfig {
            ranks: 2,
            timesteps: 3,
            compute_s: 1.0,
            overlap: 1.0,
            ..Default::default()
        };
        let mut sim = CogSim::new(gpu_fleet(2), Policy::LatencyAware, cfg);
        sim.run_to_completion();
        for s in sim.steps() {
            assert!((s.duration_s() - 1.0).abs() < 1e-12, "step {}", s.step);
            assert_eq!(s.queue_s, 0.0);
            assert_eq!(s.service_s, 0.0);
        }
    }

    #[test]
    fn swap_cost_slows_time_to_solution() {
        let tts = |swap_s: f64| {
            let cfg = CogSimConfig { swap_s, ..Default::default() };
            let mut sim = CogSim::new(pool(), Policy::RoundRobin, cfg);
            sim.run_to_completion();
            sim.time_to_solution_s()
        };
        let free = tts(0.0);
        let costly = tts(1e-3);
        assert!(costly > free, "swap 1ms {costly} vs free {free}");
    }

    #[test]
    fn residency_hits_need_no_swap() {
        // One model, one backend: exactly one miss ever.
        let cfg = CogSimConfig { models: 1, swap_s: 1e-3, ..Default::default() };
        let mut sim = CogSim::new(gpu_fleet(1), Policy::RoundRobin, cfg);
        sim.run_to_completion();
        assert_eq!(sim.swaps(), 1);
        let records = sim.records();
        let with_swap: Vec<&CogRecord> = records.iter().filter(|r| r.swap_s > 0.0).collect();
        assert_eq!(with_swap.len(), 1, "only the first dispatch pays");
    }

    #[test]
    fn overlap_hides_inference_behind_compute() {
        let tts = |overlap: f64| {
            let cfg = CogSimConfig { overlap, ..Default::default() };
            let mut sim = CogSim::new(pool(), Policy::LatencyAware, cfg);
            sim.run_to_completion();
            sim.time_to_solution_s()
        };
        assert!(tts(1.0) <= tts(0.0) + 1e-12);
    }

    #[test]
    fn mir_requests_ride_their_tier() {
        let cfg = CogSimConfig {
            ranks: 2,
            timesteps: 4,
            mir_every: 2,
            mir_samples: 128,
            ..Default::default()
        };
        let mut fleet = gpu_fleet(2);
        fleet.extend(pool());
        let mut sim =
            CogSim::with_tiers(fleet, Policy::LatencyAware, cfg, vec![2, 3], vec![0, 1]);
        sim.run_to_completion();
        assert!(sim.records().iter().any(|r| r.model == "mir"));
        for r in sim.records() {
            if r.model.starts_with("mir") {
                assert!(r.backend < 2, "mir routed to {}", r.backend);
            } else {
                assert!(r.backend >= 2, "hermit routed to {}", r.backend);
            }
        }
        // MIR fires on steps 0 and 2: 2 ranks x 2 steps
        assert_eq!(sim.records().iter().filter(|r| r.model == "mir").count(), 4);
    }

    #[test]
    fn batching_window_coalesces_the_step_burst() {
        let cfg = CogSimConfig {
            ranks: 16,
            timesteps: 3,
            models: 4,
            batching: Batching::Window { window_s: 200e-6, max_batch: 256 },
            ..Default::default()
        };
        let mut sim = CogSim::new(pool(), Policy::LatencyAware, cfg);
        sim.run_to_completion();
        assert_eq!(sim.completed(), sim.submitted());
        assert!(
            sim.batches() * 4 <= sim.submitted(),
            "{} batches for {} requests",
            sim.batches(),
            sim.submitted()
        );
        assert!(sim.records().iter().any(|r| r.batch_samples > r.samples));
    }

    #[test]
    fn summary_accounts_everything() {
        let cfg = CogSimConfig { ranks: 4, timesteps: 6, swap_s: 50e-6, ..Default::default() };
        let mut sim = CogSim::new(pool(), Policy::ModelAffinity, cfg);
        sim.run_to_completion();
        let s = sim.summary();
        assert_eq!(s.requests, sim.submitted());
        assert_eq!(s.timesteps, 6);
        assert_eq!(s.steps.len(), 6);
        assert_eq!(s.straggler_counts.iter().sum::<u64>(), 6);
        assert_eq!(s.swaps, sim.swaps());
        assert!(s.time_to_solution_s > 0.0);
        assert!((s.mean_step_s * 6.0 - s.time_to_solution_s).abs() < 1e-9);
        assert!(s.total_compute_s > 0.0);
        assert_eq!(s.total_contention_s, 0.0, "no fabric layer, no contention");
        let hist_total: u64 =
            s.latency.histogram.iter().map(|(_, c)| c).sum::<u64>() + s.latency.overflow;
        assert_eq!(hist_total, s.requests);
        // lazy bulk arrivals: a rank's whole burst is one event, but
        // every batch completion still costs one
        assert!(sim.events_processed() > 0);
        assert!(sim.events_processed() >= sim.batches(), "every batch completes via an event");
    }

    #[test]
    fn heap_and_ladder_queues_produce_identical_runs() {
        // The queue backing is a pure complexity trade: same pushes,
        // same pop order, byte-identical records, steps, and summary.
        let cfg = CogSimConfig {
            ranks: 8,
            timesteps: 6,
            swap_s: 100e-6,
            compute_jitter_s: 0.5e-3,
            mir_every: 2,
            batching: Batching::Window { window_s: 200e-6, max_batch: 256 },
            ..Default::default()
        };
        let mut lad = CogSim::new(pool(), Policy::LeastOutstanding, cfg);
        let mut heap = CogSim::new(pool(), Policy::LeastOutstanding, cfg);
        heap.use_binary_heap_queue();
        lad.run_to_completion();
        heap.run_to_completion();
        assert_eq!(lad.records(), heap.records());
        assert_eq!(lad.steps(), heap.steps());
        assert_eq!(lad.summary(), heap.summary());
        assert_eq!(lad.events_processed(), heap.events_processed());
    }

    // ------------------------------------------------- fabric layer

    fn pool_fabric(ranks: usize, oversub: f64) -> crate::fabric::FabricSpec {
        crate::fabric::FabricSpec {
            topology: crate::fabric::Topology::pooled(ranks, 2, oversub),
            accel_of_backend: vec![0, 1],
        }
    }

    #[test]
    fn fabric_run_conserves_and_breakdowns_still_sum() {
        let cfg = CogSimConfig {
            ranks: 12,
            timesteps: 5,
            swap_s: 200e-6,
            ..Default::default()
        };
        let mut sim = CogSim::with_fabric(
            pool(),
            Policy::LeastOutstanding,
            cfg,
            vec![0, 1],
            vec![0, 1],
            pool_fabric(12, 4.0),
        );
        sim.run_to_completion();
        assert_eq!(sim.steps().len(), 5);
        assert_eq!(sim.submitted(), 12 * 5 * 6);
        assert_eq!(sim.completed(), sim.submitted());
        assert_eq!(sim.in_flight(), 0);
        // the critical-path decomposition survives the multi-phase
        // pipeline: components still sum to each step's duration
        for s in sim.steps() {
            assert!(
                (s.components_sum_s() - s.duration_s()).abs() < 1e-9,
                "step {}: components {} vs duration {}",
                s.step,
                s.components_sum_s(),
                s.duration_s()
            );
            assert!(s.contention_s >= 0.0);
            assert!(s.contention_s <= s.network_s + 1e-15, "contention is a subset");
        }
        // a 12-rank burst on a 4:1 fabric must show real contention
        let s = sim.summary();
        assert!(s.total_contention_s > 0.0);
        assert!(s.total_network_s >= s.total_contention_s);
    }

    #[test]
    fn fabric_oversubscription_monotonically_slows_tts() {
        let tts = |oversub: f64| {
            let cfg = CogSimConfig { ranks: 16, timesteps: 4, ..Default::default() };
            let mut sim = CogSim::with_fabric(
                pool(),
                Policy::LeastOutstanding,
                cfg,
                vec![0, 1],
                vec![0, 1],
                pool_fabric(16, oversub),
            );
            sim.run_to_completion();
            sim.time_to_solution_s()
        };
        let mut last = 0.0;
        for oversub in [1.0, 2.0, 4.0, 8.0] {
            let t = tts(oversub);
            assert!(t >= last - 1e-12, "oversub {oversub}: TTS {t} < previous {last}");
            last = t;
        }
    }

    #[test]
    fn fabric_swap_flows_congest_inference() {
        // Same run, swaps free vs swaps as 4.2 MB weight transfers
        // (2 ms at line rate) on the shared downlink: the swap
        // traffic must slow time-to-solution, and the engine must
        // measure real swap seconds.
        let run = |swap_s: f64| {
            let cfg = CogSimConfig {
                ranks: 8,
                timesteps: 4,
                swap_s,
                ..Default::default()
            };
            let mut sim = CogSim::with_fabric(
                pool(),
                Policy::RoundRobin,
                cfg,
                vec![0, 1],
                vec![0, 1],
                pool_fabric(8, 2.0),
            );
            sim.run_to_completion();
            (sim.time_to_solution_s(), sim.summary())
        };
        let (tts_free, free) = run(0.0);
        let (tts_swap, swapped) = run(2e-3);
        assert!(tts_swap > tts_free, "{tts_swap} vs {tts_free}");
        assert_eq!(free.swap_time_s, 0.0);
        assert!(swapped.swaps > 0);
        // a contended swap takes at least its uncontended duration
        assert!(swapped.swap_time_s >= 2e-3 * swapped.swaps as f64 - 1e-9);
    }
}
