//! Arrival processes for the multi-rank CogSim request stream.
//!
//! The paper's in-the-loop workload is *bursty by construction*:
//! every MPI rank reaches the inference point of its timestep at
//! roughly the same moment and emits a handful of tiny per-material
//! requests (§IV-A).  The event simulator models that directly, plus
//! the two classical open-/closed-loop processes every queueing study
//! needs for comparison:
//!
//! * [`ArrivalProcess::Synchronized`] — timestep-synchronised bursts:
//!   at `t = k · period` every rank emits its per-material requests
//!   (optionally spread over a small jitter window).  This is the
//!   CogSim critical path and the regime where dynamic batching pays.
//! * [`ArrivalProcess::Poisson`] — open-loop Poisson arrivals per
//!   rank (exponential inter-arrival times).  Load keeps coming
//!   whether or not the fleet keeps up — exposes saturation.
//! * [`ArrivalProcess::ClosedLoop`] — each rank keeps exactly one
//!   request in flight and thinks for `think_s` between completion
//!   and the next submission — the contention-free limit the
//!   differential test (`eventsim_vs_analytic`) pins against the
//!   analytic [`crate::cluster::Cluster`].

/// How requests enter the system.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    /// Timestep-synchronised bursts across all ranks.
    Synchronized {
        /// Virtual seconds between simulation timesteps.
        period_s: f64,
        /// Requests of one burst spread uniformly over `[t, t+jitter]`
        /// (0 = perfectly synchronised, the worst case).
        jitter_s: f64,
    },
    /// Open-loop Poisson arrivals, per rank.
    Poisson {
        /// Mean request rate per rank, requests/second.
        rate_per_rank: f64,
    },
    /// Closed loop: one outstanding request per rank plus think time.
    ClosedLoop {
        /// Seconds between a completion and the rank's next request.
        think_s: f64,
    },
}

impl ArrivalProcess {
    /// Stable snake_case key for JSON artifacts.
    pub fn key(&self) -> &'static str {
        match self {
            ArrivalProcess::Synchronized { .. } => "synchronized",
            ArrivalProcess::Poisson { .. } => "poisson",
            ArrivalProcess::ClosedLoop { .. } => "closed_loop",
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            ArrivalProcess::Synchronized { .. } => "timestep-synchronized bursts",
            ArrivalProcess::Poisson { .. } => "open-loop Poisson",
            ArrivalProcess::ClosedLoop { .. } => "closed-loop with think time",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keys_are_stable() {
        assert_eq!(
            ArrivalProcess::Synchronized { period_s: 0.02, jitter_s: 0.0 }.key(),
            "synchronized"
        );
        assert_eq!(ArrivalProcess::Poisson { rate_per_rank: 100.0 }.key(), "poisson");
        assert_eq!(ArrivalProcess::ClosedLoop { think_s: 1e-3 }.key(), "closed_loop");
    }
}
