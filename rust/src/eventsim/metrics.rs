//! Full latency-distribution metrics for event-sim runs: the analytic
//! campaign reports means and a few percentiles; queueing phenomena
//! live in the tail, so the event simulator reports
//! p50/p90/p99/p99.9, a log-spaced histogram, and per-rank slowdown
//! (the paper's in-the-loop SLO is per *rank*: one slow rank stalls
//! the whole MPI timestep).

use crate::util::stats;

/// Log-spaced (1-2-5 series) histogram bucket upper bounds, µs.
pub const HIST_EDGES_US: [f64; 19] = [
    1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0, 500.0, 1e3, 2e3, 5e3, 1e4, 2e4, 5e4,
    1e5, 2e5, 5e5, 1e6,
];

/// A latency distribution: summary percentiles + histogram.
#[derive(Debug, Clone, PartialEq)]
pub struct LatencyDist {
    pub count: u64,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p90_s: f64,
    pub p99_s: f64,
    pub p999_s: f64,
    pub max_s: f64,
    /// `(upper_bound_us, count)` per bucket of [`HIST_EDGES_US`].
    pub histogram: Vec<(f64, u64)>,
    /// Latencies above the last bucket edge.
    pub overflow: u64,
}

impl LatencyDist {
    /// Build the distribution from observed latencies.  Non-finite
    /// entries — requests that never completed (failed, or truncated
    /// at the horizon) — are *excluded*, not recorded as 0-latency
    /// samples: quantiles describe completions only, and the caller
    /// reports the never-completed count separately.  When nothing
    /// completed the quantiles are NaN (`stats::percentile` on an
    /// empty population) — the report writers render those as 0.
    pub fn from_latencies(xs: &[f64]) -> LatencyDist {
        let finite: Vec<f64> = xs.iter().copied().filter(|x| x.is_finite()).collect();
        let xs = &finite[..];
        let mut histogram: Vec<(f64, u64)> =
            HIST_EDGES_US.iter().map(|&e| (e, 0u64)).collect();
        let mut overflow = 0u64;
        for &x in xs {
            let us = x * 1e6;
            match histogram.iter_mut().find(|(edge, _)| us <= *edge) {
                Some((_, count)) => *count += 1,
                None => overflow += 1,
            }
        }
        LatencyDist {
            count: xs.len() as u64,
            mean_s: stats::mean(xs),
            p50_s: stats::percentile(xs, 50.0),
            p90_s: stats::percentile(xs, 90.0),
            p99_s: stats::percentile(xs, 99.0),
            p999_s: stats::percentile(xs, 99.9),
            max_s: xs.iter().copied().fold(0.0, f64::max),
            histogram,
            overflow,
        }
    }
}

/// Critical-path decomposition of one bulk-synchronous timestep of
/// the coupled CogSim model ([`crate::eventsim::cogsim`]).  The
/// components follow the straggler rank's longest chain and sum to
/// the step duration (`end_s - start_s`) up to float associativity:
/// non-overlapped compute, then — for the request whose completion
/// released the rank — batching/backend queueing, model-swap charge,
/// link round trip, and device execution.
#[derive(Debug, Clone, PartialEq)]
pub struct StepBreakdown {
    pub step: usize,
    /// Barrier release that started this step, virtual seconds.
    pub start_s: f64,
    /// Barrier at which the last rank finished the step.
    pub end_s: f64,
    /// Rank whose finish set the barrier (lowest index on ties).
    pub straggler: usize,
    /// Non-overlapped physics compute on the critical path.
    pub compute_s: f64,
    /// Batching-window wait + backend queue wait of the critical
    /// request.
    pub queue_s: f64,
    /// Model-residency swap charge of the critical request's batch.
    pub swap_s: f64,
    /// Link round trip of the critical request's batch.
    pub network_s: f64,
    /// Contention share of `network_s`: measured transfer time minus
    /// the uncontended [`crate::netsim::Link::rtt_overhead_s`] for
    /// the same payload.  Zero without the fabric layer; a *subset*
    /// of `network_s`, not an extra component (the sum invariant is
    /// unchanged).
    pub contention_s: f64,
    /// Device execution of the critical request's batch.
    pub service_s: f64,
    /// Straggler spread: last rank finish minus first rank finish.
    pub spread_s: f64,
}

impl StepBreakdown {
    /// Step wall-clock duration.
    pub fn duration_s(&self) -> f64 {
        self.end_s - self.start_s
    }

    /// Sum of the critical-path components (equals `duration_s` up to
    /// float associativity; pinned by `rust/tests/cogsim_props.rs`).
    pub fn components_sum_s(&self) -> f64 {
        self.compute_s + self.queue_s + self.swap_s + self.network_s + self.service_s
    }
}

/// Everything one coupled CogSim run reports: the paper's figure of
/// merit (time-to-solution) plus where it went.
#[derive(Debug, Clone, PartialEq)]
pub struct CogSummary {
    pub ranks: u64,
    pub timesteps: u64,
    /// Inference requests completed (= N·T·K plus any MIR cadence).
    pub requests: u64,
    pub samples: u64,
    /// Batches dispatched to backends.
    pub batches: u64,
    /// Virtual time from t = 0 to the last barrier.
    pub time_to_solution_s: f64,
    /// Per-timestep critical-path decomposition, in step order.
    pub steps: Vec<StepBreakdown>,
    /// Component totals across all steps (critical path only).
    pub total_compute_s: f64,
    pub total_queue_s: f64,
    pub total_swap_s: f64,
    pub total_network_s: f64,
    /// Contention share of `total_network_s` (a subset, not an extra
    /// component): what the shared fabric cost beyond the degenerate
    /// 1-flow link.  Zero without the fabric layer.
    pub total_contention_s: f64,
    pub total_service_s: f64,
    /// Per-request (emit → complete) latency distribution.
    pub latency: LatencyDist,
    /// Residency misses across all dispatched batches.
    pub swaps: u64,
    /// Seconds charged for those misses.
    pub swap_time_s: f64,
    /// How often each rank was the straggler (index = rank).
    pub straggler_counts: Vec<u64>,
    /// Largest per-step finish spread across ranks.
    pub max_spread_s: f64,
    /// Mean step duration (= time_to_solution / timesteps).
    pub mean_step_s: f64,
    /// Requests that entered the router (>= `requests` whenever any
    /// were still in flight, parked, or failed at summary time).
    pub submitted: u64,
    /// Requests re-dispatched after a backend leave orphaned their
    /// batch (their latencies are excluded from `latency` — retried
    /// completions are not first-attempt observations).
    pub retries: u64,
    /// Requests not completed at summary time: in flight, parked
    /// with no live backend, or never dispatched.
    pub failed: u64,
    /// Checkpoint/restart replays across all ranks.
    pub rank_restarts: u64,
    /// Mean active backend count sampled at each step start (the
    /// autoscaler's provisioning trajectory; fleet size when static).
    pub mean_active_backends: f64,
}

/// Everything one event-sim run reports.
#[derive(Debug, Clone, PartialEq)]
pub struct EventSummary {
    /// Requests completed.
    pub requests: u64,
    /// Samples across those requests.
    pub samples: u64,
    /// Batches dispatched to backends (= requests when batching off).
    pub batches: u64,
    /// Mean samples per dispatched batch.
    pub mean_batch_samples: f64,
    /// End-to-end (arrival → completion) latency distribution.
    pub latency: LatencyDist,
    /// Mean link round-trip share of request latency, seconds.
    pub mean_link_overhead_s: f64,
    /// Mean fabric-contention share of the link overhead (measured
    /// transfer time beyond the uncontended round trip); zero without
    /// the fabric layer.
    pub mean_contention_s: f64,
    /// Mean latency per originating rank (index = rank).
    pub per_rank_mean_s: Vec<f64>,
    /// Worst rank mean over best rank mean (1.0 = perfectly fair).
    pub slowdown_max: f64,
    /// Virtual time of the last completion.
    pub makespan_s: f64,
    /// Samples over the makespan.
    pub samples_per_s: f64,
    /// Requests that entered the router (>= `requests` whenever any
    /// were still in flight, parked, or failed at summary time).
    pub submitted: u64,
    /// Requests re-dispatched after a backend leave orphaned their
    /// batch (excluded from `latency` — not first-attempt samples).
    pub retries: u64,
    /// Requests not completed at summary time: in flight, parked
    /// with no live backend, or never dispatched.
    pub failed: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dist_percentiles_and_histogram() {
        // 1..=1000 µs uniformly
        let xs: Vec<f64> = (1..=1000).map(|i| i as f64 * 1e-6).collect();
        let d = LatencyDist::from_latencies(&xs);
        assert_eq!(d.count, 1000);
        assert!((d.p50_s * 1e6 - 500.5).abs() < 1e-6);
        assert!((d.p999_s * 1e6 - 999.001).abs() < 1e-3);
        assert!((d.max_s * 1e6 - 1000.0).abs() < 1e-9);
        // buckets partition the population
        let total: u64 = d.histogram.iter().map(|(_, c)| c).sum::<u64>() + d.overflow;
        assert_eq!(total, 1000);
        // first bucket (<= 1us) holds exactly the 1us sample
        assert_eq!(d.histogram[0], (1.0, 1));
        assert_eq!(d.overflow, 0);
    }

    #[test]
    fn overflow_counted() {
        let d = LatencyDist::from_latencies(&[0.5e-6, 2.0, 5.0]);
        assert_eq!(d.overflow, 2); // 2s and 5s exceed the 1s top edge
        assert_eq!(d.histogram[0].1, 1);
    }

    #[test]
    fn empty_distribution_counts_zero_quantiles_nan() {
        // no completions -> count/mean/max are honest zeros, but the
        // quantiles are NaN (there is no p50 of nothing); the report
        // writers render NaN fields as 0 so goldens stay finite
        let d = LatencyDist::from_latencies(&[]);
        assert_eq!(d.count, 0);
        assert_eq!(d.mean_s, 0.0);
        assert_eq!(d.max_s, 0.0);
        assert_eq!(d.overflow, 0);
        assert!(d.p50_s.is_nan());
        assert!(d.p99_s.is_nan());
        // non-finite inputs are excluded, so an all-failed population
        // behaves exactly like the empty one
        let d = LatencyDist::from_latencies(&[f64::INFINITY, f64::NAN]);
        assert_eq!(d.count, 0);
        assert!(d.p999_s.is_nan());
    }
}
