//! Deterministic pseudo-random numbers: SplitMix64 seeding feeding a
//! xoshiro256** core, plus the sampling helpers the workload
//! generators and property tests need (uniform, normal, choice,
//! shuffle).  No external `rand` crate is available in the offline
//! build, and determinism across runs matters more here than raw
//! statistical strength — every experiment records its seed.

/// xoshiro256** (Blackman & Vigna), seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the 256-bit state.
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next_sm(), next_sm(), next_sm(), next_sm()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) — Lemire's method, unbiased enough
    /// for workload generation.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with the given mean and stddev.
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with the given rate (inter-arrival sampling).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        -self.f64().max(1e-300).ln() / rate
    }

    /// Pick one element uniformly.
    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            items.swap(i, self.below(i + 1));
        }
    }

    /// A vector of standard-normal f32s (synthetic model inputs).
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let a: Vec<u64> = {
            let mut r = Rng::new(42);
            (0..8).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Rng::new(42);
            (0..8).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
        let c: Vec<u64> = {
            let mut r = Rng::new(43);
            (0..8).map(|_| r.next_u64()).collect()
        };
        assert_ne!(a, c);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_is_centered() {
        let mut r = Rng::new(11);
        let mean: f64 = (0..50_000).map(|_| r.f64()).sum::<f64>() / 50_000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let xs: Vec<f64> = (0..50_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_covers_range() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            let v = r.range(3, 5);
            assert!((3..=5).contains(&v));
        }
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(17);
        let mean: f64 =
            (0..50_000).map(|_| r.exponential(4.0)).sum::<f64>() / 50_000.0;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
