//! A micro-benchmark harness (criterion replacement for the offline
//! build).  Mirrors the paper's protocol (§V-A): warm up with 10
//! mini-batches, then measure enough iterations that the wall-clock
//! exceeds a target, reporting mean latency over all mini-batches.

use std::time::{Duration, Instant};

use super::stats;

/// One benchmark's timing summary.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
    /// 95 % CI half-width over per-iteration samples.
    pub ci95: Duration,
}

impl BenchResult {
    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }

    /// Throughput given work-items per iteration.
    pub fn throughput(&self, items_per_iter: usize) -> f64 {
        items_per_iter as f64 / self.mean.as_secs_f64()
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} {:>10.3?} mean  {:>10.3?} p50  {:>10.3?} p95  ±{:>8.3?}  ({} iters)",
            self.name, self.mean, self.p50, self.p95, self.ci95, self.iters
        )
    }
}

/// Benchmark runner with paper-style warmup and a time budget.
pub struct Bencher {
    pub warmup_iters: usize,
    pub min_duration: Duration,
    pub max_iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        // The paper warms up with 10 mini-batches and sizes runs to
        // >10 s wall-clock; we default to a faster 0.5 s budget for CI
        // and let `cargo bench` targets raise it.
        Bencher { warmup_iters: 10, min_duration: Duration::from_millis(500), max_iters: 100_000 }
    }
}

impl Bencher {
    pub fn paper_protocol() -> Self {
        Bencher { warmup_iters: 10, min_duration: Duration::from_secs(10), max_iters: 10_000_000 }
    }

    pub fn quick() -> Self {
        Bencher { warmup_iters: 3, min_duration: Duration::from_millis(100), max_iters: 10_000 }
    }

    /// Run `f` repeatedly; returns timing statistics.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples: Vec<f64> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.min_duration && samples.len() < self.max_iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        let mean = stats::mean(&samples);
        BenchResult {
            name: name.to_string(),
            iters: samples.len(),
            mean: Duration::from_secs_f64(mean),
            p50: Duration::from_secs_f64(stats::percentile(&samples, 50.0)),
            p95: Duration::from_secs_f64(stats::percentile(&samples, 95.0)),
            min: Duration::from_secs_f64(
                samples.iter().copied().fold(f64::INFINITY, f64::min),
            ),
            ci95: Duration::from_secs_f64(stats::ci95_halfwidth(&samples)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bencher { warmup_iters: 1, min_duration: Duration::from_millis(20), max_iters: 1000 };
        let mut counter = 0u64;
        let r = b.run("spin", || {
            counter = counter.wrapping_add(std::hint::black_box(1));
        });
        assert!(r.iters > 0);
        assert!(r.mean > Duration::ZERO);
        assert!(r.p95 >= r.p50);
    }

    #[test]
    fn respects_max_iters() {
        let b = Bencher { warmup_iters: 0, min_duration: Duration::from_secs(5), max_iters: 50 };
        let r = b.run("capped", || {});
        assert_eq!(r.iters, 50);
    }

    #[test]
    fn throughput_computation() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean: Duration::from_millis(10),
            p50: Duration::from_millis(10),
            p95: Duration::from_millis(10),
            min: Duration::from_millis(10),
            ci95: Duration::ZERO,
        };
        // 100 items / 10 ms = 10_000 items/s
        assert!((r.throughput(100) - 10_000.0).abs() < 1e-6);
    }
}
