//! In-tree substrates for the fully-offline build environment.
//!
//! The build image vendors only the `xla` PJRT bridge and its
//! transitive dependencies — no serde, rand, criterion or proptest.
//! Rather than stub those out, this module implements the small slice
//! of each that the system needs:
//!
//! * [`json`]  — a recursive-descent JSON parser (for the AOT
//!   manifest) and a writer (for results/ CSV-adjacent dumps).
//! * [`rng`]   — SplitMix64 + xoshiro256** with normal/uniform/choice
//!   sampling (workload generation, property tests).
//! * [`stats`] — mean / stddev / percentiles / Student-t 95 % CI, the
//!   paper's measurement methodology.
//! * [`bench`] — a warmup+measure micro-benchmark harness used by the
//!   `cargo bench` targets (criterion replacement).

pub mod bench;
pub mod json;
pub mod rng;
pub mod stats;
