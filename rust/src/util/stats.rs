//! Summary statistics matching the paper's measurement methodology:
//! "All experiment measurements were replicated 5 times.  The figures
//! … plot the mean of the 5 measurements with error bars indicating
//! the 95% confidence interval."  (§V-A)

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Two-sided Student-t critical values at 95 % for small n (the paper
/// replicates 5×, i.e. 4 degrees of freedom), falling back to the
/// normal 1.96 beyond the table.
fn t_critical_95(dof: usize) -> f64 {
    const TABLE: [f64; 30] = [
        12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262, 2.228,
        2.201, 2.179, 2.160, 2.145, 2.131, 2.120, 2.110, 2.101, 2.093, 2.086,
        2.080, 2.074, 2.069, 2.064, 2.060, 2.056, 2.052, 2.048, 2.045, 2.042,
    ];
    if dof == 0 {
        return f64::INFINITY;
    }
    if dof <= TABLE.len() {
        TABLE[dof - 1]
    } else {
        1.96
    }
}

/// Half-width of the 95 % confidence interval on the mean.
pub fn ci95_halfwidth(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    t_critical_95(xs.len() - 1) * stddev(xs) / (xs.len() as f64).sqrt()
}

/// Percentile via linear interpolation on the sorted copy
/// (p in [0, 100]).
///
/// The empty slice has **no** quantiles: this returns NaN rather than
/// inventing a 0-latency observation.  Callers that can see an empty
/// population (e.g. a fully-lossy control cell with zero first-attempt
/// completions) must filter or map the NaN themselves — the report
/// writers render non-finite summary values as 0 explicitly.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut sorted: Vec<f64> = xs.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// A tail quantile that is honest at small n.
///
/// [`percentile`]'s linear interpolation is fine in the bulk of a
/// distribution, but in the tail it *invents* values below the
/// observed maximum: the p99 of 2 samples interpolated is ~98 % of
/// the way from min to max, i.e. an optimistic number no request
/// actually experienced.  For n below 100 this uses the nearest-rank
/// (ceiling) definition instead — the p99 of 1, 2, or 3 samples is
/// the observed maximum, which is the only defensible claim — and
/// hands off to the interpolating estimate once n reaches 100, where
/// the two agree to within a sample.
///
/// Boundary behaviour, pinned by the tests below: the empty slice
/// returns NaN (same contract as [`percentile`] — no observations, no
/// quantile), and the n == 100 hand-off is continuous with n == 99:
/// nearest-rank at n = 99 and interpolation at n = 100 differ by at
/// most one sample spacing for any p.
pub fn tail_quantile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let n = xs.len();
    if n >= 100 {
        return percentile(xs, p);
    }
    let mut sorted: Vec<f64> = xs.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    // nearest-rank: the smallest value with at least p% of the
    // sample at or below it
    let rank = ((p / 100.0) * n as f64).ceil() as usize;
    sorted[rank.saturating_sub(1).min(n - 1)]
}

/// A replicated measurement: mean ± 95 % CI over n runs (the paper's
/// plotting convention).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Replicated {
    pub mean: f64,
    pub ci95: f64,
    pub n: usize,
}

impl Replicated {
    pub fn from_samples(xs: &[f64]) -> Self {
        Replicated { mean: mean(xs), ci95: ci95_halfwidth(xs), n: xs.len() }
    }
}

impl std::fmt::Display for Replicated {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:.6} ±{:.6}", self.mean, self.ci95)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[3.0]), 0.0);
        assert_eq!(ci95_halfwidth(&[3.0]), 0.0);
        // no observations -> no quantile: NaN, never a phantom 0
        assert!(percentile(&[], 50.0).is_nan());
        assert!(percentile(&[], 99.0).is_nan());
    }

    #[test]
    fn ci95_five_replicates_uses_t4() {
        // n=5 -> dof=4 -> t = 2.776 (the paper's exact setting).
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        let expect = 2.776 * stddev(&xs) / 5f64.sqrt();
        assert!((ci95_halfwidth(&xs) - expect).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert!((percentile(&xs, 25.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 75.0) - 7.5).abs() < 1e-12);
    }

    #[test]
    fn tail_quantile_small_n_returns_the_observed_max() {
        // regression: interpolated p99 of 2 samples used to report
        // ~98 % of the way to the max — a latency nobody saw.
        assert_eq!(tail_quantile(&[7.0], 99.0), 7.0); // n=1
        assert_eq!(tail_quantile(&[1.0, 9.0], 99.0), 9.0); // n=2
        assert_eq!(tail_quantile(&[3.0, 1.0, 9.0], 99.0), 9.0); // n=3
        assert_eq!(tail_quantile(&[1.0, 9.0], 99.9), 9.0);
        // bulk quantiles still pick sensible ranks at small n
        assert_eq!(tail_quantile(&[3.0, 1.0, 9.0], 50.0), 3.0);
        assert!(tail_quantile(&[], 99.0).is_nan());
    }

    #[test]
    fn tail_quantile_hands_off_to_interpolation_at_n_100() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(tail_quantile(&xs, 99.0), percentile(&xs, 99.0));
        assert_eq!(tail_quantile(&xs, 50.0), percentile(&xs, 50.0));
        // at n=99 we are still nearest-rank: p99 = the 98th index (max)
        let xs: Vec<f64> = (1..=99).map(|i| i as f64).collect();
        assert_eq!(tail_quantile(&xs, 99.0), 99.0);
    }

    #[test]
    fn tail_quantile_n_100_handoff_is_continuous() {
        // the nearest-rank (n = 99) and interpolating (n = 100)
        // estimates must agree to within one sample spacing at the
        // hand-off, for tail and bulk quantiles alike
        let n99: Vec<f64> = (1..=99).map(|i| i as f64).collect();
        let n100: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        for p in [50.0, 90.0, 99.0, 99.9] {
            let jump = (tail_quantile(&n100, p) - tail_quantile(&n99, p)).abs();
            assert!(jump <= 1.0 + 1e-9, "p{p}: discontinuous hand-off ({jump})");
        }
        // exactly at n = 100 the interpolating estimate is in force
        assert_eq!(tail_quantile(&n100, 99.0), percentile(&n100, 99.0));
        assert!((tail_quantile(&n100, 99.0) - 99.01).abs() < 1e-9);
    }

    #[test]
    fn replicated_display() {
        let r = Replicated::from_samples(&[1.0, 1.0, 1.0]);
        assert_eq!(r.n, 3);
        assert_eq!(r.ci95, 0.0);
        assert!(format!("{r}").starts_with("1.0"));
    }
}
