//! Minimal JSON: a recursive-descent parser and a compact writer.
//!
//! Implements the subset of RFC 8259 the system relies on — objects,
//! arrays, strings (with `\uXXXX` escapes), f64 numbers, booleans and
//! null.  The AOT manifest (`artifacts/manifest.json`) is machine
//! generated, so the parser favours clear errors over leniency: no
//! trailing commas, no comments, no NaN/Inf literals.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.  Object keys are kept in a `BTreeMap` so that
/// iteration order (and therefore serialisation) is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Number(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_object().and_then(|o| o.get(key))
    }
}

/// A parse error with byte offset and a short description.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Parse a complete JSON document (rejects trailing garbage).
pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> ParseError {
        ParseError { offset: self.pos, message: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected {:?}", b as char)))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::String(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(format!("unexpected character {:?}", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(format!("invalid literal (expected {word})")))
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Object(map)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(items)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pairs for non-BMP code points.
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c)
                        } else {
                            char::from_u32(cp)
                        };
                        out.push(ch.ok_or_else(|| self.err("invalid \\u escape"))?);
                    }
                    _ => return Err(self.err("invalid escape sequence")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control character in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    let len = utf8_len(c);
                    if len == 1 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8 sequence"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8 in string"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut cp = 0u32;
        for _ in 0..4 {
            let d = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            cp = cp * 16
                + (d as char)
                    .to_digit(16)
                    .ok_or_else(|| self.err("invalid hex digit"))?;
        }
        Ok(cp)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Number)
            .map_err(|_| self.err(format!("invalid number {text:?}")))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// Serialise a [`Value`] compactly (keys in BTreeMap order).
pub fn write(value: &Value) -> String {
    let mut out = String::new();
    write_into(value, &mut out);
    out
}

fn write_into(value: &Value, out: &mut String) {
    match value {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Number(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Value::String(s) => write_escaped(s, out),
        Value::Array(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(item, out);
            }
            out.push(']');
        }
        Value::Object(map) => {
            out.push('{');
            for (i, (k, v)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_escaped(k, out);
                out.push(':');
                write_into(v, out);
            }
            out.push('}');
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Number(42.0));
        assert_eq!(parse("-1.5e3").unwrap(), Value::Number(-1500.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::String("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Value::Null));
    }

    #[test]
    fn parses_escapes() {
        let v = parse(r#""a\nb\tAé""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\tAé"));
    }

    #[test]
    fn parses_surrogate_pair() {
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\":1,}").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn error_carries_offset() {
        let err = parse("[1, x]").unwrap_err();
        assert_eq!(err.offset, 4);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"batches":[{"batch":1,"hlo_file":"hermit_b1.hlo.txt"}],"dtype":"f32","n":2866530}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&write(&v)).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"héllo → 世界\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → 世界"));
    }

    #[test]
    fn accessor_helpers() {
        let v = parse(r#"{"n": 3, "s": "x", "a": []}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("n").unwrap().as_str(), None);
        assert_eq!(v.get("a").unwrap().as_array().unwrap().len(), 0);
        assert_eq!(v.get("missing"), None);
        assert_eq!(parse("-1").unwrap().as_usize(), None);
        assert_eq!(parse("1.5").unwrap().as_usize(), None);
    }
}
