//! CogSim request-trace generators.
//!
//! The coordinator only ever observes a *request process*, which the
//! paper specifies precisely enough to synthesise (§IV):
//!
//! * **Hydra + Hermit** (§IV-A): each MPI rank owns some zones; every
//!   simulation timestep needs "two or three inference calculations
//!   per zone", and requests from a rank are spread across *multiple
//!   independent per-material Hermit models* ("an MPI rank might
//!   typically require results for 5-10 different materials").  With
//!   10 000 zones/GPU that is 20–30K inferences per timestep,
//!   sharded over the material models — which is why small-batch
//!   latency dominates.
//! * **MIR** (§IV-B): each timestep processes the *mixed* zones —
//!   "thousands to the hundreds of thousands" per GPU, varying over
//!   the simulation — against a 100K samples/s/rank target.

use crate::util::rng::Rng;

/// One inference request as emitted by a simulation rank.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Request {
    /// Simulation timestep the request belongs to.
    pub timestep: usize,
    /// Originating MPI rank.
    pub rank: usize,
    /// Target model instance (e.g. `hermit/mat3`).
    pub model: String,
    /// Number of samples in this request.
    pub samples: usize,
}

/// Hydra-like in-the-loop Hermit workload.
#[derive(Debug, Clone)]
pub struct HydraWorkload {
    /// MPI ranks issuing requests.
    pub ranks: usize,
    /// Zones per rank (paper: 100–1 000 for DCA, up to 10 000 with
    /// Hermit).
    pub zones_per_rank: usize,
    /// Materials (= independent Hermit model instances) per rank,
    /// paper: 5–10.
    pub materials: usize,
    /// Inference calculations per zone per timestep, paper: 2–3.
    pub inferences_per_zone: (usize, usize),
    pub seed: u64,
}

impl Default for HydraWorkload {
    fn default() -> Self {
        HydraWorkload {
            ranks: 4,
            zones_per_rank: 1000,
            materials: 8,
            inferences_per_zone: (2, 3),
            seed: 0,
        }
    }
}

impl HydraWorkload {
    /// Material-model name for an index (the registry key format).
    pub fn material_model(material: usize) -> String {
        format!("hermit/mat{material}")
    }

    /// Generate every request of one timestep.  Zones are assigned a
    /// material (stable per zone via the per-timestep rng seed mix),
    /// and each zone issues 2–3 single-sample inferences that the
    /// coordinator may then batch — the paper's point is precisely
    /// that the *natural* request grain is tiny.
    pub fn timestep(&self, t: usize) -> Vec<Request> {
        let mut rng = Rng::new(self.seed ^ (t as u64).wrapping_mul(0x9E3779B9));
        let mut reqs = Vec::new();
        for rank in 0..self.ranks {
            // per-rank per-material zone counts
            let mut zones_of_material = vec![0usize; self.materials];
            for _ in 0..self.zones_per_rank {
                zones_of_material[rng.below(self.materials)] += 1;
            }
            for (mat, &zones) in zones_of_material.iter().enumerate() {
                if zones == 0 {
                    continue;
                }
                let (lo, hi) = self.inferences_per_zone;
                let mut total = 0usize;
                for _ in 0..zones {
                    total += rng.range(lo, hi);
                }
                reqs.push(Request {
                    timestep: t,
                    rank,
                    model: Self::material_model(mat),
                    samples: total,
                });
            }
        }
        reqs
    }

    /// Total expected inferences per timestep (sanity/reporting).
    pub fn expected_inferences_per_timestep(&self) -> usize {
        let mean_ipz = (self.inferences_per_zone.0 + self.inferences_per_zone.1) as f64 / 2.0;
        (self.ranks as f64 * self.zones_per_rank as f64 * mean_ipz) as usize
    }
}

/// MIR mixed-zone workload: zone counts vary over the simulation
/// ("The number of zones per timestep may vary throughout the
/// simulation", §IV-B) — modelled as a slow sinusoidal drift around a
/// base count with lognormal-ish jitter.
#[derive(Debug, Clone)]
pub struct MirWorkload {
    pub ranks: usize,
    /// Base mixed-zone count per rank per timestep.
    pub base_zones: usize,
    /// Peak-to-base variation over the simulation.
    pub variation: f64,
    pub seed: u64,
}

impl Default for MirWorkload {
    fn default() -> Self {
        MirWorkload { ranks: 2, base_zones: 4096, variation: 0.5, seed: 0 }
    }
}

impl MirWorkload {
    /// Mixed-zone requests for one timestep.
    pub fn timestep(&self, t: usize) -> Vec<Request> {
        let mut rng = Rng::new(self.seed ^ (t as u64).wrapping_mul(0x51_7C_C1_B7));
        let phase = (t as f64) / 50.0 * std::f64::consts::TAU;
        (0..self.ranks)
            .map(|rank| {
                let drift = 1.0 + self.variation * phase.sin();
                let jitter = (1.0 + 0.1 * rng.normal()).max(0.2);
                let zones = ((self.base_zones as f64) * drift * jitter).max(1.0) as usize;
                Request { timestep: t, rank, model: "mir".to_string(), samples: zones }
            })
            .collect()
    }

    /// The paper's MIR throughput target: "the target throughput of
    /// the model is 100,000 samples per second per MPI rank".
    pub const TARGET_SAMPLES_PER_SEC_PER_RANK: f64 = 100_000.0;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hydra_request_volume_matches_paper_rates() {
        // 10K zones/GPU, 2-3 inferences/zone -> "20,000-30,000
        // inference calculations … per timestep" (§IV-A), here per rank.
        let w = HydraWorkload {
            ranks: 1,
            zones_per_rank: 10_000,
            ..Default::default()
        };
        let total: usize = w.timestep(0).iter().map(|r| r.samples).sum();
        assert!((20_000..=30_000).contains(&total), "{total}");
    }

    #[test]
    fn hydra_spreads_over_materials() {
        let w = HydraWorkload::default();
        let reqs = w.timestep(3);
        let mats: std::collections::BTreeSet<_> =
            reqs.iter().map(|r| r.model.clone()).collect();
        assert_eq!(mats.len(), w.materials);
        // every request targets a per-material hermit instance
        assert!(reqs.iter().all(|r| r.model.starts_with("hermit/mat")));
    }

    #[test]
    fn hydra_deterministic_per_seed() {
        let w = HydraWorkload::default();
        assert_eq!(w.timestep(7), w.timestep(7));
        let w2 = HydraWorkload { seed: 1, ..Default::default() };
        assert_ne!(w.timestep(7), w2.timestep(7));
    }

    #[test]
    fn hydra_all_ranks_present() {
        let w = HydraWorkload::default();
        let ranks: std::collections::BTreeSet<_> =
            w.timestep(0).iter().map(|r| r.rank).collect();
        assert_eq!(ranks.len(), w.ranks);
    }

    #[test]
    fn mir_zone_counts_vary_over_time() {
        let w = MirWorkload::default();
        let counts: Vec<usize> = (0..100)
            .map(|t| w.timestep(t).iter().map(|r| r.samples).sum())
            .collect();
        let max = *counts.iter().max().unwrap() as f64;
        let min = *counts.iter().min().unwrap() as f64;
        assert!(max / min > 1.5, "variation too small: {min}..{max}");
    }

    #[test]
    fn mir_volume_in_paper_range() {
        // "from the thousands to the hundreds of thousands" per GPU.
        let w = MirWorkload::default();
        for t in 0..50 {
            for r in w.timestep(t) {
                assert!(r.samples >= 1_000, "{}", r.samples);
                assert!(r.samples <= 200_000);
            }
        }
    }

    #[test]
    fn expected_inference_count() {
        let w = HydraWorkload::default();
        let expect = w.expected_inferences_per_timestep();
        let actual: usize = w.timestep(0).iter().map(|r| r.samples).sum();
        let ratio = actual as f64 / expect as f64;
        assert!((0.9..1.1).contains(&ratio), "{ratio}");
    }
}
