//! Dynamic batching for the in-the-loop regime.
//!
//! The paper's workload grain is tiny: each (rank, material) pair
//! contributes a handful of samples per timestep, and latency budgets
//! are tight because inference sits on the simulation's critical path
//! (§IV).  The batcher coalesces concurrent requests *per instance*
//! under two triggers:
//!
//! * **size**: a queue reaching `target_batch` samples is ready
//!   immediately;
//! * **deadline**: otherwise a queue becomes ready `max_wait` after
//!   its oldest request arrived (bounded added latency).
//!
//! This is pure data-structure logic — no threads, no clocks — so it
//! is exhaustively testable; [`super::core`] adds the time source and
//! worker threads around it.

use std::collections::{BTreeMap, VecDeque};
use std::time::{Duration, Instant};

/// Request urgency class (paper §II-B, Fig. 1).
///
/// * [`Priority::Critical`] — **in-the-loop**: the simulation's
///   timestep is blocked on the answer; tight deadline.
/// * [`Priority::Deferred`] — **on-the-loop / around-the-loop**:
///   "updating these models is not urgent" — the result is consumed
///   several timesteps later, so these may wait much longer for
///   co-batching and never pre-empt critical traffic.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Priority {
    #[default]
    Critical,
    Deferred,
}

/// One queued request: samples for one instance plus the demux key.
#[derive(Debug)]
pub struct PendingRequest {
    /// Opaque id the caller uses to match the response.
    pub id: u64,
    /// Flattened f32 input, `samples × input_elems`.
    pub input: Vec<f32>,
    /// Number of samples in `input`.
    pub samples: usize,
    /// Arrival time (deadline bookkeeping).
    pub arrived: Instant,
    /// Urgency class (in-the-loop vs on-the-loop).
    pub priority: Priority,
}

/// A ready-to-execute batch for one instance.
#[derive(Debug)]
pub struct Batch {
    pub instance: String,
    pub requests: Vec<PendingRequest>,
    pub total_samples: usize,
}

/// Batching policy knobs.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Sample count that makes a queue immediately ready.  Usually the
    /// top of the compiled batch ladder.
    pub target_batch: usize,
    /// Maximum time a *critical* request may wait for co-batching.
    pub max_wait: Duration,
    /// Maximum time a *deferred* request may wait (on-the-loop
    /// traffic; typically orders of magnitude longer).
    pub deferred_max_wait: Duration,
    /// Hard cap on samples drained into one batch (≥ target_batch).
    pub max_batch: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            target_batch: 256,
            max_wait: Duration::from_micros(200),
            deferred_max_wait: Duration::from_millis(20),
            max_batch: 1024,
        }
    }
}

impl BatcherConfig {
    fn wait_for(&self, p: Priority) -> Duration {
        match p {
            Priority::Critical => self.max_wait,
            Priority::Deferred => self.deferred_max_wait,
        }
    }
}

/// Per-instance FIFO queues with size/deadline readiness.
#[derive(Debug)]
pub struct DynamicBatcher {
    config: BatcherConfig,
    queues: BTreeMap<String, VecDeque<PendingRequest>>,
    queued_samples: BTreeMap<String, usize>,
}

impl DynamicBatcher {
    pub fn new(config: BatcherConfig) -> Self {
        assert!(config.max_batch >= config.target_batch);
        DynamicBatcher { config, queues: BTreeMap::new(), queued_samples: BTreeMap::new() }
    }

    pub fn config(&self) -> &BatcherConfig {
        &self.config
    }

    /// Queue a request for `instance`.
    pub fn enqueue(&mut self, instance: &str, req: PendingRequest) {
        *self.queued_samples.entry(instance.to_string()).or_insert(0) += req.samples;
        self.queues
            .entry(instance.to_string())
            .or_default()
            .push_back(req);
    }

    /// Total queued samples for an instance.
    pub fn queued(&self, instance: &str) -> usize {
        self.queued_samples.get(instance).copied().unwrap_or(0)
    }

    /// Total queued samples across all instances.
    pub fn queued_total(&self) -> usize {
        self.queued_samples.values().sum()
    }

    /// Is any queue ready at `now`?  A queue whose deadline equals
    /// `now` *exactly* counts as ready (`now >= deadline`) — virtual
    /// -time callers schedule wake-ups at the precise deadline instant
    /// and rely on this boundary.
    pub fn has_ready(&self, now: Instant) -> bool {
        self.queues.iter().any(|(inst, q)| self.queue_ready(inst, q, now))
    }

    /// Is any queue ready on the **size trigger alone** (a full batch
    /// can dispatch without consulting any deadline)?  Event-driven
    /// callers use this on the arrival path so that a queue whose
    /// deadline expires at the very instant new requests arrive is
    /// *not* closed mid-burst — the deadline wake-up (ordered after
    /// all same-instant arrivals) closes it with everyone aboard.
    pub fn has_size_ready(&self) -> bool {
        self.queues.iter().any(|(inst, q)| self.queue_size_ready(inst, q))
    }

    /// A queue's earliest deadline: each request expires `wait_for`
    /// its priority class after arrival (critical requests can be
    /// queued *behind* deferred ones and still fire the queue early).
    fn queue_deadline(&self, q: &VecDeque<PendingRequest>) -> Option<Instant> {
        q.iter().map(|r| r.arrived + self.config.wait_for(r.priority)).min()
    }

    fn queue_size_ready(&self, instance: &str, q: &VecDeque<PendingRequest>) -> bool {
        !q.is_empty() && self.queued(instance) >= self.config.target_batch
    }

    fn queue_ready(&self, instance: &str, q: &VecDeque<PendingRequest>, now: Instant) -> bool {
        if self.queue_size_ready(instance, q) {
            return true;
        }
        self.queue_deadline(q).is_some_and(|d| now >= d)
    }

    /// Earliest future instant at which some queue becomes
    /// deadline-ready (for worker sleep timing); `None` when idle or
    /// something is already ready.
    pub fn next_deadline(&self, now: Instant) -> Option<Instant> {
        if self.has_ready(now) {
            return None;
        }
        self.queues.values().filter_map(|q| self.queue_deadline(q)).min()
    }

    /// Drain every ready queue into batches.  Queues holding critical
    /// (in-the-loop) requests are drained before deferred-only queues;
    /// ties break by instance name for determinism.  A drain takes
    /// whole requests up to `max_batch` samples; remaining requests
    /// stay queued with their original arrival times.
    pub fn drain_ready(&mut self, now: Instant) -> Vec<Batch> {
        self.drain_picked(Some(now))
    }

    /// Drain only the size-ready queues (see [`Self::has_size_ready`]);
    /// deadline-expired queues stay put for their scheduled wake-up.
    pub fn drain_size_ready(&mut self) -> Vec<Batch> {
        self.drain_picked(None)
    }

    /// `now = Some(_)`: full readiness (size or deadline);
    /// `now = None`: size trigger only.
    fn drain_picked(&mut self, now: Option<Instant>) -> Vec<Batch> {
        let mut picked: Vec<(bool, String)> = self
            .queues
            .iter()
            .filter(|(inst, q)| match now {
                Some(n) => self.queue_ready(inst, q, n),
                None => self.queue_size_ready(inst, q),
            })
            .map(|(inst, q)| {
                let has_critical =
                    q.iter().any(|r| r.priority == Priority::Critical);
                (!has_critical, inst.clone()) // false < true: critical first
            })
            .collect();
        picked.sort();

        picked
            .into_iter()
            .map(|(_, instance)| self.drain_instance(&instance))
            .collect()
    }

    fn drain_instance(&mut self, instance: &str) -> Batch {
        let q = self.queues.get_mut(instance).expect("ready queue exists");
        let mut requests = Vec::new();
        let mut total = 0usize;
        while let Some(front) = q.front() {
            // Always take at least one request, even if it alone
            // exceeds max_batch (the engine chunks internally).
            if !requests.is_empty() && total + front.samples > self.config.max_batch {
                break;
            }
            let req = q.pop_front().unwrap();
            total += req.samples;
            requests.push(req);
        }
        *self.queued_samples.get_mut(instance).unwrap() -= total;
        Batch { instance: instance.to_string(), requests, total_samples: total }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, samples: usize, arrived: Instant) -> PendingRequest {
        PendingRequest {
            id,
            input: vec![0.0; samples * 2],
            samples,
            arrived,
            priority: Priority::Critical,
        }
    }

    fn batcher(target: usize, wait_us: u64) -> DynamicBatcher {
        DynamicBatcher::new(BatcherConfig {
            target_batch: target,
            max_wait: Duration::from_micros(wait_us),
            deferred_max_wait: Duration::from_millis(50),
            max_batch: target * 4,
        })
    }

    #[test]
    fn size_trigger() {
        let t0 = Instant::now();
        let mut b = batcher(8, 1_000_000);
        b.enqueue("m", req(1, 4, t0));
        assert!(!b.has_ready(t0));
        b.enqueue("m", req(2, 4, t0));
        assert!(b.has_ready(t0)); // 8 samples == target
        let batches = b.drain_ready(t0);
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].total_samples, 8);
        assert_eq!(batches[0].requests.len(), 2);
        assert_eq!(b.queued("m"), 0);
    }

    #[test]
    fn deadline_trigger() {
        let t0 = Instant::now();
        let mut b = batcher(1024, 100);
        b.enqueue("m", req(1, 2, t0));
        assert!(!b.has_ready(t0));
        let later = t0 + Duration::from_micros(150);
        assert!(b.has_ready(later));
        let batches = b.drain_ready(later);
        assert_eq!(batches[0].requests[0].id, 1);
    }

    #[test]
    fn next_deadline_is_oldest_plus_wait() {
        let t0 = Instant::now();
        let mut b = batcher(1024, 100);
        b.enqueue("a", req(1, 1, t0 + Duration::from_micros(50)));
        b.enqueue("b", req(2, 1, t0));
        assert_eq!(b.next_deadline(t0), Some(t0 + Duration::from_micros(100)));
        // ready queues -> None (caller should drain, not sleep)
        let later = t0 + Duration::from_micros(500);
        assert_eq!(b.next_deadline(later), None);
    }

    #[test]
    fn instances_batch_independently() {
        // The paper's requirement: independent per-material models,
        // concurrent execution — one material's queue never blocks or
        // joins another's.
        let t0 = Instant::now();
        let mut b = batcher(4, 1_000_000);
        b.enqueue("hermit/mat0", req(1, 4, t0));
        b.enqueue("hermit/mat1", req(2, 2, t0));
        let batches = b.drain_ready(t0);
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].instance, "hermit/mat0");
        assert_eq!(b.queued("hermit/mat1"), 2);
    }

    #[test]
    fn max_batch_respected_across_requests() {
        let t0 = Instant::now();
        let mut b = DynamicBatcher::new(BatcherConfig {
            target_batch: 4,
            max_wait: Duration::ZERO,
            deferred_max_wait: Duration::ZERO,
            max_batch: 10,
        });
        for i in 0..5 {
            b.enqueue("m", req(i, 4, t0));
        }
        let batches = b.drain_ready(t0);
        // 4+4 fits, +4 would exceed 10 -> batch of 8
        assert_eq!(batches[0].total_samples, 8);
        assert_eq!(b.queued("m"), 12);
    }

    #[test]
    fn oversized_single_request_still_drains() {
        let t0 = Instant::now();
        let mut b = DynamicBatcher::new(BatcherConfig {
            target_batch: 4,
            max_wait: Duration::ZERO,
            deferred_max_wait: Duration::ZERO,
            max_batch: 8,
        });
        b.enqueue("m", req(1, 100, t0));
        let batches = b.drain_ready(t0);
        assert_eq!(batches[0].total_samples, 100);
    }

    #[test]
    fn fifo_order_preserved() {
        let t0 = Instant::now();
        let mut b = batcher(2, 0);
        for i in 0..6 {
            b.enqueue("m", req(i, 1, t0));
        }
        let ids: Vec<u64> = b
            .drain_ready(t0)
            .pop()
            .unwrap()
            .requests
            .iter()
            .map(|r| r.id)
            .collect();
        // max_batch = target*4 = 8 >= 6, so one drain takes all six in
        // arrival order.
        assert_eq!(ids, vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(b.queued("m"), 0);
    }

    #[test]
    fn drain_is_deterministic_by_instance_name() {
        let t0 = Instant::now();
        let mut b = batcher(1, 0);
        b.enqueue("z", req(1, 1, t0));
        b.enqueue("a", req(2, 1, t0));
        let batches = b.drain_ready(t0);
        assert_eq!(batches[0].instance, "a");
        assert_eq!(batches[1].instance, "z");
    }

    fn deferred_req(id: u64, samples: usize, arrived: Instant) -> PendingRequest {
        PendingRequest {
            id,
            input: vec![0.0; samples * 2],
            samples,
            arrived,
            priority: Priority::Deferred,
        }
    }

    #[test]
    fn deferred_requests_wait_longer() {
        let t0 = Instant::now();
        let mut b = DynamicBatcher::new(BatcherConfig {
            target_batch: 1_000_000,
            max_wait: Duration::from_micros(100),
            deferred_max_wait: Duration::from_millis(10),
            max_batch: 1_000_000,
        });
        b.enqueue("m", deferred_req(1, 2, t0));
        // past the critical deadline but before the deferred one
        let mid = t0 + Duration::from_micros(500);
        assert!(!b.has_ready(mid), "deferred must keep waiting");
        assert_eq!(b.next_deadline(mid), Some(t0 + Duration::from_millis(10)));
        assert!(b.has_ready(t0 + Duration::from_millis(10)));
    }

    #[test]
    fn critical_arrival_fires_queue_with_deferred_head() {
        // a critical request behind a deferred one must still get the
        // critical deadline
        let t0 = Instant::now();
        let mut b = DynamicBatcher::new(BatcherConfig {
            target_batch: 1_000_000,
            max_wait: Duration::from_micros(100),
            deferred_max_wait: Duration::from_millis(10),
            max_batch: 1_000_000,
        });
        b.enqueue("m", deferred_req(1, 2, t0));
        b.enqueue("m", req(2, 2, t0 + Duration::from_micros(50)));
        let at = t0 + Duration::from_micros(150); // critical deadline passed
        assert!(b.has_ready(at));
        // the drain carries both (co-batching the deferred for free)
        let batch = b.drain_ready(at).pop().unwrap();
        assert_eq!(batch.requests.len(), 2);
    }

    #[test]
    fn critical_queues_drain_before_deferred_only_queues() {
        let t0 = Instant::now();
        let mut b = DynamicBatcher::new(BatcherConfig {
            target_batch: 1,
            max_wait: Duration::ZERO,
            deferred_max_wait: Duration::ZERO,
            max_batch: 16,
        });
        b.enqueue("a_deferred", deferred_req(1, 1, t0));
        b.enqueue("z_critical", req(2, 1, t0));
        let batches = b.drain_ready(t0);
        assert_eq!(batches[0].instance, "z_critical");
        assert_eq!(batches[1].instance, "a_deferred");
    }

    #[test]
    fn ready_exactly_at_the_deadline_instant() {
        // Regression: virtual-time callers (eventsim/cogsim) schedule
        // wake-ups at the *precise* deadline instant; `now == deadline`
        // must count as expired, one nanosecond earlier must not.
        let t0 = Instant::now();
        let mut b = batcher(1024, 100);
        b.enqueue("m", req(1, 2, t0));
        let deadline = t0 + Duration::from_micros(100);
        assert!(!b.has_ready(deadline - Duration::from_nanos(1)));
        assert!(b.has_ready(deadline));
        assert_eq!(b.drain_ready(deadline).len(), 1);
    }

    #[test]
    fn equal_deadlines_across_queues_drain_together_in_name_order() {
        // Two instances whose deadlines coincide exactly: one drain
        // call at that instant takes both, ordered by instance name.
        let t0 = Instant::now();
        let mut b = batcher(1024, 100);
        b.enqueue("z", req(1, 2, t0));
        b.enqueue("a", req(2, 2, t0));
        let deadline = t0 + Duration::from_micros(100);
        assert_eq!(b.next_deadline(t0), Some(deadline));
        let batches = b.drain_ready(deadline);
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[0].instance, "a");
        assert_eq!(batches[1].instance, "z");
        assert_eq!(b.queued_total(), 0);
    }

    #[test]
    fn size_ready_ignores_expired_deadlines() {
        // The arrival-path drain: a deadline-expired queue is NOT
        // size-ready; a target-full queue is, regardless of time.
        let t0 = Instant::now();
        let mut b = batcher(8, 100);
        b.enqueue("expired", req(1, 2, t0));
        let late = t0 + Duration::from_millis(5);
        assert!(b.has_ready(late), "deadline long past");
        assert!(!b.has_size_ready(), "2 < 8 samples: not size-ready");
        assert!(b.drain_size_ready().is_empty());
        assert_eq!(b.queued("expired"), 2, "stays for its wake-up");

        b.enqueue("full", req(2, 8, late));
        assert!(b.has_size_ready());
        let batches = b.drain_size_ready();
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].instance, "full");
        assert_eq!(b.queued("expired"), 2, "expired queue untouched");
    }

    #[test]
    fn empty_batcher_idle() {
        let b = batcher(4, 100);
        let now = Instant::now();
        assert!(!b.has_ready(now));
        assert_eq!(b.next_deadline(now), None);
        assert_eq!(b.queued_total(), 0);
    }
}
