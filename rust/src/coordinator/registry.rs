//! Multi-model instance registry.
//!
//! A logical *instance* is what clients address: `hermit/mat3`,
//! `mir`, …  Each instance resolves to one or more loaded engine
//! models — its **replica set**.  In the paper's deployment every
//! material has its own trained Hermit weights; here all materials
//! share the reproduction's single weight set (per-material
//! `.weights.npz` files drop in without code changes — the registry
//! is the only mapping layer), which preserves the serving behaviour
//! the paper studies: independent queues, independent batches,
//! concurrent execution targets.
//!
//! Replica sets are the coordinator-side half of the `cluster`
//! story: when an instance maps to several engine models (e.g. one
//! weight set deployed on two tile groups), the coordinator's routing
//! hook ([`crate::coordinator::RoutingPolicy`]) picks which replica
//! executes each request.  All replicas of an instance must share the
//! instance's input/output shape — the coordinator validates this at
//! startup.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Instance table: logical name -> engine model replica set (the
/// first entry is the *primary*).
#[derive(Debug, Clone, Default)]
pub struct Registry {
    instances: BTreeMap<String, Vec<String>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register one instance with a single engine model.
    /// Re-registering a name replaces it.
    pub fn register(&mut self, instance: impl Into<String>, engine_model: impl Into<String>) {
        self.instances.insert(instance.into(), vec![engine_model.into()]);
    }

    /// Register one instance with a replica set (first = primary).
    /// Re-registering a name replaces it.
    pub fn register_replicated(
        &mut self,
        instance: impl Into<String>,
        engine_models: impl IntoIterator<Item = impl Into<String>>,
    ) -> Result<()> {
        let models: Vec<String> = engine_models.into_iter().map(Into::into).collect();
        if models.is_empty() {
            bail!("replica set for an instance cannot be empty");
        }
        self.instances.insert(instance.into(), models);
        Ok(())
    }

    /// Register `n` per-material Hermit instances (`hermit/mat0` …),
    /// the paper's multi-material deployment shape.
    pub fn register_materials(&mut self, engine_model: &str, n: usize) {
        for m in 0..n {
            self.register(format!("{engine_model}/mat{m}"), engine_model);
        }
    }

    /// Resolve an instance to its primary engine model.
    pub fn resolve(&self, instance: &str) -> Result<&str> {
        Ok(self.replicas(instance)?[0].as_str())
    }

    /// An instance's full replica set (primary first).
    pub fn replicas(&self, instance: &str) -> Result<&[String]> {
        self.instances
            .get(instance)
            .map(Vec::as_slice)
            .ok_or_else(|| anyhow!("unknown model instance {instance:?} (registered: {:?})",
                self.instance_names()))
    }

    pub fn contains(&self, instance: &str) -> bool {
        self.instances.contains_key(instance)
    }

    pub fn instance_names(&self) -> Vec<String> {
        self.instances.keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.instances.len()
    }

    pub fn is_empty(&self) -> bool {
        self.instances.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_and_resolve() {
        let mut r = Registry::new();
        r.register("mir", "mir");
        r.register("hermit/mat0", "hermit");
        assert_eq!(r.resolve("hermit/mat0").unwrap(), "hermit");
        assert_eq!(r.resolve("mir").unwrap(), "mir");
        assert!(r.resolve("nope").is_err());
    }

    #[test]
    fn material_fanout() {
        let mut r = Registry::new();
        r.register_materials("hermit", 8);
        assert_eq!(r.len(), 8);
        for m in 0..8 {
            assert_eq!(r.resolve(&format!("hermit/mat{m}")).unwrap(), "hermit");
        }
    }

    #[test]
    fn reregistration_replaces() {
        let mut r = Registry::new();
        r.register("x", "hermit");
        r.register("x", "mir");
        assert_eq!(r.resolve("x").unwrap(), "mir");
        assert_eq!(r.len(), 1);
    }

    #[test]
    fn names_sorted_deterministic() {
        let mut r = Registry::new();
        r.register("b", "hermit");
        r.register("a", "hermit");
        assert_eq!(r.instance_names(), vec!["a".to_string(), "b".to_string()]);
    }

    #[test]
    fn replica_sets() {
        let mut r = Registry::new();
        r.register_replicated("hermit/mat0", ["hermit_a", "hermit_b"]).unwrap();
        assert_eq!(r.resolve("hermit/mat0").unwrap(), "hermit_a"); // primary
        assert_eq!(
            r.replicas("hermit/mat0").unwrap(),
            &["hermit_a".to_string(), "hermit_b".to_string()]
        );
        // single-model registration is a 1-replica set
        r.register("mir", "mir");
        assert_eq!(r.replicas("mir").unwrap().len(), 1);
        // empty set rejected
        assert!(r.register_replicated("bad", Vec::<String>::new()).is_err());
    }
}
