//! Multi-model instance registry.
//!
//! A logical *instance* is what clients address: `hermit/mat3`,
//! `mir`, …  Each instance resolves to a loaded engine model.  In the
//! paper's deployment every material has its own trained Hermit
//! weights; here all materials share the reproduction's single weight
//! set (per-material `.weights.npz` files drop in without code
//! changes — the registry is the only mapping layer), which preserves
//! the serving behaviour the paper studies: independent queues,
//! independent batches, concurrent execution targets.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

/// Instance table: logical name -> engine model name.
#[derive(Debug, Clone, Default)]
pub struct Registry {
    instances: BTreeMap<String, String>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register one instance.  Re-registering a name replaces it.
    pub fn register(&mut self, instance: impl Into<String>, engine_model: impl Into<String>) {
        self.instances.insert(instance.into(), engine_model.into());
    }

    /// Register `n` per-material Hermit instances (`hermit/mat0` …),
    /// the paper's multi-material deployment shape.
    pub fn register_materials(&mut self, engine_model: &str, n: usize) {
        for m in 0..n {
            self.register(format!("{engine_model}/mat{m}"), engine_model);
        }
    }

    /// Resolve an instance to its engine model.
    pub fn resolve(&self, instance: &str) -> Result<&str> {
        self.instances
            .get(instance)
            .map(String::as_str)
            .ok_or_else(|| anyhow!("unknown model instance {instance:?} (registered: {:?})",
                self.instance_names()))
    }

    pub fn contains(&self, instance: &str) -> bool {
        self.instances.contains_key(instance)
    }

    pub fn instance_names(&self) -> Vec<String> {
        self.instances.keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.instances.len()
    }

    pub fn is_empty(&self) -> bool {
        self.instances.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_and_resolve() {
        let mut r = Registry::new();
        r.register("mir", "mir");
        r.register("hermit/mat0", "hermit");
        assert_eq!(r.resolve("hermit/mat0").unwrap(), "hermit");
        assert_eq!(r.resolve("mir").unwrap(), "mir");
        assert!(r.resolve("nope").is_err());
    }

    #[test]
    fn material_fanout() {
        let mut r = Registry::new();
        r.register_materials("hermit", 8);
        assert_eq!(r.len(), 8);
        for m in 0..8 {
            assert_eq!(r.resolve(&format!("hermit/mat{m}")).unwrap(), "hermit");
        }
    }

    #[test]
    fn reregistration_replaces() {
        let mut r = Registry::new();
        r.register("x", "hermit");
        r.register("x", "mir");
        assert_eq!(r.resolve("x").unwrap(), "mir");
        assert_eq!(r.len(), 1);
    }

    #[test]
    fn names_sorted_deterministic() {
        let mut r = Registry::new();
        r.register("b", "hermit");
        r.register("a", "hermit");
        assert_eq!(r.instance_names(), vec!["a".to_string(), "b".to_string()]);
    }
}
