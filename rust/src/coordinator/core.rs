//! The [`Coordinator`]: request intake, replica routing, batching
//! workers, response demultiplexing.
//!
//! Threading model: callers ([`crate::net::server`] connections or
//! in-process examples) call [`Coordinator::submit`], which routes the
//! request to an engine-model replica (the [`RoutingPolicy`] hook),
//! enqueues into the [`DynamicBatcher`] and returns a channel
//! receiver.  A small pool of executor workers waits on a condvar,
//! drains ready batches, runs them on the [`Engine`] (`execute_padded`
//! — the ladder/padding policy lives in the runtime), splits the
//! output rows back per request and completes each channel.
//!
//! One worker per physical accelerator queue matches the paper's
//! setup (a single DataScale node serialises concurrent model
//! executions per tile group); more workers only help when PJRT's
//! intra-op parallelism is not already saturating the host.
//!
//! ## Replica routing
//!
//! When the [`Registry`] maps an instance to a replica set (one
//! weight set deployed on several engine models — the coordinator's
//! view of the `cluster` layer's multi-backend story), `submit` picks
//! the replica per request: sticky-primary, round-robin, or
//! least-outstanding-samples.  Requests for different replicas batch
//! independently (the physical queues are independent), so the batch
//! key carries the routed model.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::runtime::Engine;

use super::batcher::{Batch, BatcherConfig, DynamicBatcher, PendingRequest, Priority};
use super::registry::Registry;

/// Key separator between instance and routed replica in batcher
/// queue keys (ASCII unit separator — never part of a model name).
const ROUTE_SEP: char = '\u{1f}';

/// How `submit` picks the engine-model replica for an instance whose
/// registry entry names more than one (single-replica instances are
/// unaffected).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RoutingPolicy {
    /// Always the first replica (the seed behaviour).
    #[default]
    Primary,
    /// Cycle replicas per request.
    RoundRobin,
    /// The replica with the fewest samples currently in flight
    /// (ties break on model name for determinism).
    LeastOutstanding,
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub batcher: BatcherConfig,
    /// Executor worker threads.
    pub workers: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig { batcher: BatcherConfig::default(), workers: 1 }
    }
}

/// A completed inference: output rows for the request's samples.
pub type InferenceResult = Result<Vec<f32>, String>;

/// Counters exposed for monitoring and the §Perf analysis.
#[derive(Debug, Default)]
pub struct CoordinatorStats {
    pub requests: AtomicU64,
    pub samples: AtomicU64,
    pub batches: AtomicU64,
    pub padded_samples: AtomicU64,
    pub errors: AtomicU64,
}

impl CoordinatorStats {
    /// Mean samples per executed batch (batching effectiveness).
    pub fn samples_per_batch(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.samples.load(Ordering::Relaxed) as f64 / b as f64
        }
    }
}

/// Per-engine-model routing accounting.
#[derive(Debug, Default)]
struct RouteState {
    /// Samples submitted but not yet executed, per engine model.
    outstanding: BTreeMap<String, u64>,
    /// Cumulative samples executed, per engine model.
    routed: BTreeMap<String, u64>,
    /// Round-robin cursor per *instance* (a shared cursor would let
    /// regularly interleaved multi-instance traffic alias onto one
    /// replica each).
    rr_cursor: BTreeMap<String, u64>,
}

struct Shared {
    batcher: Mutex<DynamicBatcher>,
    ready: Condvar,
    shutdown: AtomicBool,
    completions: Mutex<BTreeMap<u64, SyncSender<InferenceResult>>>,
    routes: Mutex<RouteState>,
}

/// The serving core.  See module docs.
pub struct Coordinator {
    engine: Arc<Engine>,
    registry: Registry,
    routing: RoutingPolicy,
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    next_id: AtomicU64,
    pub stats: Arc<CoordinatorStats>,
}

impl Coordinator {
    /// Start a coordinator over a loaded engine with the default
    /// (primary) replica routing.  `registry` defines the logical
    /// instances clients may address.
    pub fn start(engine: Engine, registry: Registry, config: CoordinatorConfig) -> Result<Self> {
        Self::start_with_router(engine, registry, config, RoutingPolicy::Primary)
    }

    /// Start with an explicit replica-routing policy (the `submit`
    /// routing hook).
    pub fn start_with_router(
        engine: Engine,
        registry: Registry,
        config: CoordinatorConfig,
        routing: RoutingPolicy,
    ) -> Result<Self> {
        if registry.is_empty() {
            return Err(anyhow!("registry has no instances"));
        }
        // validate every replica resolves to a loaded model and that
        // replica sets are shape-consistent (routing must be invisible
        // to the caller)
        for inst in registry.instance_names() {
            let replicas = registry.replicas(&inst)?;
            if inst.contains(ROUTE_SEP) || replicas.iter().any(|m| m.contains(ROUTE_SEP)) {
                bail!("instance {inst:?}: names must not contain U+001F (batch-key separator)");
            }
            let primary = engine.spec(&replicas[0])?;
            let (in_el, out_el) = (primary.input_elems(), primary.output_elems());
            for model in &replicas[1..] {
                let spec = engine.spec(model)?;
                if spec.input_elems() != in_el || spec.output_elems() != out_el {
                    bail!(
                        "instance {inst:?}: replica {model:?} shape \
                         {}x{} != primary {}x{}",
                        spec.input_elems(),
                        spec.output_elems(),
                        in_el,
                        out_el
                    );
                }
            }
        }

        let engine = Arc::new(engine);
        let shared = Arc::new(Shared {
            batcher: Mutex::new(DynamicBatcher::new(config.batcher.clone())),
            ready: Condvar::new(),
            shutdown: AtomicBool::new(false),
            completions: Mutex::new(BTreeMap::new()),
            routes: Mutex::new(RouteState::default()),
        });
        let stats = Arc::new(CoordinatorStats::default());

        let mut workers = Vec::new();
        for w in 0..config.workers.max(1) {
            let engine = Arc::clone(&engine);
            let shared = Arc::clone(&shared);
            let stats = Arc::clone(&stats);
            let registry = registry.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("cogsim-exec-{w}"))
                    .spawn(move || worker_loop(engine, registry, shared, stats))
                    .expect("spawn worker"),
            );
        }

        Ok(Coordinator {
            engine,
            registry,
            routing,
            shared,
            workers,
            next_id: AtomicU64::new(1),
            stats,
        })
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    pub fn routing(&self) -> RoutingPolicy {
        self.routing
    }

    /// Cumulative samples executed per engine model (observability for
    /// the routing hook; single-replica deployments see their primary
    /// model only).
    pub fn routed_samples(&self) -> BTreeMap<String, u64> {
        self.shared.routes.lock().unwrap().routed.clone()
    }

    /// The routing hook: pick the engine-model replica for one
    /// request of `samples` samples.  Selection and the in-flight
    /// increment happen under one lock so concurrent submits cannot
    /// all pick the same "least outstanding" replica.
    fn route(&self, instance: &str, replicas: &[String], samples: usize) -> String {
        if replicas.len() == 1 {
            return replicas[0].clone();
        }
        let mut routes = self.shared.routes.lock().unwrap();
        let chosen = match self.routing {
            RoutingPolicy::Primary => replicas[0].clone(),
            RoutingPolicy::RoundRobin => {
                let cursor = routes.rr_cursor.entry(instance.to_string()).or_insert(0);
                let i = *cursor as usize % replicas.len();
                *cursor += 1;
                replicas[i].clone()
            }
            RoutingPolicy::LeastOutstanding => replicas
                .iter()
                .min_by_key(|m| {
                    (routes.outstanding.get(*m).copied().unwrap_or(0), m.as_str())
                })
                .expect("non-empty replica set")
                .clone(),
        };
        *routes.outstanding.entry(chosen.clone()).or_insert(0) += samples as u64;
        chosen
    }

    /// Submit `samples` flattened samples for `instance` at critical
    /// (in-the-loop) priority.  Returns a receiver that yields the
    /// output rows (or an error string).
    pub fn submit(&self, instance: &str, input: Vec<f32>) -> Result<Receiver<InferenceResult>> {
        self.submit_with_priority(instance, input, Priority::Critical)
    }

    /// Submit with an explicit urgency class (paper SII-B: in-the-loop
    /// vs on-the-loop traffic).
    pub fn submit_with_priority(
        &self,
        instance: &str,
        input: Vec<f32>,
        priority: Priority,
    ) -> Result<Receiver<InferenceResult>> {
        let replicas = self.registry.replicas(instance)?;
        let spec = self.engine.spec(&replicas[0])?;
        let in_el = spec.input_elems();
        if input.is_empty() || input.len() % in_el != 0 {
            return Err(anyhow!(
                "{instance}: input length {} is not a positive multiple of {in_el}",
                input.len()
            ));
        }
        let samples = input.len() / in_el;
        let model = self.route(instance, replicas, samples);
        // Single-replica instances keep the bare instance as the
        // batch key (seed behaviour); replicated ones batch per
        // (instance, replica) pair.
        let key = if replicas.len() == 1 {
            instance.to_string()
        } else {
            format!("{instance}{ROUTE_SEP}{model}")
        };

        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = sync_channel(1);

        self.shared.completions.lock().unwrap().insert(id, tx);
        {
            let mut batcher = self.shared.batcher.lock().unwrap();
            batcher.enqueue(
                &key,
                PendingRequest { id, input, samples, arrived: Instant::now(), priority },
            );
        }
        self.shared.ready.notify_one();
        self.stats.requests.fetch_add(1, Ordering::Relaxed);
        self.stats.samples.fetch_add(samples as u64, Ordering::Relaxed);
        Ok(rx)
    }

    /// Convenience: submit and wait.
    pub fn infer(&self, instance: &str, input: Vec<f32>) -> Result<Vec<f32>> {
        let rx = self.submit(instance, input)?;
        rx.recv()
            .map_err(|_| anyhow!("coordinator dropped the request"))?
            .map_err(|e| anyhow!(e))
    }

    /// Graceful shutdown: stop workers after the queues drain.
    pub fn shutdown(mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.ready.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.ready.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Split a batch key back into (instance, routed replica).
fn split_key(key: &str) -> (&str, Option<&str>) {
    match key.split_once(ROUTE_SEP) {
        Some((instance, model)) => (instance, Some(model)),
        None => (key, None),
    }
}

fn worker_loop(
    engine: Arc<Engine>,
    registry: Registry,
    shared: Arc<Shared>,
    stats: Arc<CoordinatorStats>,
) {
    loop {
        // -- wait for a ready batch (or shutdown) --
        let batches: Vec<Batch> = {
            let mut batcher = shared.batcher.lock().unwrap();
            loop {
                if shared.shutdown.load(Ordering::SeqCst) && batcher.queued_total() == 0 {
                    return;
                }
                let now = Instant::now();
                // Idle fast path (§Perf): this worker is by definition
                // idle here, so holding a lone request for `max_wait`
                // only adds latency — batches form naturally while
                // workers are busy executing (continuous batching).
                // The deadline policy still governs whenever every
                // worker is occupied.  Measured: -440 µs at batch 1
                // (1.00 ms -> 0.59 ms with a 200 µs deadline config).
                if batcher.queued_total() > 0 {
                    break batcher.drain_ready(now + Duration::from_secs(3600));
                }
                // during shutdown, force-drain whatever is queued
                if shared.shutdown.load(Ordering::SeqCst) {
                    let all = batcher.drain_ready(now + Duration::from_secs(3600));
                    if all.is_empty() {
                        return;
                    }
                    break all;
                }
                match batcher.next_deadline(now) {
                    Some(deadline) => {
                        let wait = deadline.saturating_duration_since(now);
                        let (b, _timeout) = shared
                            .ready
                            .wait_timeout(batcher, wait.max(Duration::from_micros(10)))
                            .unwrap();
                        batcher = b;
                    }
                    None => {
                        batcher = shared.ready.wait(batcher).unwrap();
                    }
                }
            }
        };

        // -- execute outside the lock --
        for batch in batches {
            execute_batch(&engine, &registry, &shared, &stats, batch);
        }
    }
}

fn execute_batch(
    engine: &Engine,
    registry: &Registry,
    shared: &Shared,
    stats: &CoordinatorStats,
    batch: Batch,
) {
    stats.batches.fetch_add(1, Ordering::Relaxed);

    // the routed replica rides in the batch key; single-replica
    // instances resolve through the registry as before
    let (instance, routed) = split_key(&batch.instance);
    let model: Result<String> = match routed {
        Some(m) => Ok(m.to_string()),
        None => registry.resolve(instance).map(String::from),
    };

    let result: Result<Vec<f32>> = (|| {
        let model = model.as_ref().map_err(|e| anyhow!("{e:#}"))?;
        // gather request inputs into one contiguous mini-batch
        let spec = engine.spec(model)?;
        let in_el = spec.input_elems();
        let mut input = Vec::with_capacity(batch.total_samples * in_el);
        for req in &batch.requests {
            input.extend_from_slice(&req.input);
        }
        let waste = engine.padding_waste(model, batch.total_samples)?;
        stats.padded_samples.fetch_add(
            (waste * batch.total_samples as f64) as u64,
            Ordering::Relaxed,
        );
        let (out, _t) = engine.execute_padded(model, &input)?;
        Ok(out)
    })();

    // -- routing accounting: the batch is no longer in flight either
    // way; it only counts as *executed* when execution succeeded --
    if let Ok(model) = &model {
        let mut routes = shared.routes.lock().unwrap();
        let n = batch.total_samples as u64;
        if let Some(v) = routes.outstanding.get_mut(model) {
            *v = v.saturating_sub(n);
        }
        if result.is_ok() {
            *routes.routed.entry(model.clone()).or_insert(0) += n;
        }
    }

    // -- demux responses --
    let mut completions = shared.completions.lock().unwrap();
    match result {
        Ok(out) => {
            let model = model.as_ref().expect("result Ok implies model Ok");
            let out_el = engine.spec(model).expect("validated").output_elems();
            let mut offset = 0usize;
            for req in &batch.requests {
                let rows = out[offset..offset + req.samples * out_el].to_vec();
                offset += req.samples * out_el;
                if let Some(tx) = completions.remove(&req.id) {
                    let _ = tx.send(Ok(rows));
                }
            }
        }
        Err(e) => {
            stats.errors.fetch_add(1, Ordering::Relaxed);
            for req in &batch.requests {
                if let Some(tx) = completions.remove(&req.id) {
                    let _ = tx.send(Err(format!("{e:#}")));
                }
            }
        }
    }
}
