//! The [`Coordinator`]: request intake, batching workers, response
//! demultiplexing.
//!
//! Threading model: callers ([`crate::net::server`] connections or
//! in-process examples) call [`Coordinator::submit`], which enqueues
//! into the [`DynamicBatcher`] and returns a channel receiver.  A
//! small pool of executor workers waits on a condvar, drains ready
//! batches, runs them on the PJRT [`Engine`] (`execute_padded` — the
//! ladder/padding policy lives in the runtime), splits the output
//! rows back per request and completes each channel.
//!
//! One worker per physical accelerator queue matches the paper's
//! setup (a single DataScale node serialises concurrent model
//! executions per tile group); more workers only help when PJRT's
//! intra-op parallelism is not already saturating the host.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::runtime::Engine;

use super::batcher::{Batch, BatcherConfig, DynamicBatcher, PendingRequest, Priority};
use super::registry::Registry;

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub batcher: BatcherConfig,
    /// Executor worker threads.
    pub workers: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig { batcher: BatcherConfig::default(), workers: 1 }
    }
}

/// A completed inference: output rows for the request's samples.
pub type InferenceResult = Result<Vec<f32>, String>;

/// Counters exposed for monitoring and the §Perf analysis.
#[derive(Debug, Default)]
pub struct CoordinatorStats {
    pub requests: AtomicU64,
    pub samples: AtomicU64,
    pub batches: AtomicU64,
    pub padded_samples: AtomicU64,
    pub errors: AtomicU64,
}

impl CoordinatorStats {
    /// Mean samples per executed batch (batching effectiveness).
    pub fn samples_per_batch(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.samples.load(Ordering::Relaxed) as f64 / b as f64
        }
    }
}

struct Shared {
    batcher: Mutex<DynamicBatcher>,
    ready: Condvar,
    shutdown: AtomicBool,
    completions: Mutex<BTreeMap<u64, SyncSender<InferenceResult>>>,
}

/// The serving core.  See module docs.
pub struct Coordinator {
    engine: Arc<Engine>,
    registry: Registry,
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    next_id: AtomicU64,
    pub stats: Arc<CoordinatorStats>,
}

impl Coordinator {
    /// Start a coordinator over a loaded engine.  `registry` defines
    /// the logical instances clients may address.
    pub fn start(engine: Engine, registry: Registry, config: CoordinatorConfig) -> Result<Self> {
        if registry.is_empty() {
            return Err(anyhow!("registry has no instances"));
        }
        // validate every instance resolves to a loaded model
        for inst in registry.instance_names() {
            let model = registry.resolve(&inst)?;
            engine.spec(model)?;
        }

        let engine = Arc::new(engine);
        let shared = Arc::new(Shared {
            batcher: Mutex::new(DynamicBatcher::new(config.batcher.clone())),
            ready: Condvar::new(),
            shutdown: AtomicBool::new(false),
            completions: Mutex::new(BTreeMap::new()),
        });
        let stats = Arc::new(CoordinatorStats::default());

        let mut workers = Vec::new();
        for w in 0..config.workers.max(1) {
            let engine = Arc::clone(&engine);
            let shared = Arc::clone(&shared);
            let stats = Arc::clone(&stats);
            let registry = registry.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("cogsim-exec-{w}"))
                    .spawn(move || worker_loop(engine, registry, shared, stats))
                    .expect("spawn worker"),
            );
        }

        Ok(Coordinator {
            engine,
            registry,
            shared,
            workers,
            next_id: AtomicU64::new(1),
            stats,
        })
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Submit `samples` flattened samples for `instance` at critical
    /// (in-the-loop) priority.  Returns a receiver that yields the
    /// output rows (or an error string).
    pub fn submit(&self, instance: &str, input: Vec<f32>) -> Result<Receiver<InferenceResult>> {
        self.submit_with_priority(instance, input, Priority::Critical)
    }

    /// Submit with an explicit urgency class (paper SII-B: in-the-loop
    /// vs on-the-loop traffic).
    pub fn submit_with_priority(
        &self,
        instance: &str,
        input: Vec<f32>,
        priority: Priority,
    ) -> Result<Receiver<InferenceResult>> {
        let model = self.registry.resolve(instance)?;
        let spec = self.engine.spec(model)?;
        let in_el = spec.input_elems();
        if input.is_empty() || input.len() % in_el != 0 {
            return Err(anyhow!(
                "{instance}: input length {} is not a positive multiple of {in_el}",
                input.len()
            ));
        }
        let samples = input.len() / in_el;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = sync_channel(1);

        self.shared.completions.lock().unwrap().insert(id, tx);
        {
            let mut batcher = self.shared.batcher.lock().unwrap();
            batcher.enqueue(
                instance,
                PendingRequest { id, input, samples, arrived: Instant::now(), priority },
            );
        }
        self.shared.ready.notify_one();
        self.stats.requests.fetch_add(1, Ordering::Relaxed);
        self.stats.samples.fetch_add(samples as u64, Ordering::Relaxed);
        Ok(rx)
    }

    /// Convenience: submit and wait.
    pub fn infer(&self, instance: &str, input: Vec<f32>) -> Result<Vec<f32>> {
        let rx = self.submit(instance, input)?;
        rx.recv()
            .map_err(|_| anyhow!("coordinator dropped the request"))?
            .map_err(|e| anyhow!(e))
    }

    /// Graceful shutdown: stop workers after the queues drain.
    pub fn shutdown(mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.ready.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.ready.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    engine: Arc<Engine>,
    registry: Registry,
    shared: Arc<Shared>,
    stats: Arc<CoordinatorStats>,
) {
    loop {
        // -- wait for a ready batch (or shutdown) --
        let batches: Vec<Batch> = {
            let mut batcher = shared.batcher.lock().unwrap();
            loop {
                if shared.shutdown.load(Ordering::SeqCst) && batcher.queued_total() == 0 {
                    return;
                }
                let now = Instant::now();
                // Idle fast path (§Perf): this worker is by definition
                // idle here, so holding a lone request for `max_wait`
                // only adds latency — batches form naturally while
                // workers are busy executing (continuous batching).
                // The deadline policy still governs whenever every
                // worker is occupied.  Measured: -440 µs at batch 1
                // (1.00 ms -> 0.59 ms with a 200 µs deadline config).
                if batcher.queued_total() > 0 {
                    break batcher.drain_ready(now + Duration::from_secs(3600));
                }
                // during shutdown, force-drain whatever is queued
                if shared.shutdown.load(Ordering::SeqCst) {
                    let all = batcher.drain_ready(now + Duration::from_secs(3600));
                    if all.is_empty() {
                        return;
                    }
                    break all;
                }
                match batcher.next_deadline(now) {
                    Some(deadline) => {
                        let wait = deadline.saturating_duration_since(now);
                        let (b, _timeout) = shared
                            .ready
                            .wait_timeout(batcher, wait.max(Duration::from_micros(10)))
                            .unwrap();
                        batcher = b;
                    }
                    None => {
                        batcher = shared.ready.wait(batcher).unwrap();
                    }
                }
            }
        };

        // -- execute outside the lock --
        for batch in batches {
            execute_batch(&engine, &registry, &shared, &stats, batch);
        }
    }
}

fn execute_batch(
    engine: &Engine,
    registry: &Registry,
    shared: &Shared,
    stats: &CoordinatorStats,
    batch: Batch,
) {
    stats.batches.fetch_add(1, Ordering::Relaxed);

    let result: Result<Vec<f32>> = (|| {
        let model = registry.resolve(&batch.instance)?;
        // gather request inputs into one contiguous mini-batch
        let spec = engine.spec(model)?;
        let in_el = spec.input_elems();
        let mut input = Vec::with_capacity(batch.total_samples * in_el);
        for req in &batch.requests {
            input.extend_from_slice(&req.input);
        }
        let waste = engine.padding_waste(model, batch.total_samples)?;
        stats.padded_samples.fetch_add(
            (waste * batch.total_samples as f64) as u64,
            Ordering::Relaxed,
        );
        let (out, _t) = engine.execute_padded(model, &input)?;
        Ok(out)
    })();

    // -- demux responses --
    let mut completions = shared.completions.lock().unwrap();
    match result {
        Ok(out) => {
            let model = registry.resolve(&batch.instance).expect("validated");
            let out_el = engine.spec(model).expect("validated").output_elems();
            let mut offset = 0usize;
            for req in &batch.requests {
                let rows = out[offset..offset + req.samples * out_el].to_vec();
                offset += req.samples * out_el;
                if let Some(tx) = completions.remove(&req.id) {
                    let _ = tx.send(Ok(rows));
                }
            }
        }
        Err(e) => {
            stats.errors.fetch_add(1, Ordering::Relaxed);
            for req in &batch.requests {
                if let Some(tx) = completions.remove(&req.id) {
                    let _ = tx.send(Err(format!("{e:#}")));
                }
            }
        }
    }
}
