//! The serving core — the paper's missing system piece (§VI: "a
//! generalized application for remote inference … which supports
//! remote inference to multiple, independent models").
//!
//! * [`registry`] — maps logical model *instances* (one Hermit per
//!   material, "an MPI rank might typically require results for 5-10
//!   different materials", §IV-A) onto loaded engine models.
//! * [`batcher`]  — the dynamic batcher: in-the-loop requests arrive
//!   as a few samples per (rank, material); the batcher coalesces
//!   them per instance under a latency deadline, padding to the
//!   compiled mini-batch ladder.
//! * [`core`]     — [`Coordinator`]: worker threads pull ready
//!   batches, execute them on the engine, and demultiplex the
//!   per-request responses.  `submit` carries the replica routing
//!   hook ([`RoutingPolicy`]) that picks which engine-model replica
//!   serves each request when an instance is deployed more than once.

pub mod batcher;
pub mod core;
pub mod registry;

pub use batcher::{Batch, BatcherConfig, DynamicBatcher, PendingRequest};
pub use core::{Coordinator, CoordinatorConfig, CoordinatorStats, RoutingPolicy};
pub use registry::Registry;
