//! # cogsim-disagg
//!
//! A disaggregated in-the-loop inference system for HPC cognitive
//! simulation (CogSim), reproducing *"Is Disaggregation possible for
//! HPC Cognitive Simulation?"* (Wyatt et al., CS.DC 2021).
//!
//! The paper asks whether surrogate-model inference that sits **inside
//! the timestep loop** of a multi-physics code (Hydra at LLNL) can be
//! offloaded from node-local GPUs to a network-attached AI accelerator
//! (a SambaNova DataScale on 100 Gb/s Infiniband).  Its §VI names the
//! missing system piece — "a generalized application for remote
//! inference … to multiple, independent models" — which is exactly
//! what this crate builds:
//!
//! * [`runtime`] — loads the AOT-compiled surrogate models (JAX →
//!   Pallas → HLO text) and executes them on a PJRT device.  Python is
//!   never on the request path.
//! * [`coordinator`] — the serving core: a multi-model registry
//!   (per-material Hermit instances + MIR), a request router, and a
//!   dynamic batcher tuned for the paper's small-mini-batch regime.
//! * [`net`] — the wire protocol and threaded TCP server/client (the
//!   paper's "prototype C++ API and library" equivalent) with
//!   asynchronous double-buffering (client sends mini-batch *n+1*
//!   before results for *n* return — the paper's throughput trick).
//! * [`devices`] — calibrated analytic performance models for every
//!   accelerator/API configuration in the paper's evaluation (P100,
//!   V100, A100, MI50, MI100 × PyTorch/TensorRT/CUDA-Graphs/C++).
//! * [`rdu`] — a dataflow-accelerator simulator: tiles, micro-batch
//!   pipelining, config-validity rules, preferred multiple-of-6 sizes.
//! * [`netsim`] — the Infiniband link model (100 Gb/s, 1 µs).
//! * [`fabric`] — the contention-aware fabric simulator: leaf/spine
//!   [`fabric::Topology`] graphs (host NICs, oversubscribed uplinks,
//!   accelerator NICs; `node_local` / `pooled` / `hybrid`
//!   constructors), a max-min fair-share bandwidth allocator
//!   (progressive filling), and the incremental
//!   [`fabric::FabricEngine`] that turns every remote dispatch into
//!   time-varying transfer events — request payload in, model-swap
//!   traffic competing on the same uplinks, result payload out.
//!   [`netsim::Link`] is the exact degenerate 1-flow case
//!   (`rust/tests/fabric_props.rs`).
//! * [`cluster`] — the multi-backend layer: a [`cluster::Backend`]
//!   trait unifying the GPU/RDU device models behind `latency_s` /
//!   `throughput` / `queue_s`, composed into a [`cluster::Cluster`]
//!   with pluggable routing policies (round-robin, least-outstanding,
//!   model-affinity, latency-aware).
//! * [`simcore`] — the engine-agnostic request pipeline shared by
//!   every discrete-event engine: policy routing via
//!   [`cluster::policy`], the router-level dynamic-batching stage
//!   (reusing [`coordinator::batcher`]), per-backend LRU model
//!   residency with the weights-ready gate, the legacy fixed-charge
//!   dispatch, and the multi-phase fabric path with its per-device
//!   busy clock — one copy, driven by both engines through a narrow
//!   effect-based surface ([`simcore::Pipeline`]).
//! * [`eventsim`] — deterministic discrete-event simulator: binary-heap
//!   event queue (class-tiered same-instant ordering), multi-rank
//!   arrival processes (timestep-synchronised bursts, open-loop
//!   Poisson, closed-loop think time), and full latency distributions
//!   (p50/p99/p99.9, histograms, per-rank slowdown) around the shared
//!   [`simcore::Pipeline`].  Degrades provably to the analytic
//!   [`cluster::Cluster`] in the contention-free limit
//!   (`rust/tests/eventsim_vs_analytic.rs`).
//! * [`eventsim::cogsim`] — the **coupled** CogSim application model:
//!   N ranks × T bulk-synchronous timesteps, each rank stalling on
//!   its in-the-loop inference burst (K per-material requests over M
//!   models + optional MIR cadence), partial compute/inference
//!   overlap, per-backend LRU model residency with swap costs, and
//!   per-timestep critical-path breakdowns (compute / queue / swap /
//!   network / service) behind the paper's real figure of merit —
//!   time-to-solution.  Degrades to `compute + Cluster` in the
//!   1-rank/1-model limit (`rust/tests/cogsim_vs_analytic.rs`).
//! * [`workload`] — Hydra/MIR request-trace generators.
//! * [`metrics`] — the paper's measurement methodology (mean over
//!   mini-batches, 5 replicates, 95 % confidence intervals).
//! * [`harness`] — one regenerator per paper figure (4–20), the
//!   scaling frontier, and the declarative scenario grid
//!   ([`harness::scenario`]: one axes×kind struct, one sweep engine
//!   ([`harness::sweep`]), one report layer ([`harness::report`]) —
//!   with heterogeneous mixed GPU+RDU pool fleets as a first-class
//!   axis).
//! * [`fluid`] — the steady-state **fluid tier**: closed-form
//!   queueing on the analytic service models + a max-min burst model
//!   of the fabric, microseconds per cell — the scale-out study
//!   (`repro scale`) sweeps leadership-class rank counts (64–16 384)
//!   against pool sizes on it, cross-validated against the event
//!   engine with pinned error bounds (`rust/tests/fluid_props.rs`).
//! * [`surrogate`] — a fitted surrogate of the simulator itself:
//!   clamped multilinear interpolation over event-engine grid
//!   results, exact on training cells and ≤ 5 % on held-out interior
//!   cells of the pinned validation slice.
//! * [`trace`] — the flight recorder: off-by-default, virtual-time
//!   -only tracing of the shared pipeline (per-request span
//!   lifecycles, device occupancy tracks, fabric link-utilization
//!   series, control-plane markers), exported as Chrome trace-event
//!   JSON (Perfetto-loadable) plus an aggregated attribution summary
//!   (`repro trace`, `--trace`); byte-identical across thread counts
//!   and output-unobservable when disarmed
//!   (`rust/tests/trace_props.rs`).
//! * [`util`] — in-tree substrates for the offline build environment:
//!   JSON parsing, a PCG-family RNG, statistics, and a micro-bench
//!   harness (no serde/rand/criterion available).
//!
//! See DESIGN.md for the substitution table (what the paper ran on real
//! hardware vs. what is simulated here and why the shape is preserved)
//! and EXPERIMENTS.md for paper-vs-reproduced numbers per figure.

pub mod cluster;
pub mod coordinator;
pub mod devices;
pub mod eventsim;
pub mod fabric;
pub mod fluid;
pub mod harness;
pub mod metrics;
pub mod net;
pub mod netsim;
pub mod rdu;
pub mod runtime;
pub mod simcore;
pub mod surrogate;
pub mod trace;
pub mod util;
pub mod workload;

pub use coordinator::{Coordinator, CoordinatorConfig};
pub use runtime::{Engine, Manifest};
