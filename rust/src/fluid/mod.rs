//! The steady-state **fluid tier**: closed-form queueing on the
//! analytic backend service models plus a max-min burst abstraction of
//! the pooled fabric — microseconds per cell instead of seconds, so a
//! sweep reaches leadership-class rank/pool counts the event-for-event
//! engines cannot.
//!
//! The fluid tier solves one cognitive-simulation timestep in closed
//! form:
//!
//! * requests are aggregated into per-model batches (the
//!   batching-window correction), split over homogeneous fleet
//!   *classes* by the routing policy's steady-state weights;
//! * each backend serves its share of batches serially; LRU swap cost
//!   enters as a steady-state miss rate (IRM: `1 - slots/models` per
//!   backend, with the model-affinity exception);
//! * the request burst and the staggered response stream cross the
//!   fabric at max-min burst rates; the response concurrency is a
//!   damped fixed point (completions arrive at the pool's service
//!   rate, so the number of in-flight response flows must be
//!   self-consistent with the per-flow rate they imply).
//!
//! The fluid tier models the hermit (hydra) stream only; MIR traffic
//! is out of scope (cross-validation always runs with `mir_every = 0`,
//! the default).  `python/sim/fluid.py` is the op-for-op mirror; the
//! committed scale golden (`rust/tests/golden/scale_summary.json`)
//! pins that both produce byte-identical JSON.

use crate::cluster::{Backend, GpuBackend, Policy, RduBackend};
use crate::devices::{profiles, Api, Gpu};
use crate::harness::scenario::{Fleet, Knobs, Topology};
use crate::harness::{run_cog_scenario, CogCampaignConfig};
use crate::netsim::Link;
use crate::rdu::RduApi;

/// Response-flow fixed-point iteration cap.
pub const FIXED_POINT_MAX_ITERS: usize = 64;
/// Convergence tolerance on the in-flight flow count.
pub const FIXED_POINT_TOL: f64 = 1e-9;
/// Damping factor (new = d·old + (1−d)·target).
pub const FIXED_POINT_DAMPING: f64 = 0.5;

/// One solved fluid cell: the same figures the event-for-event cog
/// summary reports, from the steady-state model.
#[derive(Debug, Clone)]
pub struct FluidSummary {
    pub ranks: u64,
    pub timesteps: u64,
    pub requests: u64,
    pub samples: u64,
    pub batches: u64,
    pub time_to_solution_s: f64,
    pub mean_step_s: f64,
    pub total_compute_s: f64,
    pub total_queue_s: f64,
    pub total_swap_s: f64,
    pub total_network_s: f64,
    pub total_service_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    /// Iterations the response-flow fixed point took (0 on local).
    pub fixed_point_iterations: u64,
    /// Whether the fixed point met [`FIXED_POINT_TOL`] (always true
    /// on local topologies, which have no fabric phase).
    pub converged: bool,
    /// Name of the bottleneck (straggler) backend class.
    pub bottleneck: String,
}

/// Homogeneous `(count, backend)` classes of the hermit tier.
///
/// Local: every rank owns an identical A100/TRT-CG, so one class of
/// `ranks` members with a zero-cost link.  Pooled/hybrid: the pool
/// members grouped by identical shape — the default fleet is the
/// 4-tile-C++ / 2-tile-Python pair; `Mixed { gpus, rdus }` is `gpus`
/// remote GPUs plus `ceil(rdus/2)` 4-tile and `floor(rdus/2)` 2-tile
/// groups (the alternating [`crate::harness::build_fleet`] pool
/// construction collapsed to class counts).
pub fn fleet_classes(
    topology: Topology,
    ranks: usize,
    fleet: Fleet,
    pool_link: &Link,
) -> Vec<(usize, Box<dyn Backend>)> {
    if topology == Topology::Local {
        return vec![(
            ranks,
            Box::new(GpuBackend::node_local("gpu/local", Gpu::a100(), Api::TrtCudaGraphs)),
        )];
    }
    let (gpus, rdus) = match fleet {
        Fleet::DefaultPool => {
            return vec![
                (
                    1,
                    Box::new(RduBackend::with_link(
                        "rdu/pool0",
                        4,
                        RduApi::CppOptimized,
                        pool_link.clone(),
                    )) as Box<dyn Backend>,
                ),
                (
                    1,
                    Box::new(RduBackend::with_link(
                        "rdu/pool1",
                        2,
                        RduApi::Python,
                        pool_link.clone(),
                    )),
                ),
            ];
        }
        Fleet::Mixed { gpus, rdus } => (gpus as usize, rdus as usize),
    };
    assert!(gpus + rdus >= 1, "mixed fleet needs members");
    let mut classes: Vec<(usize, Box<dyn Backend>)> = Vec::new();
    if gpus > 0 {
        classes.push((
            gpus,
            Box::new(GpuBackend::remote(
                "gpu/pool",
                Gpu::a100(),
                Api::TrtCudaGraphs,
                pool_link.clone(),
            )),
        ));
    }
    let four_tile = (rdus + 1) / 2;
    let two_tile = rdus / 2;
    if four_tile > 0 {
        classes.push((
            four_tile,
            Box::new(RduBackend::with_link(
                "rdu/pool-4t",
                4,
                RduApi::CppOptimized,
                pool_link.clone(),
            )),
        ));
    }
    if two_tile > 0 {
        classes.push((
            two_tile,
            Box::new(RduBackend::with_link(
                "rdu/pool-2t",
                2,
                RduApi::Python,
                pool_link.clone(),
            )),
        ));
    }
    classes
}

/// Per-flow max-min rate for a symmetric burst of `flows` flows.
///
/// Mirrors the pooled/hybrid capacity layout: per-source NIC ports,
/// source aggregation at `n_src·nic/oversub`, destination aggregation
/// at `n_dst·nic/oversub`, per-destination NIC ports.  With the flows
/// spread evenly, each port carries `flows/n` of them.
pub fn burst_rate(nic: f64, oversub: f64, flows: f64, n_src: usize, n_dst: usize) -> f64 {
    let per_src = nic / (flows / n_src as f64).max(1.0);
    let src_agg = n_src as f64 * nic / oversub / flows;
    let dst_agg = n_dst as f64 * nic / oversub / flows;
    let per_dst = nic / (flows / n_dst as f64).max(1.0);
    f64::min(f64::min(per_src, src_agg), f64::min(dst_agg, per_dst))
}

fn averaged(batch_sizes: &[usize], f: impl Fn(usize) -> f64) -> f64 {
    let mut total = 0.0;
    for &b in batch_sizes {
        total += f(b);
    }
    total / batch_sizes.len() as f64
}

/// Solve one grid cell in closed form.  The knobs consumed are
/// `samples_per_request`, `requests_per_step`, `max_batch`,
/// `residency_slots`, `timesteps` and `compute_s`; `window_us` rides
/// in separately because it is a grid axis, not a knob.
#[allow(clippy::too_many_arguments)]
pub fn solve_cell(
    topology: Topology,
    fleet: Fleet,
    policy: Policy,
    ranks: usize,
    models: usize,
    swap_s: f64,
    overlap: f64,
    oversub: f64,
    window_us: f64,
    knobs: &Knobs,
) -> FluidSummary {
    let profile = profiles::hermit();
    let pool_link = Link::infiniband_cx6();
    let classes = fleet_classes(topology, ranks, fleet, &pool_link);
    let n_backends: usize = classes.iter().map(|(c, _)| c).sum();

    let (lo, hi) = knobs.samples_per_request;
    let s_mean = (lo as f64 + hi as f64) / 2.0;
    let requests_per_step = ranks as f64 * knobs.requests_per_step as f64;
    let window_s = window_us * 1e-6;

    // -- batching-window correction: per-model aggregation ------------
    let (total_batches, window_wait, batch_sizes, mean_batch) = if window_s > 0.0 {
        let samples_m = requests_per_step * s_mean / models as f64;
        let batches_m = (samples_m / knobs.max_batch as f64).max(1.0);
        let wait = if samples_m < knobs.max_batch as f64 { window_s } else { 0.0 };
        let sizes = vec![((samples_m / batches_m).round() as usize).max(1)];
        let mean = sizes[0] as f64;
        (models as f64 * batches_m, wait, sizes, mean)
    } else {
        // window off: every request is its own batch; service values
        // are expectations over the integer sample distribution
        (requests_per_step, 0.0, (lo..=hi).collect::<Vec<usize>>(), s_mean)
    };

    // -- per-class service rates (averaged over batch sizes) ----------
    let execs: Vec<f64> = classes
        .iter()
        .map(|(_, be)| averaged(&batch_sizes, |b| be.execute_s(&profile, b)))
        .collect();
    let occs: Vec<f64> = classes
        .iter()
        .map(|(_, be)| averaged(&batch_sizes, |b| be.occupancy_s(&profile, b)))
        .collect();
    let link_ohs: Vec<f64> = classes
        .iter()
        .map(|(_, be)| averaged(&batch_sizes, |b| be.link_overhead_s(&profile, b)))
        .collect();

    // -- routing-policy load split ------------------------------------
    // The cursor policy deals batches evenly; queue/latency-aware
    // policies equalise backlog, so class load goes with
    // count/occupancy.  Model affinity assigns each model to the
    // least-queued backend at first touch, which is also speed-biased,
    // and concentrates the whole stream on at most `models` backends.
    // Affinity assignment happens at first touch, when every request
    // misses: the queue the assignment reads includes the swap charge,
    // so the speed bias washes out as swap_s grows.
    let weights: Vec<f64> = classes
        .iter()
        .zip(&occs)
        .map(|((count, _), occ)| match policy {
            Policy::RoundRobin => *count as f64,
            Policy::ModelAffinity => *count as f64 / (occ + swap_s),
            _ => *count as f64 / occ,
        })
        .collect();
    let mut wsum = 0.0;
    for w in &weights {
        wsum += w;
    }

    let slots = knobs.residency_slots as f64;
    let mut per_backend_batches = Vec::new();
    let mut per_backend_models = Vec::new();
    let mut loaded_per_class = Vec::new();
    for ((count, _), w) in classes.iter().zip(&weights) {
        let share = w / wsum;
        let loaded = if policy == Policy::ModelAffinity {
            (*count as f64).min(models as f64 * share)
        } else {
            *count as f64
        };
        loaded_per_class.push(loaded);
        per_backend_batches.push(total_batches * share / loaded);
        per_backend_models.push(models as f64 * share / loaded);
    }
    let mut loaded_total = 0.0;
    for l in &loaded_per_class {
        loaded_total += l;
    }

    // -- steady-state LRU miss rate (IRM) -----------------------------
    // Under round-robin / least-outstanding / latency-aware routing a
    // backend eventually sees the whole model population, so the LRU
    // hit ratio is slots/models (uniform IRM); model affinity pins
    // each model to one backend, leaving models/loaded distinct models
    // per loaded backend.
    // -- straggler corrections ----------------------------------------
    // The barrier ends a step at the MAX over backends, so the
    // bottleneck backend carries a Gumbel-style excess over the mean:
    // miss counts fluctuate binomially under cursor routing (fully for
    // round-robin, half-damped when backlog-aware policies reshuffle
    // load away from unlucky backends), and affinity's first-touch
    // assignment leaves a multinomial imbalance in both batches and
    // models per backend.
    let ln_loaded = if loaded_total > 1.0 { loaded_total.ln() } else { 0.0 };

    let multinomial_max = |mean: f64| {
        if ln_loaded == 0.0 {
            mean
        } else {
            mean + (mean * (1.0 - 1.0 / loaded_total) * ln_loaded).sqrt()
        }
    };

    let lru_miss = |models_per_backend: f64| {
        if models_per_backend <= slots {
            0.0
        } else {
            1.0 - slots / models_per_backend
        }
    };

    let mut misses = Vec::new();
    let mut misses_strag = Vec::new();
    for &m_b in &per_backend_models {
        if policy == Policy::ModelAffinity {
            misses.push(lru_miss(m_b));
            misses_strag.push(lru_miss(multinomial_max(m_b)));
        } else {
            misses.push(lru_miss(models as f64));
            misses_strag.push(lru_miss(models as f64));
        }
    }
    let mut miss_mean = 0.0;
    for (loaded, m) in loaded_per_class.iter().zip(&misses) {
        miss_mean += m * loaded;
    }
    miss_mean /= loaded_total;

    let straggler_miss = |i: usize, b: f64| {
        let p = misses_strag[i];
        if policy == Policy::ModelAffinity || p <= 0.0 || p >= 1.0 || ln_loaded == 0.0 {
            return p;
        }
        let damping = if policy == Policy::RoundRobin { 1.0 } else { 0.5 };
        (p + damping * (p * (1.0 - p) * ln_loaded / b).sqrt()).min(1.0)
    };

    let straggler_batches = |b: f64| {
        if policy != Policy::ModelAffinity {
            b
        } else {
            multinomial_max(b)
        }
    };

    // -- swap cost per miss -------------------------------------------
    // Direct (local) dispatch charges swap_s on the backend.  Over the
    // fabric a swap is a weight transfer of swap_s * nic bytes down
    // the shared swap path, so its duration stretches with
    // oversubscription and with the number of concurrently-swapping
    // pool members.
    let swap_cost = if topology == Topology::Local || swap_s <= 0.0 {
        swap_s
    } else {
        let concurrency = 1.0 + miss_mean * (n_backends as f64 - 1.0);
        swap_s * (oversub * concurrency / n_backends as f64).max(1.0)
    };

    // -- fabric burst phase (pooled / hybrid only) --------------------
    let mut fixed_point_iterations = 0u64;
    let mut converged = true;
    let (t_in, t_out, dir_fixed) = if topology == Topology::Local {
        (0.0, 0.0, 0.0)
    } else {
        let nic = pool_link.eff_bandwidth;
        let in_bytes = 2.0 * profile.input_elems as f64 * mean_batch;
        let out_bytes = 2.0 * profile.output_elems as f64 * mean_batch;
        let rate_in = burst_rate(nic, oversub, total_batches, ranks, n_backends);
        // pool service rate in batches/s: completions leave at mu, so
        // in-flight response flows F satisfy F = mu * out_bytes/rate(F)
        let mut mu = 0.0;
        for (((count, _), ex), m) in classes.iter().zip(&execs).zip(&misses) {
            mu += *count as f64 / (ex + m * swap_cost);
        }
        let mut flows = 1.0;
        converged = false;
        for _ in 0..FIXED_POINT_MAX_ITERS {
            fixed_point_iterations += 1;
            let rate = burst_rate(nic, oversub, flows, n_backends, ranks);
            let mut target = mu * out_bytes / rate;
            if target < 1.0 {
                target = 1.0;
            }
            if target > total_batches {
                target = total_batches;
            }
            let nxt = FIXED_POINT_DAMPING * flows + (1.0 - FIXED_POINT_DAMPING) * target;
            if (nxt - flows).abs() < FIXED_POINT_TOL {
                flows = nxt;
                converged = true;
                break;
            }
            flows = nxt;
        }
        let t_out = out_bytes / burst_rate(nic, oversub, flows, n_backends, ranks);
        (in_bytes / rate_in, t_out, pool_link.dir_fixed_s())
    };

    // -- per-class inference phase (straggler backend) ----------------
    let mut phases = Vec::new();
    let mut queues = Vec::new();
    let mut nets = Vec::new();
    let mut swaps = Vec::new();
    for (i, b_c) in per_backend_batches.iter().enumerate() {
        let b_strag = straggler_batches(*b_c);
        let p_strag = straggler_miss(i, b_c.max(1.0));
        let (gap, net) = if topology == Topology::Local {
            (occs[i] + p_strag * swap_cost, link_ohs[i])
        } else {
            (execs[i] + p_strag * swap_cost, t_in + dir_fixed + t_out + dir_fixed)
        };
        let queue = window_wait + (b_strag - 1.0).max(0.0) * gap;
        let phase = queue + p_strag * swap_cost + net + execs[i];
        phases.push(phase);
        queues.push(queue);
        nets.push(net);
        swaps.push(p_strag * swap_cost);
    }

    let mut bottleneck_idx = 0;
    for i in 1..phases.len() {
        if phases[i] > phases[bottleneck_idx] {
            bottleneck_idx = i;
        }
    }
    let phase_max = phases[bottleneck_idx];

    // -- step assembly (mirrors the cogsim emit model) ----------------
    let compute = knobs.compute_s;
    let emit_offset = (1.0 - overlap) * compute;
    let step = compute.max(emit_offset + phase_max);
    let timesteps = knobs.timesteps;
    let tts = step * timesteps as f64;

    // -- request quantiles: weighted per-batch-position latencies -----
    let mut entries: Vec<(f64, f64)> = Vec::new();
    for (i, b_c) in per_backend_batches.iter().enumerate() {
        let gap = if topology == Topology::Local {
            occs[i] + misses[i] * swap_cost
        } else {
            execs[i] + misses[i] * swap_cost
        };
        let base = window_wait + misses[i] * swap_cost + nets[i] + execs[i];
        let mut k = 0usize;
        loop {
            let weight = loaded_per_class[i] * (b_c - k as f64).min(1.0);
            if weight <= 0.0 {
                break;
            }
            entries.push((base + k as f64 * gap, weight));
            k += 1;
        }
    }
    entries.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("finite latencies"));
    let mut total_weight = 0.0;
    for (_, w) in &entries {
        total_weight += w;
    }

    let weighted_quantile = |q: f64| {
        let thresh = q / 100.0 * total_weight;
        let mut cum = 0.0;
        for &(latency, w) in &entries {
            cum += w;
            if cum >= thresh {
                return latency;
            }
        }
        entries[entries.len() - 1].0
    };

    let p50 = weighted_quantile(50.0);
    let p99 = weighted_quantile(99.0);

    FluidSummary {
        ranks: ranks as u64,
        timesteps: timesteps as u64,
        requests: (ranks * knobs.requests_per_step * timesteps) as u64,
        samples: (requests_per_step * s_mean).round() as u64 * timesteps as u64,
        batches: total_batches.round() as u64 * timesteps as u64,
        time_to_solution_s: tts,
        mean_step_s: step,
        total_compute_s: emit_offset * timesteps as f64,
        total_queue_s: queues[bottleneck_idx] * timesteps as f64,
        total_swap_s: swaps[bottleneck_idx] * timesteps as f64,
        total_network_s: nets[bottleneck_idx] * timesteps as f64,
        total_service_s: execs[bottleneck_idx] * timesteps as f64,
        p50_s: p50,
        p99_s: p99,
        fixed_point_iterations,
        converged,
        bottleneck: classes[bottleneck_idx].1.name().to_string(),
    }
}

// ------------------------------------------------------ scale campaign

/// The scale-out study: pooled-vs-local crossover over leadership-class
/// rank counts × pool sizes, on the fluid tier (the whole campaign
/// runs in milliseconds).
#[derive(Debug, Clone)]
pub struct ScaleCampaignConfig {
    pub rank_counts: Vec<usize>,
    pub pool_sizes: Vec<usize>,
    pub policy: Policy,
    /// Fabric oversubscription of the pooled cells (local runs 1:1).
    pub oversub: f64,
    pub models_per_rank: usize,
    pub swap_s: f64,
    pub overlap: f64,
    pub timesteps: usize,
    pub compute_s: f64,
    pub requests_per_step: usize,
    pub samples_per_request: (usize, usize),
    pub residency_slots: usize,
    /// Batching window, µs (0 = off — the small-batch regime where
    /// the RDU pool's small-batch latency advantage matters).
    pub window_us: f64,
    pub max_batch: usize,
    /// Rank counts where the coupled event-for-event engine re-runs a
    /// swap-free pooled cell next to the fluid solution, pinning the
    /// fluid tier's TTS error beyond the 32-rank campaign grid.
    pub anchor_rank_counts: Vec<usize>,
}

impl Default for ScaleCampaignConfig {
    fn default() -> Self {
        ScaleCampaignConfig {
            rank_counts: vec![64, 256, 1024, 4096, 16384],
            pool_sizes: vec![8, 16, 32, 64, 128, 256, 512],
            policy: Policy::RoundRobin,
            oversub: 4.0,
            models_per_rank: 8,
            swap_s: 2e-3,
            overlap: 0.0,
            timesteps: 8,
            compute_s: 2e-3,
            requests_per_step: 6,
            samples_per_request: (2, 3),
            residency_slots: 4,
            window_us: 0.0,
            max_batch: 256,
            anchor_rank_counts: vec![64, 256],
        }
    }
}

impl ScaleCampaignConfig {
    /// CI-sized: two rank counts, two pool sizes (8 cells), one
    /// event-engine anchor.
    pub fn smoke() -> Self {
        ScaleCampaignConfig {
            rank_counts: vec![64, 1024],
            pool_sizes: vec![8, 64],
            anchor_rank_counts: vec![64],
            ..Default::default()
        }
    }

    fn knobs(&self) -> Knobs {
        Knobs {
            samples_per_request: self.samples_per_request,
            requests_per_step: self.requests_per_step,
            max_batch: self.max_batch,
            timesteps: self.timesteps,
            compute_s: self.compute_s,
            residency_slots: self.residency_slots,
            ..Knobs::default()
        }
    }
}

/// One rank count's cells: the local baseline, every pooled pool size,
/// and the crossover (smallest pool whose TTS matches local).
#[derive(Debug, Clone)]
pub struct ScaleRow {
    pub ranks: usize,
    pub local: FluidSummary,
    pub pools: Vec<(usize, FluidSummary)>,
    /// Smallest swept pool with pooled TTS <= local TTS, if any.
    pub crossover_pool: Option<usize>,
}

/// The fluid-vs-event TTS bound the anchor cells re-validate at
/// scale-out rank counts — the same 15 % contract `fluid_props` pins
/// on the 32-rank campaign grid (measured ~0.1 % on the swap-free
/// anchors themselves).
pub const ANCHOR_TTS_BOUND: f64 = 0.15;

/// One event-engine anchor cell: the coupled event-for-event engine
/// and the fluid tier solve the same pooled cell and the TTS
/// discrepancy is pinned.  Anchors run **swap-free** at the campaign's
/// oversubscription: the fluid swap-concurrency model is deliberately
/// outside the cross-validation contract (like the congested corner
/// of the campaign grid), and the swap-free half is where the ≤ 15 %
/// bound holds.
#[derive(Debug, Clone)]
pub struct ScaleAnchor {
    pub ranks: usize,
    pub oversub: f64,
    /// Always 0.0 — kept so the serialized anchor is self-describing.
    pub swap_s: f64,
    pub event_tts_s: f64,
    pub fluid_tts_s: f64,
}

impl ScaleAnchor {
    /// Signed relative TTS error of the fluid solution vs the event
    /// engine.
    pub fn tts_error(&self) -> f64 {
        self.fluid_tts_s / self.event_tts_s - 1.0
    }

    /// Does this anchor hold [`ANCHOR_TTS_BOUND`]?
    pub fn within_bound(&self) -> bool {
        self.tts_error().abs() <= ANCHOR_TTS_BOUND
    }
}

/// The executed scale campaign.
#[derive(Debug, Clone)]
pub struct ScaleCampaignResult {
    pub config: ScaleCampaignConfig,
    pub rows: Vec<ScaleRow>,
    /// Event-engine cross-checks; empty unless the campaign ran via
    /// [`run_scale_campaign_with_anchors`] (the plain fluid sweep must
    /// stay microseconds-per-cell fast).
    pub anchors: Vec<ScaleAnchor>,
}

impl ScaleCampaignResult {
    /// Row lookup by rank count.
    pub fn row(&self, ranks: usize) -> Option<&ScaleRow> {
        self.rows.iter().find(|r| r.ranks == ranks)
    }
}

/// Run the scale campaign (sequential: tens of cells, microseconds
/// each).
pub fn run_scale_campaign(cfg: &ScaleCampaignConfig) -> ScaleCampaignResult {
    let knobs = cfg.knobs();
    let rows = cfg
        .rank_counts
        .iter()
        .map(|&ranks| {
            let local = solve_cell(
                Topology::Local,
                Fleet::DefaultPool,
                cfg.policy,
                ranks,
                cfg.models_per_rank,
                cfg.swap_s,
                cfg.overlap,
                1.0,
                cfg.window_us,
                &knobs,
            );
            let mut pools = Vec::new();
            let mut crossover = None;
            for &pool in &cfg.pool_sizes {
                let s = solve_cell(
                    Topology::Pooled,
                    Fleet::Mixed { gpus: 0, rdus: pool as u16 },
                    cfg.policy,
                    ranks,
                    cfg.models_per_rank,
                    cfg.swap_s,
                    cfg.overlap,
                    cfg.oversub,
                    cfg.window_us,
                    &knobs,
                );
                if crossover.is_none() && s.time_to_solution_s <= local.time_to_solution_s {
                    crossover = Some(pool);
                }
                pools.push((pool, s));
            }
            ScaleRow { ranks, local, pools, crossover_pool: crossover }
        })
        .collect();
    ScaleCampaignResult { config: cfg.clone(), rows, anchors: Vec::new() }
}

/// Run the event-engine anchor cells: for each anchor rank count,
/// the coupled event-for-event engine and the fluid tier solve the
/// same swap-free pooled cell (default pool fleet, the campaign's
/// oversubscription and knobs).  Affordable now that the event
/// engine's hot path runs on the ladder queue with lazy bulk arrivals
/// and coalesced fabric wakes — a 256-rank coupled cell is a
/// sub-second run instead of a campaign-sized one.
pub fn run_scale_anchors(cfg: &ScaleCampaignConfig) -> Vec<ScaleAnchor> {
    let knobs = cfg.knobs();
    let cog = CogCampaignConfig {
        timesteps: cfg.timesteps,
        compute_s: cfg.compute_s,
        requests_per_step: cfg.requests_per_step,
        samples_per_request: cfg.samples_per_request,
        residency_slots: cfg.residency_slots,
        window_us: cfg.window_us,
        max_batch: cfg.max_batch,
        ..CogCampaignConfig::default()
    };
    cfg.anchor_rank_counts
        .iter()
        .map(|&ranks| {
            let event = run_cog_scenario(
                Topology::Pooled,
                cfg.policy,
                ranks,
                cfg.models_per_rank,
                0.0,
                cfg.overlap,
                cfg.oversub,
                &cog,
            );
            let fluid = solve_cell(
                Topology::Pooled,
                Fleet::DefaultPool,
                cfg.policy,
                ranks,
                cfg.models_per_rank,
                0.0,
                cfg.overlap,
                cfg.oversub,
                cfg.window_us,
                &knobs,
            );
            ScaleAnchor {
                ranks,
                oversub: cfg.oversub,
                swap_s: 0.0,
                event_tts_s: event.summary.time_to_solution_s,
                fluid_tts_s: fluid.time_to_solution_s,
            }
        })
        .collect()
}

/// The scale campaign plus its event-engine anchors — the document
/// the committed scale golden pins.
pub fn run_scale_campaign_with_anchors(cfg: &ScaleCampaignConfig) -> ScaleCampaignResult {
    let mut result = run_scale_campaign(cfg);
    result.anchors = run_scale_anchors(cfg);
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn burst_rate_uncontended_single_flow_gets_the_nic() {
        // one flow, plenty of ports on both sides, 1:1 fabric: the
        // flow is NIC-limited
        let nic = 2.1e9;
        assert_eq!(burst_rate(nic, 1.0, 1.0, 4, 4), nic);
        // oversubscription caps the aggregate
        assert!(burst_rate(nic, 4.0, 8.0, 4, 4) < burst_rate(nic, 1.0, 8.0, 4, 4));
    }

    #[test]
    fn local_cell_has_no_fabric_phase() {
        let s = solve_cell(
            Topology::Local,
            Fleet::DefaultPool,
            Policy::RoundRobin,
            4,
            8,
            0.0,
            0.0,
            1.0,
            0.0,
            &Knobs::default(),
        );
        assert_eq!(s.total_network_s, 0.0);
        assert_eq!(s.fixed_point_iterations, 0);
        assert!(s.converged);
        assert_eq!(s.bottleneck, "gpu/local");
        assert!(s.time_to_solution_s > 0.0);
    }

    #[test]
    fn pooled_cell_pays_the_fabric_and_converges() {
        let s = solve_cell(
            Topology::Pooled,
            Fleet::DefaultPool,
            Policy::RoundRobin,
            4,
            8,
            0.0,
            0.0,
            1.0,
            0.0,
            &Knobs::default(),
        );
        assert!(s.total_network_s > 0.0);
        assert!(s.converged, "fixed point must converge on the default cell");
        assert!(s.fixed_point_iterations > 0);
        assert!(s.p99_s >= s.p50_s);
    }

    #[test]
    fn scale_campaign_covers_the_grid_and_orders_pools() {
        let cfg = ScaleCampaignConfig::smoke();
        let r = run_scale_campaign(&cfg);
        assert_eq!(r.rows.len(), cfg.rank_counts.len());
        for row in &r.rows {
            assert_eq!(row.pools.len(), cfg.pool_sizes.len());
            // bigger pools never hurt at fixed ranks
            for w in row.pools.windows(2) {
                assert!(
                    w[1].1.time_to_solution_s <= w[0].1.time_to_solution_s + 1e-12,
                    "ranks {}: pool {} slower than pool {}",
                    row.ranks,
                    w[1].0,
                    w[0].0
                );
            }
            // the crossover marker is consistent with the cells
            if let Some(x) = row.crossover_pool {
                let (_, s) = row.pools.iter().find(|(p, _)| *p == x).expect("swept pool");
                assert!(s.time_to_solution_s <= row.local.time_to_solution_s);
            }
        }
    }
}
