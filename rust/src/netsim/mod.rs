//! Analytic model of the disaggregation link: Mellanox Infiniband
//! ConnectX-6, "up to 100Gb/s bandwidth and less than 1µs latency"
//! (§II-A), driven through the paper's prototype C++ remote-inference
//! API.
//!
//! The wire itself is fast; what the paper's remote measurements show
//! (Fig. 15: +0.01 ms at mini-batch 4, +1.14 ms at 16K over local
//! C++) is the *software* path: serialisation, the message rendezvous
//! and a single-stream effective bandwidth well under line rate.  The
//! model:
//!
//! ```text
//! overhead(bytes) = 2·wire_latency + soft_per_msg + bytes/eff_bw
//! ```
//!
//! For throughput the client double-buffers (sends mini-batch n+1
//! before n returns, §V-A), overlapping roughly half of the transfer
//! with device execution — calibrated to Fig. 16's 6.4 M samples/s at
//! 16K remote vs 8.14 M local.

/// Link + software-path constants.
#[derive(Debug, Clone, PartialEq)]
pub struct Link {
    /// One-way wire latency, seconds.
    pub wire_latency_s: f64,
    /// Software cost per request/response pair (serialisation, recv
    /// wakeup, completion handling), seconds.
    pub soft_per_msg_s: f64,
    /// Effective single-stream bandwidth through the prototype API,
    /// bytes/s.
    pub eff_bandwidth: f64,
    /// Raw line rate, bytes/s (reported, not the software bottleneck).
    pub line_rate: f64,
    /// Fraction of the transfer hidden behind device execution when
    /// the client double-buffers.
    pub async_overlap: f64,
}

impl Link {
    /// The Corona <-> DataScale link from the paper.
    pub fn infiniband_cx6() -> Link {
        Link {
            wire_latency_s: 1e-6,          // "less than 1µs latency"
            soft_per_msg_s: 8e-6,          // prototype C++ API per-message cost
            eff_bandwidth: 2.1e9,          // single-stream software path
            line_rate: 100e9 / 8.0,        // "up to 100Gb/s"
            async_overlap: 0.5,
        }
    }

    /// An ideal link (zero everything) — the node-local limit.
    pub fn local() -> Link {
        Link {
            wire_latency_s: 0.0,
            soft_per_msg_s: 0.0,
            eff_bandwidth: f64::INFINITY,
            line_rate: f64::INFINITY,
            async_overlap: 1.0,
        }
    }

    /// Round-trip overhead added to one remote inference of
    /// `bytes_total` (request payload + response payload), seconds.
    ///
    /// The transfer term is guarded: [`Link::local`] models an ideal
    /// link with `eff_bandwidth = ∞`, and a zero-byte payload (a
    /// metadata-only request, or a degenerate batch) would otherwise
    /// evaluate `0/0`-adjacent expressions — `∞/∞` is NaN, and a NaN
    /// here poisons every queue/latency figure downstream.
    pub fn rtt_overhead_s(&self, bytes_total: f64) -> f64 {
        let transfer_s = if bytes_total > 0.0 && self.eff_bandwidth.is_finite() {
            bytes_total / self.eff_bandwidth
        } else {
            0.0
        };
        2.0 * self.wire_latency_s + self.soft_per_msg_s + transfer_s
    }

    /// Fixed per-direction latency when the round trip is split into
    /// two transfers (request in, result out), as the flow-level
    /// fabric simulator ([`crate::fabric`]) does: one wire traversal
    /// plus half the per-message software cost each way, so that
    ///
    /// ```text
    /// 2 · dir_fixed_s + bytes_total / eff_bandwidth == rtt_overhead_s
    /// ```
    ///
    /// holds exactly — [`Link`] stays the degenerate 1-flow case the
    /// fabric collapses to when nothing competes for bandwidth.
    pub fn dir_fixed_s(&self) -> f64 {
        self.wire_latency_s + 0.5 * self.soft_per_msg_s
    }

    /// Remote latency given node-local latency and payload bytes.
    pub fn remote_latency_s(&self, local_latency_s: f64, bytes_total: f64) -> f64 {
        local_latency_s + self.rtt_overhead_s(bytes_total)
    }

    /// Effective period between completed mini-batches under async
    /// double-buffering (the paper's remote-throughput trick).
    pub fn remote_period_s(&self, local_latency_s: f64, bytes_total: f64) -> f64 {
        local_latency_s + self.rtt_overhead_s(bytes_total) * (1.0 - self.async_overlap)
    }

    /// Remote throughput in samples/s for a mini-batch of `n` samples.
    pub fn remote_throughput(
        &self,
        local_latency_s: f64,
        bytes_total: f64,
        n: usize,
    ) -> f64 {
        n as f64 / self.remote_period_s(local_latency_s, bytes_total)
    }
}

/// Payload bytes for a Hermit/MIR inference round trip at half
/// precision (input up, output back — the paper's remote tests move
/// both directions, §V-A).
pub fn payload_bytes(input_elems: usize, output_elems: usize, batch: usize) -> f64 {
    2.0 * (input_elems + output_elems) as f64 * batch as f64
}

/// Per-direction payload bytes at half precision: `(request, result)`.
/// Sums to [`payload_bytes`]; the fabric simulator charges each
/// direction as its own flow.
pub fn dir_payload_bytes(input_elems: usize, output_elems: usize, batch: usize) -> (f64, f64) {
    (
        2.0 * input_elems as f64 * batch as f64,
        2.0 * output_elems as f64 * batch as f64,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    const HERMIT_IN: usize = 42;
    const HERMIT_OUT: usize = 30;

    #[test]
    fn calibration_anchor_small_batch_overhead() {
        // Fig. 15: remote four-sample latency 0.05 ms vs the 0.04 ms
        // local minimum -> ~0.01 ms added.
        let link = Link::infiniband_cx6();
        let over = link.rtt_overhead_s(payload_bytes(HERMIT_IN, HERMIT_OUT, 4));
        assert!((8e-6..=14e-6).contains(&over), "{over}");
    }

    #[test]
    fn calibration_anchor_16k_overhead() {
        // Fig. 15: "At a mini-batch size of 16K … the largest
        // difference … with the C++ API at 1.14ms".
        let link = Link::infiniband_cx6();
        let over = link.rtt_overhead_s(payload_bytes(HERMIT_IN, HERMIT_OUT, 16384));
        assert!((over / 1.14e-3 - 1.0).abs() < 0.15, "{over}");
    }

    #[test]
    fn calibration_anchor_remote_throughput_16k() {
        // Fig. 16: "a maximum remote inference throughput of 6.4M
        // samples/s" at 16K, against the 8.14M local.
        let link = Link::infiniband_cx6();
        let local = 16384.0 / 8.14e6; // paper's local latency at 16K
        let thr = link.remote_throughput(
            local,
            payload_bytes(HERMIT_IN, HERMIT_OUT, 16384),
            16384,
        );
        assert!((thr / 6.4e6 - 1.0).abs() < 0.15, "{thr}");
    }

    #[test]
    fn remote_slower_than_local_always() {
        let link = Link::infiniband_cx6();
        for b in crate::devices::PAPER_BATCHES {
            let local = 1e-3;
            let bytes = payload_bytes(HERMIT_IN, HERMIT_OUT, b);
            assert!(link.remote_latency_s(local, bytes) > local);
            assert!(link.remote_period_s(local, bytes) <= link.remote_latency_s(local, bytes));
        }
    }

    #[test]
    fn local_link_is_free() {
        let link = Link::local();
        assert_eq!(link.rtt_overhead_s(1e9), 0.0);
        assert_eq!(link.remote_latency_s(2e-3, 1e9), 2e-3);
    }

    #[test]
    fn payload_accounting_fp16() {
        // 4 samples of Hermit: (42 + 30) * 2 bytes * 4 = 576 bytes.
        assert_eq!(payload_bytes(42, 30, 4), 576.0);
    }

    #[test]
    fn zero_byte_and_infinite_bandwidth_never_nan() {
        // Regression: Link::local() uses eff_bandwidth = INFINITY;
        // the transfer term must stay exactly 0 (never NaN) for
        // zero-byte, huge, and even infinite payloads, and the
        // Infiniband link must charge only its fixed per-message cost
        // on an empty payload.
        let local = Link::local();
        for bytes in [0.0, 1.0, 1e18, f64::INFINITY] {
            let over = local.rtt_overhead_s(bytes);
            assert_eq!(over, 0.0, "local link, {bytes} bytes");
            assert!(local.remote_latency_s(1e-3, bytes).is_finite());
            assert!(local.remote_period_s(1e-3, bytes).is_finite());
        }
        let ib = Link::infiniband_cx6();
        let over = ib.rtt_overhead_s(0.0);
        assert!(over.is_finite() && !over.is_nan());
        assert_eq!(over, 2.0 * ib.wire_latency_s + ib.soft_per_msg_s);
        // zero-batch payload sizing composes with the guard
        assert_eq!(payload_bytes(42, 30, 0), 0.0);
        assert!(ib.rtt_overhead_s(payload_bytes(42, 30, 0)).is_finite());
    }

    #[test]
    fn direction_split_reassembles_the_round_trip() {
        // The fabric charges each direction separately; the split
        // must reassemble the legacy single charge exactly.
        let link = Link::infiniband_cx6();
        for batch in [1usize, 4, 256, 16384] {
            let total = payload_bytes(HERMIT_IN, HERMIT_OUT, batch);
            let (up, down) = dir_payload_bytes(HERMIT_IN, HERMIT_OUT, batch);
            assert_eq!(up + down, total);
            let split = 2.0 * link.dir_fixed_s() + total / link.eff_bandwidth;
            assert!(
                (split - link.rtt_overhead_s(total)).abs() < 1e-15,
                "batch {batch}: {split} vs {}",
                link.rtt_overhead_s(total)
            );
        }
        // the local link splits to zero fixed cost per direction
        assert_eq!(Link::local().dir_fixed_s(), 0.0);
    }

    #[test]
    fn software_path_is_the_bottleneck() {
        // The effective single-stream bandwidth must be far below the
        // line rate — the paper's remote penalty is software, not wire.
        let link = Link::infiniband_cx6();
        assert!(link.eff_bandwidth < 0.25 * link.line_rate);
    }
}
