//! The flight recorder: deterministic, virtual-time-only tracing of
//! the shared request pipeline.
//!
//! Off by default.  When armed (via `Pipeline::arm_trace`, surfaced
//! as `repro trace` and the `--trace` flag on the scenario
//! subcommands) the [`Recorder`] captures, entirely in **virtual
//! time**:
//!
//! * per-request span lifecycles — queued → batched → payload flow →
//!   weights gate → device busy → result flow ([`Span`]/[`Phase`]);
//! * per-device occupancy tracks ([`BusyInterval`] — one interval per
//!   served batch, so the per-device busy integral is exactly the sum
//!   of service durations, which the property tests reconcile against
//!   the pipeline's own always-on counter to 1e-9);
//! * fabric per-link utilization and constrained-flow-count time
//!   series, sampled at every flow start/finish/cancel/degrade (the
//!   only instants rates can change — the series is exact, not
//!   polled);
//! * control-plane markers (leave/join, degrade/restore, rank fail,
//!   autoscaler steps).
//!
//! Exports: [`Recorder::chrome_trace`] renders a Chrome trace-event
//! JSON array (load the emitted file in <https://ui.perfetto.dev>),
//! and [`Recorder::attribution`] a compact aggregated summary
//! (per-device utilization integrals, gate-wait totals, the
//! batch-occupancy histogram, per-link busy fractions).
//!
//! Determinism contract — enforced by `rust/tests/trace_props.rs`:
//!
//! * every timestamp in an emitted record is virtual time (no
//!   `Instant`, no wall clock — the only wall-clock figure anywhere
//!   near this layer is the `--timings` side-channel, which is a
//!   separate file precisely so it can be honest about being
//!   non-deterministic);
//! * armed traces are byte-identical across `--threads` values (cells
//!   record single-threaded; the sweep merges in input order);
//! * disarmed, the recorder is output-unobservable: every hook is an
//!   `Option` check on the pipeline's hot path and no golden or
//!   `BENCH_*` floor moves.

use std::collections::BTreeMap;

use crate::fabric::FabricEngine;
use crate::util::json::Value;

/// One phase of a request's lifecycle.  The legacy fixed-charge path
/// tiles `Queued → Wait → Swap → Link → Exec`; the fabric path tiles
/// `Queued → XferIn → Gate → Wait → Exec → XferOut`.  Both partitions
/// cover `[emit, complete]` exactly (the same identity the breakdown
/// tests pin to 1e-9).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Submitted, waiting in the batching window / router.
    Queued,
    /// Backend routing-queue wait (legacy) or device-busy wait
    /// (fabric: after the gate, before execution).
    Wait,
    /// Residency swap charge on the critical chain (legacy path).
    Swap,
    /// Fixed link charge, both directions (legacy path).
    Link,
    /// Device execution.
    Exec,
    /// Request payload on the wire, host → accelerator.
    XferIn,
    /// Parked on the weights-ready gate (swap excess not hidden
    /// behind the payload transfer).
    Gate,
    /// Result payload on the wire, accelerator → host.
    XferOut,
}

impl Phase {
    pub fn name(self) -> &'static str {
        match self {
            Phase::Queued => "queued",
            Phase::Wait => "wait",
            Phase::Swap => "swap",
            Phase::Link => "link",
            Phase::Exec => "exec",
            Phase::XferIn => "xfer_in",
            Phase::Gate => "gate",
            Phase::XferOut => "xfer_out",
        }
    }
}

/// One closed per-request span, timestamps in virtual seconds.
#[derive(Debug, Clone, Copy)]
pub struct Span {
    pub id: usize,
    pub rank: u32,
    /// Dense model id; resolve via [`Recorder::model_name`].
    pub model: u32,
    pub backend: usize,
    pub phase: Phase,
    pub t0_s: f64,
    pub t1_s: f64,
}

/// One batch's exclusive occupancy of a device.
#[derive(Debug, Clone, Copy)]
pub struct BusyInterval {
    pub t0_s: f64,
    pub t1_s: f64,
    /// Requests in the batch (the occupancy histogram's unit).
    pub requests: usize,
}

/// A control-plane instant.
#[derive(Debug, Clone)]
pub struct Marker {
    pub t_s: f64,
    pub name: &'static str,
    pub detail: String,
}

/// A point on the fabric time series: per-link utilization (current
/// fair-share rate / as-built capacity) plus the constrained-flow
/// count.  Consecutive identical samples are coalesced.
#[derive(Debug, Clone)]
struct FabricSample {
    t_s: f64,
    util: Vec<f64>,
    constrained: usize,
}

/// A request submitted but not yet dispatched.
#[derive(Debug, Clone, Copy)]
struct PendingReq {
    submit_s: f64,
    rank: u32,
    model: u32,
}

/// The flight recorder.  Created armed by `Pipeline::arm_trace`;
/// [`Recorder::disarmed`] exists only for the bench's
/// compiled-but-disarmed overhead probe.
#[derive(Debug)]
pub struct Recorder {
    armed: bool,
    /// Mirrors the pipeline's dense model intern table (grown at
    /// submit, so ids match by construction).
    models: Vec<String>,
    devices: Vec<String>,
    links: Vec<String>,
    link_caps: Vec<f64>,
    /// Submit metadata per request id (dense; ids are submit-ordered).
    pending: Vec<Option<PendingReq>>,
    spans: Vec<Span>,
    busy: Vec<Vec<BusyInterval>>,
    markers: Vec<Marker>,
    fabric_samples: Vec<FabricSample>,
    /// Integrals under the piecewise-constant utilization series.
    link_busy_s: Vec<f64>,
    link_util_s: Vec<f64>,
    /// Scratch for [`FabricEngine::link_rates_into`].
    scratch: Vec<f64>,
    batch_hist: BTreeMap<usize, u64>,
    gate_wait_s: f64,
    gate_wait_by_model: BTreeMap<u32, f64>,
    swap_misses: u64,
    /// Latest virtual timestamp seen anywhere (the trace horizon).
    horizon_s: f64,
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder {
            armed: true,
            models: Vec::new(),
            devices: Vec::new(),
            links: Vec::new(),
            link_caps: Vec::new(),
            pending: Vec::new(),
            spans: Vec::new(),
            busy: Vec::new(),
            markers: Vec::new(),
            fabric_samples: Vec::new(),
            link_busy_s: Vec::new(),
            link_util_s: Vec::new(),
            scratch: Vec::new(),
            batch_hist: BTreeMap::new(),
            gate_wait_s: 0.0,
            gate_wait_by_model: BTreeMap::new(),
            swap_misses: 0,
            horizon_s: 0.0,
        }
    }

    /// A recorder that records nothing: the bench's probe for the
    /// cost of carrying the hooks on the hot path.
    pub fn disarmed() -> Recorder {
        let mut r = Recorder::new();
        r.armed = false;
        r
    }

    #[inline]
    pub fn armed(&self) -> bool {
        self.armed
    }

    // ------------------------------------------------- registration

    pub fn register_devices(&mut self, names: impl Iterator<Item = String>) {
        self.devices = names.collect();
        self.busy = self.devices.iter().map(|_| Vec::new()).collect();
    }

    pub fn register_links(&mut self, labels: Vec<String>, caps: Vec<f64>) {
        assert_eq!(labels.len(), caps.len());
        self.link_busy_s = vec![0.0; labels.len()];
        self.link_util_s = vec![0.0; labels.len()];
        self.links = labels;
        self.link_caps = caps;
    }

    pub fn model_name(&self, mid: u32) -> &str {
        &self.models[mid as usize]
    }

    pub fn device_name(&self, idx: usize) -> &str {
        &self.devices[idx]
    }

    pub fn devices(&self) -> usize {
        self.devices.len()
    }

    // ------------------------------------------------------- hooks

    fn touch(&mut self, t_s: f64) {
        if t_s > self.horizon_s {
            self.horizon_s = t_s;
        }
    }

    pub fn on_submit(&mut self, id: usize, rank: u32, model: u32, name: &str, t_s: f64) {
        if self.models.len() <= model as usize {
            self.models.push(name.to_string());
        }
        if self.pending.len() <= id {
            self.pending.resize(id + 1, None);
        }
        self.pending[id] = Some(PendingReq { submit_s: t_s, rank, model });
        self.touch(t_s);
    }

    /// Close the queued span for each id in a dispatching batch and
    /// count the batch in the occupancy histogram.  Returns nothing;
    /// later phases are recorded by the path-specific hooks.  On a
    /// control-plane *re*-dispatch the pending entry is already
    /// spent — the queued span was emitted by the first dispatch and
    /// is not duplicated.
    fn close_queued(&mut self, ids: &[usize], backend: usize, t_s: f64) {
        for &id in ids {
            if let Some(p) = self.pending.get_mut(id).and_then(Option::take) {
                self.spans.push(Span {
                    id,
                    rank: p.rank,
                    model: p.model,
                    backend,
                    phase: Phase::Queued,
                    t0_s: p.submit_s,
                    t1_s: t_s,
                });
            }
        }
        *self.batch_hist.entry(ids.len()).or_insert(0) += 1;
    }

    /// Legacy fixed-charge dispatch: every phase share is known at
    /// dispatch time, so the whole lifecycle lands at once.
    #[allow(clippy::too_many_arguments)]
    pub fn on_direct(
        &mut self,
        ids: &[usize],
        backend: usize,
        dispatch_s: f64,
        wait_s: f64,
        swap_s: f64,
        link_s: f64,
        exec_s: f64,
        complete_s: f64,
        miss: bool,
    ) {
        *self.batch_hist.entry(ids.len()).or_insert(0) += 1;
        if miss {
            self.swap_misses += 1;
        }
        for &id in ids {
            let (rank, model) = match self.pending.get_mut(id).and_then(Option::take) {
                Some(p) => {
                    self.spans.push(Span {
                        id,
                        rank: p.rank,
                        model: p.model,
                        backend,
                        phase: Phase::Queued,
                        t0_s: p.submit_s,
                        t1_s: dispatch_s,
                    });
                    (p.rank, p.model)
                }
                // control-plane retry: the queued span was emitted by
                // the first dispatch; recover the metadata from it
                None => self.meta_of(id),
            };
            let mut t = dispatch_s;
            for (phase, dt) in [
                (Phase::Wait, wait_s),
                (Phase::Swap, swap_s),
                (Phase::Link, link_s),
                (Phase::Exec, exec_s),
            ] {
                self.spans.push(Span {
                    id,
                    rank,
                    model,
                    backend,
                    phase,
                    t0_s: t,
                    t1_s: t + dt,
                });
                t += dt;
            }
        }
        self.on_occupy(backend, complete_s - exec_s, complete_s, ids.len());
        self.touch(complete_s);
    }

    /// Fabric dispatch: only the queued span closes here; the
    /// measured phases land at [`Self::on_transit_done`].
    pub fn on_remote_dispatch(&mut self, ids: &[usize], backend: usize, t_s: f64, miss: bool) {
        self.close_queued(ids, backend, t_s);
        if miss {
            self.swap_misses += 1;
        }
        self.touch(t_s);
    }

    /// The result landed: tile the transit's measured phases over
    /// `[dispatch, done]` for every rider.  `meta` pairs each id with
    /// its `(rank, model)` (the recorder's pending entry was spent by
    /// the queued span at dispatch).
    #[allow(clippy::too_many_arguments)]
    pub fn on_transit_done(
        &mut self,
        ids: &[usize],
        meta: impl Fn(usize) -> (u32, u32),
        backend: usize,
        dispatch_s: f64,
        in_done_s: f64,
        gate_s: f64,
        wait_s: f64,
        exec_s: f64,
        out_start_s: f64,
        done_s: f64,
    ) {
        for &id in ids {
            let (rank, model) = meta(id);
            for (phase, t0, t1) in [
                (Phase::XferIn, dispatch_s, in_done_s),
                (Phase::Gate, in_done_s, in_done_s + gate_s),
                (Phase::Wait, in_done_s + gate_s, in_done_s + gate_s + wait_s),
                (Phase::Exec, out_start_s - exec_s, out_start_s),
                (Phase::XferOut, out_start_s, done_s),
            ] {
                self.spans.push(Span { id, rank, model, backend, phase, t0_s: t0, t1_s: t1 });
            }
            if gate_s > 0.0 {
                self.gate_wait_s += gate_s;
                *self.gate_wait_by_model.entry(model).or_insert(0.0) += gate_s;
            }
        }
        self.touch(done_s);
    }

    /// One batch occupied a device for `[t0, t1]` (the fabric path's
    /// `occupy` is exclusive by construction; the legacy path's exec
    /// windows follow the queue-seconds model).
    pub fn on_occupy(&mut self, backend: usize, t0_s: f64, t1_s: f64, requests: usize) {
        if backend < self.busy.len() {
            self.busy[backend].push(BusyInterval { t0_s, t1_s, requests });
        }
        self.touch(t1_s);
    }

    pub fn marker(&mut self, name: &'static str, detail: String, t_s: f64) {
        self.markers.push(Marker { t_s, name, detail });
        self.touch(t_s);
    }

    /// Sample the fabric's per-link rates (the only instants rates
    /// change are flow mutations, so calling this at each mutation
    /// site yields an exact piecewise-constant series).
    pub fn fabric_sample(&mut self, t_s: f64, engine: &mut FabricEngine) {
        let mut buf = std::mem::take(&mut self.scratch);
        let constrained = engine.link_rates_into(&mut buf);
        self.integrate_to(t_s);
        let util: Vec<f64> = buf
            .iter()
            .zip(&self.link_caps)
            .map(|(&r, &c)| if c.is_finite() && c > 0.0 { r / c } else { 0.0 })
            .collect();
        let same = self
            .fabric_samples
            .last()
            .is_some_and(|s| s.util == util && s.constrained == constrained);
        if !same {
            self.fabric_samples.push(FabricSample { t_s, util, constrained });
        }
        self.scratch = buf;
        self.touch(t_s);
    }

    /// Advance the link integrals to `t_s` under the last sample's
    /// piecewise-constant utilization.
    fn integrate_to(&mut self, t_s: f64) {
        if let Some(last) = self.fabric_samples.last() {
            let dt = t_s - last.t_s;
            if dt > 0.0 {
                for (l, &u) in last.util.iter().enumerate() {
                    self.link_util_s[l] += u * dt;
                    if u > 0.0 {
                        self.link_busy_s[l] += dt;
                    }
                }
            }
        }
    }

    /// Close the books at the run's end (integrate the fabric series
    /// out to the final virtual clock).
    pub fn finalize(&mut self, t_s: f64) {
        self.touch(t_s);
        self.integrate_to(self.horizon_s);
        if let Some(last) = self.fabric_samples.last_mut() {
            if last.t_s < self.horizon_s {
                last.t_s = self.horizon_s;
            }
        }
    }

    /// Recover `(rank, model)` for a control-plane retry (the pending
    /// entry was spent by the first dispatch).  Linear scan — retries
    /// are rare by construction (each orphan re-dispatches once).
    fn meta_of(&self, id: usize) -> (u32, u32) {
        self.spans
            .iter()
            .rev()
            .find(|s| s.id == id)
            .map(|s| (s.rank, s.model))
            .unwrap_or((0, 0))
    }

    // --------------------------------------------------- accessors

    pub fn spans(&self) -> &[Span] {
        &self.spans
    }

    pub fn busy_intervals(&self, backend: usize) -> &[BusyInterval] {
        &self.busy[backend]
    }

    /// Total device-busy seconds of `backend` (Σ interval lengths).
    pub fn busy_integral_s(&self, backend: usize) -> f64 {
        self.busy[backend].iter().map(|b| b.t1_s - b.t0_s).sum()
    }

    pub fn markers(&self) -> &[Marker] {
        &self.markers
    }

    pub fn gate_wait_total_s(&self) -> f64 {
        self.gate_wait_s
    }

    pub fn swap_misses(&self) -> u64 {
        self.swap_misses
    }

    pub fn horizon_s(&self) -> f64 {
        self.horizon_s
    }

    pub fn batch_histogram(&self) -> &BTreeMap<usize, u64> {
        &self.batch_hist
    }

    // ----------------------------------------------------- exports

    /// Render the Chrome trace-event array for this recorder's run.
    /// `label` prefixes the process names (the sweep merges several
    /// cells into one file); `pid_base` offsets the four process ids
    /// so merged cells stay disjoint.  Events are sorted by
    /// `(pid, tid, ts)` — the validator's monotone-per-track
    /// invariant holds by construction.
    pub fn chrome_trace(&self, label: &str, pid_base: u64) -> Vec<Value> {
        let pid_req = pid_base + 1;
        let pid_dev = pid_base + 2;
        let pid_fab = pid_base + 3;
        let pid_ctl = pid_base + 4;
        let us = |t: f64| t * 1e6;
        let mut meta_events: Vec<Value> = Vec::new();
        let mut meta_event = |pid: u64, tid: u64, which: &str, name: String| {
            let mut args = BTreeMap::new();
            args.insert("name".to_string(), Value::String(name));
            let mut e = BTreeMap::new();
            e.insert("ph".to_string(), Value::String("M".to_string()));
            e.insert("pid".to_string(), Value::Number(pid as f64));
            e.insert("tid".to_string(), Value::Number(tid as f64));
            e.insert("name".to_string(), Value::String(which.to_string()));
            e.insert("args".to_string(), Value::Object(args));
            meta_events.push(Value::Object(e));
        };
        let procname = |what: &str| {
            if label.is_empty() {
                what.to_string()
            } else {
                format!("{label} {what}")
            }
        };

        // (pid, tid, ts_us, seq) -> event; stable sort keeps the
        // recorder's push order for equal timestamps.
        let mut timed: Vec<(u64, u64, f64, Value)> = Vec::new();
        let event = |ph: &str, name: String, pid: u64, tid: u64, ts: f64,
                     extra: Vec<(&str, Value)>| {
            let mut e = BTreeMap::new();
            e.insert("ph".to_string(), Value::String(ph.to_string()));
            e.insert("name".to_string(), Value::String(name));
            e.insert("pid".to_string(), Value::Number(pid as f64));
            e.insert("tid".to_string(), Value::Number(tid as f64));
            e.insert("ts".to_string(), Value::Number(ts));
            for (k, v) in extra {
                e.insert(k.to_string(), v);
            }
            Value::Object(e)
        };

        // ---- requests: one thread per rank, X span per phase
        meta_event(pid_req, 0, "process_name", procname("requests"));
        let mut ranks: Vec<u32> = self.spans.iter().map(|s| s.rank).collect();
        ranks.sort_unstable();
        ranks.dedup();
        for &r in &ranks {
            meta_event(pid_req, r as u64 + 1, "thread_name", format!("rank{r}"));
        }
        for s in &self.spans {
            let mut args = BTreeMap::new();
            args.insert("id".to_string(), Value::Number(s.id as f64));
            args.insert(
                "model".to_string(),
                Value::String(self.models[s.model as usize].clone()),
            );
            args.insert(
                "backend".to_string(),
                Value::String(self.devices.get(s.backend).cloned().unwrap_or_default()),
            );
            timed.push((
                pid_req,
                s.rank as u64 + 1,
                us(s.t0_s),
                event(
                    "X",
                    s.phase.name().to_string(),
                    pid_req,
                    s.rank as u64 + 1,
                    us(s.t0_s),
                    vec![
                        ("dur", Value::Number(us(s.t1_s - s.t0_s))),
                        ("args", Value::Object(args)),
                    ],
                ),
            ));
        }

        // ---- devices: one thread per backend, B/E busy pairs
        meta_event(pid_dev, 0, "process_name", procname("devices"));
        for (d, name) in self.devices.iter().enumerate() {
            meta_event(pid_dev, d as u64 + 1, "thread_name", name.clone());
        }
        for (d, intervals) in self.busy.iter().enumerate() {
            let tid = d as u64 + 1;
            let mut sorted: Vec<&BusyInterval> = intervals.iter().collect();
            sorted.sort_by(|a, b| a.t0_s.total_cmp(&b.t0_s));
            for b in sorted {
                let mut args = BTreeMap::new();
                args.insert("requests".to_string(), Value::Number(b.requests as f64));
                timed.push((
                    pid_dev,
                    tid,
                    us(b.t0_s),
                    event(
                        "B",
                        "busy".to_string(),
                        pid_dev,
                        tid,
                        us(b.t0_s),
                        vec![("args", Value::Object(args))],
                    ),
                ));
                timed.push((
                    pid_dev,
                    tid,
                    us(b.t1_s),
                    event("E", "busy".to_string(), pid_dev, tid, us(b.t1_s), vec![]),
                ));
            }
        }

        // ---- fabric: counter tracks (per-link utilization +
        // constrained flows), one C event pair per sample
        if !self.links.is_empty() {
            meta_event(pid_fab, 0, "process_name", procname("fabric"));
            meta_event(pid_fab, 1, "thread_name", "links".to_string());
            for s in &self.fabric_samples {
                let mut args = BTreeMap::new();
                for (l, &u) in s.util.iter().enumerate() {
                    args.insert(self.links[l].clone(), Value::Number(u));
                }
                timed.push((
                    pid_fab,
                    1,
                    us(s.t_s),
                    event(
                        "C",
                        "link_util".to_string(),
                        pid_fab,
                        1,
                        us(s.t_s),
                        vec![("args", Value::Object(args))],
                    ),
                ));
                let mut args = BTreeMap::new();
                args.insert(
                    "count".to_string(),
                    Value::Number(s.constrained as f64),
                );
                timed.push((
                    pid_fab,
                    1,
                    us(s.t_s),
                    event(
                        "C",
                        "constrained_flows".to_string(),
                        pid_fab,
                        1,
                        us(s.t_s),
                        vec![("args", Value::Object(args))],
                    ),
                ));
            }
        }

        // ---- control plane: instant events
        if !self.markers.is_empty() {
            meta_event(pid_ctl, 0, "process_name", procname("control"));
            meta_event(pid_ctl, 1, "thread_name", "events".to_string());
            for m in &self.markers {
                let mut args = BTreeMap::new();
                args.insert("detail".to_string(), Value::String(m.detail.clone()));
                timed.push((
                    pid_ctl,
                    1,
                    us(m.t_s),
                    event(
                        "i",
                        m.name.to_string(),
                        pid_ctl,
                        1,
                        us(m.t_s),
                        vec![
                            ("s", Value::String("t".to_string())),
                            ("args", Value::Object(args)),
                        ],
                    ),
                ));
            }
        }

        timed.sort_by(|a, b| {
            a.0.cmp(&b.0).then(a.1.cmp(&b.1)).then(a.2.total_cmp(&b.2))
        });
        meta_events.extend(timed.into_iter().map(|(_, _, _, e)| e));
        meta_events
    }

    /// The compact aggregated attribution summary.
    pub fn attribution(&self) -> Value {
        let horizon = self.horizon_s;
        let mut doc = BTreeMap::new();
        doc.insert("horizon_us".to_string(), Value::Number(horizon * 1e6));

        let devices: Vec<Value> = self
            .devices
            .iter()
            .enumerate()
            .map(|(d, name)| {
                let busy = self.busy_integral_s(d);
                let mut m = BTreeMap::new();
                m.insert("name".to_string(), Value::String(name.clone()));
                m.insert("busy_us".to_string(), Value::Number(busy * 1e6));
                m.insert(
                    "batches".to_string(),
                    Value::Number(self.busy[d].len() as f64),
                );
                m.insert(
                    "utilization".to_string(),
                    Value::Number(if horizon > 0.0 { busy / horizon } else { 0.0 }),
                );
                Value::Object(m)
            })
            .collect();
        doc.insert("devices".to_string(), Value::Array(devices));

        let mut gate = BTreeMap::new();
        gate.insert("total_us".to_string(), Value::Number(self.gate_wait_s * 1e6));
        let by_model: BTreeMap<String, Value> = self
            .gate_wait_by_model
            .iter()
            .map(|(&mid, &s)| (self.models[mid as usize].clone(), Value::Number(s * 1e6)))
            .collect();
        gate.insert("by_model_us".to_string(), Value::Object(by_model));
        doc.insert("gate_wait".to_string(), Value::Object(gate));

        let hist: BTreeMap<String, Value> = self
            .batch_hist
            .iter()
            .map(|(&k, &v)| (format!("{k:04}"), Value::Number(v as f64)))
            .collect();
        doc.insert("batch_occupancy".to_string(), Value::Object(hist));

        let links: Vec<Value> = self
            .links
            .iter()
            .enumerate()
            .map(|(l, name)| {
                let mut m = BTreeMap::new();
                m.insert("name".to_string(), Value::String(name.clone()));
                m.insert(
                    "busy_frac".to_string(),
                    Value::Number(if horizon > 0.0 {
                        self.link_busy_s[l] / horizon
                    } else {
                        0.0
                    }),
                );
                m.insert(
                    "mean_util".to_string(),
                    Value::Number(if horizon > 0.0 {
                        self.link_util_s[l] / horizon
                    } else {
                        0.0
                    }),
                );
                Value::Object(m)
            })
            .collect();
        doc.insert("links".to_string(), Value::Array(links));

        doc.insert("swaps".to_string(), Value::Number(self.swap_misses as f64));
        doc.insert("markers".to_string(), Value::Number(self.markers.len() as f64));
        doc.insert("spans".to_string(), Value::Number(self.spans.len() as f64));
        Value::Object(doc)
    }
}

impl Default for Recorder {
    fn default() -> Recorder {
        Recorder::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn armed_with_device() -> Recorder {
        let mut r = Recorder::new();
        r.register_devices(["dev0".to_string()].into_iter());
        r
    }

    #[test]
    fn queued_span_closes_once_per_id() {
        let mut r = armed_with_device();
        r.on_submit(0, 2, 0, "hermit", 0.5);
        r.on_direct(&[0], 0, 1.0, 0.1, 0.0, 0.2, 0.3, 1.6, false);
        let q: Vec<&Span> =
            r.spans().iter().filter(|s| s.phase == Phase::Queued).collect();
        assert_eq!(q.len(), 1);
        assert_eq!((q[0].t0_s, q[0].t1_s), (0.5, 1.0));
        assert_eq!(q[0].rank, 2);
        // a second dispatch of the same id (control-plane retry)
        // must not duplicate the queued span
        r.on_direct(&[0], 0, 2.0, 0.0, 0.0, 0.2, 0.3, 2.5, false);
        let q2 = r.spans().iter().filter(|s| s.phase == Phase::Queued).count();
        assert_eq!(q2, 1);
    }

    #[test]
    fn direct_phases_tile_dispatch_to_complete() {
        let mut r = armed_with_device();
        r.on_submit(0, 0, 0, "hermit", 0.0);
        r.on_direct(&[0], 0, 1.0, 0.25, 0.5, 0.125, 0.125, 2.0, true);
        let mut t = 1.0;
        for phase in [Phase::Wait, Phase::Swap, Phase::Link, Phase::Exec] {
            let s = r.spans().iter().find(|s| s.phase == phase).unwrap();
            assert_eq!(s.t0_s, t, "{phase:?} start");
            t = s.t1_s;
        }
        assert_eq!(t, 2.0);
        assert_eq!(r.swap_misses(), 1);
        assert!((r.busy_integral_s(0) - 0.125).abs() < 1e-12);
        assert_eq!(r.batch_histogram().get(&1), Some(&1));
    }

    #[test]
    fn transit_phases_tile_and_gate_accumulates() {
        let mut r = armed_with_device();
        r.on_submit(0, 1, 0, "hermit", 0.0);
        r.on_remote_dispatch(&[0], 0, 0.5, true);
        r.on_transit_done(
            &[0],
            |_| (1, 0),
            0,
            0.5,  // dispatch
            1.0,  // in_done
            0.25, // gate
            0.25, // wait
            0.5,  // exec
            2.0,  // out_start (= 1.0 + .25 + .25 + .5)
            2.25, // done
        );
        let phases: Vec<(Phase, f64, f64)> = r
            .spans()
            .iter()
            .filter(|s| s.phase != Phase::Queued)
            .map(|s| (s.phase, s.t0_s, s.t1_s))
            .collect();
        assert_eq!(
            phases,
            vec![
                (Phase::XferIn, 0.5, 1.0),
                (Phase::Gate, 1.0, 1.25),
                (Phase::Wait, 1.25, 1.5),
                (Phase::Exec, 1.5, 2.0),
                (Phase::XferOut, 2.0, 2.25),
            ]
        );
        assert!((r.gate_wait_total_s() - 0.25).abs() < 1e-12);
        assert_eq!(r.horizon_s(), 2.25);
    }

    #[test]
    fn chrome_trace_is_sorted_and_declares_tracks() {
        let mut r = armed_with_device();
        r.on_submit(0, 0, 0, "hermit", 0.0);
        r.on_direct(&[0], 0, 1.0, 0.1, 0.0, 0.1, 0.3, 1.5, false);
        r.marker("backend_leave", "backend 0".to_string(), 1.7);
        r.finalize(2.0);
        let events = r.chrome_trace("cell", 0);
        // every non-metadata event's (pid, tid, ts) is sorted
        let mut last: Option<(f64, f64, f64)> = None;
        let mut metas = 0;
        for e in &events {
            let ph = e.get("ph").and_then(Value::as_str).unwrap();
            if ph == "M" {
                metas += 1;
                continue;
            }
            let key = (
                e.get("pid").and_then(Value::as_f64).unwrap(),
                e.get("tid").and_then(Value::as_f64).unwrap(),
                e.get("ts").and_then(Value::as_f64).unwrap(),
            );
            if let Some(prev) = last {
                assert!(prev <= key, "events out of order: {prev:?} then {key:?}");
            }
            last = Some(key);
        }
        // process names for requests/devices/control + thread names
        assert!(metas >= 5, "expected track metadata, got {metas}");
    }

    #[test]
    fn disarmed_recorder_reports_disarmed() {
        assert!(!Recorder::disarmed().armed());
        assert!(Recorder::new().armed());
    }
}
