//! Measurement infrastructure following the paper's methodology
//! (§V-A): warm-up, per-mini-batch latency means, throughput over all
//! samples, 5 replicates, 95 % confidence intervals.

use std::time::{Duration, Instant};

use crate::util::stats::{self, Replicated};

/// Records per-request latencies and exposes the paper's summary
/// statistics.
#[derive(Debug, Clone, Default)]
pub struct LatencyRecorder {
    samples_s: Vec<f64>,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, d: Duration) {
        self.samples_s.push(d.as_secs_f64());
    }

    pub fn record_secs(&mut self, s: f64) {
        self.samples_s.push(s);
    }

    pub fn len(&self) -> usize {
        self.samples_s.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples_s.is_empty()
    }

    /// Mean latency across all recorded mini-batches (the paper's
    /// latency metric).
    pub fn mean_s(&self) -> f64 {
        stats::mean(&self.samples_s)
    }

    pub fn p50_s(&self) -> f64 {
        stats::percentile(&self.samples_s, 50.0)
    }

    /// Tail percentile, honest at small n: with fewer than 100
    /// samples this is the nearest-rank quantile (the p95 of 2
    /// samples is the observed max, not an interpolated value no
    /// request experienced).  p95/p99/p99.9 all route through the
    /// same estimator — mixing interpolation into one of them made
    /// p95 > p99 possible at small n.
    pub fn p95_s(&self) -> f64 {
        stats::tail_quantile(&self.samples_s, 95.0)
    }

    pub fn p99_s(&self) -> f64 {
        stats::tail_quantile(&self.samples_s, 99.0)
    }

    pub fn p999_s(&self) -> f64 {
        stats::tail_quantile(&self.samples_s, 99.9)
    }

    pub fn max_s(&self) -> f64 {
        self.samples_s.iter().copied().fold(0.0, f64::max)
    }

    pub fn clear(&mut self) {
        self.samples_s.clear();
    }
}

/// A log-spaced latency histogram with an explicit zero bucket.
///
/// Buckets are geometric from `floor` by `ratio`, with one overflow
/// bucket at the top.  Exact-zero (and negative) samples land in a
/// dedicated `zeros` bucket instead of being silently dropped — a
/// cache-hit path that completes in 0 time is real traffic, and a
/// histogram whose total undercounts it skews every fraction
/// computed from it.
#[derive(Debug, Clone)]
pub struct LogHistogram {
    edges: Vec<f64>,
    /// One count per edge (sample <= edge), plus overflow at the end.
    counts: Vec<u64>,
    zeros: u64,
}

impl LogHistogram {
    /// Geometric edges `floor, floor*ratio, ...` (`buckets` of them).
    pub fn new(floor: f64, ratio: f64, buckets: usize) -> LogHistogram {
        assert!(floor > 0.0 && ratio > 1.0 && buckets >= 1);
        let mut edges = Vec::with_capacity(buckets);
        let mut edge = floor;
        for _ in 0..buckets {
            edges.push(edge);
            edge *= ratio;
        }
        LogHistogram { counts: vec![0; buckets + 1], edges, zeros: 0 }
    }

    pub fn record(&mut self, x: f64) {
        if !(x > 0.0) {
            // counted, not dropped: zero-latency samples are traffic
            self.zeros += 1;
            return;
        }
        for (b, &edge) in self.edges.iter().enumerate() {
            if x <= edge {
                self.counts[b] += 1;
                return;
            }
        }
        *self.counts.last_mut().expect("overflow bucket") += 1;
    }

    /// Exact-zero (or sub-zero) samples recorded.
    pub fn zeros(&self) -> u64 {
        self.zeros
    }

    /// Upper edge of each finite bucket.
    pub fn edges(&self) -> &[f64] {
        &self.edges
    }

    /// Per-bucket counts (last entry = overflow); zeros are separate.
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Every sample ever recorded, zeros included.
    pub fn total(&self) -> u64 {
        self.zeros + self.counts.iter().sum::<u64>()
    }
}

/// Counts samples over a wall-clock window -> samples/s.
#[derive(Debug, Clone)]
pub struct ThroughputCounter {
    start: Instant,
    samples: u64,
}

impl Default for ThroughputCounter {
    fn default() -> Self {
        Self::new()
    }
}

impl ThroughputCounter {
    pub fn new() -> Self {
        ThroughputCounter { start: Instant::now(), samples: 0 }
    }

    pub fn add(&mut self, n: usize) {
        self.samples += n as u64;
    }

    pub fn samples(&self) -> u64 {
        self.samples
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn per_second(&self) -> f64 {
        let secs = self.start.elapsed().as_secs_f64();
        if secs == 0.0 {
            0.0
        } else {
            self.samples as f64 / secs
        }
    }
}

/// Run a measurement closure `replicates` times (paper: 5) and return
/// mean ± 95 % CI — the exact plotting convention of every figure.
pub fn replicate<F: FnMut() -> f64>(replicates: usize, mut f: F) -> Replicated {
    let samples: Vec<f64> = (0..replicates).map(|_| f()).collect();
    Replicated::from_samples(&samples)
}

/// The paper's replicate count.
pub const PAPER_REPLICATES: usize = 5;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_recorder_stats() {
        let mut r = LatencyRecorder::new();
        for ms in [1.0, 2.0, 3.0, 4.0, 100.0] {
            r.record_secs(ms * 1e-3);
        }
        assert_eq!(r.len(), 5);
        assert!((r.mean_s() - 0.022).abs() < 1e-9);
        assert!((r.p50_s() - 0.003).abs() < 1e-9);
        assert!(r.p99_s() > r.p50_s());
        assert!((r.max_s() - 0.1).abs() < 1e-12);
        r.clear();
        assert!(r.is_empty());
    }

    #[test]
    fn small_n_tail_is_the_observed_max() {
        // regression: p99 over 2 samples used to interpolate to a
        // value below the max; it must be the max.
        let mut r = LatencyRecorder::new();
        r.record_secs(0.001);
        r.record_secs(0.100);
        assert_eq!(r.p99_s(), 0.100);
        assert_eq!(r.p999_s(), 0.100);
        let mut one = LatencyRecorder::new();
        one.record_secs(0.042);
        assert_eq!(one.p99_s(), 0.042);
    }

    #[test]
    fn p95_uses_the_same_tail_estimator_as_p99() {
        // regression: p95 interpolated while p99 was nearest-rank, so
        // at small n the recorder could report p95 above p99.  All
        // three tails now share `stats::tail_quantile`.
        let mut one = LatencyRecorder::new();
        one.record_secs(0.042);
        assert_eq!(one.p95_s(), 0.042); // n = 1: the only observation

        let mut two = LatencyRecorder::new();
        two.record_secs(0.001);
        two.record_secs(0.100);
        assert_eq!(two.p95_s(), 0.100); // n = 2: the observed max
        assert!(two.p95_s() <= two.p99_s());

        let mut three = LatencyRecorder::new();
        for s in [0.001, 0.002, 0.300] {
            three.record_secs(s);
        }
        assert_eq!(three.p95_s(), 0.300); // n = 3: still the max
        assert!(three.p95_s() <= three.p99_s());

        // n = 100: the estimator hands off to interpolation
        let mut hundred = LatencyRecorder::new();
        for i in 1..=100 {
            hundred.record_secs(i as f64);
        }
        assert!((hundred.p95_s() - 95.05).abs() < 1e-9);
        assert!(hundred.p95_s() <= hundred.p99_s());
    }

    #[test]
    fn log_histogram_counts_exact_zeros() {
        // regression: zero-latency samples were dropped from the
        // histogram, undercounting its total.
        let mut h = LogHistogram::new(1e-6, 10.0, 4);
        h.record(0.0);
        h.record(0.0);
        h.record(5e-7); // first bucket (<= 1e-6)
        h.record(5e-4); // fourth bucket (<= 1e-3)
        h.record(1.0); // overflow
        assert_eq!(h.zeros(), 2);
        assert_eq!(h.counts(), &[1, 0, 0, 1, 1]);
        assert_eq!(h.total(), 5);
        assert_eq!(h.edges().len(), 4);
    }

    #[test]
    fn throughput_counter() {
        let mut c = ThroughputCounter::new();
        c.add(100);
        c.add(50);
        assert_eq!(c.samples(), 150);
        std::thread::sleep(Duration::from_millis(5));
        assert!(c.per_second() > 0.0);
    }

    #[test]
    fn replicate_five() {
        let mut i = 0.0;
        let rep = replicate(PAPER_REPLICATES, || {
            i += 1.0;
            i
        });
        assert_eq!(rep.n, 5);
        assert!((rep.mean - 3.0).abs() < 1e-12);
        assert!(rep.ci95 > 0.0);
    }
}
