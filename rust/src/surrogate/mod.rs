//! Fitted **surrogate of the simulator**: a clamped multilinear
//! interpolator over event-engine grid results.
//!
//! Cells sharing a categorical key — (topology, fleet, policy, models,
//! overlap, control) — form a dense 4-D table over the numeric axes
//! (ranks, oversub, swap_us, window_us).  Predictions are multilinear
//! interpolations over that table: exact on training nodes,
//! nearest-cell (clamped) outside the convex hull, and a few hundred
//! nanoseconds per query — cheap enough to embed in an optimiser loop
//! where even the fluid tier is too slow.
//!
//! Coordinates are raw **linear** values: TTS is near-affine in ranks
//! (the per-step batch count scales with ranks at fixed pool) and in
//! oversubscription (the swap-transfer cost scales with it), so linear
//! interpolation beats log coordinates on held-out interior cells by
//! an order of magnitude.  `python/sim/surrogate.py` is the op-for-op
//! mirror.

use std::collections::BTreeMap;

use crate::harness::sweep::CogCampaignResult;

/// Categorical table key: (topology, fleet, policy, models,
/// overlap-bits, control).  Overlap enters via [`f64::to_bits`] so the
/// key is hashable/ordered; fit and predict use the same encoding, so
/// equal floats always collide.
pub type TableKey = (String, String, String, usize, u64, String);

/// One training cell for [`Surrogate::fit`].
#[derive(Debug, Clone)]
pub struct SurrogateRow {
    pub topology: String,
    pub fleet: String,
    pub policy: String,
    pub models: usize,
    pub overlap: f64,
    pub control: String,
    pub ranks: f64,
    pub oversub: f64,
    pub swap_us: f64,
    pub window_us: f64,
    pub tts_s: f64,
    pub p99_s: f64,
}

/// Clamped bracketing: `(lo_index, fraction in [0, 1])`.
fn axis_bracket(axis: &[f64], x: f64) -> (usize, f64) {
    let n = axis.len();
    if n == 1 || x <= axis[0] {
        return (0, 0.0);
    }
    if x >= axis[n - 1] {
        return (n - 2, 1.0);
    }
    let mut i = 0;
    while x > axis[i + 1] {
        i += 1;
    }
    (i, (x - axis[i]) / (axis[i + 1] - axis[i]))
}

/// Dense 4-D table over (ranks, oversub, swap_us, window_us).
#[derive(Debug, Clone)]
pub struct Table4 {
    ranks: Vec<f64>,
    oversubs: Vec<f64>,
    swaps: Vec<f64>,
    windows: Vec<f64>,
    tts: Vec<f64>,
    p99: Vec<f64>,
}

impl Table4 {
    fn index(&self, ir: usize, io: usize, isw: usize, iw: usize) -> usize {
        ((ir * self.oversubs.len() + io) * self.swaps.len() + isw) * self.windows.len() + iw
    }

    fn interpolate(&self, grid: &[f64], ranks: f64, oversub: f64, swap_us: f64, window_us: f64) -> f64 {
        let (ir, fr) = axis_bracket(&self.ranks, ranks);
        let (io, fo) = axis_bracket(&self.oversubs, oversub);
        let (isw, fs) = axis_bracket(&self.swaps, swap_us);
        let (iw, fw) = axis_bracket(&self.windows, window_us);

        let corner = |dr: usize, do_: usize, ds: usize, dw: usize| {
            let jr = (ir + dr).min(self.ranks.len() - 1);
            let jo = (io + do_).min(self.oversubs.len() - 1);
            let js = (isw + ds).min(self.swaps.len() - 1);
            let jw = (iw + dw).min(self.windows.len() - 1);
            grid[self.index(jr, jo, js, jw)]
        };

        let mut total = 0.0;
        for dr in 0..2usize {
            let wr = if dr == 0 { 1.0 - fr } else { fr };
            if wr == 0.0 {
                continue;
            }
            for do_ in 0..2usize {
                let wo = if do_ == 0 { 1.0 - fo } else { fo };
                if wo == 0.0 {
                    continue;
                }
                for ds in 0..2usize {
                    let ws = if ds == 0 { 1.0 - fs } else { fs };
                    if ws == 0.0 {
                        continue;
                    }
                    for dw in 0..2usize {
                        let ww = if dw == 0 { 1.0 - fw } else { fw };
                        if ww == 0.0 {
                            continue;
                        }
                        total += wr * wo * ws * ww * corner(dr, do_, ds, dw);
                    }
                }
            }
        }
        total
    }
}

fn sorted_distinct(values: impl Iterator<Item = f64>) -> Vec<f64> {
    let mut out: Vec<f64> = values.collect();
    out.sort_by(|a, b| a.partial_cmp(b).expect("finite axis values"));
    out.dedup();
    out
}

fn axis_index(axis: &[f64], x: f64) -> usize {
    axis.iter().position(|&v| v == x).expect("cell on a fitted axis")
}

/// Fitted interpolator over event-engine grid results.
#[derive(Debug, Clone, Default)]
pub struct Surrogate {
    tables: BTreeMap<TableKey, Table4>,
}

impl Surrogate {
    /// Fit from training cells.  Rows sharing a categorical key form a
    /// table over the distinct numeric coordinates they cover; tables
    /// with missing grid corners are dropped (the surrogate answers
    /// `None` for those keys rather than extrapolating from holes).
    pub fn fit(rows: &[SurrogateRow]) -> Surrogate {
        let mut by_key: BTreeMap<TableKey, Vec<&SurrogateRow>> = BTreeMap::new();
        for row in rows {
            let key = (
                row.topology.clone(),
                row.fleet.clone(),
                row.policy.clone(),
                row.models,
                row.overlap.to_bits(),
                row.control.clone(),
            );
            by_key.entry(key).or_default().push(row);
        }

        let mut sur = Surrogate::default();
        for (key, cells) in by_key {
            let ranks = sorted_distinct(cells.iter().map(|c| c.ranks));
            let oversubs = sorted_distinct(cells.iter().map(|c| c.oversub));
            let swaps = sorted_distinct(cells.iter().map(|c| c.swap_us));
            let windows = sorted_distinct(cells.iter().map(|c| c.window_us));
            let n = ranks.len() * oversubs.len() * swaps.len() * windows.len();
            let mut tts: Vec<Option<f64>> = vec![None; n];
            let mut p99: Vec<Option<f64>> = vec![None; n];
            let table = Table4 {
                ranks: ranks.clone(),
                oversubs: oversubs.clone(),
                swaps: swaps.clone(),
                windows: windows.clone(),
                tts: Vec::new(),
                p99: Vec::new(),
            };
            for c in &cells {
                let idx = table.index(
                    axis_index(&ranks, c.ranks),
                    axis_index(&oversubs, c.oversub),
                    axis_index(&swaps, c.swap_us),
                    axis_index(&windows, c.window_us),
                );
                tts[idx] = Some(c.tts_s);
                p99[idx] = Some(c.p99_s);
            }
            if tts.iter().all(|v| v.is_some()) {
                let table = Table4 {
                    tts: tts.into_iter().map(|v| v.expect("checked complete")).collect(),
                    p99: p99.into_iter().map(|v| v.unwrap_or(0.0)).collect(),
                    ..table
                };
                sur.tables.insert(key, table);
            }
        }
        sur
    }

    /// Number of complete fitted tables.
    pub fn table_count(&self) -> usize {
        self.tables.len()
    }

    /// `(tts_s, p99_s)`, or `None` when no complete table covers the
    /// categorical key.
    #[allow(clippy::too_many_arguments)]
    pub fn predict(
        &self,
        topology: &str,
        policy: &str,
        models: usize,
        overlap: f64,
        ranks: f64,
        oversub: f64,
        swap_us: f64,
        window_us: f64,
        fleet: &str,
        control: &str,
    ) -> Option<(f64, f64)> {
        let key = (
            topology.to_string(),
            fleet.to_string(),
            policy.to_string(),
            models,
            overlap.to_bits(),
            control.to_string(),
        );
        let table = self.tables.get(&key)?;
        let tts = table.interpolate(&table.tts, ranks, oversub, swap_us, window_us);
        let p99 = table.interpolate(&table.p99, ranks, oversub, swap_us, window_us);
        Some((tts, p99))
    }
}

/// Fit a surrogate from a coupled-sweep ([`CogCampaignResult`]) run.
pub fn fit_cog_campaign(result: &CogCampaignResult) -> Surrogate {
    let rows: Vec<SurrogateRow> = result
        .scenarios
        .iter()
        .map(|s| SurrogateRow {
            topology: s.topology.key().to_string(),
            fleet: "default".to_string(),
            policy: s.policy.key().to_string(),
            models: s.models,
            overlap: s.overlap,
            control: "static".to_string(),
            ranks: s.ranks as f64,
            oversub: s.oversub,
            swap_us: s.swap_s * 1e6,
            window_us: result.config.window_us,
            tts_s: s.summary.time_to_solution_s,
            p99_s: s.summary.latency.p99_s,
        })
        .collect();
    Surrogate::fit(&rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid_rows() -> Vec<SurrogateRow> {
        let mut rows = Vec::new();
        for &ranks in &[4.0, 32.0] {
            for &oversub in &[1.0, 4.0] {
                rows.push(SurrogateRow {
                    topology: "pooled".into(),
                    fleet: "default".into(),
                    policy: "round_robin".into(),
                    models: 8,
                    overlap: 0.0,
                    control: "static".into(),
                    ranks,
                    oversub,
                    swap_us: 0.0,
                    window_us: 0.0,
                    // affine in both axes, so interpolation is exact
                    tts_s: 1.0 + 0.5 * ranks + 2.0 * oversub,
                    p99_s: 0.1 * ranks,
                });
            }
        }
        rows
    }

    #[test]
    fn exact_on_training_nodes_and_affine_interiors() {
        let sur = Surrogate::fit(&grid_rows());
        assert_eq!(sur.table_count(), 1);
        let (tts, p99) = sur
            .predict("pooled", "round_robin", 8, 0.0, 4.0, 1.0, 0.0, 0.0, "default", "static")
            .expect("fitted key");
        assert!((tts - 5.0).abs() < 1e-12);
        assert!((p99 - 0.4).abs() < 1e-12);
        // interior of an affine surface is reproduced exactly
        let (tts, _) = sur
            .predict("pooled", "round_robin", 8, 0.0, 18.0, 2.5, 0.0, 0.0, "default", "static")
            .expect("fitted key");
        assert!((tts - (1.0 + 0.5 * 18.0 + 2.0 * 2.5)).abs() < 1e-12);
    }

    #[test]
    fn clamps_outside_the_hull() {
        let sur = Surrogate::fit(&grid_rows());
        let lo = sur
            .predict("pooled", "round_robin", 8, 0.0, 1.0, 0.5, 0.0, 0.0, "default", "static")
            .expect("fitted key");
        let corner = sur
            .predict("pooled", "round_robin", 8, 0.0, 4.0, 1.0, 0.0, 0.0, "default", "static")
            .expect("fitted key");
        assert_eq!(lo, corner);
    }

    #[test]
    fn incomplete_tables_are_dropped_and_unknown_keys_answer_none() {
        let mut rows = grid_rows();
        rows.pop();
        let sur = Surrogate::fit(&rows);
        assert_eq!(sur.table_count(), 0);
        assert!(sur
            .predict("pooled", "round_robin", 8, 0.0, 4.0, 1.0, 0.0, 0.0, "default", "static")
            .is_none());
    }
}
