//! Max-min fair bandwidth allocation by progressive filling.
//!
//! Given a set of directed links with capacities (bytes/s) and a set
//! of flows, each crossing a list of links, the max-min fair
//! allocation gives every flow the largest rate such that no flow can
//! be raised without lowering a flow that already has less — the
//! steady state TCP-fair transport converges to on a shared fabric,
//! and the standard fluid model for flow-level network simulation
//! (CXL-ClusterSim, SimAI and friends use the same allocator).
//!
//! Progressive filling: repeatedly find the *bottleneck* link — the
//! one whose remaining capacity divided by its unfrozen flow count is
//! smallest — freeze every flow crossing it at that fair share,
//! subtract the frozen bandwidth everywhere those flows go, and
//! recurse on what is left.  Flows whose whole path has infinite
//! capacity (node-local "links") get an infinite rate.
//!
//! Degenerate inputs are *guarded*, not panicked on (mirroring the
//! `Link::rtt_overhead_s` NaN guard): a flow crossing a link index
//! the capacity table doesn't know, or any link with non-positive (or
//! NaN) capacity, freezes at a 0.0 rate — it can make no progress,
//! but it neither poisons other flows' shares with NaN nor crashes a
//! sweep at extreme oversubscription.
//!
//! Everything is deterministic: links scan in index order, strict
//! `<` picks the first minimal bottleneck, flows freeze in index
//! order — identical inputs always produce identical allocations
//! (the event engines' byte-stable summaries depend on it).

/// Reusable scratch buffers for [`max_min_rates_into`]: a caller that
/// re-solves on every flow-set change (the fabric engine) allocates
/// these once instead of four times per solve.
#[derive(Debug, Default, Clone)]
pub struct Workspace {
    frozen: Vec<bool>,
    remaining: Vec<f64>,
    users: Vec<usize>,
    /// CSR inverted index, link -> flows crossing it, rebuilt per
    /// solve.  Buckets list flows in flow order (one entry per path
    /// occurrence), so the freeze pass walks only the bottleneck's
    /// users while keeping the exact flow-order freeze contract.
    idx_off: Vec<usize>,
    idx_flow: Vec<usize>,
    idx_cursor: Vec<usize>,
}

/// Max-min fair rates for `flows` over `capacities`.
///
/// `capacities[l]` is link `l`'s capacity in bytes/s (may be
/// `f64::INFINITY` for a free resource); `flows[f]` lists the link
/// indices flow `f` crosses (an empty path means the flow never
/// touches a constrained resource and rates at infinity).  Paths are
/// taken by reference (`&[usize]`, `Vec<usize>`, ...) so the hot
/// recompute path never clones them.
///
/// Returns one rate per flow, in flow order.
pub fn max_min_rates<P: AsRef<[usize]>>(capacities: &[f64], flows: &[P]) -> Vec<f64> {
    let mut rates = Vec::new();
    max_min_rates_into(capacities, flows, &mut Workspace::default(), &mut rates);
    rates
}

/// [`max_min_rates`] writing into caller-owned buffers: `rates` is
/// cleared and refilled (one rate per flow, flow order), `ws` holds
/// the solver's scratch between calls.
pub fn max_min_rates_into<P: AsRef<[usize]>>(
    capacities: &[f64],
    flows: &[P],
    ws: &mut Workspace,
    rates: &mut Vec<f64>,
) {
    let n = flows.len();
    rates.clear();
    rates.resize(n, 0.0);
    ws.frozen.clear();
    ws.frozen.resize(n, false);
    ws.remaining.clear();
    ws.remaining.extend_from_slice(capacities);
    ws.users.clear();
    ws.users.resize(capacities.len(), 0);

    // A usable link is in range with a strictly positive capacity;
    // `!(c > 0.0)` also catches NaN.
    let usable = |l: usize| l < capacities.len() && capacities[l] > 0.0;

    for f in 0..n {
        let path = flows[f].as_ref();
        if path.iter().any(|&l| !usable(l)) {
            // guarded degenerate path: zero rate, never a user
            ws.frozen[f] = true;
        } else if path.is_empty() || path.iter().all(|&l| capacities[l].is_infinite()) {
            rates[f] = f64::INFINITY;
            ws.frozen[f] = true;
        } else {
            for &l in path {
                ws.users[l] += 1;
            }
        }
    }

    // Inverted index over the participating flows: each filling round
    // below freezes only the bottleneck link's users, so a burst of F
    // flows costs O(total path incidences) per round instead of a
    // full O(F · path) rescan.  Bucket order is flow order, which is
    // exactly the order the old `for f in 0..n` scan froze flows in —
    // the allocation stays bit-identical.
    ws.idx_off.clear();
    ws.idx_off.resize(capacities.len() + 1, 0);
    for f in 0..n {
        if ws.frozen[f] {
            continue;
        }
        for &l in flows[f].as_ref() {
            ws.idx_off[l + 1] += 1;
        }
    }
    for l in 0..capacities.len() {
        ws.idx_off[l + 1] += ws.idx_off[l];
    }
    ws.idx_flow.clear();
    ws.idx_flow.resize(*ws.idx_off.last().unwrap_or(&0), 0);
    ws.idx_cursor.clear();
    ws.idx_cursor.extend_from_slice(&ws.idx_off[..capacities.len()]);
    for f in 0..n {
        if ws.frozen[f] {
            continue;
        }
        for &l in flows[f].as_ref() {
            ws.idx_flow[ws.idx_cursor[l]] = f;
            ws.idx_cursor[l] += 1;
        }
    }

    let mut left = ws.frozen.iter().filter(|&&fz| !fz).count();
    while left > 0 {
        // the bottleneck: smallest fair share among loaded finite links
        let mut bottleneck: Option<(f64, usize)> = None;
        for (l, &cap) in ws.remaining.iter().enumerate() {
            if ws.users[l] == 0 || cap.is_infinite() {
                continue;
            }
            let share = cap / ws.users[l] as f64;
            if bottleneck.is_none_or(|(best, _)| share < best) {
                bottleneck = Some((share, l));
            }
        }
        let Some((share, link)) = bottleneck else {
            // every remaining flow crosses only unloaded/infinite
            // links — cannot happen while users > 0 on finite links,
            // but guard against an all-infinite residual anyway
            for f in 0..n {
                if !ws.frozen[f] {
                    rates[f] = f64::INFINITY;
                    ws.frozen[f] = true;
                }
            }
            break;
        };
        // freeze every unfrozen flow crossing the bottleneck (bucket
        // order == flow order; duplicate path entries revisit a flow
        // already frozen this round and fall through the guard)
        for i in ws.idx_off[link]..ws.idx_off[link + 1] {
            let f = ws.idx_flow[i];
            if ws.frozen[f] {
                continue;
            }
            rates[f] = share;
            ws.frozen[f] = true;
            left -= 1;
            for &l in flows[f].as_ref() {
                if ws.remaining[l].is_finite() {
                    ws.remaining[l] = (ws.remaining[l] - share).max(0.0);
                }
                ws.users[l] -= 1;
            }
        }
    }
}

/// Is `rates` a feasible allocation for `flows` over `capacities` —
/// no finite link carrying more than its capacity (plus `slack_frac`
/// relative slack for float error), no NaN rate anywhere?  The
/// control-plane chaos suites call this after every capacity mutation
/// (degrade/restore) and flow cancellation: whatever sequence of
/// mid-run events hit the allocator, the shares it hands out must
/// still fit the links that remain.
pub fn allocation_feasible<P: AsRef<[usize]>>(
    capacities: &[f64],
    flows: &[P],
    rates: &[f64],
    slack_frac: f64,
) -> bool {
    if rates.len() != flows.len() || rates.iter().any(|r| r.is_nan()) {
        return false;
    }
    for (l, &cap) in capacities.iter().enumerate() {
        if cap.is_infinite() {
            continue;
        }
        let load: f64 = flows
            .iter()
            .zip(rates)
            .filter(|(p, _)| p.as_ref().contains(&l))
            .map(|(_, &r)| r)
            .filter(|r| r.is_finite())
            .sum();
        if load > cap * (1.0 + slack_frac) {
            return false;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    const INF: f64 = f64::INFINITY;

    #[test]
    fn feasibility_checker_accepts_the_solver_and_rejects_overload() {
        let caps = [7.0, 11.0, 5.0, 13.0];
        let paths = vec![vec![0, 1], vec![1, 2], vec![0, 2, 3], vec![3], vec![1, 3]];
        let rates = max_min_rates(&caps, &paths);
        assert!(allocation_feasible(&caps, &paths, &rates, 1e-9));
        // doubling every share must blow at least one link
        let doubled: Vec<f64> = rates.iter().map(|r| r * 2.0).collect();
        assert!(!allocation_feasible(&caps, &paths, &doubled, 1e-9));
        // NaN anywhere is an automatic fail
        let mut poisoned = rates.clone();
        poisoned[0] = f64::NAN;
        assert!(!allocation_feasible(&caps, &paths, &poisoned, 1e-9));
    }

    #[test]
    fn single_flow_gets_the_path_minimum() {
        // NIC 10, uplink 40: a lone flow runs at its NIC rate.
        let rates = max_min_rates(&[10.0, 40.0], &[vec![0, 1]]);
        assert_eq!(rates, vec![10.0]);
    }

    #[test]
    fn two_flows_split_a_shared_link_evenly() {
        // hand-computed: one link of 10, two flows -> 5 each
        let rates = max_min_rates(&[10.0], &[vec![0], vec![0]]);
        assert_eq!(rates, vec![5.0, 5.0]);
    }

    #[test]
    fn three_flows_bottlenecked_at_different_tiers() {
        // hand-computed: links A=12, B=4.
        //   f0 = {A}, f1 = {A, B}, f2 = {B}
        // B is the bottleneck first: 4/2 = 2 -> f1 = f2 = 2.
        // A keeps 12 - 2 = 10 for f0 alone -> f0 = 10.
        let rates =
            max_min_rates(&[12.0, 4.0], &[vec![0], vec![0, 1], vec![1]]);
        assert_eq!(rates, vec![10.0, 2.0, 2.0]);
    }

    #[test]
    fn four_flows_nic_vs_uplink_bottlenecks() {
        // hand-computed leaf-spine cut: two host NICs of 10 (links 0,
        // 1), one oversubscribed uplink of 8 (link 2), one fat
        // receiver NIC of 100 (link 3).
        //   f0, f1 from host 0; f2, f3 from host 1; all cross 2, 3.
        // Uplink first: 8/4 = 2 each — below the NIC share 10/2 = 5 —
        // so every flow freezes at 2 (uplink-bound, not NIC-bound).
        let paths = vec![
            vec![0, 2, 3],
            vec![0, 2, 3],
            vec![1, 2, 3],
            vec![1, 2, 3],
        ];
        let rates = max_min_rates(&[10.0, 10.0, 8.0, 100.0], &paths);
        assert_eq!(rates, vec![2.0, 2.0, 2.0, 2.0]);

        // raise the uplink to 32 and the NICs bottleneck instead:
        // 10/2 = 5 each, uplink only half-used.
        let rates = max_min_rates(&[10.0, 10.0, 32.0, 100.0], &paths);
        assert_eq!(rates, vec![5.0, 5.0, 5.0, 5.0]);
    }

    #[test]
    fn asymmetric_hosts_reclaim_leftover_uplink() {
        // hand-computed: host NICs 10 (link 0) and 10 (link 1),
        // uplink 18 (link 2).  Three flows on host 0, one on host 1.
        //   NIC0 share: 10/3 = 3.33; NIC1: 10/1 = 10; uplink: 18/4 = 4.5
        // NIC0 freezes f0..f2 at 10/3; uplink keeps 18 - 10 = 8 for
        // f3, NIC1 allows 10 -> f3 = 8 (uplink-bound).
        let paths = vec![vec![0, 2], vec![0, 2], vec![0, 2], vec![1, 2]];
        let rates = max_min_rates(&[10.0, 10.0, 18.0], &paths);
        let third = 10.0 / 3.0;
        for f in 0..3 {
            assert!((rates[f] - third).abs() < 1e-12, "f{f}: {}", rates[f]);
        }
        assert!((rates[3] - 8.0).abs() < 1e-12, "{}", rates[3]);
    }

    #[test]
    fn empty_and_infinite_paths_rate_at_infinity() {
        let rates = max_min_rates(&[10.0, INF], &[vec![], vec![1], vec![0]]);
        assert_eq!(rates[0], INF);
        assert_eq!(rates[1], INF);
        assert_eq!(rates[2], 10.0);
    }

    #[test]
    fn unknown_link_gets_a_guarded_zero_rate() {
        // regression: this used to assert/panic.  The bad flow
        // freezes at 0; the healthy flow still gets its full share.
        let rates = max_min_rates(&[10.0], &[vec![0, 7], vec![0]]);
        assert_eq!(rates, vec![0.0, 10.0]);
    }

    #[test]
    fn zero_capacity_link_gets_a_guarded_zero_rate_not_nan() {
        // regression: a 0-capacity uplink used to assert (and the
        // division would produce NaN poisoning every summary
        // downstream).  Flows crossing it freeze at 0.0; flows
        // avoiding it are untouched.
        let rates = max_min_rates(&[10.0, 0.0], &[vec![0, 1], vec![0]]);
        assert!(rates.iter().all(|r| !r.is_nan()), "{rates:?}");
        assert_eq!(rates, vec![0.0, 10.0]);

        // NaN capacity is guarded the same way.
        let rates = max_min_rates(&[10.0, f64::NAN], &[vec![1], vec![0]]);
        assert_eq!(rates, vec![0.0, 10.0]);
    }

    #[test]
    fn conservation_no_link_oversubscribed() {
        // arbitrary mesh: total allocated through any finite link must
        // not exceed its capacity (up to float slack)
        let caps = [7.0, 11.0, 5.0, 13.0];
        let paths = vec![
            vec![0, 1],
            vec![1, 2],
            vec![0, 2, 3],
            vec![3],
            vec![1, 3],
        ];
        let rates = max_min_rates(&caps, &paths);
        for (l, &cap) in caps.iter().enumerate() {
            let load: f64 = paths
                .iter()
                .zip(&rates)
                .filter(|(p, _)| p.contains(&l))
                .map(|(_, &r)| r)
                .sum();
            assert!(load <= cap + 1e-9, "link {l}: {load} > {cap}");
        }
        // and every rate is positive: progressive filling starves no one
        assert!(rates.iter().all(|&r| r > 0.0));
    }

    #[test]
    fn allocation_is_deterministic() {
        let caps = [3.0, 9.0, 4.0];
        let paths = vec![vec![0, 1], vec![1, 2], vec![0, 2], vec![1]];
        let a = max_min_rates(&caps, &paths);
        let b = max_min_rates(&caps, &paths);
        assert_eq!(a, b);
    }

    #[test]
    fn workspace_reuse_matches_fresh_solves() {
        // the _into variant with a dirty workspace must agree with
        // the allocating wrapper on every call
        let caps = [3.0, 9.0, 4.0];
        let cases: [&[Vec<usize>]; 3] = [
            &[vec![0, 1], vec![1, 2]],
            &[vec![0], vec![1], vec![2], vec![0, 1, 2]],
            &[vec![2, 1]],
        ];
        let mut ws = Workspace::default();
        let mut rates = Vec::new();
        for paths in cases {
            max_min_rates_into(&caps, paths, &mut ws, &mut rates);
            assert_eq!(rates, max_min_rates(&caps, paths));
        }
    }

    #[test]
    fn no_flows_is_fine() {
        assert!(max_min_rates(&[5.0], &[]).is_empty());
    }

    #[test]
    fn duplicate_path_entries_keep_per_occurrence_user_counts() {
        // a path listing the same link twice counts as two users of
        // it (pre-index behavior the CSR freeze pass must preserve):
        // link 0 of 12 carries occurrences [0,0] and [0] -> share
        // 12/3 = 4 for both flows.
        let rates = max_min_rates(&[12.0], &[vec![0, 0], vec![0]]);
        assert_eq!(rates, vec![4.0, 4.0]);
    }
}
