//! Contention-aware fabric simulator: shared-bandwidth network
//! topologies for the disaggregated pool.
//!
//! The analytic [`crate::netsim::Link`] charges every remote request
//! a fixed `rtt_overhead_s` — correct for one stream, but blind to
//! *sharing*: a 64-rank burst pays the same per-request network cost
//! as a single request, which systematically flatters the pooled
//! topology exactly where the paper's question is hardest.  This
//! module adds the missing layer:
//!
//! * [`topology`] — leaf/spine [`Topology`] graphs (host NICs,
//!   oversubscribed uplinks, accelerator NICs) with `node_local`,
//!   `pooled` and `hybrid` constructors;
//! * [`fairshare`] — the max-min fair-share allocator (progressive
//!   filling over the active flow set);
//! * [`engine`] — the incremental [`FabricEngine`]: start flows,
//!   recompute shares on every start/finish, report the next
//!   completion time.
//!
//! The event engines ([`crate::eventsim`], [`crate::eventsim::cogsim`])
//! consume this through a [`FabricSpec`]: each backend maps to an
//! accelerator endpoint, each rank to a host NIC, and a remote
//! dispatch becomes three-to-four *events* instead of one fixed
//! charge — request payload in, optional model-swap transfer
//! competing on the same uplinks, device execution, result payload
//! out.  One flow alone on a 1:1 fabric reproduces
//! `Link::rtt_overhead_s` to 1e-9 (`rust/tests/fabric_props.rs`), so
//! [`crate::netsim::Link`] remains the exact degenerate case.

pub mod engine;
pub mod fairshare;
pub mod topology;

pub use engine::FabricEngine;
pub use fairshare::{allocation_feasible, max_min_rates};
pub use topology::Topology;

/// How an event engine's fleet plugs into a fabric: the topology plus
/// the backend-index → accelerator-endpoint map.  Ranks map to host
/// NICs round-robin (`rank % hosts`).
#[derive(Debug, Clone)]
pub struct FabricSpec {
    pub topology: Topology,
    /// Accelerator endpoint (index into the topology's accels) per
    /// backend index.
    pub accel_of_backend: Vec<usize>,
}

impl FabricSpec {
    /// Validate against a fleet of `n_backends` backends.
    pub fn validate(&self, n_backends: usize) {
        assert_eq!(
            self.accel_of_backend.len(),
            n_backends,
            "fabric spec must map every backend to an accel endpoint"
        );
        for &a in &self.accel_of_backend {
            assert!(a < self.topology.accels(), "unknown accel endpoint {a}");
        }
    }

    /// Host NIC for a rank.
    pub fn host_of_rank(&self, rank: usize) -> usize {
        rank % self.topology.hosts()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_maps_ranks_and_backends() {
        let spec = FabricSpec {
            topology: Topology::pooled(4, 2, 2.0),
            accel_of_backend: vec![0, 1],
        };
        spec.validate(2);
        assert_eq!(spec.host_of_rank(0), 0);
        assert_eq!(spec.host_of_rank(5), 1);
    }

    #[test]
    #[should_panic(expected = "accel endpoint")]
    fn spec_rejects_out_of_range_endpoints() {
        let spec = FabricSpec {
            topology: Topology::pooled(2, 1, 1.0),
            accel_of_backend: vec![3],
        };
        spec.validate(1);
    }
}
