//! The incremental flow-level fabric engine.
//!
//! [`FabricEngine`] tracks the set of active byte transfers over a
//! [`Topology`], assigns each the max-min fair share of the links it
//! crosses ([`super::fairshare`]), and answers the one question an
//! event engine needs: *when does the next transfer finish?*  Rates
//! only change when the flow set changes, so the engine integrates
//! lazily — on every mutation it first credits each active flow
//! `rate × dt` of progress, then recomputes the allocation.  Between
//! mutations, completion times are exact linear extrapolations.
//!
//! The caller (the event engines in [`crate::eventsim`]) arms a
//! wake-up at [`FabricEngine::next_completion_s`], and on firing
//! calls [`FabricEngine::take_completed`]; because any flow start can
//! invalidate a previously armed wake-up, callers version their
//! wake-up events and ignore stale ones.
//!
//! Everything is deterministic: flows are kept in a `BTreeMap` keyed
//! by their monotonically assigned id, allocation scans in id order,
//! and completions pop in id order within one instant.
//!
//! The solve itself is incremental: the allocation only depends on
//! the set of *constrained* flows (those crossing at least one
//! finite-capacity link — free-path flows rate at infinity and are
//! never counted as link users), so the engine re-solves only when
//! that set actually changes.  A node-local dispatch burst of free
//! flows starts and drains without touching the allocator at all,
//! and the solver's scratch buffers are reused across re-solves.
//!
//! Re-solves are additionally *coalesced*: a mutation only marks the
//! rate table dirty, and the actual fair-share solve runs at the next
//! observation point (a rate query, a wake-time query, or the first
//! progress integration over dt > 0).  A same-instant burst of N
//! starts or cancellations with no observation in between therefore
//! costs one solve, not N — and because the solver is a deterministic
//! function of the final flow set, the rates any observer sees are
//! bit-identical to the eager schedule's.  The last computed
//! next-completion time is cached and reused only while nothing (flow
//! set, capacities, clock) has changed; any advance over dt > 0
//! invalidates it, since `remaining - rate·dt` re-derives the ETA in
//! floats rather than preserving the old absolute value.

use std::collections::BTreeMap;

use super::fairshare::{max_min_rates_into, Workspace};
use super::topology::Topology;

/// Below this many bytes a flow counts as finished (float slack from
/// incremental integration is ~ulp-sized; this is far above it and
/// far below any real payload).
const DONE_BYTES: f64 = 1e-6;

#[derive(Debug, Clone)]
struct Flow {
    path: Vec<usize>,
    remaining: f64,
    rate: f64,
    /// Crosses at least one finite-capacity link: participates in
    /// the fair-share solve.  Free flows never change other rates,
    /// so starting/finishing one skips the recompute entirely.
    constrained: bool,
}

/// Active transfers + fair-share rates over a topology.
pub struct FabricEngine {
    topo: Topology,
    flows: BTreeMap<u64, Flow>,
    next_id: u64,
    now_s: f64,
    /// Count of constrained active flows (recompute trigger).
    constrained: usize,
    /// The rate table is stale: a constrained flow joined or left (or
    /// capacities changed) since the last solve.  Cleared by
    /// [`Self::ensure_rates`] at the next observation point.
    dirty: bool,
    /// Memoized [`Self::next_completion_s`] answer; `None` when it
    /// must be recomputed (any mutation or any dt > 0 advance).
    eta_cache: Option<Option<f64>>,
    /// Solver scratch, reused across recomputes.
    ws: Workspace,
    rates: Vec<f64>,
}

impl FabricEngine {
    pub fn new(topo: Topology) -> FabricEngine {
        FabricEngine {
            topo,
            flows: BTreeMap::new(),
            next_id: 0,
            now_s: 0.0,
            constrained: 0,
            dirty: false,
            eta_cache: None,
            ws: Workspace::default(),
            rates: Vec::new(),
        }
    }

    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// Active (unfinished) flow count.
    pub fn active(&self) -> usize {
        self.flows.len()
    }

    /// Current fair-share rate of a flow, bytes/s.
    pub fn rate_of(&mut self, id: u64) -> Option<f64> {
        self.ensure_rates();
        self.flows.get(&id).map(|f| f.rate)
    }

    /// Sum the current fair-share rates crossing each directed link
    /// into `out` (cleared and resized to the topology's link count);
    /// returns the constrained-flow count.  Free (infinite-rate)
    /// flows never hold link capacity and are skipped.  This is the
    /// flight recorder's sampling hook: rates only change on flow
    /// mutations, so sampling at each mutation site yields an exact
    /// piecewise-constant utilization series.
    pub fn link_rates_into(&mut self, out: &mut Vec<f64>) -> usize {
        self.ensure_rates();
        let n = self.topo.n_links();
        out.clear();
        out.resize(n, 0.0);
        for f in self.flows.values() {
            if !f.rate.is_finite() {
                continue;
            }
            for &l in &f.path {
                if l < n {
                    out[l] += f.rate;
                }
            }
        }
        self.constrained
    }

    /// Start a transfer of `bytes` along `path` at `now_s`; returns
    /// the flow id.  Constrained flows mark the rate table dirty (the
    /// fair-share re-solve is coalesced into the next observation
    /// point, so a same-instant dispatch burst solves once); a
    /// free-path flow (empty path, or infinite capacity everywhere
    /// it goes) rates at infinity directly, leaving every other
    /// flow's share untouched.  A zero-byte or free-path flow
    /// completes at the very next [`Self::take_completed`].
    pub fn start(&mut self, now_s: f64, path: Vec<usize>, bytes: f64) -> u64 {
        assert!(bytes >= 0.0 && bytes.is_finite(), "bad flow size {bytes}");
        self.advance_to(now_s);
        let id = self.next_id;
        self.next_id += 1;
        let caps = self.topo.capacities();
        let free = path
            .iter()
            .all(|&l| l < caps.len() && caps[l].is_infinite());
        let rate = if free { f64::INFINITY } else { 0.0 };
        self.flows
            .insert(id, Flow { path, remaining: bytes, rate, constrained: !free });
        self.eta_cache = None;
        if free {
            return id;
        }
        self.constrained += 1;
        self.dirty = true;
        id
    }

    /// Credit progress up to `t_s` at the current rates (monotone;
    /// earlier times are a no-op).  A pending re-solve is flushed
    /// first: flows accrue progress over `[now, t_s]` at the rates
    /// the final flow set of the previous instant solves to — the
    /// same rates the eager schedule integrated at.
    pub fn advance_to(&mut self, t_s: f64) {
        let dt = t_s - self.now_s;
        if dt > 0.0 {
            self.ensure_rates();
            for f in self.flows.values_mut() {
                if f.rate.is_infinite() {
                    f.remaining = 0.0;
                } else {
                    f.remaining = (f.remaining - f.rate * dt).max(0.0);
                }
            }
            self.eta_cache = None;
        }
        self.now_s = self.now_s.max(t_s);
    }

    /// Flush a deferred fair-share solve (the coalescing point: any
    /// number of same-instant mutations collapse into this one call).
    fn ensure_rates(&mut self) {
        if self.dirty {
            self.recompute();
            self.dirty = false;
        }
    }

    fn recompute(&mut self) {
        let paths: Vec<&[usize]> =
            self.flows.values().map(|f| f.path.as_slice()).collect();
        max_min_rates_into(self.topo.capacities(), &paths, &mut self.ws, &mut self.rates);
        for (f, &r) in self.flows.values_mut().zip(&self.rates) {
            f.rate = r;
        }
        self.eta_cache = None;
    }

    /// Virtual time at which the earliest active flow finishes under
    /// the current rates; `None` when idle (or when every remaining
    /// flow is stalled at a guarded 0 rate and will never finish).
    /// The answer is memoized: repeated queries with no intervening
    /// mutation or advance skip the full-flow scan.
    pub fn next_completion_s(&mut self) -> Option<f64> {
        self.ensure_rates();
        if let Some(cached) = self.eta_cache {
            return cached;
        }
        let now = self.now_s;
        let eta = self
            .flows
            .values()
            .map(|f| now + Self::eta_s(f))
            .filter(|t| t.is_finite())
            .min_by(f64::total_cmp);
        self.eta_cache = Some(eta);
        eta
    }

    fn eta_s(f: &Flow) -> f64 {
        if f.remaining <= DONE_BYTES || f.rate.is_infinite() {
            0.0
        } else {
            f.remaining / f.rate
        }
    }

    /// Degrade (or restore) the fabric mid-run: credit every active
    /// flow its progress up to `now_s` at the *old* rates, scale every
    /// link capacity to `factor` times its as-built value, then
    /// re-solve the fair shares over what is left.  Free flows stay
    /// free (a finite capacity scaled stays finite), so the
    /// constrained count is unchanged.
    pub fn set_capacity_scale(&mut self, now_s: f64, factor: f64) {
        self.advance_to(now_s);
        self.topo.set_capacity_scale(factor);
        if self.constrained > 0 {
            self.dirty = true;
            self.eta_cache = None;
        }
    }

    /// Cancel an active flow (control plane: its destination backend
    /// left the fleet).  Progress is credited up to `now_s` first, so
    /// surviving flows keep exactly the bytes they moved while the
    /// cancelled flow held its share.  Returns false when the id is
    /// unknown or already completed.
    pub fn cancel(&mut self, now_s: f64, id: u64) -> bool {
        self.advance_to(now_s);
        match self.flows.remove(&id) {
            Some(f) => {
                self.eta_cache = None;
                if f.constrained {
                    self.constrained -= 1;
                    self.dirty = true;
                }
                true
            }
            None => false,
        }
    }

    /// Advance to `now_s` and drain every finished flow (in id
    /// order); remaining flows' shares are re-solved only when a
    /// *constrained* flow left (free flows never held link capacity,
    /// so their departure cannot change anyone's rate).  The re-solve
    /// itself is deferred to the next observation, so a same-instant
    /// completion burst costs one solve no matter how many flows
    /// drain.  The done filter is solve-insensitive: `remaining`
    /// depends only on (ensured) integration, and the infinite-rate
    /// test only ever matches free flows, whose rate is set at
    /// insertion, never by the solver.
    pub fn take_completed(&mut self, now_s: f64) -> Vec<u64> {
        self.advance_to(now_s);
        let done: Vec<u64> = self
            .flows
            .iter()
            .filter(|(_, f)| f.remaining <= DONE_BYTES || f.rate.is_infinite())
            .map(|(&id, _)| id)
            .collect();
        let mut constrained_left = 0usize;
        for id in &done {
            let f = self.flows.remove(id).expect("completed flow is active");
            if f.constrained {
                constrained_left += 1;
            }
        }
        self.constrained -= constrained_left;
        if !done.is_empty() {
            self.eta_cache = None;
        }
        if constrained_left > 0 {
            self.dirty = true;
        }
        done
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::Link;

    fn pooled(hosts: usize, accels: usize, over: f64) -> Topology {
        Topology::pooled(hosts, accels, over)
    }

    #[test]
    fn one_flow_alone_matches_the_link_transfer_time() {
        // The degenerate case: one flow on a 1:1 fabric moves at the
        // NIC's eff_bandwidth, i.e. Link's transfer term exactly.
        let link = Link::infiniband_cx6();
        let topo = pooled(4, 2, 1.0);
        let mut eng = FabricEngine::new(topo);
        let bytes = 1e6;
        let path = eng.topology().request_path(0, 0);
        eng.start(0.0, path, bytes);
        let done = eng.next_completion_s().unwrap();
        let expect = bytes / link.eff_bandwidth;
        assert!((done - expect).abs() < 1e-12, "{done} vs {expect}");
        let finished = eng.take_completed(done);
        assert_eq!(finished, vec![0]);
        assert_eq!(eng.active(), 0);
        assert_eq!(eng.next_completion_s(), None);
    }

    #[test]
    fn two_flows_to_one_accel_halve_each_other() {
        let topo = pooled(4, 1, 1.0);
        let nic = topo.link().eff_bandwidth;
        let mut eng = FabricEngine::new(topo);
        let p0 = eng.topology().request_path(0, 0);
        let p1 = eng.topology().request_path(1, 0);
        let a = eng.start(0.0, p0, 1e6);
        assert_eq!(eng.rate_of(a), Some(nic));
        let b = eng.start(0.0, p1, 1e6);
        // both bottleneck on accel0's rx NIC: half rate each
        assert_eq!(eng.rate_of(a), Some(nic / 2.0));
        assert_eq!(eng.rate_of(b), Some(nic / 2.0));
        let t = eng.next_completion_s().unwrap();
        assert!((t - 2e6 / nic).abs() < 1e-12, "{t}");
        // both finish at the same instant, popped in id order
        assert_eq!(eng.take_completed(t), vec![a, b]);
    }

    #[test]
    fn late_joiner_slows_the_incumbent_incrementally() {
        let topo = pooled(2, 1, 1.0);
        let nic = topo.link().eff_bandwidth;
        let mut eng = FabricEngine::new(topo);
        let p0 = eng.topology().request_path(0, 0);
        let p1 = eng.topology().request_path(1, 0);
        // flow a: 1e6 bytes alone for the time of its first half
        let half_t = 0.5e6 / nic;
        let a = eng.start(0.0, p0, 1e6);
        // at half_t, b joins; a has 0.5e6 left at rate nic/2
        let b = eng.start(half_t, p1, 1e6);
        let t_a = eng.next_completion_s().unwrap();
        assert!((t_a - (half_t + 0.5e6 / (nic / 2.0))).abs() < 1e-9, "{t_a}");
        assert_eq!(eng.take_completed(t_a), vec![a]);
        // b ran at nic/2 while a lived, then speeds back to nic
        assert_eq!(eng.rate_of(b), Some(nic));
        let t_b = eng.next_completion_s().unwrap();
        // b moved 0.5e6 during [half_t, t_a]; 0.5e6 left at full rate
        assert!((t_b - (t_a + 0.5e6 / nic)).abs() < 1e-9, "{t_b}");
        assert_eq!(eng.take_completed(t_b), vec![b]);
    }

    #[test]
    fn zero_byte_and_free_path_flows_finish_immediately() {
        let mut eng = FabricEngine::new(Topology::node_local(2));
        let a = eng.start(1.0, Vec::new(), 5e9);
        let b = eng.start(1.0, Vec::new(), 0.0);
        assert_eq!(eng.next_completion_s(), Some(1.0));
        assert_eq!(eng.take_completed(1.0), vec![a, b]);
    }

    #[test]
    fn guarded_stalled_flow_never_arms_a_wakeup() {
        // regression: a flow over a link the topology doesn't know
        // used to panic inside the allocator.  It now stalls at a
        // guarded 0 rate, next_completion_s skips it (no infinite
        // wake-up times reach the event queue), and healthy flows
        // are unaffected.
        let topo = pooled(2, 1, 1.0);
        let nic = topo.link().eff_bandwidth;
        let mut eng = FabricEngine::new(topo);
        let bad = eng.start(0.0, vec![999], 1e6);
        assert_eq!(eng.rate_of(bad), Some(0.0));
        assert_eq!(eng.next_completion_s(), None);
        let p0 = eng.topology().request_path(0, 0);
        let good = eng.start(0.0, p0, 1e6);
        assert_eq!(eng.rate_of(good), Some(nic));
        let t = eng.next_completion_s().unwrap();
        assert_eq!(eng.take_completed(t), vec![good]);
        // the stalled flow stays active, still never completing
        assert_eq!(eng.active(), 1);
        assert_eq!(eng.next_completion_s(), None);
    }

    #[test]
    fn free_flow_starts_skip_the_resolve_but_match_it() {
        // a node-local (free-path) start must leave a pooled
        // incumbent's rate bit-identical to a from-scratch solve
        let topo = pooled(2, 1, 1.0);
        let nic = topo.link().eff_bandwidth;
        let mut eng = FabricEngine::new(topo);
        let p0 = eng.topology().request_path(0, 0);
        let a = eng.start(0.0, p0, 1e6);
        assert_eq!(eng.rate_of(a), Some(nic));
        let free = eng.start(0.0, Vec::new(), 3e6);
        assert_eq!(eng.rate_of(free), Some(f64::INFINITY));
        assert_eq!(eng.rate_of(a), Some(nic));
        // free flow drains without re-solving; a is untouched
        assert_eq!(eng.take_completed(0.0), vec![free]);
        assert_eq!(eng.rate_of(a), Some(nic));
    }

    #[test]
    fn oversubscription_monotonically_slows_completions() {
        // 8 hosts all sending to 2 accels: higher oversubscription
        // must never speed any completion up.
        let mut last = 0.0;
        for over in [1.0, 2.0, 4.0, 8.0] {
            let topo = pooled(8, 2, over);
            let mut eng = FabricEngine::new(topo);
            for h in 0..8 {
                let p = eng.topology().request_path(h, h % 2);
                eng.start(0.0, p, 1e6);
            }
            // drain fully; the last completion is the burst makespan
            let mut t = 0.0;
            while let Some(next) = eng.next_completion_s() {
                t = next;
                eng.take_completed(next);
            }
            assert!(
                t >= last - 1e-12,
                "oversub {over}: makespan {t} < previous {last}"
            );
            last = t;
        }
    }

    #[test]
    fn degrade_slows_and_restore_resumes_exactly() {
        let topo = pooled(2, 1, 1.0);
        let nic = topo.link().eff_bandwidth;
        let mut eng = FabricEngine::new(topo);
        let p = eng.topology().request_path(0, 0);
        let a = eng.start(0.0, p, 1e6);
        assert_eq!(eng.rate_of(a), Some(nic));
        // half the bytes move, then the fabric browns out to 25%
        let half_t = 0.5e6 / nic;
        eng.set_capacity_scale(half_t, 0.25);
        assert_eq!(eng.rate_of(a), Some(nic * 0.25));
        // a quarter of the remainder crawls through, then restore
        let crawl_t = half_t + 0.125e6 / (nic * 0.25);
        eng.set_capacity_scale(crawl_t, 1.0);
        assert_eq!(eng.rate_of(a), Some(nic));
        let done = eng.next_completion_s().unwrap();
        assert!((done - (crawl_t + 0.375e6 / nic)).abs() < 1e-9, "{done}");
        assert_eq!(eng.take_completed(done), vec![a]);
    }

    #[test]
    fn cancel_returns_the_share_to_survivors() {
        let topo = pooled(4, 1, 1.0);
        let nic = topo.link().eff_bandwidth;
        let mut eng = FabricEngine::new(topo);
        let p0 = eng.topology().request_path(0, 0);
        let p1 = eng.topology().request_path(1, 0);
        let a = eng.start(0.0, p0, 1e6);
        let b = eng.start(0.0, p1, 1e6);
        assert_eq!(eng.rate_of(a), Some(nic / 2.0));
        // b is cancelled after a quarter of a's bytes moved at half
        // rate; a immediately speeds back up to the full NIC
        let t = 0.25e6 / (nic / 2.0);
        assert!(eng.cancel(t, b));
        assert!(!eng.cancel(t, b), "double cancel is a no-op");
        assert_eq!(eng.rate_of(a), Some(nic));
        assert_eq!(eng.active(), 1);
        let done = eng.next_completion_s().unwrap();
        assert!((done - (t + 0.75e6 / nic)).abs() < 1e-9, "{done}");
        assert_eq!(eng.take_completed(done), vec![a]);
    }

    #[test]
    fn coalesced_burst_solves_once_and_matches_incremental_rates() {
        // A same-instant start burst with no observation in between
        // collapses into one deferred solve; the rates and wake time
        // seen afterwards equal the eager per-mutation schedule's
        // (the solver is a pure function of the final flow set).
        let topo = pooled(4, 1, 1.0);
        let nic = topo.link().eff_bandwidth;
        let mut eng = FabricEngine::new(topo);
        let ids: Vec<u64> = (0..4)
            .map(|h| {
                let p = eng.topology().request_path(h, 0);
                eng.start(0.0, p, 1e6)
            })
            .collect();
        for &id in &ids {
            assert_eq!(eng.rate_of(id), Some(nic / 4.0));
        }
        let t = eng.next_completion_s().unwrap();
        assert!((t - 4e6 / nic).abs() < 1e-9, "{t}");
        // the memoized wake answer is identical on a repeated query
        assert_eq!(eng.next_completion_s(), Some(t));
        // all four finish together, popped in id order
        assert_eq!(eng.take_completed(t), ids);
        assert_eq!(eng.active(), 0);
    }

    #[test]
    fn conservation_bytes_delivered_equals_bytes_sent() {
        // integrate rate * dt across all mutations: the engine's
        // lazy accounting must deliver every byte exactly once.
        let topo = pooled(4, 2, 2.0);
        let mut eng = FabricEngine::new(topo);
        let sizes = [3e5, 7e5, 1e6, 2e5];
        for (h, &bytes) in sizes.iter().enumerate() {
            let p = eng.topology().request_path(h, h % 2);
            eng.start(h as f64 * 1e-5, p, bytes);
        }
        let mut finished = 0usize;
        while let Some(t) = eng.next_completion_s() {
            finished += eng.take_completed(t).len();
        }
        assert_eq!(finished, sizes.len());
        assert_eq!(eng.active(), 0);
    }
}
