//! Shared-bandwidth network topologies for the disaggregated pool.
//!
//! A [`Topology`] is a two-tier leaf/spine graph of *directed* links:
//! every compute node (MPI rank host) has a NIC (tx + rx), every
//! pooled accelerator has a NIC (tx + rx), and the host leaf and
//! accelerator leaf hang off the spine through uplinks whose capacity
//! is the aggregate NIC bandwidth of their side divided by the
//! **oversubscription** factor — the knob datacentre fabrics actually
//! buy down (1:1 = non-blocking, 8:1 = an eighth of the bisection).
//!
//! ```text
//!  host0 ─nic┐                      ┌nic─ accel0
//!  host1 ─nic┤► host-leaf ═uplink═ spine ═uplink═ accel-leaf ├nic─ accel1
//!  host2 ─nic┘   (Σnic/over)          (Σnic/over)            ┘
//! ```
//!
//! Three constructors span the paper's coupling axis:
//!
//! * [`Topology::node_local`] — every accelerator sits in its host
//!   node; no shared links at all (the degenerate free fabric);
//! * [`Topology::pooled`] — all accelerators behind the leaf/spine
//!   fabric (the paper's disaggregated DataScale);
//! * [`Topology::hybrid`] — per-host local accelerators *plus* a
//!   shared pool (MIR local, Hermit pooled).
//!
//! Per-endpoint constants (effective single-stream bandwidth, wire
//! latency, per-message software cost) delegate to
//! [`crate::netsim::Link`]: a NIC's capacity is the link's
//! `eff_bandwidth`, and each direction of a transfer pays
//! [`Link::dir_fixed_s`] on top of its bytes — so one flow alone on a
//! 1:1 fabric reproduces `Link::rtt_overhead_s` exactly
//! (`rust/tests/fabric_props.rs` pins it to 1e-9).
//!
//! Model-swap traffic enters from a parameter store at the spine and
//! shares the accelerator-leaf downlink and the accelerator's rx NIC
//! with inbound inference payloads — swapping weights onto a pooled
//! accelerator congests the very links inference needs.

use crate::netsim::Link;

/// One pooled accelerator's NIC port pair (directed link indices).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct AccelPort {
    tx: usize,
    rx: usize,
}

/// A leaf/spine fabric over hosts and accelerators.
#[derive(Debug, Clone)]
pub struct Topology {
    link: Link,
    oversubscription: f64,
    /// Directed link capacities, bytes/s (the as-built values scaled
    /// by the current degrade factor — see [`Self::set_capacity_scale`]).
    capacities: Vec<f64>,
    /// As-built capacities: the restore point for degrade events.
    base_capacities: Vec<f64>,
    /// Current fabric-wide degrade factor (1.0 = healthy).
    capacity_scale: f64,
    hosts: usize,
    /// Per-accelerator port pair; `None` = node-local (no fabric).
    accel_ports: Vec<Option<AccelPort>>,
    host_tx: Vec<usize>,
    host_rx: Vec<usize>,
    /// Host-leaf uplink toward the spine / back down.
    host_up: usize,
    host_down: usize,
    /// Accel-leaf uplink toward the spine / back down.
    accel_up: usize,
    accel_down: usize,
}

impl Topology {
    /// Every accelerator lives in its host node: no constrained links,
    /// zero fixed latency ([`Link::local`]).  The fabric engine's
    /// degenerate free case.
    pub fn node_local(n_nodes: usize) -> Topology {
        assert!(n_nodes >= 1);
        Topology {
            link: Link::local(),
            oversubscription: 1.0,
            capacities: Vec::new(),
            base_capacities: Vec::new(),
            capacity_scale: 1.0,
            hosts: n_nodes,
            accel_ports: vec![None; n_nodes],
            host_tx: Vec::new(),
            host_rx: Vec::new(),
            host_up: usize::MAX,
            host_down: usize::MAX,
            accel_up: usize::MAX,
            accel_down: usize::MAX,
        }
    }

    /// All accelerators behind the shared leaf/spine fabric, reached
    /// over the paper's Infiniband software path.
    pub fn pooled(n_hosts: usize, n_accels: usize, oversubscription: f64) -> Topology {
        Self::build(n_hosts, 0, n_accels, oversubscription, Link::infiniband_cx6())
    }

    /// As [`Topology::pooled`] with an explicit per-endpoint link
    /// model (the campaign's link-ablation hook).
    pub fn pooled_with_link(
        n_hosts: usize,
        n_accels: usize,
        oversubscription: f64,
        link: Link,
    ) -> Topology {
        Self::build(n_hosts, 0, n_accels, oversubscription, link)
    }

    /// `n_hosts` nodes each with one local accelerator (accel ids
    /// `0..n_hosts`, free) plus `n_pool` shared accelerators behind
    /// the fabric (accel ids `n_hosts..n_hosts + n_pool`).
    pub fn hybrid(n_hosts: usize, n_pool: usize, oversubscription: f64) -> Topology {
        Self::build(n_hosts, n_hosts, n_pool, oversubscription, Link::infiniband_cx6())
    }

    fn build(
        n_hosts: usize,
        n_local_accels: usize,
        n_pool: usize,
        oversubscription: f64,
        link: Link,
    ) -> Topology {
        assert!(n_hosts >= 1 && n_pool >= 1);
        assert!(
            oversubscription >= 1.0 && oversubscription.is_finite(),
            "oversubscription must be >= 1 ({oversubscription})"
        );
        let nic = link.eff_bandwidth;
        assert!(
            nic > 0.0 && nic.is_finite(),
            "pooled fabric needs a finite NIC bandwidth (got {nic}); \
             use Topology::node_local for the free-link limit"
        );

        let mut capacities = Vec::new();
        let mut push = |cap: f64| -> usize {
            capacities.push(cap);
            capacities.len() - 1
        };
        let host_tx: Vec<usize> = (0..n_hosts).map(|_| push(nic)).collect();
        let host_rx: Vec<usize> = (0..n_hosts).map(|_| push(nic)).collect();
        let host_up = push(n_hosts as f64 * nic / oversubscription);
        let host_down = push(n_hosts as f64 * nic / oversubscription);
        let accel_up = push(n_pool as f64 * nic / oversubscription);
        let accel_down = push(n_pool as f64 * nic / oversubscription);
        let mut accel_ports: Vec<Option<AccelPort>> = vec![None; n_local_accels];
        for _ in 0..n_pool {
            let tx = push(nic);
            let rx = push(nic);
            accel_ports.push(Some(AccelPort { tx, rx }));
        }

        Topology {
            link,
            oversubscription,
            base_capacities: capacities.clone(),
            capacity_scale: 1.0,
            capacities,
            hosts: n_hosts,
            accel_ports,
            host_tx,
            host_rx,
            host_up,
            host_down,
            accel_up,
            accel_down,
        }
    }

    pub fn hosts(&self) -> usize {
        self.hosts
    }

    pub fn accels(&self) -> usize {
        self.accel_ports.len()
    }

    pub fn n_links(&self) -> usize {
        self.capacities.len()
    }

    pub fn capacities(&self) -> &[f64] {
        &self.capacities
    }

    pub fn oversubscription(&self) -> f64 {
        self.oversubscription
    }

    /// Current fabric-wide degrade factor (1.0 = healthy as-built).
    pub fn capacity_scale(&self) -> f64 {
        self.capacity_scale
    }

    /// Degrade (or restore) the whole fabric: every directed link's
    /// capacity becomes `factor` times its as-built value.  The
    /// control-plane model is a fabric-wide brownout — a flapping
    /// spine, a firmware-throttled leaf — rather than a single cable:
    /// the fair-share allocator then re-splits whatever is left.
    /// `factor = 1.0` restores the as-built capacities exactly
    /// (recomputed *from the base*, so repeated degrade/restore cycles
    /// cannot accumulate float drift).  No-op topologically for
    /// node-local (no shared links to degrade).
    pub fn set_capacity_scale(&mut self, factor: f64) {
        assert!(
            factor > 0.0 && factor.is_finite(),
            "capacity scale must be a positive finite factor ({factor})"
        );
        self.capacity_scale = factor;
        for (cap, &base) in self.capacities.iter_mut().zip(&self.base_capacities) {
            *cap = if factor == 1.0 { base } else { base * factor };
        }
    }

    /// The per-endpoint link model the fabric delegates to.
    pub fn link(&self) -> &Link {
        &self.link
    }

    /// Fixed per-direction latency ([`Link::dir_fixed_s`]); zero for
    /// node-local accelerators.
    pub fn dir_fixed_s(&self, accel: usize) -> f64 {
        if self.accel_ports[accel].is_some() {
            self.link.dir_fixed_s()
        } else {
            0.0
        }
    }

    /// Does `accel` sit behind the shared fabric (vs in its node)?
    pub fn is_pooled(&self, accel: usize) -> bool {
        self.accel_ports[accel].is_some()
    }

    /// Directed links a request payload crosses, host -> accel.
    /// Empty for a node-local accelerator.
    pub fn request_path(&self, host: usize, accel: usize) -> Vec<usize> {
        assert!(host < self.hosts, "unknown host {host}");
        match self.accel_ports[accel] {
            None => Vec::new(),
            Some(port) => {
                vec![self.host_tx[host], self.host_up, self.accel_down, port.rx]
            }
        }
    }

    /// Directed links a result payload crosses, accel -> host.
    pub fn response_path(&self, host: usize, accel: usize) -> Vec<usize> {
        assert!(host < self.hosts, "unknown host {host}");
        match self.accel_ports[accel] {
            None => Vec::new(),
            Some(port) => {
                vec![port.tx, self.accel_up, self.host_down, self.host_rx[host]]
            }
        }
    }

    /// Directed links a model-swap transfer crosses: the parameter
    /// store sits at the spine, so weights ride the accel-leaf
    /// downlink and the accelerator's rx NIC — straight through the
    /// inference request path's last hops.
    pub fn swap_path(&self, accel: usize) -> Vec<usize> {
        match self.accel_ports[accel] {
            None => Vec::new(),
            Some(port) => vec![self.accel_down, port.rx],
        }
    }

    /// Human-readable label for directed link `i` (the flight
    /// recorder's track names): per-endpoint NIC ports plus the four
    /// leaf/spine uplinks.
    pub fn link_label(&self, i: usize) -> String {
        if let Some(h) = self.host_tx.iter().position(|&l| l == i) {
            return format!("host{h}.tx");
        }
        if let Some(h) = self.host_rx.iter().position(|&l| l == i) {
            return format!("host{h}.rx");
        }
        if i == self.host_up {
            return "host_leaf.up".to_string();
        }
        if i == self.host_down {
            return "host_leaf.down".to_string();
        }
        if i == self.accel_up {
            return "accel_leaf.up".to_string();
        }
        if i == self.accel_down {
            return "accel_leaf.down".to_string();
        }
        for (a, port) in self.accel_ports.iter().enumerate() {
            if let Some(p) = port {
                if p.tx == i {
                    return format!("accel{a}.tx");
                }
                if p.rx == i {
                    return format!("accel{a}.rx");
                }
            }
        }
        format!("link{i}")
    }

    /// The rate one flow gets when nothing else is active: the
    /// minimum capacity along its path (`INFINITY` for an empty
    /// path).  On a 1:1 fabric this is the NIC = `eff_bandwidth`,
    /// which is what makes [`Link`] the exact degenerate case.
    pub fn solo_rate(&self, path: &[usize]) -> f64 {
        path.iter().map(|&l| self.capacities[l]).fold(f64::INFINITY, f64::min)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_local_is_free() {
        let t = Topology::node_local(4);
        assert_eq!(t.hosts(), 4);
        assert_eq!(t.accels(), 4);
        assert_eq!(t.n_links(), 0);
        for a in 0..4 {
            assert!(!t.is_pooled(a));
            assert!(t.request_path(0, a).is_empty());
            assert!(t.response_path(0, a).is_empty());
            assert!(t.swap_path(a).is_empty());
            assert_eq!(t.dir_fixed_s(a), 0.0);
        }
        assert_eq!(t.solo_rate(&[]), f64::INFINITY);
    }

    #[test]
    fn pooled_single_flow_runs_at_nic_rate_at_one_to_one() {
        let t = Topology::pooled(8, 2, 1.0);
        let nic = Link::infiniband_cx6().eff_bandwidth;
        let up = t.request_path(3, 1);
        assert_eq!(up.len(), 4, "nic, host uplink, accel downlink, accel nic");
        assert_eq!(t.solo_rate(&up), nic, "1:1 fabric: solo flow is NIC-bound");
        let down = t.response_path(3, 1);
        assert_eq!(t.solo_rate(&down), nic);
        // request and response ride disjoint directed links
        assert!(up.iter().all(|l| !down.contains(l)));
    }

    #[test]
    fn oversubscription_cuts_the_uplinks_only() {
        let o = 8.0;
        let t1 = Topology::pooled(16, 2, 1.0);
        let t8 = Topology::pooled(16, 2, o);
        let nic = Link::infiniband_cx6().eff_bandwidth;
        // NICs unchanged; uplink capacities divided by o
        assert_eq!(t8.capacities()[t8.host_tx[0]], nic);
        assert_eq!(
            t8.capacities()[t8.host_up] * o,
            t1.capacities()[t1.host_up]
        );
        assert_eq!(
            t8.capacities()[t8.accel_down] * o,
            t1.capacities()[t1.accel_down]
        );
        // 2 accels at 8:1: the pool-side uplink is below one NIC —
        // even a lone flow feels the oversubscribed cut
        assert!(t8.solo_rate(&t8.request_path(0, 0)) < nic);
    }

    #[test]
    fn swap_traffic_shares_the_inference_downlink() {
        let t = Topology::pooled(4, 2, 2.0);
        let swap = t.swap_path(0);
        let req = t.request_path(1, 0);
        // the swap's two links are both on the request path
        assert!(swap.iter().all(|l| req.contains(l)));
        // but not on the response path (results leave on tx)
        let resp = t.response_path(1, 0);
        assert!(swap.iter().all(|l| !resp.contains(l)));
    }

    #[test]
    fn hybrid_mixes_local_and_pooled_accels() {
        let t = Topology::hybrid(4, 2, 4.0);
        assert_eq!(t.accels(), 6);
        for a in 0..4 {
            assert!(!t.is_pooled(a), "accel {a} is node-local");
            assert!(t.request_path(a, a).is_empty());
        }
        for a in 4..6 {
            assert!(t.is_pooled(a));
            assert_eq!(t.request_path(0, a).len(), 4);
            assert!(t.dir_fixed_s(a) > 0.0);
        }
    }

    #[test]
    #[should_panic(expected = "oversubscription")]
    fn rejects_sub_unit_oversubscription() {
        Topology::pooled(4, 2, 0.5);
    }

    #[test]
    fn degrade_scales_every_link_and_restore_is_exact() {
        let mut t = Topology::pooled(4, 2, 2.0);
        let base: Vec<f64> = t.capacities().to_vec();
        t.set_capacity_scale(0.25);
        assert_eq!(t.capacity_scale(), 0.25);
        for (c, b) in t.capacities().iter().zip(&base) {
            assert_eq!(*c, b * 0.25);
        }
        // restore goes back to the as-built values bit-for-bit even
        // after stacked degrades (recomputed from the base, not by
        // inverse multiplication)
        t.set_capacity_scale(0.3);
        t.set_capacity_scale(1.0);
        assert_eq!(t.capacities(), &base[..]);
    }

    #[test]
    #[should_panic(expected = "capacity scale")]
    fn rejects_nonpositive_capacity_scale() {
        Topology::pooled(4, 2, 1.0).set_capacity_scale(0.0);
    }
}
